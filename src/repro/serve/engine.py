"""Quantized-KV continuous-batching serving engine.

One jitted forward serves both phases over the paged pools
(`serve/kv_cache.py`): batched decode traces at (max_batch, 1), chunked
prefill at (1, chunk). Each attention layer

    projects q/k/v for the incoming tokens, applies rope at their
    absolute positions, quantizes the new K/V rows into their pages in
    ONE ``pallas_call`` (``kernels.fused_kv.append_kv``), gathers the
    sequence's pages into a contiguous context view, and attends through
    the fused dequant-attention kernel (``ops.decode_attend``) — or, for
    the bf16 escape hatch, stores raw rows and runs the dense
    ``masked_decode_attention`` (bit-identical to the ring-buffer decode
    path at equal context).

Determinism: random-round schemes key their threefry stream on
(request seed, absolute position, layer, K/V) — never on batch shape or
slot index — so a sequence's greedy tokens are identical whether it runs
alone or mixed into a busy batch (pinned by tests/test_serve_engine.py).

Inactive decode slots point at the reserved trash page (their page-table
rows are swapped to TRASH_PAGE for the step) and their outputs are
discarded, so the decode step keeps a fixed shape with no host-side
re-batching. Pools are donated through the jit, so append updates are
in-place buffer reuse.
"""
from __future__ import annotations

import math
import time
import zlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.fused_kv import append_kv
from repro.models.attention import _scale, masked_decode_attention
from repro.models.blocks import (_apply_norm, _ffn_train, _gqa_project,
                                 attn_spec)
from repro.models.layers import apply_rope, softcap
from repro.models.model import LM
from repro.serve.kv_cache import (KVQuantSpec, append_rows, gather_context,
                                  init_kv_pools, pool_bytes, token_rbits,
                                  TRASH_PAGE)
from repro.serve.scheduler import Request, Scheduler, SeqState, ServeConfig


def _layer_salt(gi: int, j: int, flavor: str) -> int:
    return zlib.crc32(f"kv/g{gi}/pos{j}/{flavor}".encode()) & 0x7FFFFFFF


class Engine:
    """Continuous-batching engine over a paged (quantized) KV cache."""

    def __init__(self, model: LM, params, cfg: ServeConfig):
        self._validate(model)
        self.model = model
        self.cfg = cfg
        mc = model.cfg
        self.kvq = KVQuantSpec(cfg.kv_quant, mc.num_kv_heads,
                               mc.resolved_head_dim, clip_c=cfg.clip_c)
        if not self.kvq.is_bf16:
            from repro.core.comm import wire
            self.qz = self.kvq.quantizer()
            self._rr = wire._fused_mode(self.qz) == "rr"
        else:
            self.qz, self._rr = None, False
        self.C_max = cfg.max_context
        if any(s.kind == "attn_local" for s in model.specs):
            if mc.window and self.C_max > mc.window and not self.kvq.is_bf16:
                # windowed layers still gather the full C_max context; the
                # mask trims it, so this is correctness-safe — just noting
                # the paged pools don't yet exploit window-bounded frees
                pass
        self.params = params
        self.pools = init_kv_pools(model, self.kvq, cfg.resolved_num_pages,
                                   cfg.page_size)
        self.sched = Scheduler(cfg)
        self.page_table = np.zeros((cfg.max_batch, cfg.max_pages_per_seq),
                                   np.int32)
        self.seeds = np.zeros((cfg.max_batch,), np.int32)
        self._fwd = jax.jit(self._forward, donate_argnums=(1,))
        self._next_rid = 0
        # aggregate metrics
        self.prefill_time = 0.0
        self.prefill_tokens = 0
        self.decode_times: List[float] = []
        self.decode_tokens = 0

    @staticmethod
    def _validate(model: LM) -> None:
        mc = model.cfg
        bad = [s.kind for s in model.specs
               if s.kind not in ("attn", "attn_local")]
        if bad or mc.mla is not None or mc.encoder is not None:
            raise ValueError(
                f"paged KV serving supports GQA attention stacks only "
                f"(kinds={sorted(set(bad))!r}, mla={mc.mla is not None}, "
                f"encoder={mc.encoder is not None})")
        if any(s.moe for s in model.specs):
            # MoE capacity dispatch couples tokens across the batch, which
            # would break mixed-vs-alone determinism
            raise ValueError("paged KV serving does not support MoE layers")

    def cache_bytes(self) -> int:
        return pool_bytes(self.pools)

    # ------------------------------------------------------------------
    # jitted forward (traced at (max_batch, 1) decode / (1, chunk) prefill)
    # ------------------------------------------------------------------

    def _attn_layer(self, gi, j, spec, p, x, pool, table, qpos, mask,
                    seeds, rep):
        mc = self.model.cfg
        asp = attn_spec(mc, spec)
        B, T = x.shape[:2]
        KV, hd = mc.num_kv_heads, mc.resolved_head_dim
        xn = _apply_norm(mc, p["norm1"], x)
        q, k, v = _gqa_project(mc, p["attn"], xn)
        q = apply_rope(q, qpos, asp.rope_theta)
        k = apply_rope(k, qpos, asp.rope_theta)
        flat_pos = qpos.reshape(-1)
        pages = jnp.take_along_axis(
            table, qpos // self.cfg.page_size, axis=1).reshape(-1)
        slots = flat_pos % self.cfg.page_size
        if spec.kind == "attn_local" and mc.window:
            carr = jnp.arange(self.C_max, dtype=jnp.int32)
            mask = mask & ((qpos[:, :, None] - carr[None, None, :])
                           < mc.window)
        if self.kvq.is_bf16:
            npool = append_rows(pool, pages, slots,
                                {"k": k.reshape(B * T, KV, hd),
                                 "v": v.reshape(B * T, KV, hd)})
            ctx = gather_context(npool, table)
            o = masked_decode_attention(q, ctx["k"], ctx["v"], mask, asp)
        else:
            d = KV * hd
            k_rows = k.astype(jnp.float32).reshape(B * T, d)
            v_rows = v.astype(jnp.float32).reshape(B * T, d)
            rbits = None
            if self._rr:
                seeds_rows = jnp.repeat(seeds, T)
                rk = token_rbits(seeds_rows, flat_pos,
                                 _layer_salt(gi, j, "k"), rep, d)
                rv = token_rbits(seeds_rows, flat_pos,
                                 _layer_salt(gi, j, "v"), rep, d)
                rbits = jnp.concatenate([rk, rv], axis=0)
            kw, klv, vw, vlv = append_kv(self.qz, k_rows, v_rows, rbits)
            npool = append_rows(pool, pages, slots,
                                {"kw": kw, "klv": klv, "vw": vw,
                                 "vlv": vlv})
            ctx = gather_context(npool, table)
            o = ops.decode_attend(
                q, ctx["kw"], ctx["klv"], ctx["vw"], ctx["vlv"], mask,
                bits=self.qz.wire_bits_per_element, kv_heads=KV,
                scale=_scale(asp), softcap=asp.attn_softcap)
            o = o.astype(x.dtype)
        h = x + o.reshape(B, T, -1) @ p["attn"]["wo"]
        y, _ = _ffn_train(mc, spec, p["ffn"],
                          _apply_norm(mc, p["norm2"], h))
        return h + y, npool

    def _forward(self, params, pools, table, pos, seeds, tokens):
        """tokens (B, T) at absolute positions pos[b]..pos[b]+T-1 ->
        (last-position logits (B, V) f32, greedy next token (B,) int32,
        new pools). Decode runs at T == 1 over max_batch slots; prefill
        at B == 1 over a chunk."""
        model, mc = self.model, self.model.cfg
        B, T = tokens.shape
        x = jnp.take(model._cast(params["embed"]), tokens, axis=0)
        if mc.embed_scale:
            x = x * jnp.bfloat16(math.sqrt(mc.d_model))
        qpos = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        carr = jnp.arange(self.C_max, dtype=jnp.int32)
        mask = carr[None, None, :] <= qpos[:, :, None]     # (B, T, C_max)
        new_pools = []
        for gi, (g, gp, gpool) in enumerate(
                zip(model.groups, params["groups"], pools)):
            gname = f"g{gi}/"

            def body(x, xs):
                unit_p, unit_pool, rep = xs
                npool = {}
                for j, spec in enumerate(g.unit):
                    pj = model._gather_tree(
                        unit_p[f"pos{j}"], lambda p, l, s: l,
                        gname + f"pos{j}", rep)
                    x, nc = self._attn_layer(gi, j, spec, pj, x,
                                             unit_pool[f"pos{j}"], table,
                                             qpos, mask, seeds, rep)
                    npool[f"pos{j}"] = nc
                return x, npool

            x, npool = jax.lax.scan(body, x,
                                    (gp, gpool, jnp.arange(g.repeats)))
            new_pools.append(npool)
        x = x[:, -1:]
        fp = model._gather_tree(params["final_norm"],
                                lambda p, l, s: l, "final_norm", 0)
        x = model._final_norm(fp, x)
        head = model._head(params, lambda p, l, s: l)
        lg = (x @ head.astype(x.dtype)).astype(jnp.float32)
        lg = softcap(lg, mc.final_softcap)[:, 0]           # (B, V)
        return lg, jnp.argmax(lg, axis=-1).astype(jnp.int32), new_pools

    # ------------------------------------------------------------------
    # host loop
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new: int, seed: Optional[int] = None,
               arrival: int = 0) -> int:
        """Queue a request; returns its rid. ``seed`` defaults to a hash
        of the prompt CONTENT (not the rid), so the same prompt draws the
        same quantization noise in any run composition."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if seed is None:
            seed = zlib.crc32(prompt.tobytes()) & 0x7FFFFFFF
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(Request(rid=rid, prompt=prompt, max_new=max_new,
                                  seed=int(seed), arrival=arrival))
        return rid

    def _write_slot(self, st: SeqState) -> None:
        row = np.full((self.cfg.max_pages_per_seq,), TRASH_PAGE, np.int32)
        row[:len(st.pages)] = st.pages
        self.page_table[st.slot] = row
        self.seeds[st.slot] = st.req.seed

    def _clear_slot(self, st: SeqState) -> None:
        self.page_table[st.slot] = TRASH_PAGE
        self.seeds[st.slot] = 0

    def _emit(self, st: SeqState, tok: int, lg, now: float) -> None:
        st.generated.append(int(tok))
        st.token_times.append(now)
        if st.first_token_time < 0:
            st.first_token_time = now
        if self.cfg.record_logits:
            st.logits.append(np.asarray(lg))
        if st.done:
            self._clear_slot(st)
            self.sched.finish(st, now)

    def step(self) -> str:
        """Run one tick: admission, then one prefill chunk OR one batched
        decode step. Returns 'prefill' | 'decode' | 'idle'."""
        now = time.perf_counter()
        for st in self.sched.admit(now):
            self._write_slot(st)
        self.sched.tick += 1
        st = self.sched.next_prefill()
        if st is not None:
            T = min(self.cfg.prefill_chunk,
                    st.prompt_len - st.n_prefilled)
            toks = st.req.prompt[st.n_prefilled:st.n_prefilled + T]
            t0 = time.perf_counter()
            lg, ntok, self.pools = self._fwd(
                self.params, self.pools,
                jnp.asarray(self.page_table[st.slot:st.slot + 1]),
                jnp.asarray([st.n_prefilled], np.int32),
                jnp.asarray(self.seeds[st.slot:st.slot + 1]),
                jnp.asarray(toks[None]))
            ntok = np.asarray(ntok)
            dt = time.perf_counter() - t0
            self.prefill_time += dt
            self.prefill_tokens += T
            st.n_prefilled += T
            if not st.in_prefill:
                self._emit(st, int(ntok[0]), np.asarray(lg[0]),
                           time.perf_counter())
            return "prefill"
        ready = self.sched.decode_ready()
        if not ready:
            return "idle"
        tokens = np.zeros((self.cfg.max_batch, 1), np.int32)
        pos = np.zeros((self.cfg.max_batch,), np.int32)
        table = np.full_like(self.page_table, TRASH_PAGE)
        for st in ready:
            tokens[st.slot, 0] = st.generated[-1]
            pos[st.slot] = st.next_pos
            table[st.slot] = self.page_table[st.slot]
        t0 = time.perf_counter()
        lg, ntok, self.pools = self._fwd(
            self.params, self.pools, jnp.asarray(table),
            jnp.asarray(pos), jnp.asarray(self.seeds),
            jnp.asarray(tokens))
        ntok, lg = np.asarray(ntok), np.asarray(lg)
        dt = time.perf_counter() - t0
        self.decode_times.append(dt)
        self.decode_tokens += len(ready)
        now = time.perf_counter()
        for st in ready:
            self._emit(st, int(ntok[st.slot]), lg[st.slot], now)
        return "decode"

    def run(self, max_ticks: int = 100_000) -> Dict[int, SeqState]:
        """Drive ticks until every submitted request finishes."""
        for _ in range(max_ticks):
            if not self.sched.has_work:
                break
            kind = self.step()
            if kind == "idle" and not self.sched.waiting:
                break
        else:
            raise RuntimeError(f"engine did not drain in {max_ticks} ticks")
        if self.sched.has_work:
            raise RuntimeError(
                "engine idle with work left (arrivals in the future? "
                "call step() manually for open-loop workloads)")
        return dict(self.sched.finished)
