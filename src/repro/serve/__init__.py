from repro.serve.step import ServePlan, make_prefill_step, make_serve_step, plan_serve_sharding

__all__ = ["make_serve_step", "make_prefill_step", "plan_serve_sharding",
           "ServePlan"]
