from repro.serve.engine import Engine
from repro.serve.kv_cache import KVQuantSpec, PageAllocator, init_kv_pools
from repro.serve.scheduler import Request, Scheduler, ServeConfig
from repro.serve.step import (ServePlan, make_chunked_prefill_step,
                              make_prefill_step, make_serve_step,
                              plan_serve_sharding)

__all__ = ["make_serve_step", "make_prefill_step",
           "make_chunked_prefill_step", "plan_serve_sharding", "ServePlan",
           "Engine", "ServeConfig", "Scheduler", "Request", "KVQuantSpec",
           "PageAllocator", "init_kv_pools"]
