"""Serving: cached decode step + prefill forward, pure jit + NamedSharding.

No gradients flow at serving time, so the paper's quantized collectives are
not in this path; parameters are bf16, TP-sharded over ``model``. Cache
sharding:
  * batched decode (decode_32k): batch over the dp axes, heads over model;
  * long-context decode (long_500k, batch 1): the cache SEQUENCE dim is
    sharded over ``data`` — XLA derives the flash-decoding-style distributed
    softmax (partial max/sum + combine) from the sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.model import LM
from repro.utils.sharding import choose_fsdp_dim


@dataclasses.dataclass(frozen=True)
class ServePlan:
    param_specs: Any
    cache_specs: Any

    def param_shardings(self, mesh):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), self.param_specs)

    def cache_shardings(self, mesh):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), self.cache_specs)


def plan_serve_sharding(model: LM, aparams, acache, mesh,
                        *, seq_sharded: bool = False) -> ServePlan:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([sizes[a] for a in dp_axes])) if dp_axes else 1
    dp_ent = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                               else None)
    paths = model.param_paths(aparams)

    def pspec(path, leaf):
        shape = leaf.shape
        stacked = path.startswith("g") or path.startswith("enc/g")
        off = 1 if stacked else 0
        slice_shape = shape[off:]
        cand = [i for i, s in enumerate(slice_shape)
                if s % n_model == 0 and s >= n_model]
        ent = [None] * len(shape)
        if cand and n_model > 1:
            n_exp = model.cfg.moe.num_experts if model.cfg.moe else -1
            pref = [i for i in cand if slice_shape[i] == n_exp]
            t = pref[0] if pref else max(cand, key=lambda i: slice_shape[i])
            ent[off + t] = "model"
        return P(*ent)

    param_specs = jax.tree_util.tree_map(pspec, paths, aparams)

    def cspec(leaf):
        # cache leaves: (reps, B, C, heads, hd) attn / (reps, B, ...) states.
        # Attention caches shard batch over dp and SEQUENCE over model
        # (flash-decoding layout: XLA derives the distributed softmax
        # combine); with global batch 1 the sequence dim takes both.
        ent = [None] * leaf.ndim
        if leaf.ndim >= 2 and dp_ent is not None:
            if seq_sharded:
                if leaf.ndim >= 3:
                    both = (dp_axes + ("model",) if n_model > 1
                            else dp_axes)
                    total = n_dp * (n_model if n_model > 1 else 1)
                    if leaf.shape[2] % total == 0:
                        ent[2] = both
                    elif leaf.shape[2] % n_dp == 0:
                        ent[2] = dp_ent
            else:
                if leaf.shape[1] % n_dp == 0:
                    ent[1] = dp_ent
                if (leaf.ndim >= 3 and n_model > 1
                        and leaf.shape[2] % n_model == 0):
                    ent[2] = "model"
        return P(*ent)

    cache_specs = jax.tree_util.tree_map(cspec, acache)
    return ServePlan(param_specs=param_specs, cache_specs=cache_specs)


def make_serve_step(model: LM, mesh, plan: ServePlan, *,
                    batch_dp: bool = True):
    """decode one token: (params, cache, tokens (B,1), pos) -> (logits,
    cache). ``batch_dp=False`` replicates the token batch over the dp axes
    (long-context decode with global batch 1: the cache seq dim carries the
    dp sharding instead)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ent = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                               else None)
    if not batch_dp:
        dp_ent = None

    def step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return jax.jit(
        step,
        in_shardings=(plan.param_shardings(mesh),
                      plan.cache_shardings(mesh),
                      NamedSharding(mesh, P(dp_ent)),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(dp_ent)),
                       plan.cache_shardings(mesh)),
        donate_argnums=(1,),
    )


def make_chunked_prefill_step(model: LM, mesh, plan: ServePlan):
    """Cache-filling chunked prefill: (params, cache, tokens (B,T),
    start) -> (logits (B,T,V), cache). Writes the chunk's K/V into the
    decode cache at absolute positions start..start+T-1 (the launcher
    guarantees the chunk fits every layer's cache — no ring wrap), so a
    prompt prefills in ceil(S/T) forwards instead of S decode steps.
    Retraces per distinct chunk length; the cache is donated like the
    decode step's."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ent = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                               else None)

    def step(params, cache, tokens, start):
        return model.prefill_chunk(params, cache, tokens, start)

    return jax.jit(
        step,
        in_shardings=(plan.param_shardings(mesh),
                      plan.cache_shardings(mesh),
                      NamedSharding(mesh, P(dp_ent)),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(dp_ent)),
                       plan.cache_shardings(mesh)),
        donate_argnums=(1,),
    )


def make_prefill_step(model: LM, mesh, plan: ServePlan):
    """Chunked-forward prefill producing all-position logits (the
    inference-prefill shape): (params, tokens (B,S) [, enc_embeds]) ->
    logits (B,S,V)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ent = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                               else None)

    def step(params, batch):
        lg, _ = model.logits(params, batch["tokens"],
                             enc_embeds=batch.get("enc_embeds"))
        return lg

    batch_sh = {"tokens": NamedSharding(mesh, P(dp_ent))}
    if model.cfg.encoder:
        batch_sh["enc_embeds"] = NamedSharding(mesh, P(dp_ent))
    return jax.jit(
        step,
        in_shardings=(plan.param_shardings(mesh), batch_sh),
        out_shardings=NamedSharding(mesh, P(dp_ent)),
    )
