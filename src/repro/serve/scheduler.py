"""Continuous-batching scheduler: request admission, slot + page
bookkeeping, per-request lifecycle metrics.

Host-side state machine, no jax. A request moves

    WAITING --admit--> PREFILLING --last chunk--> DECODING --max_new--> DONE
             (slot + pages            (first token                (pages
              allocated)               emitted)                    freed)

The engine drives one *tick* at a time: admission first, then either ONE
prefill chunk (lowest occupied slot still prefilling — prefill has
priority so admitted requests reach their first token quickly) or ONE
batched decode step over every fully-prefilled slot. Pages are allocated
up front at admission for the worst case ceil((prompt+max_new)/page_size)
so a running request can never be stranded mid-decode by pool exhaustion;
admission is all-or-nothing and FIFO.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serve.kv_cache import PageAllocator


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static engine configuration (shapes are fixed at construction)."""

    kv_quant: str = "bf16"       # "bf16" | fused-encode scheme (e.g. orq-9)
    page_size: int = 16
    max_batch: int = 4           # decode-batch slots
    max_pages_per_seq: int = 16  # context cap = max_pages_per_seq*page_size
    num_pages: Optional[int] = None   # default: full occupancy + trash page
    prefill_chunk: int = 16
    clip_c: Optional[float] = None
    record_logits: bool = False  # keep per-token logits (drift metrics)

    @property
    def resolved_num_pages(self) -> int:
        if self.num_pages is not None:
            return self.num_pages
        return 1 + self.max_batch * self.max_pages_per_seq

    @property
    def max_context(self) -> int:
        return self.max_pages_per_seq * self.page_size


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    seed: int
    arrival: int = 0             # tick index at which it becomes visible


@dataclasses.dataclass
class SeqState:
    req: Request
    slot: int
    pages: List[int]
    n_prefilled: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    submit_time: float = 0.0
    first_token_time: float = -1.0
    finish_time: float = -1.0
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.req.prompt.shape[0])

    @property
    def in_prefill(self) -> bool:
        return self.n_prefilled < self.prompt_len

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new

    @property
    def next_pos(self) -> int:
        """Absolute position of the next token fed to decode (= position
        at which the last generated token's KV is appended)."""
        return self.prompt_len + len(self.generated) - 1


class Scheduler:
    """Slot/page bookkeeping for the continuous-batching engine."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.alloc = PageAllocator(cfg.resolved_num_pages)
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[SeqState]] = [None] * cfg.max_batch
        self.finished: Dict[int, SeqState] = {}
        self.tick = 0

    # -- submission ------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = self.pages_needed(req)
        if need > self.cfg.max_pages_per_seq:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt.shape[0]} + "
                f"max_new {req.max_new} needs {need} pages > "
                f"max_pages_per_seq {self.cfg.max_pages_per_seq}")
        self.waiting.append(req)

    def pages_needed(self, req: Request) -> int:
        total = int(req.prompt.shape[0]) + req.max_new
        return -(-total // self.cfg.page_size)

    # -- per-tick transitions -------------------------------------------

    def admit(self, now: float) -> List[SeqState]:
        """FIFO all-or-nothing admission into free slots (arrived
        requests only). Returns the newly admitted states."""
        admitted = []
        for slot in range(self.cfg.max_batch):
            if self.slots[slot] is not None:
                continue
            if not self.waiting or self.waiting[0].arrival > self.tick:
                break
            pages = self.alloc.alloc(self.pages_needed(self.waiting[0]))
            if pages is None:
                break
            req = self.waiting.popleft()
            st = SeqState(req=req, slot=slot, pages=pages, submit_time=now)
            self.slots[slot] = st
            admitted.append(st)
        return admitted

    def next_prefill(self) -> Optional[SeqState]:
        for st in self.slots:
            if st is not None and st.in_prefill:
                return st
        return None

    def decode_ready(self) -> List[SeqState]:
        return [st for st in self.slots
                if st is not None and not st.in_prefill and not st.done]

    def finish(self, st: SeqState, now: float) -> None:
        """Evict a finished sequence: free its pages and its slot."""
        st.finish_time = now
        self.alloc.free(st.pages)
        self.slots[st.slot] = None
        self.finished[st.req.rid] = st

    @property
    def has_work(self) -> bool:
        return (bool(self.waiting)
                or any(st is not None for st in self.slots))
