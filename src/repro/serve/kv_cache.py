"""Paged quantized KV cache for the serving engine.

Layout. The cache is a pool of ``num_pages`` fixed-size page slots per
attention layer; a page holds ``page_size`` consecutive tokens of ONE
sequence. Each sequence owns an ordered page table (row of page ids), so
token at absolute position ``p`` lives in page ``table[p // page_size]``
at slot ``p % page_size`` — gathering a sequence's pages in table order
yields its context contiguously. Pages are allocated up front when a
request is admitted and freed when it finishes (host-side free list).

Wire format. One bucket row per token spanning all KV heads
(d = num_kv_heads * head_dim), exactly the training exchange's
``(words, levels)`` unit:

    kw, vw    (pages, page_size, nw) uint32 — bit-packed level indices
    klv, vlv  (pages, page_size, s)  f32    — per-token runtime levels

quantized through ``kernels.fused_kv.append_kv`` (one ``pallas_call``,
the σ-fit → level-search → round → pack sweep of ``fused_encode``). The
``bf16`` scheme is the escape hatch: raw (pages, page_size, KV, hd)
bf16 pools, bit-identical to the dense ring-buffer decode path.

Page 0 is the reserved TRASH page: inactive decode-batch slots append
into it and no sequence's page table ever contains it, so a fixed-shape
batched decode step needs no scatter masking.

Per-layer pools carry the model's stacked-repeats leading axis, mirroring
``LM.init_cache``, so the engine scans them with the same
``lax.scan``-over-repeats structure as the dense decode step.

Randomness. The random-round schemes draw their threefry stream per
(request seed, absolute position, layer salt) via :func:`token_rbits` —
NOT per batch shape — so a token's quantized bits are independent of
which decode slot the sequence occupies and of what else shares the
batch (the mixed-vs-alone determinism the engine tests pin).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import encode as E
from repro.core import rounding as R

TRASH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Static description of the KV cache quantization scheme."""

    scheme: str                  # "bf16" or a fused-encode quantizer name
    num_kv_heads: int
    head_dim: int
    clip_c: Optional[float] = None

    @property
    def d(self) -> int:
        """Bucket width: one bucket per token spans all KV heads."""
        return self.num_kv_heads * self.head_dim

    @property
    def is_bf16(self) -> bool:
        return self.scheme == "bf16"

    def quantizer(self):
        from repro.core.api import make_quantizer
        from repro.core.comm import wire

        qz = make_quantizer(self.scheme, bucket_size=self.d,
                            clip_c=self.clip_c)
        if qz.is_identity or not wire._fused_mode(qz):
            raise ValueError(
                f"--kv-quant {self.scheme!r}: KV pages need a fused "
                f"one-pass encode (random-round schemes, bingrad-b, "
                f"signsgd) or the 'bf16' escape hatch")
        return qz

    @property
    def bits(self) -> int:
        return self.quantizer().wire_bits_per_element

    @property
    def s(self) -> int:
        return self.quantizer().s

    @property
    def nw(self) -> int:
        return E.packed_words(self.d, self.bits)

    def token_bytes(self) -> int:
        """Cache bytes for one token (K + V) in one attention layer."""
        if self.is_bf16:
            return 2 * self.d * 2
        return 2 * (4 * self.nw + 4 * self.s)


def token_bytes_ratio(spec: KVQuantSpec) -> float:
    """Quantized-vs-bf16 cache bytes at equal batch × context."""
    bf16 = KVQuantSpec("bf16", spec.num_kv_heads, spec.head_dim)
    return spec.token_bytes() / bf16.token_bytes()


# ---------------------------------------------------------------------------
# pools
# ---------------------------------------------------------------------------

def _init_layer_pool(kvq: KVQuantSpec, reps: int, num_pages: int,
                     page_size: int) -> Dict[str, jnp.ndarray]:
    P, S = num_pages, page_size
    if kvq.is_bf16:
        KV, hd = kvq.num_kv_heads, kvq.head_dim
        return {"k": jnp.zeros((reps, P, S, KV, hd), jnp.bfloat16),
                "v": jnp.zeros((reps, P, S, KV, hd), jnp.bfloat16)}
    return {"kw": jnp.zeros((reps, P, S, kvq.nw), jnp.uint32),
            "klv": jnp.zeros((reps, P, S, kvq.s), jnp.float32),
            "vw": jnp.zeros((reps, P, S, kvq.nw), jnp.uint32),
            "vlv": jnp.zeros((reps, P, S, kvq.s), jnp.float32)}


def init_kv_pools(model, kvq: KVQuantSpec, num_pages: int, page_size: int):
    """Paged pools mirroring the model's scan-group cache structure:
    tuple-of-groups of {pos_j: pool leaves with leading (repeats,) axis}.
    Only GQA attention layers are supported (the engine validates)."""
    pools = []
    for g in model.groups:
        gp = {}
        for j, spec in enumerate(g.unit):
            if spec.kind not in ("attn", "attn_local") or spec.cross_attn:
                raise ValueError(
                    f"paged KV serving supports plain GQA attention "
                    f"layers only (got kind={spec.kind!r}, "
                    f"cross_attn={spec.cross_attn})")
            gp[f"pos{j}"] = _init_layer_pool(kvq, g.repeats, num_pages,
                                             page_size)
        pools.append(gp)
    return tuple(pools)


def pool_bytes(pools) -> int:
    """Total device bytes held by the paged pools."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(pools))


def append_rows(pool: Dict[str, jnp.ndarray], pages: jnp.ndarray,
                slots: jnp.ndarray, parts: Dict[str, jnp.ndarray]):
    """Scatter R new tokens' rows into one layer's pool (leading reps axis
    already consumed by the caller's scan): pool leaf (P, S, ...),
    pages/slots (R,) int32, parts name -> (R, ...) new rows."""
    return {k: pool[k].at[pages, slots].set(v.astype(pool[k].dtype))
            for k, v in parts.items()}


def gather_context(pool: Dict[str, jnp.ndarray], page_table: jnp.ndarray):
    """Gather per-sequence contiguous context views from one layer's pool:
    page_table (B, max_pages) int32 -> leaf (B, max_pages*page_size, ...).
    Context index c IS absolute position c (pages are sequence-ordered)."""
    out = {}
    for k, leaf in pool.items():
        g = leaf[page_table]                  # (B, maxp, S, ...)
        out[k] = g.reshape(g.shape[0], g.shape[1] * g.shape[2],
                           *g.shape[3:])
    return out


# ---------------------------------------------------------------------------
# deterministic per-token rounding stream
# ---------------------------------------------------------------------------

def token_rbits(seeds: jnp.ndarray, positions: jnp.ndarray, salt: int,
                rep: jnp.ndarray, d: int) -> jnp.ndarray:
    """(R,) request seeds + (R,) absolute token positions -> (R, d) uint32
    threefry stream for the random-round schemes, keyed on
    (seed, position, static layer salt, scan repeat index). Slot- and
    batch-composition-independent by construction."""
    def row(seed, pos):
        k = jax.random.PRNGKey(seed)
        k = jax.random.fold_in(k, pos)
        k = jax.random.fold_in(k, salt)
        k = jax.random.fold_in(k, rep)
        return R.random_bits(k, (d,))

    return jax.vmap(row)(seeds, positions)


# ---------------------------------------------------------------------------
# host-side page allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Free-list allocator over the page pool. Page 0 (TRASH_PAGE) is
    reserved — inactive decode slots write into it, sequences never do."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (1 is the trash page), "
                             f"got {num_pages}")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, TRASH_PAGE, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n pages, or None (allocation is all-or-nothing)."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("freeing the trash page")
            self._free.append(p)
