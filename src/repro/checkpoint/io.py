"""Checkpointing: pytree <-> .npz with structure manifest.

Arrays are fetched to host (fully addressable or replicated views) and
written as a flat npz keyed by the pytree key-path; a JSON manifest records
the treedef so restore round-trips arbitrary nests of dict/tuple/list and
NamedTuple-free optimizer states. Scalars and step counters ride along.

Crash safety: both files are written to temp paths and committed with
``os.replace``, and the manifest is ALSO embedded inside the npz itself
(``__manifest__`` entry), so the npz replace is the single atomic commit
point — a crash mid-save can never leave a manifest pointing at a stale or
truncated npz; the previous checkpoint stays loadable. The external
``.manifest.json`` is kept for inspection and for checkpoints written by
older versions.

Restore is strict: shape mismatches, dtype mismatches (an f32 checkpoint
restored into a bf16 leaf would otherwise truncate silently), and
missing/extra keys all raise ``ValueError`` naming the offending key —
never ``assert`` (stripped under ``python -O``) and never a silent cast.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in leaves}


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    order = sorted(flat)
    manifest = {"keys": order, "step": step}
    npz = _npz_path(path)
    tmp = npz + ".tmp"
    # file-object write: np.savez must not append its own ".npz" suffix to
    # the temp name. A crash here leaves only *.tmp litter — the committed
    # files are untouched until os.replace below.
    with open(tmp, "wb") as f:
        np.savez_compressed(
            f, __manifest__=np.asarray(json.dumps(manifest)),
            **{f"arr_{i}": flat[k] for i, k in enumerate(order)})
    os.replace(tmp, npz)     # the atomic commit point
    mpath = path + ".manifest.json"
    tmp_m = mpath + ".tmp"
    with open(tmp_m, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp_m, mpath)


def _load_manifest(path: str, data) -> dict:
    if "__manifest__" in data:
        return json.loads(str(data["__manifest__"][()]))
    # pre-embedding checkpoints: external manifest only
    with open(path + ".manifest.json") as f:
        return json.load(f)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like``. Shapes AND dtypes must match
    exactly; key set mismatches raise with the offending paths named."""
    data = np.load(_npz_path(path))
    manifest = _load_manifest(path, data)
    by_key = {k: data[f"arr_{i}"] for i, k in enumerate(manifest["keys"])}

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    want_keys = [jax.tree_util.keystr(p) for p, _ in paths]
    missing = [k for k in want_keys if k not in by_key]
    extra = sorted(set(by_key) - set(want_keys))
    if missing or extra:
        raise ValueError(
            f"checkpoint {path!r} does not match the restore target: "
            f"missing keys {missing[:5]}{'...' if len(missing) > 5 else ''} "
            f"(total {len(missing)}), extra keys "
            f"{extra[:5]}{'...' if len(extra) > 5 else ''} "
            f"(total {len(extra)})")

    leaves = []
    for key, (_, leaf) in zip(want_keys, paths):
        arr = by_key[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} but "
                f"the restore target expects {tuple(leaf.shape)}")
        want_dtype = np.dtype(leaf.dtype)
        if np.dtype(arr.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint leaf {key!r} has dtype {arr.dtype} but the "
                f"restore target expects {want_dtype}; refusing to cast "
                f"silently — convert the checkpoint (or the target tree) "
                f"explicitly if the narrowing is intended")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
