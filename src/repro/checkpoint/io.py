"""Checkpointing: pytree <-> .npz with structure manifest.

Arrays are fetched to host (fully addressable or replicated views) and
written as a flat npz keyed by the pytree key-path; a JSON manifest records
the treedef so restore round-trips arbitrary nests of dict/tuple/list and
NamedTuple-free optimizer states. Scalars and step counters ride along.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in leaves}


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    order = sorted(flat)
    np.savez_compressed(path, **{f"arr_{i}": flat[k]
                                 for i, k in enumerate(order)})
    manifest = {"keys": order, "step": step}
    with open(path + ".manifest.json", "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (shapes must match)."""
    with open(path + ".manifest.json") as f:
        manifest = json.load(f)
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    by_key = {k: data[f"arr_{i}"] for i, k in enumerate(manifest["keys"])}

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, leaf in paths:
        key = jax.tree_util.keystr(path_)
        arr = by_key[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
