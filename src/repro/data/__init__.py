from repro.data.synthetic import SyntheticLM, cifar_like_batches

__all__ = ["SyntheticLM", "cifar_like_batches"]
