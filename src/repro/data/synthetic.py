"""Deterministic synthetic data pipeline.

No datasets ship in this container, so the pipeline synthesizes *learnable*
token streams: a fixed random Markov chain over the vocabulary (order-1 with
a long-range copy channel), generated counter-based from (seed, step) — the
stream is reproducible, shardable by host, and has real structure so
training loss decreases measurably below ln(V) (needed by the convergence
benchmarks that stand in for the paper's CIFAR/ImageNet runs).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    n_states: int = 64        # low-rank structure of the transition model
    copy_offset: int = 8      # long-range correlation: token repeats from t-8
    copy_prob: float = 0.3

    def _chain(self):
        """Static transition structure (numpy, computed once)."""
        rng = np.random.RandomState(self.seed)
        # each state prefers a small set of next tokens
        table = rng.randint(0, self.vocab_size,
                            size=(self.n_states, 4)).astype(np.int32)
        return jnp.asarray(table)

    def batch(self, step: int) -> dict:
        """Batch for a global step: {tokens (B, S+1)} (loss shifts off 1)."""
        table = self._chain()
        key = jax.random.fold_in(jax.random.key(self.seed), step)

        def sample_row(key):
            def body(carry, k):
                state, hist = carry
                k1, k2, k3 = jax.random.split(k, 3)
                choice = table[state % self.n_states,
                               jax.random.randint(k1, (), 0, 4)]
                copy = hist[0]
                tok = jnp.where(
                    jax.random.uniform(k2) < self.copy_prob, copy, choice)
                tok = tok % self.vocab_size
                hist = jnp.concatenate([hist[1:], tok[None]])
                return (tok % self.n_states, hist), tok

            k0, k1 = jax.random.split(key)
            hist0 = jax.random.randint(k0, (self.copy_offset,), 0,
                                       self.vocab_size)
            state0 = jax.random.randint(k1, (), 0, self.n_states)
            keys = jax.random.split(key, self.seq_len + 1)
            _, toks = jax.lax.scan(body, (state0, hist0), keys)
            return toks

        keys = jax.random.split(key, self.batch_size)
        tokens = jax.vmap(sample_row)(keys)
        return {"tokens": tokens.astype(jnp.int32)}

    def iterator(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        batch_fn = jax.jit(self.batch)
        while True:
            yield batch_fn(step)
            step += 1


def cifar_like_batches(batch_size: int, seed: int = 0,
                       num_classes: int = 10) -> Iterator[dict]:
    """Synthetic 32x32x3 image-classification stream (class-conditional
    Gaussian blobs + noise) standing in for CIFAR in the paper-repro
    example. Linearly separable enough that quantization-scheme differences
    show up in convergence speed."""
    rng = np.random.RandomState(seed)
    prototypes = rng.randn(num_classes, 32, 32, 3).astype(np.float32)
    step = 0
    while True:
        r = np.random.RandomState(seed * 100003 + step)
        labels = r.randint(0, num_classes, size=(batch_size,))
        noise = r.randn(batch_size, 32, 32, 3).astype(np.float32)
        images = prototypes[labels] * 0.7 + noise
        yield {"images": jnp.asarray(images),
               "labels": jnp.asarray(labels, dtype=jnp.int32)}
        step += 1
