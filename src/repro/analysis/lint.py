"""AST source lint (the second half of the auditor; stdlib-only).

Four rules over the ``src/repro`` tree:

  env-read            trace-time ``os.environ``/``os.getenv`` access
                      anywhere but the central ``utils/env.py`` accessor
                      (scattered reads mean flags get resolved at
                      different times relative to jit tracing)
  set-axis-names      set-typed axis names (set iteration order follows
                      PYTHONHASHSEED — collectives would change axis
                      order between processes; ``_names()`` rejects them
                      at runtime, the lint rejects them at review time)
  pallas-body-discipline
                      inside a ``kernels/`` pallas body (the function
                      handed to ``pl.pallas_call``, plus module-local
                      helpers it calls): no ``jax.random`` draws (streams
                      are drawn ONCE outside and threaded in as rbits
                      refs — the source-level twin of the
                      ``prng-single-draw`` trace rule), no nested
                      ``pallas_call``, no jit/vmap/grad, no float64.
                      Plain ``jnp`` math is NOT flagged: inside Pallas it
                      lowers to in-register VPU ops, which is the idiom
                      the kernels are built on — the discipline worth
                      machine-checking is what breaks one-pass/VMEM/
                      bit-identity, not the namespace.
  registry-bypass     direct ``Quantizer(...)`` construction outside the
                      scheme registry (``core/api.py``) / the defining
                      module — bypassing ``make_quantizer`` skips name
                      parsing, level tables, and policy resolution

Used by ``python -m repro.analysis`` and ``tests/test_analysis.py``
through the same ``run_checks`` engine as the trace rules.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.engine import SourceBundle, SourceFile, register_check
from repro.analysis.findings import Finding

#: files allowed to touch os.environ (the accessor itself)
ENV_ACCESSOR_FILES = ("repro/utils/env.py",)

#: files allowed to construct Quantizer directly: the registry and the
#: defining module
REGISTRY_FILES = ("repro/core/api.py", "repro/core/quantizers.py")

#: keyword names whose values must never be set-typed
AXIS_KEYWORDS = ("axis_names", "axis_name", "intra_axes", "inter_axes",
                 "axes")

#: attribute chains forbidden inside a pallas kernel body
KERNEL_FORBIDDEN_PREFIXES = (
    ("jax", "random"),          # draw streams outside, thread rbits in
    ("pl", "pallas_call"),      # no nested kernel launches
    ("jax", "jit"), ("jax", "vmap"), ("jax", "grad"),
    ("jax", "value_and_grad"), ("jax", "device_put"),
)
KERNEL_FORBIDDEN_DTYPES = ("float64",)


def collect_sources(root: Optional[Path] = None,
                    label: str = "src/repro") -> SourceBundle:
    """Parse every ``.py`` under the ``repro`` package into a bundle.

    ``root`` defaults to the installed package directory; paths in
    findings are reported relative to its parent (``repro/...``)."""
    pkg = Path(root) if root else Path(__file__).resolve().parents[1]
    base = pkg.parent
    files = []
    for p in sorted(pkg.rglob("*.py")):
        text = p.read_text()
        files.append(SourceFile(path=str(p.relative_to(base)), text=text,
                                tree=ast.parse(text, filename=str(p))))
    return SourceBundle(label=label, files=tuple(files))


def _dotted(node) -> Tuple[str, ...]:
    """(`a`, `b`, `c`) for an ``a.b.c`` attribute chain, else ()."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _is_set_expr(node) -> bool:
    if isinstance(node, ast.Set):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "set")


def _finding(rule: str, f: SourceFile, node, msg: str) -> Finding:
    return Finding(rule=rule, severity="error", bundle="src/repro",
                   location=f"{f.path}:{getattr(node, 'lineno', 0)}",
                   message=msg)


@register_check(
    "env-read", kind="source",
    protects="env flags resolve through ONE validated accessor with one "
             "trace-time semantics")
def env_read(bundle: SourceBundle) -> List[Finding]:
    out: List[Finding] = []
    for f in bundle.files:
        if f.path in ENV_ACCESSOR_FILES:
            continue
        for node in ast.walk(f.tree):
            chain = _dotted(node) if isinstance(node, ast.Attribute) else ()
            # flag the exact ``os.environ`` attribute node (not every
            # enclosing ``os.environ.get`` chain) — one finding per access
            if chain == ("os", "environ"):
                out.append(_finding(
                    "env-read", f, node,
                    "os.environ access outside repro.utils.env — use the "
                    "central accessor (env_flag/force_host_device_count)"))
            elif (isinstance(node, ast.Call)
                  and _dotted(node.func)[:2] == ("os", "getenv")):
                out.append(_finding(
                    "env-read", f, node,
                    "os.getenv outside repro.utils.env — use the central "
                    "accessor"))
    return out


@register_check(
    "set-axis-names", kind="source",
    protects="collective axis order is deterministic (never "
             "PYTHONHASHSEED-dependent set iteration)")
def set_axis_names(bundle: SourceBundle) -> List[Finding]:
    out: List[Finding] = []
    for f in bundle.files:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call):
                # jax.shard_map's own ``axis_names`` parameter is
                # set-typed BY its signature (manual-mode axis *membership*,
                # no ordering semantics) — the hazard is sets flowing into
                # repo collectives, where order defines the wire layout
                if _dotted(node.func)[-1:] == ("shard_map",):
                    continue
                for kw in node.keywords:
                    if kw.arg in AXIS_KEYWORDS and _is_set_expr(kw.value):
                        out.append(_finding(
                            "set-axis-names", f, kw.value,
                            f"set-typed {kw.arg}= — axis names must be "
                            f"an ordered tuple/list"))
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Name)
                            and tgt.id.endswith(("axis_names", "axes"))
                            and _is_set_expr(node.value)):
                        out.append(_finding(
                            "set-axis-names", f, node,
                            f"set-typed axis container {tgt.id!r} — use "
                            f"an ordered tuple/list"))
    return out


def _kernel_bodies(tree) -> Dict[str, ast.FunctionDef]:
    """FunctionDefs reachable from a ``pl.pallas_call`` first argument in
    this module, transitively through module-local helper calls."""
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)}
    roots: Set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _dotted(node.func)[-1:] == ("pallas_call",)
                and node.args):
            continue
        kern = node.args[0]
        if (isinstance(kern, ast.Call)
                and _dotted(kern.func)[-1:] == ("partial",) and kern.args):
            kern = kern.args[0]
        if isinstance(kern, ast.Name) and kern.id in defs:
            roots.add(kern.id)
    # transitive closure over module-local calls from kernel bodies
    seen: Set[str] = set()
    frontier = list(roots)
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for node in ast.walk(defs[name]):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in defs and node.func.id not in seen):
                frontier.append(node.func.id)
    return {n: defs[n] for n in seen}


@register_check(
    "pallas-body-discipline", kind="source",
    protects="kernel bodies stay one-pass: no in-kernel PRNG draws "
             "(bit-identity), no nested launches, no f64")
def pallas_body_discipline(bundle: SourceBundle) -> List[Finding]:
    out: List[Finding] = []
    for f in bundle.files:
        if not f.path.startswith("repro/kernels/"):
            continue
        for name, fn in sorted(_kernel_bodies(f.tree).items()):
            for node in ast.walk(fn):
                chain = _dotted(node) if isinstance(
                    node, ast.Attribute) else ()
                for bad in KERNEL_FORBIDDEN_PREFIXES:
                    if chain[:len(bad)] == bad:
                        out.append(_finding(
                            "pallas-body-discipline", f, node,
                            f"{'.'.join(chain)} inside pallas body "
                            f"{name!r} — kernels receive rounding bits / "
                            f"data as refs and never launch or draw"))
                if chain and chain[-1] in KERNEL_FORBIDDEN_DTYPES:
                    out.append(_finding(
                        "pallas-body-discipline", f, node,
                        f"float64 inside pallas body {name!r}"))
                if (isinstance(node, ast.Constant)
                        and node.value in KERNEL_FORBIDDEN_DTYPES):
                    out.append(_finding(
                        "pallas-body-discipline", f, node,
                        f"float64 dtype string inside pallas body "
                        f"{name!r}"))
    return out


@register_check(
    "registry-bypass", kind="source",
    protects="every scheme is constructed through the registry "
             "(make_quantizer) — names, level tables, and policy "
             "resolution stay consistent")
def registry_bypass(bundle: SourceBundle) -> List[Finding]:
    out: List[Finding] = []
    for f in bundle.files:
        if f.path in REGISTRY_FILES:
            continue
        for node in ast.walk(f.tree):
            if (isinstance(node, ast.Call)
                    and _dotted(node.func)[-1:] == ("Quantizer",)):
                out.append(_finding(
                    "registry-bypass", f, node,
                    "direct Quantizer(...) construction — build schemes "
                    "via repro.core.api.make_quantizer / "
                    "QuantConfig.to_quantizer"))
    return out
