"""The shared sub-jaxpr traversal.

Every jaxpr consumer in the repo (``utils/jaxpr.py`` collective/outvar
counting, ``launch/hlo_cost.py`` pallas stats, the ``analysis`` rules)
walks eqns through this module, so "which sub-jaxpr kinds do we descend
into" is answered in exactly one place. Handled kinds:

  * ``ClosedJaxpr``-valued params        — pjit, scan (``jaxpr``), while
    (``body_jaxpr``/``cond_jaxpr``), custom_vjp (``fun_jaxpr``),
    custom_jvp (``call_jaxpr``), closed_call, remat
  * raw ``Jaxpr``-valued params          — shard_map, pallas_call
  * tuple/list params of either          — cond ``branches``
  * ``custom_vjp_call_jaxpr``'s **fwd rule** via ``fwd_jaxpr_thunk``
    (opt-in: the fwd body duplicates the primal ``fun_jaxpr`` content,
    so counting rules must not traverse both) — the kind the three
    pre-``analysis`` ad-hoc walkers silently skipped

No jax import: the walk is pure duck-typing over eqn/params objects, so
the analysis CLI can configure ``XLA_FLAGS`` before jax ever loads.
"""
from __future__ import annotations

from typing import Any, Iterator, List, Tuple

#: path component marking eqns reached through a custom_vjp fwd rule
CUSTOM_VJP_FWD = "custom_vjp_fwd"


def _as_jaxprs(v, seen: set) -> List[Any]:
    """Raw ``Jaxpr`` objects reachable from one eqn param value."""
    sub = getattr(v, "jaxpr", None)
    if sub is not None and hasattr(sub, "eqns"):      # ClosedJaxpr
        v = sub
    if hasattr(v, "eqns"):                            # raw Jaxpr
        if id(v) in seen:
            return []
        seen.add(id(v))
        return [v]
    if isinstance(v, (tuple, list)):
        out: List[Any] = []
        for u in v:
            out.extend(_as_jaxprs(u, seen))
        return out
    return []


def custom_vjp_fwd_jaxprs(eqn) -> List[Any]:
    """Jaxprs of the custom_vjp FWD rule, if this eqn carries one.

    ``fwd_jaxpr_thunk`` traces the user's fwd function on demand; it
    takes one ``symbolic_zeros`` boolean per primal input and returns
    ``(jaxpr, consts)``. Returns ``[]`` for non-custom_vjp eqns and for
    thunks that fail to trace (nothing to audit there)."""
    thunk = eqn.params.get("fwd_jaxpr_thunk")
    if thunk is None:
        return []
    n_primal = len(eqn.invars) - int(eqn.params.get("num_consts", 0))
    try:
        res = thunk(*([False] * max(n_primal, 0)))
    except Exception:  # noqa: BLE001 — un-traceable thunk: skip, don't fail
        return []
    jx = res[0] if isinstance(res, (tuple, list)) and res else res
    return _as_jaxprs(jx, set())


def eqn_sub_jaxprs(eqn, *, include_custom_vjp_fwd: bool = False
                   ) -> List[Tuple[str, Any]]:
    """``(tag, raw_jaxpr)`` pairs directly under one eqn. ``tag`` is the
    eqn's primitive name, or :data:`CUSTOM_VJP_FWD` for fwd-rule bodies."""
    seen: set = set()
    name = eqn.primitive.name
    subs = [(name, jx) for v in eqn.params.values()
            for jx in _as_jaxprs(v, seen)]
    if include_custom_vjp_fwd:
        subs += [(CUSTOM_VJP_FWD, jx) for jx in custom_vjp_fwd_jaxprs(eqn)]
    return subs


def walk_eqns(closed_or_jaxpr, *, include_custom_vjp_fwd: bool = False
              ) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Depth-first ``(eqn, path)`` over a jaxpr and every sub-jaxpr.

    ``path`` is the tuple of enclosing primitive names, outermost first
    (e.g. ``("pjit", "scan")``) — rules use it to scope counts, e.g.
    "not inside a pallas_call body". Accepts a ``ClosedJaxpr``, a raw
    ``Jaxpr``, or anything with a ``.jaxpr``.
    """
    root = getattr(closed_or_jaxpr, "jaxpr", closed_or_jaxpr)

    def rec(jx, path):
        for eqn in jx.eqns:
            yield eqn, path
            for tag, sub in eqn_sub_jaxprs(
                    eqn, include_custom_vjp_fwd=include_custom_vjp_fwd):
                yield from rec(sub, path + (tag,))

    yield from rec(root, ())


def aval_elems(v) -> int:
    """Element count of a var's abstract value (1 for scalars/unknown)."""
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    if not shape:
        return 1
    n = 1
    for d in shape:
        n *= int(d)
    return n


def aval_dtype(v) -> str:
    """Dtype name of a var's abstract value ("" when unknown)."""
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    return "" if dt is None else str(dt)
