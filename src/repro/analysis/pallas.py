"""Pallas-kernel cost extraction (jaxpr-based).

The HLO text parser in ``launch/hlo_cost.py`` never sees the fused
quantization kernels: in interpret mode a pallas_call lowers to ordinary
HLO ops with no custom-call marker. The jaxpr, however, carries every
pallas_call eqn with its full grid mapping — block shapes, array shapes,
dtypes — which is exactly what a VMEM/roofline report (and the
``vmem-tile-budget`` rule) needs, identically between interpret and
compiled lowering. ``launch/hlo_cost.py`` re-exports these for callers.
"""
from __future__ import annotations

from typing import List

from repro.analysis.traversal import aval_elems, walk_eqns

#: elementwise / reduce primitives counted as one op per element for the
#: arithmetic-intensity estimate (bit-twiddling in the pack stage included:
#: on TPU those are real VPU lanes, not free address arithmetic)
_ARITH_PRIMS = {
    "add", "sub", "mul", "div", "rem", "max", "min", "neg", "abs", "sign",
    "floor", "ceil", "round", "exp", "log", "sqrt", "rsqrt", "integer_pow",
    "pow", "select_n", "and", "or", "xor", "not", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "ge", "gt", "le", "lt",
    "eq", "ne", "reduce_sum", "reduce_max", "reduce_min", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "dot_general",
}


def _block_elems(block_shape) -> int:
    n = 1
    for d in block_shape:
        if d is None:               # squeezed / unblocked dim
            continue
        try:
            n *= int(d)
        except TypeError:           # BlockDim wrapper in newer jax
            n *= int(getattr(d, "block_size", 1))
    return n


def kernel_flops(jaxpr) -> float:
    """Per-grid-step op estimate: one op per element of the widest operand
    of every elementwise/reduce eqn, recursing into sub-jaxprs (via the
    shared ``repro.analysis.traversal`` walk)."""
    flops = 0.0
    for eqn, _path in walk_eqns(jaxpr):
        if eqn.primitive.name in _ARITH_PRIMS:
            flops += max([aval_elems(v) for v in
                          list(eqn.invars) + list(eqn.outvars)] or [1])
    return flops


def pallas_eqn_stats(eqn) -> dict:
    """Footprint of ONE ``pallas_call`` eqn (see ``pallas_call_stats``)."""
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    steps = 1
    for g in grid:
        steps *= g
    vmem = hbm = 0
    for bm in gm.block_mappings:
        sds = bm.array_shape_dtype
        isz = sds.dtype.itemsize
        vmem += _block_elems(bm.block_shape) * isz
        full = 1
        for d in sds.shape:
            full *= int(d)
        hbm += full * isz
    kj = eqn.params.get("jaxpr")
    body = getattr(kj, "jaxpr", kj)
    flops = (kernel_flops(body) * steps
             if hasattr(body, "eqns") else 0.0)
    nsi = eqn.params.get("name_and_src_info")
    return {
        "kernel": getattr(nsi, "name", None) or str(nsi),
        "grid": grid, "grid_steps": steps,
        "vmem_bytes": vmem, "hbm_bytes": hbm, "flops": flops,
        "arithmetic_intensity": round(flops / hbm, 3) if hbm else 0.0,
    }


def pallas_call_stats(closed) -> List[dict]:
    """Per-``pallas_call`` VMEM footprint and arithmetic intensity.

    ``closed`` is what ``jax.make_jaxpr(fn)(*args)`` returns. For every
    pallas_call eqn (nested sub-jaxprs included) reports:

      * ``kernel``       — kernel function name
      * ``grid``         — grid tuple; ``grid_steps`` its product
      * ``vmem_bytes``   — resident bytes per grid step: sum of
                           block_shape x dtype over every operand/output
                           BlockSpec (the quantity the kernels' row_block
                           sizing holds under VMEM_TILE_BYTES)
      * ``hbm_bytes``    — full operand + output array bytes (a one-pass
                           kernel touches each exactly once)
      * ``flops``        — elementwise-op estimate over the whole grid
      * ``arithmetic_intensity`` — flops / hbm_bytes
    """
    return [pallas_eqn_stats(eqn)
            for eqn, path in walk_eqns(closed)
            if eqn.primitive.name == "pallas_call"
            and "pallas_call" not in path]
