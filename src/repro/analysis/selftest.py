"""Seeded-violation corpus: one bundle per rule that MUST fire.

A rule that silently stops matching is worse than no rule (the matrix
audit would go green while the invariant rots), so CI runs
``python -m repro.analysis --selftest`` next to the real audit:
every registered rule is applied to a bundle constructed to violate it
and must produce at least one finding. ``tests/test_analysis.py``
asserts the same corpus rule by rule (true-positive tests), and
``--inject-violation RULE`` appends one of these bundles to the real
matrix to demonstrate the nonzero ``--check`` exit end to end.

Trace seeds are tiny standalone programs (no mesh needed except for the
collective seed, which uses however many fake devices the process was
started with); source seeds are synthetic files violating each lint.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.engine import (CHECKS, SourceBundle, SourceFile,
                                   TraceBundle, run_checks)
from repro.analysis.findings import Finding

#: synthetic sources violating each lint rule (paths matter: the pallas
#: seed must live under repro/kernels/ for the rule to scope it)
_BAD_SOURCES: Dict[str, SourceFile] = {}


def _bad_source(rule: str, path: str, text: str) -> None:
    _BAD_SOURCES[rule] = SourceFile(path=path, text=text,
                                    tree=ast.parse(text, filename=path))


_bad_source("env-read", "repro/core/_seeded_env_read.py", (
    "import os\n"
    "USE_KERNELS = os.environ.get('REPRO_USE_KERNELS', '1')\n"
    "INTERPRET = os.getenv('REPRO_PALLAS_INTERPRET')\n"))

_bad_source("set-axis-names", "repro/core/_seeded_set_axes.py", (
    "def exchange(x, reduce):\n"
    "    dp_axis_names = set(('pod', 'data'))\n"
    "    return reduce(x, axis_names={'data'})\n"))

_bad_source("pallas-body-discipline", "repro/kernels/_seeded_body.py", (
    "import jax\n"
    "from jax.experimental import pallas as pl\n"
    "\n"
    "def _kernel(x_ref, o_ref):\n"
    "    noise = jax.random.uniform(jax.random.key(0), x_ref.shape)\n"
    "    o_ref[...] = (x_ref[...] + noise).astype('float64')\n"
    "\n"
    "def op(x):\n"
    "    return pl.pallas_call(_kernel, out_shape=x)(x)\n"))

_bad_source("registry-bypass", "repro/train/_seeded_bypass.py", (
    "from repro.core.quantizers import Quantizer\n"
    "\n"
    "def make(d):\n"
    "    return Quantizer(bucket_size=d, method='orq', num_levels=9)\n"))


def _seeded_collective_trace() -> TraceBundle:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    f = shard_map(lambda x: lax.pmean(x, "data"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    closed = jax.make_jaxpr(f)(jnp.ones(8))
    return TraceBundle(
        label="seeded/collective-budget", kind="exchange", closed=closed,
        meta={
            # exact-count path: the budget promises an all_gather that
            # the trace never launches
            "expected_collectives": {("all_gather", ("data",)): 1},
            # exclusivity path: psum is banned from every axis, yet the
            # pmean traced one
            "exclusive_prims": {"psum": []},
        })


def _seeded_multipass_trace() -> TraceBundle:
    """The real multi-pass encoder claiming to be one-pass."""
    import jax
    import jax.numpy as jnp
    from repro.core import make_quantizer
    from repro.core.comm import wire

    qz = make_quantizer("orq-9", bucket_size=37)
    bkt = jnp.ones((5, 37))
    mask = jnp.ones((5, 37), bool)
    closed = jax.make_jaxpr(
        lambda b, m, k: wire.encode_multipass(qz, b, m, k))(
            bkt, mask, jax.random.key(0))
    return TraceBundle(label="seeded/one-pallas-call", kind="wire_op",
                       closed=closed, meta={"expect_pallas_calls": 1})


def _seeded_vmem_trace() -> TraceBundle:
    """A copy kernel whose single block is 4 MiB — double the tile
    budget."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _copy(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    x = jnp.ones((1024, 1024), jnp.float32)
    closed = jax.make_jaxpr(
        lambda v: pl.pallas_call(
            _copy, out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype))(v))(x)
    return TraceBundle(label="seeded/vmem-tile-budget", kind="wire_op",
                       closed=closed, meta={"expect_pallas_calls": 1})


def _seeded_materialization_trace() -> TraceBundle:
    import jax
    import jax.numpy as jnp

    n = 1 << 17
    closed = jax.make_jaxpr(
        lambda x: (x + 1.0) * (x - 2.0))(jnp.ones((n,), jnp.float32))
    return TraceBundle(
        label="seeded/no-materialization", kind="exchange", closed=closed,
        meta={"materialization": {"min_elems": n, "dtype": "float32",
                                  "max_count": 0}})


def _seeded_donation_trace() -> TraceBundle:
    """A jitted state update that copies instead of donating."""
    import jax
    import jax.numpy as jnp

    step = jax.jit(lambda s: s + 1.0)     # no donate_argnums
    closed = jax.make_jaxpr(step)(jnp.ones((8,)))
    return TraceBundle(label="seeded/donation", kind="train_step",
                       closed=closed, meta={"expect_donated": 1})


def _seeded_widening_trace() -> TraceBundle:
    import jax
    import jax.numpy as jnp

    n = 1 << 17
    closed = jax.make_jaxpr(
        lambda w: w.astype(jnp.float32) / 2.0)(
            jnp.ones((n,), jnp.uint32))
    return TraceBundle(label="seeded/no-fp32-widening", kind="wire_op",
                       closed=closed, meta={"wire_min_elems": n})


def _seeded_prng_trace() -> TraceBundle:
    """The per-chunk re-draw bug the pipelined exchange must never have."""
    import jax

    def redraw(key):
        a = jax.random.bits(key, (4, 64))
        b = jax.random.bits(jax.random.fold_in(key, 1), (4, 64))
        return a ^ b

    closed = jax.make_jaxpr(redraw)(jax.random.key(0))
    return TraceBundle(label="seeded/prng-single-draw", kind="wire_op",
                       closed=closed,
                       meta={"prng": {"random_bits": 1, "fold_ins": 0}})


_TRACE_SEEDS = {
    "collective-budget": _seeded_collective_trace,
    "one-pallas-call": _seeded_multipass_trace,
    "vmem-tile-budget": _seeded_vmem_trace,
    "no-materialization": _seeded_materialization_trace,
    "donation": _seeded_donation_trace,
    "no-fp32-widening": _seeded_widening_trace,
    "prng-single-draw": _seeded_prng_trace,
}


def seeded_bundle(rule: str):
    """The bundle constructed to violate ``rule``."""
    if rule in _TRACE_SEEDS:
        return _TRACE_SEEDS[rule]()
    if rule in _BAD_SOURCES:
        return SourceBundle(label=f"seeded/{rule}",
                            files=(_BAD_SOURCES[rule],))
    raise KeyError(f"no seeded violation for rule {rule!r}; "
                   f"seeds: {sorted(_TRACE_SEEDS) + sorted(_BAD_SOURCES)}")


def run_selftest() -> Dict[str, List[Finding]]:
    """rule id -> findings its seeded bundle produced (must be non-empty
    for every registered rule)."""
    out: Dict[str, List[Finding]] = {}
    for rule in CHECKS:
        found = run_checks([seeded_bundle(rule)], rules=[rule])
        out[rule] = [f for f in found if f.rule == rule]
    return out
