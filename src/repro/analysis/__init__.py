"""Trace auditor: jaxpr/HLO invariant engine + AST source lint.

The repo's load-bearing claims — O(1) quantized collectives per step,
exactly one ``pallas_call`` per wire op, single-draw rounding streams,
no dequantized full-buffer materialization, donated train state — are
machine-checked here over the scheme x mode matrix instead of living
only in scattered per-test jaxpr pins.

Layout (everything below ``audit`` is import-light — no jax — so the
CLI can set ``XLA_FLAGS`` before jax initializes):

  ``traversal``  the ONE shared sub-jaxpr walker (pjit/scan/while/cond/
                 shard_map/pallas_call bodies + the custom_vjp fwd rule
                 the old ad-hoc walkers silently skipped)
  ``findings``   the structured ``Finding`` record
  ``engine``     ``@register_check`` registry + ``run_checks``
  ``rules``      the trace-invariant rules (importing registers them)
  ``lint``       the AST source-lint rules (ditto)
  ``audit``      matrix builders (imports jax + the train/serve stack)
  ``selftest``   seeded-violation corpus: one true positive per rule

Run ``PYTHONPATH=src python -m repro.analysis --check`` for the full
matrix; see EXPERIMENTS.md "Static invariants" for the rule catalog.
"""
from repro.analysis.findings import Finding
from repro.analysis.engine import (CHECKS, SourceBundle, TraceBundle,
                                   register_check, run_checks)
from repro.analysis import rules as _rules    # noqa: F401  (registers)
from repro.analysis import lint as _lint      # noqa: F401  (registers)

__all__ = ["Finding", "CHECKS", "SourceBundle", "TraceBundle",
           "register_check", "run_checks"]
