"""Trace-invariant rules (the jaxpr half of the auditor).

Each rule reads the expectation keys it understands from
``bundle.meta`` and returns ``[]`` when a bundle doesn't opt in — the
``audit`` builders decide which invariants apply to which traced
program. Expectations are EXACT where the repo's claims are exact
(collective launch budgets, pallas-call counts, PRNG draw counts) and
bounds where they are bounds (VMEM tile bytes, materialization,
donation floor).

Meta keys by rule:

  collective-budget   "expected_collectives": {(prim, axes): count}
                      "exclusive_prims": {prim: [axes, ...]} — prim may
                      appear ONLY on the listed axis tuples
  one-pallas-call     "expect_pallas_calls": int
  vmem-tile-budget    "vmem_budget": bytes (default DEFAULT_VMEM_BUDGET)
  no-materialization  "materialization": {"min_elems", "dtype",
                      "max_count"}
  donation            "expect_donated": int (minimum donated invars)
  no-fp32-widening    "wire_min_elems": int (default 65536)
  prng-single-draw    "prng": {"random_bits": int[, "fold_ins": int]}
"""
from __future__ import annotations

from typing import List

from repro.analysis import stats
from repro.analysis.engine import TraceBundle, register_check
from repro.analysis.findings import Finding
from repro.analysis.pallas import pallas_call_stats
from repro.analysis.traversal import aval_dtype, aval_elems, walk_eqns

#: mirrors ``repro.kernels.fused_encode.VMEM_TILE_BYTES`` (tested equal)
#: without importing jax into the rule layer
DEFAULT_VMEM_BUDGET = 2 * 1024 * 1024

#: arrays at least this large are "wire payload sized" for the widening
#: rule when a bundle doesn't set its own threshold
DEFAULT_WIRE_MIN_ELEMS = 1 << 16


def _loc(path) -> str:
    return "/".join(path) if path else "<top>"


@register_check(
    "collective-budget", kind="trace",
    protects="O(1) quantized collectives per step; DCN-only quantized "
             "traffic in two-level mode; 2K/4K launches at "
             "pipeline_chunks=K")
def collective_budget(bundle: TraceBundle) -> List[Finding]:
    expected = bundle.meta.get("expected_collectives")
    exclusive = bundle.meta.get("exclusive_prims", {})
    if expected is None and not exclusive:
        return []
    counts = stats.collective_axis_counts(bundle.closed)
    out: List[Finding] = []
    for (prim, axes), want in (expected or {}).items():
        got = stats.axis_collectives(counts, prim, axes)
        if got != want:
            out.append(Finding(
                rule="collective-budget", severity="error",
                bundle=bundle.label, location=f"{prim}[{axes}]",
                message=f"expected exactly {want} {prim} launches on "
                        f"axes {axes}, traced {got}"))
    for prim, allowed in exclusive.items():
        allowed = {tuple(a) for a in allowed}
        for (p, ax), n in counts.items():
            if p == prim and ax not in allowed:
                out.append(Finding(
                    rule="collective-budget", severity="error",
                    bundle=bundle.label, location=f"{prim}[{ax}]",
                    message=f"{n} {prim} launch(es) on non-budgeted axes "
                            f"{ax} (allowed: {sorted(allowed)})"))
    return out


@register_check(
    "one-pallas-call", kind="trace",
    protects="each fused wire op is ONE kernel launch (one HBM pass)")
def one_pallas_call(bundle: TraceBundle) -> List[Finding]:
    want = bundle.meta.get("expect_pallas_calls")
    if want is None:
        return []
    got = stats.pallas_call_count(bundle.closed)
    if got == want:
        return []
    return [Finding(
        rule="one-pallas-call", severity="error", bundle=bundle.label,
        location="pallas_call",
        message=f"expected exactly {want} pallas_call launch(es), "
                f"traced {got}")]


@register_check(
    "vmem-tile-budget", kind="trace",
    protects="every kernel's per-grid-step residency fits the VMEM tile "
             "budget regardless of buffer size")
def vmem_tile_budget(bundle: TraceBundle) -> List[Finding]:
    budget = bundle.meta.get("vmem_budget", DEFAULT_VMEM_BUDGET)
    out: List[Finding] = []
    for st in pallas_call_stats(bundle.closed):
        if st["vmem_bytes"] > budget:
            out.append(Finding(
                rule="vmem-tile-budget", severity="error",
                bundle=bundle.label, location=str(st["kernel"]),
                message=f"kernel {st['kernel']} holds "
                        f"{st['vmem_bytes']} bytes per grid step "
                        f"(> budget {budget}); grid={st['grid']}"))
    return out


@register_check(
    "no-materialization", kind="trace",
    protects="chunked/pipelined schedules never materialize extra "
             "full-buffer f32 intermediates")
def no_materialization(bundle: TraceBundle) -> List[Finding]:
    spec = bundle.meta.get("materialization")
    if spec is None:
        return []
    got = stats.sized_outvar_count(bundle.closed, spec["min_elems"],
                                   spec.get("dtype"))
    if got <= spec["max_count"]:
        return []
    return [Finding(
        rule="no-materialization", severity="error", bundle=bundle.label,
        location=f">={spec['min_elems']} elems",
        message=f"{got} outvars of >= {spec['min_elems']} "
                f"{spec.get('dtype', 'any')} elements (baseline allows "
                f"{spec['max_count']}): a full-size buffer is being "
                f"materialized")]


@register_check(
    "donation", kind="trace",
    protects="the train state / KV pools are donated (updated in place, "
             "no 2x-state HBM spike)")
def donation(bundle: TraceBundle) -> List[Finding]:
    want = bundle.meta.get("expect_donated")
    if want is None:
        return []
    got = stats.donated_invar_count(bundle.closed)
    if got >= want:
        return []
    return [Finding(
        rule="donation", severity="error", bundle=bundle.label,
        location="pjit.donated_invars",
        message=f"only {got} donated invars on the top-level pjit "
                f"(expected >= {want}): state buffers are being copied, "
                f"not aliased")]


@register_check(
    "no-fp32-widening", kind="trace",
    protects="packed wire payloads cross the network as uint words — "
             "never widened to floats outside a kernel — and nothing "
             "computes in f64")
def no_fp32_widening(bundle: TraceBundle) -> List[Finding]:
    min_elems = bundle.meta.get("wire_min_elems", DEFAULT_WIRE_MIN_ELEMS)
    if min_elems is None:       # bundle explicitly opts out
        return []
    out: List[Finding] = []
    for eqn, path in walk_eqns(bundle.closed):
        if "pallas_call" in path:
            continue        # in-VMEM dequant inside a kernel is the point
        for v in eqn.outvars:
            if aval_dtype(v) == "float64" and aval_elems(v) > 1:
                out.append(Finding(
                    rule="no-fp32-widening", severity="error",
                    bundle=bundle.label, location=_loc(path),
                    message=f"float64 intermediate of {aval_elems(v)} "
                            f"elements under {_loc(path)}"))
        if eqn.primitive.name != "convert_element_type":
            continue
        (iv,), (ov,) = eqn.invars, eqn.outvars
        if (aval_dtype(iv).startswith("uint")
                and aval_dtype(ov).startswith("float")
                and aval_elems(iv) >= min_elems):
            out.append(Finding(
                rule="no-fp32-widening", severity="error",
                bundle=bundle.label, location=_loc(path),
                message=f"wire-sized {aval_dtype(iv)} payload "
                        f"({aval_elems(iv)} elems) widened to "
                        f"{aval_dtype(ov)} outside a kernel under "
                        f"{_loc(path)}"))
    return out


@register_check(
    "prng-single-draw", kind="trace",
    protects="rounding streams are drawn once at full shape and sliced "
             "(chunked/paged schedules stay bit-identical and ORQ stays "
             "unbiased)")
def prng_single_draw(bundle: TraceBundle) -> List[Finding]:
    spec = bundle.meta.get("prng")
    if spec is None:
        return []
    out: List[Finding] = []
    got = stats.prng_draw_count(bundle.closed)
    want = spec["random_bits"]
    if got != want:
        out.append(Finding(
            rule="prng-single-draw", severity="error",
            bundle=bundle.label, location="random_bits",
            message=f"{got} rounding-stream draws traced, baseline "
                    f"schedule draws {want}: a stream is being re-drawn "
                    f"per chunk/page (breaks bit-identity and the "
                    f"single-draw unbiasedness argument)"))
    if "fold_ins" in spec:
        gf = stats.prng_fold_count(bundle.closed)
        if gf != spec["fold_ins"]:
            out.append(Finding(
                rule="prng-single-draw", severity="error",
                bundle=bundle.label, location="random_fold_in",
                message=f"{gf} key fold_ins traced, baseline has "
                        f"{spec['fold_ins']}: the key schedule depends "
                        f"on the chunking"))
    return out
