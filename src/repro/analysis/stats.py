"""Counting queries over traced programs (all via the shared traversal).

These are the primitives the invariant rules and the legacy
``repro.utils.jaxpr`` helpers are built from: collective tallies by
axis name, sized-outvar counts (full-buffer materialization), PRNG-draw
counts, and pallas-call counts.
"""
from __future__ import annotations

from collections import Counter
from typing import Optional, Tuple

import numpy as np

from repro.analysis.traversal import aval_elems, walk_eqns

COLLECTIVE_PRIMS = ("all_to_all", "all_gather", "psum_scatter",
                    "reduce_scatter", "psum", "pmean", "ppermute")

#: jaxpr-level PRNG primitives: ``random_bits`` materializes a rounding
#: stream, ``random_fold_in`` derives a subkey (jax >= 0.4 key arrays
#: and raw uint32 keys both trace to these)
PRNG_DRAW_PRIMS = ("random_bits", "threefry2x32")
PRNG_FOLD_PRIMS = ("random_fold_in",)


def eqn_axes(eqn) -> Tuple:
    """The axis-name tuple of a collective eqn (scalar names wrapped)."""
    ax = eqn.params.get("axis_name", eqn.params.get("axes"))
    return tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)


def collective_axis_counts(closed) -> Counter:
    """Counter mapping ``(primitive_name, axis_names_tuple)`` -> count of
    eqns, over the whole jaxpr including nested sub-jaxprs."""
    counts: Counter = Counter()
    for eqn, _path in walk_eqns(closed):
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            counts[(eqn.primitive.name, eqn_axes(eqn))] += 1
    return counts


def axis_collectives(counts: Counter, prim: str,
                     axes: Tuple[str, ...]) -> int:
    """Total count of ``prim`` eqns whose axis tuple is exactly ``axes``."""
    return sum(n for (p, ax), n in counts.items()
               if p == prim and ax == tuple(axes))


def sized_outvar_count(closed, min_elems: int, dtype=None) -> int:
    """Count eqn OUTPUT variables (nested sub-jaxprs included) holding at
    least ``min_elems`` elements, optionally restricted to ``dtype``."""
    want = None if dtype is None else np.dtype(dtype)
    count = 0
    for eqn, _path in walk_eqns(closed):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is None or not getattr(aval, "shape", None):
                continue
            if want is not None and aval.dtype != want:
                continue
            if aval_elems(v) >= min_elems:
                count += 1
    return count


def prim_count(closed, names, *, exclude_under: Tuple[str, ...] = ()) -> int:
    """Count eqns whose primitive is in ``names``, skipping eqns nested
    under any primitive named in ``exclude_under``."""
    if isinstance(names, str):
        names = (names,)
    n = 0
    for eqn, path in walk_eqns(closed):
        if eqn.primitive.name in names and not any(
                p in exclude_under for p in path):
            n += 1
    return n


def pallas_call_count(closed) -> int:
    """Top-level-executed ``pallas_call`` eqns (never counts a kernel
    nested inside another kernel's body twice)."""
    return prim_count(closed, "pallas_call", exclude_under=("pallas_call",))


def prng_draw_count(closed) -> int:
    """Rounding-stream draws: ``random_bits``/``threefry2x32`` eqns
    outside pallas bodies (kernels receive rbits as inputs, never draw)."""
    return prim_count(closed, PRNG_DRAW_PRIMS,
                      exclude_under=("pallas_call",))


def prng_fold_count(closed) -> int:
    return prim_count(closed, PRNG_FOLD_PRIMS,
                      exclude_under=("pallas_call",))


def donated_invar_count(closed) -> int:
    """Donated inputs summed over TOP-LEVEL ``pjit`` eqns (tracing a
    jitted function yields one outer pjit carrying ``donated_invars``)."""
    total = 0
    jaxpr = getattr(closed, "jaxpr", closed)
    for eqn in jaxpr.eqns:
        total += sum(bool(d)
                     for d in eqn.params.get("donated_invars", ()))
    return total


def convert_eqns(closed):
    """Yield ``(eqn, path)`` for every convert_element_type eqn."""
    for eqn, path in walk_eqns(closed):
        if eqn.primitive.name == "convert_element_type":
            yield eqn, path
