"""Matrix audit: trace the REAL programs and attach computed invariants.

Builders for the three bundle families the CI ``static-analysis`` job
(and ``tests/test_analysis.py``) runs rules against:

  * :func:`wire_bundles`    — ``wire.encode``/``qdq``/``decode_mean``/
    ``decode_each`` traced per registered scheme (the PR-5 one-launch
    pins).
  * :func:`train_bundles`   — the actual jitted train step traced for
    replicated/FSDP x flat/two_level x pipeline_chunks on an 8-fake-
    device mesh, with collective budgets derived from the SAME
    ``ExchangeEngines`` objects ``make_train_step`` uses (span schedule,
    pipeline clamp, requant mode all come from the engine — no parallel
    accounting model to drift).
  * :func:`serve_bundles`   — the serving ``Engine._fwd`` traced at the
    decode shape per KV scheme (the PR-7 one-launch + donation pins).

Expected collective counts pin only the gradient-wire primitives
(``all_to_all``/``all_gather``/``reduce_scatter``); ``psum`` carries
loss/metric reductions too, so it is constrained by axis (``psum`` may
only touch the dp axes) rather than by exact count.

Every builder needs >= 8 local devices for the train meshes — call
``repro.utils.env.force_host_device_count(8)`` before importing jax
(``python -m repro.analysis`` does; tests go through a subprocess).
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis.engine import TraceBundle
from repro.core import QuantPolicy, all_methods, make_quantizer
from repro.core.comm import hierarchical, wire
from repro.core.comm.exchange import GradientExchange
from repro.utils.env import kernels_enabled

#: smoke arch every trace uses (2 attention layers, one orq + one fp
#: policy group under the mixed policy below)
ARCH = "lm-100m"
MIXED_POLICY = "norm|bias=fp,default=orq-9"
UNIFORM_POLICY = "orq-9"

#: (mode, hierarchy, pipeline_chunks, mesh shape, mesh axes, policy) —
#: every leg needs 8 fake devices; K=1 legs double as the
#: materialization baseline for the K=3 legs
TRAIN_MATRIX: List[Tuple[str, str, int, tuple, tuple, str]] = [
    ("replicated", "flat", 1, (8,), ("data",), MIXED_POLICY),
    ("replicated", "flat", 3, (8,), ("data",), MIXED_POLICY),
    ("replicated", "two_level", 1, (2, 4), ("pod", "data"), MIXED_POLICY),
    ("replicated", "two_level", 3, (2, 4), ("pod", "data"), MIXED_POLICY),
    ("replicated", "flat", 1, (8,), ("data",), UNIFORM_POLICY),
    ("fsdp", "flat", 1, (8,), ("data",), MIXED_POLICY),
    ("fsdp", "flat", 3, (8,), ("data",), MIXED_POLICY),
    ("fsdp", "two_level", 1, (2, 4), ("pod", "data"), MIXED_POLICY),
    ("fsdp", "two_level", 3, (2, 4), ("pod", "data"), MIXED_POLICY),
]

#: KV schemes the serve audit traces: rr + bin + sign rounding families
#: plus the bf16 escape hatch (zero kernel launches)
SERVE_SCHEMES = ("orq-9", "bingrad-b", "signsgd", "bf16")

#: adaptive bit-schedule audit: the per-phase specialized steps of ONE
#: by-rule engine skeleton, traced at every distinct phase the schedule
#: produces — collective/pallas/prng budgets must track the phase bits
#: while the group structure (EF shapes) stays put
SCHED_SCHEDULE = "embed=orq@5..3,norm|bias=fp,default=orq@4..2"
SCHED_STEPS, SCHED_RESOLVE = 100, 50
SCHED_MATRIX: List[Tuple[str, str, tuple, tuple]] = [
    ("replicated", "flat", (8,), ("data",)),
    ("replicated", "two_level", (2, 4), ("pod", "data")),
    ("fsdp", "flat", (8,), ("data",)),
]

#: temporal-hierarchy audit: (local_steps H, pipeline_chunks) legs of the
#: two_level_async step pair on the 2x4 pod mesh. The INNER step must
#: carry ZERO wire collectives (the whole point of the time hierarchy:
#: quantized traffic exists only on DCN axes and only on sync steps) and
#: the SYNC step must carry exactly the two_level outer-exchange budget.
ASYNC_MATRIX: List[Tuple[int, int]] = [
    (4, 1),
    (4, 3),
]


# ---------------------------------------------------------------------------
# wire-op bundles (per registered scheme)
# ---------------------------------------------------------------------------

def wire_bundles(schemes: Optional[Sequence[str]] = None, *, nb: int = 5,
                 d: int = 37, workers: int = 3) -> List[TraceBundle]:
    """One bundle per (scheme, op) on a ragged (nb, d) buffer: exactly one
    pallas_call per fused op (zero under the reference oracle), and the
    rounding stream drawn exactly once for the 'rr' schemes."""
    names = [n for n in (schemes or all_methods())
             if not make_quantizer(n, bucket_size=d).is_identity]
    key = jax.random.key(11)
    bkt = jax.random.laplace(jax.random.key(1), (nb, d)) * 0.1
    mask = jnp.arange(nb * d).reshape(nb, d) < (nb * d - 3)
    kern = 1 if kernels_enabled() else 0
    out: List[TraceBundle] = []
    for name in names:
        qz = make_quantizer(name, bucket_size=d)
        draws = 1 if wire._fused_mode(qz) == "rr" else 0
        enc = jax.make_jaxpr(
            lambda b, m, k: wire.encode(qz, b, m, k))(bkt, mask, key)
        out.append(TraceBundle(
            label=f"wire/{name}/encode", kind="wire_op", closed=enc,
            meta={"expect_pallas_calls": kern,
                  "prng": {"random_bits": draws}}))
        qdq = jax.make_jaxpr(
            lambda b, m, k: wire.qdq(qz, b, m, k))(bkt, mask, key)
        out.append(TraceBundle(
            label=f"wire/{name}/qdq", kind="wire_op", closed=qdq,
            meta={"expect_pallas_calls": kern,
                  "prng": {"random_bits": draws}}))
        # decode input shapes come from the encoder itself, not a
        # hand-maintained words-per-bucket table
        w_sds, l_sds = jax.eval_shape(
            lambda b, m, k: wire.encode(qz, b, m, k), bkt, mask, key)
        ws = jnp.zeros((workers,) + w_sds.shape, w_sds.dtype)
        lvs = jnp.zeros((workers,) + l_sds.shape, l_sds.dtype)
        for avg in (True, False):
            dec = jax.make_jaxpr(
                lambda w, l, a=avg: wire.decode(qz, w, l, d, average=a))(
                    ws, lvs)
            out.append(TraceBundle(
                label=f"wire/{name}/decode_{'mean' if avg else 'each'}",
                kind="wire_op", closed=dec,
                meta={"expect_pallas_calls": kern,
                      "prng": {"random_bits": 0}}))
    return out


# ---------------------------------------------------------------------------
# train-step bundles (the scheme x mode matrix)
# ---------------------------------------------------------------------------

def _axis_prod(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in axes])) if axes else 1


def _replicated_group_budget(exp: Counter, e: GradientExchange, n: int,
                             mesh) -> None:
    """Wire collectives one PartitionedExchange group contributes: the
    span schedule, pipeline clamp, and requant mode are read off the
    engine itself."""
    intra, inter = tuple(e.intra_axes), tuple(e.axis_names)
    if intra:
        # fp scatter + reassembly gather bracket EVERY group (identity
        # included) in two-level mode
        exp[("reduce_scatter", intra)] += 1
        exp[("all_gather", intra)] += 1
        n = hierarchical.intra_chunk_len(n, _axis_prod(mesh, intra))
    if e.qz.is_identity:
        return                      # flat identity is a pmean (psum)
    n_inter = _axis_prod(mesh, inter)
    for a, b in e.spans(n):
        k = e._pipeline_k(b - a, n_inter)
        exp[("all_to_all", inter)] += 2 * k       # words + levels / chunk
        if e.server_requant:
            exp[("all_gather", inter)] += 2 * k   # requantized broadcast
        else:
            exp[("all_gather", inter)] += 1       # one f32 gather / span


def expected_train_collectives(eng, mesh,
                               pipeline_chunks: int) -> Dict[str, object]:
    """{"expected_collectives", "exclusive_prims"} for one traced train
    step, derived from the ``ExchangeEngines`` the step itself built."""
    exp: Counter = Counter()
    intra = tuple(eng.intra_axes)
    inter = tuple(eng.inter_axes)
    full_dp = inter + intra         # worker-major: inter axes lead
    if eng.fused_fsdp:
        n_intra = eng.fex.n_intra
        for e, g in zip(eng.fex.engines, eng.fex.layout.groups):
            if not g.sharded:
                _replicated_group_budget(exp, e, g.size, mesh)
                continue
            # ZeRO-3 parameter broadcast in the next forward
            exp[("all_gather", full_dp)] += 1
            if intra:
                exp[("reduce_scatter", intra)] += 1   # worker-major rows
                m, workers = g.size // n_intra, eng.fex.n_inter
            else:
                m, workers = g.size, eng.fex.layout.n_shards
            if e.qz.is_identity:
                exp[("reduce_scatter", inter if intra else full_dp)] += 1
            else:
                launches, _ = GradientExchange.rs_stats(
                    e.qz, m, workers, pipeline_chunks)
                exp[("all_to_all", inter if intra else full_dp)] += launches
    else:
        for e, g in zip(eng.pex.engines, eng.pex.layout.groups):
            _replicated_group_budget(exp, e, g.size, mesh)
    wire_axes = [ax for (_, ax) in exp]
    return {
        "expected_collectives": dict(exp),
        # psum carries metric reductions (un-pinned counts) but may only
        # ever touch dp axes; a2a is the quantized payload and may only
        # run where the budget above placed it (the DCN-only claim)
        "exclusive_prims": {
            "all_to_all": [ax for (p, ax) in exp if p == "all_to_all"],
            "all_gather": [ax for (p, ax) in exp if p == "all_gather"],
            "reduce_scatter": [ax for (p, ax) in exp
                               if p == "reduce_scatter"],
            "psum": [ax for ax in (full_dp, inter, intra) if ax],
            "psum_scatter": wire_axes,
        },
    }


def expected_train_pallas(eng, mesh, pipeline_chunks: int, *,
                          ef: bool = False) -> Optional[int]:
    """Kernel launches one step makes: replicated requant = encode +
    server decode_each + re-encode + worker decode per chunk (4K);
    fsdp reduce-scatter = encode + decode_mean per chunk (2K). ``ef``
    adds the replicated error-feedback residual's fused local qdq — one
    launch per span of every quantized group (``local_qdq_shard``)."""
    if not kernels_enabled():
        return 0
    total = 0
    intra = tuple(eng.intra_axes)
    if eng.fused_fsdp:
        n_intra = eng.fex.n_intra
        for e, g in zip(eng.fex.engines, eng.fex.layout.groups):
            if e.qz.is_identity:
                continue
            if not g.sharded:
                if not e.server_requant:
                    return None     # non-requant split not modelled yet
                m = g.size
                if intra:
                    m = hierarchical.intra_chunk_len(
                        m, _axis_prod(mesh, intra))
                total += sum(
                    4 * e._pipeline_k(b - a,
                                      _axis_prod(mesh, e.axis_names))
                    for a, b in e.spans(m))
                continue
            m = g.size // n_intra if intra else g.size
            workers = eng.fex.n_inter if intra else eng.fex.layout.n_shards
            launches, _ = GradientExchange.rs_stats(
                e.qz, m, workers, pipeline_chunks)
            total += launches       # 2K: encode + fused decode per chunk
    else:
        for e, g in zip(eng.pex.engines, eng.pex.layout.groups):
            if e.qz.is_identity:
                continue
            if not e.server_requant:
                return None
            m = g.size
            if intra:
                m = hierarchical.intra_chunk_len(m, _axis_prod(mesh, intra))
            total += sum(
                4 * e._pipeline_k(b - a, _axis_prod(mesh, e.axis_names))
                for a, b in e.spans(m))
            if ef:
                total += len(e.spans(m))
    return total


def expected_train_draws(eng, mesh, *, ef: bool = False) -> int:
    """Rounding-stream draws per step: one per quantized encode site
    (worker encode + server re-encode per span when re-quantizing; the
    fsdp reduce-scatter has no server phase). Invariant in K — the
    pipelined schedule slices ONE full-shape stream."""
    draws = 0
    intra = tuple(eng.intra_axes)
    if eng.fused_fsdp:
        for e, g in zip(eng.fex.engines, eng.fex.layout.groups):
            if e.qz.is_identity or wire._fused_mode(e.qz) != "rr":
                continue
            if g.sharded:
                draws += 1
            else:
                m = g.size
                if intra:
                    m = hierarchical.intra_chunk_len(
                        m, _axis_prod(mesh, intra))
                draws += len(e.spans(m)) * (2 if e.server_requant else 1)
    else:
        for e, g in zip(eng.pex.engines, eng.pex.layout.groups):
            if e.qz.is_identity or wire._fused_mode(e.qz) != "rr":
                continue
            m = g.size
            if intra:
                m = hierarchical.intra_chunk_len(m, _axis_prod(mesh, intra))
            draws += len(e.spans(m)) * (2 if e.server_requant else 1)
            if ef:
                # the residual's local qdq folds the same span keys and
                # draws its own stream copy per span
                draws += len(e.spans(m))
    return draws


def _smoke_setup():
    from repro.configs.base import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models import LM

    cfg = get_smoke_config(ARCH)
    model = LM(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8,
                      seed=0)
    return model, data


def train_bundles(matrix: Optional[Sequence[tuple]] = None
                  ) -> List[TraceBundle]:
    """Trace the real train step for every matrix leg. The K=1 trace of a
    (mode, hierarchy, policy) leg is the materialization baseline its
    K>1 legs are checked against (a chunked schedule may never hold MORE
    group-sized f32 buffers than the single-shot one)."""
    from repro.analysis import stats
    from repro.optim.schedule import constant_lr
    from repro.train import TrainConfig, make_train_step
    from repro.train.step import exchange_engines, init_state

    model, data = _smoke_setup()
    batch = data.batch(0)
    out: List[TraceBundle] = []
    mat_baseline: Dict[tuple, int] = {}
    for mode, hier, k, shape, axes, policy in (matrix or TRAIN_MATRIX):
        mesh = jax.make_mesh(shape, axes)
        tcfg = TrainConfig(policy=QuantPolicy.parse(policy), mode=mode,
                           hierarchy=hier, pipeline_chunks=k)
        state = jax.eval_shape(
            lambda key: init_state(model, mesh, tcfg, key),
            jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        closed = jax.make_jaxpr(step_fn)(state, batch, jax.random.key(1))
        eng = exchange_engines(model, mesh, tcfg)
        meta = expected_train_collectives(eng, mesh, k)
        meta["expect_donated"] = len(jax.tree_util.tree_leaves(state))
        meta["prng"] = {"random_bits": expected_train_draws(eng, mesh)}
        pallas = expected_train_pallas(eng, mesh, k)
        if pallas is not None:
            meta["expect_pallas_calls"] = pallas
        group_elems = max(g.size for g in eng.pex.layout.groups)
        leg = (mode, hier, policy)
        if k == 1:
            mat_baseline[leg] = stats.sized_outvar_count(
                closed, group_elems, "float32")
        elif leg in mat_baseline:
            meta["materialization"] = {"min_elems": group_elems,
                                       "dtype": "float32",
                                       "max_count": mat_baseline[leg]}
        out.append(TraceBundle(
            label=f"train/{mode}/{hier}/k{k}/{policy}", kind="train_step",
            closed=closed, meta=meta))
    return out


def sched_bundles(matrix: Optional[Sequence[tuple]] = None
                  ) -> List[TraceBundle]:
    """Trace the adaptive bit schedule's per-phase specialized steps.

    One by-rule engine SKELETON per leg (what ``ScheduledTrainStep``
    holds), re-specialized for every distinct phase of
    ``SCHED_SCHEDULE`` — each phase's trace gets its own collective/
    pallas/prng budget derived from the SPECIALIZED engines, extending
    the invariant matrix across schedule boundaries: a bits change must
    move the wire budgets and nothing else."""
    import dataclasses

    from repro.core.policy import BitSchedule
    from repro.optim.schedule import constant_lr
    from repro.train import TrainConfig, make_train_step
    from repro.train.step import (exchange_engines, init_state,
                                  specialize_engines)

    model, data = _smoke_setup()
    batch = data.batch(0)
    schedule = BitSchedule.parse(SCHED_SCHEDULE)
    out: List[TraceBundle] = []
    for mode, hier, shape, axes in (matrix or SCHED_MATRIX):
        mesh = jax.make_mesh(shape, axes)
        base = TrainConfig(
            policy=schedule.policy_at(schedule.ceil_assignment()),
            mode=mode, hierarchy=hier, group_by_rule=True)
        skeleton = exchange_engines(model, mesh, base)
        state = jax.eval_shape(
            lambda key: init_state(model, mesh, base, key),
            jax.random.key(0))
        for start, assignment in schedule.phases(SCHED_STEPS,
                                                 SCHED_RESOLVE):
            policy = schedule.policy_at(assignment)
            eng = specialize_engines(skeleton, policy)
            step_fn, _ = make_train_step(
                model, mesh, dataclasses.replace(base, policy=policy),
                constant_lr(0.05), engines=eng)
            closed = jax.make_jaxpr(step_fn)(state, batch,
                                             jax.random.key(1))
            meta = expected_train_collectives(eng, mesh, 1)
            meta["expect_donated"] = len(jax.tree_util.tree_leaves(state))
            meta["prng"] = {"random_bits": expected_train_draws(eng, mesh)}
            pallas = expected_train_pallas(eng, mesh, 1)
            if pallas is not None:
                meta["expect_pallas_calls"] = pallas
            bits = ",".join("fp" if b is None else str(b)
                            for b in assignment)
            out.append(TraceBundle(
                label=f"train/sched/{mode}/{hier}/phase{start}/b{bits}",
                kind="train_step", closed=closed, meta=meta))
    return out


def async_bundles(matrix: Optional[Sequence[tuple]] = None
                  ) -> List[TraceBundle]:
    """Trace BOTH programs of the two_level_async step pair.

    The dispatcher (``AsyncTrainStep``) is host-side, so the time
    hierarchy's central claim lives in two separate jaxprs:

      * ``inner_fn`` — H-1 of every H steps.  May touch NO wire
        primitive at all (``all_to_all``/``all_gather``/
        ``reduce_scatter``/``psum_scatter`` forbidden outright), draws
        no rounding bits, launches no kernels; its only collectives are
        ``psum`` means on the dp axes (grad pmean over intra, metric
        pmean over full dp).
      * ``sync_fn``  — the window's last step.  Must carry EXACTLY the
        two_level outer-exchange budget derived from the engines as
        built (fp scatter/gather on intra, quantized a2a + gather on
        inter only), pinned with the same collective/pallas/prng/
        materialization rules as the synchronous train legs.
    """
    from repro.analysis import stats
    from repro.optim.schedule import constant_lr
    from repro.train import TrainConfig, make_train_step
    from repro.train.step import exchange_engines, init_state

    model, data = _smoke_setup()
    batch = data.batch(0)
    shape, axes, policy = (2, 4), ("pod", "data"), MIXED_POLICY
    out: List[TraceBundle] = []
    mat_baseline: Dict[int, int] = {}
    for local_steps, k in (matrix or ASYNC_MATRIX):
        mesh = jax.make_mesh(shape, axes)
        tcfg = TrainConfig(policy=QuantPolicy.parse(policy),
                           mode="replicated", hierarchy="two_level_async",
                           local_steps=local_steps, error_feedback=True,
                           pipeline_chunks=k)
        state = jax.eval_shape(
            lambda key: init_state(model, mesh, tcfg, key),
            jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        eng = exchange_engines(model, mesh, tcfg)
        intra = tuple(eng.intra_axes)
        inter = tuple(eng.inter_axes)
        full_dp = inter + intra
        donated = len(jax.tree_util.tree_leaves(state))
        tag = f"train/async/h{local_steps}/k{k}/{policy}"

        closed = jax.make_jaxpr(step_fn.inner_fn)(state, batch,
                                                  jax.random.key(1))
        out.append(TraceBundle(
            label=f"{tag}/inner", kind="train_step", closed=closed,
            meta={
                "expected_collectives": {},
                # empty allowed-axes list = the primitive may not appear
                # anywhere: inner steps are wire-silent by construction
                "exclusive_prims": {
                    "all_to_all": [],
                    "all_gather": [],
                    "reduce_scatter": [],
                    "psum_scatter": [],
                    "psum": [ax for ax in (full_dp, intra) if ax],
                },
                "expect_pallas_calls": 0,
                "prng": {"random_bits": 0},
                "expect_donated": donated,
            }))

        closed = jax.make_jaxpr(step_fn.sync_fn)(state, batch,
                                                 jax.random.key(1))
        meta = expected_train_collectives(eng, mesh, k)
        meta["expect_donated"] = donated
        meta["prng"] = {"random_bits":
                        expected_train_draws(eng, mesh, ef=True)}
        pallas = expected_train_pallas(eng, mesh, k, ef=True)
        if pallas is not None:
            meta["expect_pallas_calls"] = pallas
        group_elems = max(g.size for g in eng.pex.layout.groups)
        if k == 1:
            mat_baseline[local_steps] = stats.sized_outvar_count(
                closed, group_elems, "float32")
        elif local_steps in mat_baseline:
            meta["materialization"] = {"min_elems": group_elems,
                                       "dtype": "float32",
                                       "max_count":
                                           mat_baseline[local_steps]}
        out.append(TraceBundle(label=f"{tag}/sync", kind="train_step",
                               closed=closed, meta=meta))
    return out


# ---------------------------------------------------------------------------
# serve bundles (Engine._fwd at the decode shape)
# ---------------------------------------------------------------------------

def serve_bundles(schemes: Sequence[str] = SERVE_SCHEMES
                  ) -> List[TraceBundle]:
    from repro.serve import Engine, ServeConfig

    model, _ = _smoke_setup()
    params = jax.eval_shape(model.init, jax.random.key(0))
    params = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(
            a.shape, jnp.bfloat16 if jnp.issubdtype(a.dtype, jnp.floating)
            else a.dtype), params)
    n_attn = sum(1 for s in model.specs if s.kind in ("attn", "attn_local"))
    out: List[TraceBundle] = []
    for scheme in schemes:
        cfg = ServeConfig(kv_quant=scheme, page_size=4, max_batch=2,
                          max_pages_per_seq=4, prefill_chunk=4)
        eng = Engine(model, params, cfg)
        B = cfg.max_batch
        table = jnp.zeros((B, cfg.max_pages_per_seq), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        seeds = jnp.zeros((B,), jnp.int32)
        toks = jnp.zeros((B, 1), jnp.int32)
        closed = jax.make_jaxpr(eng._fwd)(
            eng.params, eng.pools, table, pos, seeds, toks)
        quantized = eng.qz is not None
        meta = {
            # one fused dequant-attend launch per attention layer at the
            # decode shape; the bf16 escape hatch launches none
            "expect_pallas_calls":
                n_attn if quantized and kernels_enabled() else 0,
            # the paged pools are donated (updated in place)
            "expect_donated": len(jax.tree_util.tree_leaves(eng.pools)),
            "prng": {"random_bits": n_attn if eng._rr else 0},
        }
        out.append(TraceBundle(label=f"serve/{scheme}/_fwd",
                               kind="serve_fwd", closed=closed, meta=meta))
    return out


# ---------------------------------------------------------------------------
# the full matrix
# ---------------------------------------------------------------------------

def build_bundles(*, wire_ops: bool = True, train: bool = True,
                  serve: bool = True) -> List[TraceBundle]:
    bundles: List[TraceBundle] = []
    if wire_ops:
        bundles += wire_bundles()
    if train:
        bundles += train_bundles()
        bundles += sched_bundles()
        bundles += async_bundles()
    if serve:
        bundles += serve_bundles()
    return bundles
