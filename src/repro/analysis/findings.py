"""Structured findings produced by the analysis rules."""
from __future__ import annotations

import dataclasses
from typing import Optional

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``rule``      registered rule id (e.g. ``collective-budget``)
    ``severity``  ``error`` (CI-failing) or ``warning``
    ``bundle``    label of the audited trace/source bundle
    ``location``  jaxpr path / ``file:line`` the violation anchors to
    ``message``   human-readable statement of the violated invariant
    """

    rule: str
    severity: str
    bundle: str
    location: str
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, "
                f"got {self.severity!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:  # CLI/pytest-failure rendering
        return (f"[{self.severity}] {self.rule} @ {self.bundle}"
                f" ({self.location}): {self.message}")


def render(findings, *, limit: Optional[int] = None) -> str:
    """Multi-line report of findings (most severe first)."""
    order = {s: i for i, s in enumerate(SEVERITIES)}
    ranked = sorted(findings, key=lambda f: (order.get(f.severity, 9),
                                             f.rule, f.bundle))
    lines = [str(f) for f in (ranked if limit is None else ranked[:limit])]
    if limit is not None and len(ranked) > limit:
        lines.append(f"... and {len(ranked) - limit} more")
    return "\n".join(lines)
