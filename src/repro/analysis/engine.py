"""``@register_check`` registry + bundle types + ``run_checks``.

A *check* is a function ``check(bundle) -> list[Finding]`` registered
under a stable rule id. Bundles come in two kinds:

  * :class:`TraceBundle`  — one traced program (``jax.make_jaxpr``
    output) plus the invariant expectations computed for it by
    ``repro.analysis.audit`` (expected collective counts, pallas-call
    budget, donation floor, PRNG baseline, ...). Trace rules read only
    ``bundle.meta`` keys they understand and return ``[]`` when a key
    is absent — so one bundle opts into exactly the rules that make
    sense for it.
  * :class:`SourceBundle` — parsed ASTs of the ``src/repro`` tree for
    the lint rules.

Tests and the ``python -m repro.analysis`` CLI both go through
:func:`run_checks`, so an invariant pinned in a test IS the rule the CI
matrix audit enforces (no parallel hand-rolled walkers).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding


@dataclasses.dataclass(frozen=True)
class TraceBundle:
    """One traced program + its invariant expectations.

    ``label``  display name, e.g. ``train/replicated/two_level/k3``
    ``kind``   ``train_step`` | ``wire_op`` | ``serve_fwd`` | ``exchange``
    ``closed`` the ``ClosedJaxpr`` from ``jax.make_jaxpr``
    ``meta``   rule expectations; see each rule in ``rules.py``
    """

    label: str
    kind: str
    closed: Any
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SourceFile:
    path: str          # repo-relative, e.g. src/repro/kernels/ops.py
    text: str
    tree: Any          # ast.Module


@dataclasses.dataclass(frozen=True)
class SourceBundle:
    label: str
    files: Tuple[SourceFile, ...]
    kind: str = "source"


@dataclasses.dataclass(frozen=True)
class Check:
    rule: str
    fn: Callable[[Any], List[Finding]]
    kind: str            # "trace" | "source"
    severity: str
    protects: str        # one-liner: which repo claim this rule guards


#: rule id -> Check, in registration order
CHECKS: Dict[str, Check] = {}


def register_check(rule: str, *, kind: str, severity: str = "error",
                   protects: str = ""):
    """Decorator registering ``fn(bundle) -> list[Finding]`` as a rule."""
    if kind not in ("trace", "source"):
        raise ValueError(f"kind must be 'trace' or 'source', got {kind!r}")

    def deco(fn):
        if rule in CHECKS:
            raise ValueError(f"duplicate rule id {rule!r}")
        CHECKS[rule] = Check(rule=rule, fn=fn, kind=kind,
                             severity=severity, protects=protects)
        return fn

    return deco


def run_checks(bundles: Sequence[Any], *,
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Apply every registered (or selected) rule to every bundle of the
    matching kind; returns the concatenated findings."""
    if rules is not None:
        unknown = [r for r in rules if r not in CHECKS]
        if unknown:
            raise KeyError(
                f"unknown rule(s) {unknown}; registered: "
                f"{sorted(CHECKS)}")
    findings: List[Finding] = []
    for bundle in bundles:
        is_source = getattr(bundle, "kind", None) == "source"
        for check in CHECKS.values():
            if rules is not None and check.rule not in rules:
                continue
            if (check.kind == "source") != is_source:
                continue
            findings.extend(check.fn(bundle))
    return findings
