"""``python -m repro.analysis`` — the invariant auditor CLI.

    PYTHONPATH=src python -m repro.analysis --check            # CI gate
    PYTHONPATH=src python -m repro.analysis --list-rules
    PYTHONPATH=src python -m repro.analysis --rules env-read --no-train
    PYTHONPATH=src python -m repro.analysis --selftest         # rules fire?
    PYTHONPATH=src python -m repro.analysis --check \
        --inject-violation prng-single-draw                    # exits 1

Runs the AST lint over ``src/repro`` plus the traced matrix
(``audit.py``: wire ops x registered schemes, train step x
replicated/FSDP x flat/two_level x pipeline_chunks, serve ``_fwd`` x KV
schemes) through the same ``run_checks`` engine the tests call.
``--check`` exits nonzero iff any finding survives; ``--json`` writes
the structured report (the CI artifact / ``benchmarks/ANALYSIS.json``
snapshot).
"""
# Before ANY jax import: the train matrix needs 8 fake devices (jax
# locks the device count on first init).
from repro.utils.env import force_host_device_count

force_host_device_count(8)

import argparse
import json
import sys


def _report(bundles, findings, selftest=None):
    import jax

    from repro.analysis.engine import CHECKS

    by_rule = {r: 0 for r in CHECKS}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    rep = {
        "schema": 1,
        "jax": jax.__version__,
        "n_findings": len(findings),
        "rules": [
            {"rule": c.rule, "kind": c.kind, "severity": c.severity,
             "protects": c.protects, "findings": by_rule.get(c.rule, 0)}
            for c in CHECKS.values()
        ],
        "bundles": [
            {"label": b.label, "kind": b.kind,
             "meta_keys": sorted(getattr(b, "meta", {}))}
            if getattr(b, "kind", None) != "source"
            else {"label": b.label, "kind": "source",
                  "files": len(b.files)}
            for b in bundles
        ],
        "findings": [f.to_dict() for f in findings],
    }
    if selftest is not None:
        rep["selftest"] = {r: len(fs) for r, fs in selftest.items()}
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr/HLO invariant audit + source lint")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any finding is produced")
    ap.add_argument("--json", metavar="PATH",
                    help="write the structured report to PATH")
    ap.add_argument("--rules", metavar="R1,R2",
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    ap.add_argument("--selftest", action="store_true",
                    help="apply every rule to its seeded violation; exit "
                         "nonzero if any rule fails to fire")
    ap.add_argument("--inject-violation", metavar="RULE",
                    help="append RULE's seeded-violation bundle to the "
                         "matrix (demonstrates the --check gate)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST source lint")
    ap.add_argument("--no-wire", action="store_true",
                    help="skip the per-scheme wire-op traces")
    ap.add_argument("--no-train", action="store_true",
                    help="skip the train-step matrix traces")
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the serve _fwd traces")
    args = ap.parse_args(argv)

    from repro.analysis import run_checks
    from repro.analysis.engine import CHECKS
    from repro.analysis.findings import render

    if args.list_rules:
        for c in CHECKS.values():
            print(f"{c.rule:24s} [{c.kind:6s}] {c.protects}")
        return 0

    if args.selftest:
        from repro.analysis.selftest import run_selftest

        silent = [r for r, fs in run_selftest().items() if not fs]
        if silent:
            print(f"SELFTEST FAIL: rule(s) did not fire on their seeded "
                  f"violation: {silent}")
            return 1
        print(f"selftest: all {len(CHECKS)} rules fire on their seeded "
              f"violations")
        return 0

    rules = args.rules.split(",") if args.rules else None
    from repro.analysis import audit, lint

    bundles = []
    if not args.no_lint:
        bundles.append(lint.collect_sources())
    bundles += audit.build_bundles(wire_ops=not args.no_wire,
                                   train=not args.no_train,
                                   serve=not args.no_serve)
    if args.inject_violation:
        from repro.analysis.selftest import seeded_bundle

        bundles.append(seeded_bundle(args.inject_violation))

    findings = run_checks(bundles, rules=rules)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(_report(bundles, findings), fh, indent=1)
    n_rules = len(rules) if rules else len(CHECKS)
    if findings:
        print(render(findings))
        print(f"{len(findings)} finding(s) across {len(bundles)} bundles "
              f"({n_rules} rules)")
        return 1 if args.check else 0
    print(f"OK: {len(bundles)} bundles x {n_rules} rules, 0 findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
