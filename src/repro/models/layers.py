"""Shared neural building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# sharding helper: constraint only when the axis exists in the current mesh
# ---------------------------------------------------------------------------

def shard(x: jnp.ndarray, *spec):
    """with_sharding_constraint that degrades to a no-op off-mesh.

    ``spec`` entries are axis names (or None / tuples). Axes absent from the
    ambient abstract mesh are dropped, so the same model code runs in smoke
    tests (1 device, no mesh), under jit+NamedSharding, and inside shard_map
    bodies with auto axes.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - older jax
        return x
    if mesh is None or not mesh.axis_names:
        return x
    avail = set(mesh.axis_names)
    # inside shard_map, manual axes cannot appear in constraints
    try:
        manual = {a for a in mesh.axis_names
                  if mesh._name_to_type[a] == jax.sharding.AxisType.Manual}
    except Exception:  # pragma: no cover
        manual = set()
    usable = avail - manual

    sizes = dict(zip(mesh.axis_names, mesh.shape.values())) \
        if hasattr(mesh.shape, "values") else dict(mesh.shape)

    def _axes_size(entry) -> int:
        if isinstance(entry, (tuple, list)):
            n = 1
            for a in entry:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(entry, 1)

    def _filter(entry, dim_size):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in usable)
            entry = kept if kept else None
        elif entry not in usable:
            entry = None
        if entry is None:
            return None
        # dimension must divide evenly across the axis (e.g. whisper's 8
        # heads cannot shard over a 16-way model axis)
        if dim_size % _axes_size(entry) != 0:
            return None
        return entry

    spec = list(spec)
    if len(spec) < x.ndim:  # left-pad: spec aligns to trailing dims
        spec = [None] * (x.ndim - len(spec)) + spec
    filtered = [_filter(e, d) for e, d in zip(spec, x.shape)]
    if all(f is None for f in filtered):
        return x
    return jax.lax.with_sharding_constraint(x, P(*filtered))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             *, offset: float = 1.0) -> jnp.ndarray:
    """RMSNorm with (1 + scale) parameterization (gemma/llama style)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (offset + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x (..., S, H, hd), positions (..., S) -> rotated x (split halves)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def gated_mlp(p, x, *, act: str = "silu"):
    """p: {wi_gate (D,F), wi_up (D,F), wo (F,D)}; x (..., D)."""
    g = x @ p["wi_gate"]
    u = x @ p["wi_up"]
    g = shard(g, None, None, "model")
    h = _act(act)(g) * u
    return h @ p["wo"]


def dense_mlp(p, x, *, act: str = "gelu"):
    """p: {wi (D,F), bi (F,), wo (F,D), bo (D,)} (whisper-style)."""
    h = _act(act)(x @ p["wi"] + p["bi"])
    h = shard(h, None, None, "model")
    return h @ p["wo"] + p["bo"]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)
