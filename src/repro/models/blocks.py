"""Per-layer parameter construction and application (train + decode).

A layer is described by a ``LayerSpec`` (kind, MoE?, ff width, local?);
``init_layer`` builds its parameter dict and ``apply_layer_train`` /
``apply_layer_decode`` run it. The model stacks layers into scan groups
(see model.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (AttnSpec, chunked_attention,
                                    decode_attention,
                                    masked_decode_attention)
from repro.models.layers import (apply_rope, dense_init, gated_mlp,
                                 layer_norm, rms_norm, shard)
from repro.models.moe import MoESpec, moe_ffn


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str            # attn | attn_local | mamba | rwkv
    moe: bool
    d_ff: int
    cross_attn: bool = False   # whisper decoder layers
    causal: bool = True


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, spec: LayerSpec) -> AttnSpec:
    local = spec.kind == "attn_local"
    theta = (cfg.rope_theta_local
             if (local and cfg.rope_theta_local) else cfg.rope_theta)
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        window=cfg.window if local else None,
        causal=spec.causal,
        attn_softcap=cfg.attn_softcap,
        rope_theta=theta,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        probs_bf16=cfg.attn_probs_bf16,
    )


def mamba_spec(cfg: ModelConfig) -> ssm_mod.MambaSpec:
    m = cfg.mamba
    return ssm_mod.MambaSpec(d_model=cfg.d_model, d_state=m.d_state,
                       d_conv=m.d_conv, expand=m.expand)


def rwkv_spec(cfg: ModelConfig) -> rwkv_mod.RWKVSpec:
    r = cfg.rwkv
    return rwkv_mod.RWKVSpec(d_model=cfg.d_model, head_dim=r.head_dim,
                             lora_mix=r.lora_mix, lora_decay=r.lora_decay,
                             chunk=r.chunk)


def moe_spec(cfg: ModelConfig) -> MoESpec:
    m = cfg.moe
    return MoESpec(num_experts=m.num_experts, top_k=m.top_k,
                   d_ff_expert=m.d_ff_expert, num_shared=m.num_shared,
                   capacity_factor=m.capacity_factor,
                   router_aux_weight=m.router_aux_weight, act=cfg.mlp_act)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_p(cfg: ModelConfig, key, D):
    if cfg.norm == "ln":
        return {"scale": jnp.ones((D,), jnp.float32),
                "bias": jnp.zeros((D,), jnp.float32)}
    return {"scale": jnp.zeros((D,), jnp.float32)}


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "ln":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def _init_attn(cfg: ModelConfig, key):
    D, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (D, H * hd)),
        "wk": dense_init(ks[1], (D, KV * hd)),
        "wv": dense_init(ks[2], (D, KV * hd)),
        "wo": dense_init(ks[3], (H * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _init_mla(cfg: ModelConfig, key):
    D, H = cfg.d_model, cfg.num_heads
    m = cfg.mla
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (D, m.q_lora)),
        "q_norm": jnp.zeros((m.q_lora,), jnp.float32),
        "wq_b": dense_init(ks[1], (m.q_lora,
                                   H * (m.nope_head_dim + m.rope_head_dim))),
        "wkv_a": dense_init(ks[2], (D, m.kv_lora + m.rope_head_dim)),
        "kv_norm": jnp.zeros((m.kv_lora,), jnp.float32),
        "wkv_b": dense_init(ks[3], (m.kv_lora,
                                    H * (m.nope_head_dim + m.v_head_dim))),
        "wo": dense_init(ks[4], (H * m.v_head_dim, D)),
    }


def _init_ffn(cfg: ModelConfig, key, spec: LayerSpec):
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    if spec.moe:
        m = cfg.moe
        E, Fe = m.num_experts, m.d_ff_expert
        p = {
            "router": dense_init(ks[0], (D, E)),
            "wg": dense_init(ks[1], (E, D, Fe), in_axis=1),
            "wu": dense_init(ks[2], (E, D, Fe), in_axis=1),
            "wo": dense_init(ks[3], (E, Fe, D), in_axis=1),
        }
        if m.num_shared:
            Fs = Fe * m.num_shared
            p["shared_wg"] = dense_init(ks[4], (D, Fs))
            p["shared_wu"] = dense_init(ks[5], (D, Fs))
            p["shared_wo"] = dense_init(ks[6], (Fs, D))
        return p
    F = spec.d_ff
    if cfg.norm == "ln":  # whisper-style dense mlp with biases
        return {"wi": dense_init(ks[0], (D, F)),
                "bi": jnp.zeros((F,), jnp.float32),
                "wo": dense_init(ks[1], (F, D)),
                "bo": jnp.zeros((D,), jnp.float32)}
    return {"wi_gate": dense_init(ks[0], (D, F)),
            "wi_up": dense_init(ks[1], (D, F)),
            "wo": dense_init(ks[2], (F, D))}


def init_layer(cfg: ModelConfig, spec: LayerSpec, key):
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    if spec.kind == "mamba":
        ms = mamba_spec(cfg)
        d_in, N, rank = ms.d_inner, ms.d_state, ms.rank
        return {
            "norm": _norm_p(cfg, ks[0], D),
            "in_proj": dense_init(ks[0], (D, 2 * d_in)),
            "conv_w": dense_init(ks[1], (ms.d_conv, d_in)) * 0.1,
            "conv_b": jnp.zeros((d_in,), jnp.float32),
            "x_proj": dense_init(ks[2], (d_in, rank + 2 * N)),
            "dt_proj": dense_init(ks[3], (rank, d_in)),
            "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus ~ 0.01
            "A_log": jnp.log(jnp.tile(
                jnp.arange(1, N + 1, dtype=jnp.float32)[None], (d_in, 1))),
            "D": jnp.ones((d_in,), jnp.float32),
            "out_proj": dense_init(ks[4], (d_in, D)),
        }
    if spec.kind == "rwkv":
        rs = rwkv_spec(cfg)
        H, hd, Lm, Ld = rs.num_heads, rs.head_dim, rs.lora_mix, rs.lora_decay
        F = spec.d_ff
        kk = jax.random.split(key, 16)
        return {
            "norm1": _norm_p(cfg, kk[0], D),
            "norm2": _norm_p(cfg, kk[1], D),
            "tm_mu": jnp.full((6, D), 0.5, jnp.float32),
            "tm_w1": dense_init(kk[2], (D, 5 * Lm)) * 0.1,
            "tm_w2": dense_init(kk[3], (5, Lm, D), in_axis=1) * 0.1,
            "w0": jnp.full((D,), -2.0, jnp.float32),
            "dec_w1": dense_init(kk[4], (D, Ld)) * 0.1,
            "dec_w2": dense_init(kk[5], (Ld, D)) * 0.1,
            "u": dense_init(kk[6], (H, hd)),
            "wr": dense_init(kk[7], (D, D)),
            "wk": dense_init(kk[8], (D, D)),
            "wv": dense_init(kk[9], (D, D)),
            "wg": dense_init(kk[10], (D, D)),
            "ln_x": jnp.ones((D,), jnp.float32),
            "wo": dense_init(kk[11], (D, D)),
            "cm_mu_k": jnp.full((D,), 0.5, jnp.float32),
            "cm_mu_r": jnp.full((D,), 0.5, jnp.float32),
            "ck": dense_init(kk[12], (D, F)),
            "cv": dense_init(kk[13], (F, D)),
            "cr": dense_init(kk[14], (D, D)),
        }
    # attention layer
    p = {
        "norm1": _norm_p(cfg, ks[0], D),
        "norm2": _norm_p(cfg, ks[1], D),
        "attn": _init_mla(cfg, ks[2]) if cfg.mla else _init_attn(cfg, ks[2]),
        "ffn": _init_ffn(cfg, ks[3], spec),
    }
    if spec.cross_attn:
        p["norm_x"] = _norm_p(cfg, ks[4], D)
        p["xattn"] = _init_attn(cfg, ks[5])
    return p


# ---------------------------------------------------------------------------
# train-path application
# ---------------------------------------------------------------------------

def _gqa_project(cfg, p, x):
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = shard(q, None, None, "model", None)
    return q, k, v


def _mla_project(cfg, p, x):
    """MLA expanded-form projections (train). Returns q,k,v with
    head_dim = nope+rope for q/k and v_head_dim for v."""
    B, S, D = x.shape
    m, H = cfg.mla, cfg.num_heads
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    kv_in = x @ p["wkv_a"]                      # (B,S,kv_lora+rope)
    ckv = rms_norm(kv_in[..., : m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_in[..., m.kv_lora:]             # (B,S,rope) shared head
    kvb = (ckv @ p["wkv_b"]).reshape(B, S, H,
                                     m.nope_head_dim + m.v_head_dim)
    k_nope = kvb[..., : m.nope_head_dim]
    v = kvb[..., m.nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (B, S, H, m.rope_head_dim))], axis=-1)
    q = shard(q, None, None, "model", None)
    return q, k, v


def _attn_block_train(cfg, spec, p, x):
    asp = attn_spec(cfg, spec)
    if cfg.mla:
        m = cfg.mla
        q, k, v = _mla_project(cfg, p["attn"], x)
        asp = asp._replace(num_kv_heads=cfg.num_heads,
                           head_dim=m.nope_head_dim + m.rope_head_dim,
                           scale=(m.nope_head_dim + m.rope_head_dim) ** -0.5,
                           rope_dims=m.rope_head_dim)
        # pad v to qk head_dim for the shared attention codepath
        pad = asp.head_dim - m.v_head_dim
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
        o = chunked_attention(q, k, v, asp)[..., : m.v_head_dim]
        B, S = x.shape[:2]
        return o.reshape(B, S, -1) @ p["attn"]["wo"]
    q, k, v = _gqa_project(cfg, p["attn"], x)
    o = chunked_attention(q, k, v, asp)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["attn"]["wo"]


def _ffn_train(cfg, spec, p, x):
    """Returns (y, aux)."""
    if spec.moe:
        B, S, D = x.shape
        y, aux = moe_ffn(p, x.reshape(B * S, D), moe_spec(cfg))
        return y.reshape(B, S, D), aux
    if cfg.norm == "ln":
        from repro.models.layers import dense_mlp
        return dense_mlp(p, x, act=cfg.mlp_act), jnp.float32(0)
    return gated_mlp(p, x, act=cfg.mlp_act), jnp.float32(0)


def apply_layer_train(cfg: ModelConfig, spec: LayerSpec, p, x,
                      enc_out=None):
    """x (B,S,D) -> (x', aux_loss)."""
    if spec.kind == "mamba":
        return x + ssm_mod.mamba_forward(
            {k: v for k, v in p.items() if k != "norm"},
            _apply_norm(cfg, p["norm"], x), mamba_spec(cfg)), jnp.float32(0)
    if spec.kind == "rwkv":
        h = x + rwkv_mod.time_mix(p, _apply_norm(cfg, p["norm1"], x), rwkv_spec(cfg))
        h = h + rwkv_mod.channel_mix_train(p, _apply_norm(cfg, p["norm2"], h))
        return h, jnp.float32(0)
    # attention block
    h = x + _attn_block_train(cfg, spec, p, _apply_norm(cfg, p["norm1"], x))
    if spec.cross_attn:
        hx = _apply_norm(cfg, p["norm_x"], h)
        B, S, D = hx.shape
        asp = attn_spec(cfg, spec)._replace(causal=False, window=None,
                                            use_rope=False)
        q, _, _ = _gqa_project(cfg, p["xattn"], hx)
        _, k, v = _gqa_project(cfg, p["xattn"], enc_out)
        o = chunked_attention(q, k, v, asp)
        h = h + o.reshape(B, S, -1) @ p["xattn"]["wo"]
    y, aux = _ffn_train(cfg, spec, p["ffn"], _apply_norm(cfg, p["norm2"], h))
    return h + y, aux


# ---------------------------------------------------------------------------
# decode-path application (one token, cached)
# ---------------------------------------------------------------------------

def _mla_decode(cfg: ModelConfig, spec: LayerSpec, p, x, xn, cache, pos):
    """Absorbed-form MLA decode over the compressed (ckv, kr) cache.

    q_nope is absorbed through W_uk so attention scores are taken directly
    against the 512-d latent; the attended latent is expanded through W_uv.
    Per-token FLOPs H·(dn·dc + dc) per cache slot — the compressed cache is
    what makes deepseek-v2 decode fit HBM.
    """
    m = cfg.mla
    pa = p["attn"]
    B = x.shape[0]
    H, dn, dr, dv, dc = (cfg.num_heads, m.nope_head_dim, m.rope_head_dim,
                         m.v_head_dim, m.kv_lora)
    cq = rms_norm(xn @ pa["wq_a"], pa["q_norm"], cfg.norm_eps)
    q = (cq @ pa["wq_b"]).reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, posv, cfg.rope_theta)

    kv_in = xn[:, 0] @ pa["wkv_a"]                      # (B, dc + dr)
    ckv_t = rms_norm(kv_in[..., :dc], pa["kv_norm"], cfg.norm_eps)
    kr_t = apply_rope(kv_in[..., dc:][:, None, None, :], posv,
                      cfg.rope_theta)[:, 0, 0]           # (B, dr)

    C = cache["ckv"].shape[1]
    slot = pos % C
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_t[:, None].astype(cache["ckv"].dtype), slot, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], kr_t[:, None].astype(cache["kr"].dtype), slot, axis=1)
    posa = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
    valid = posa >= 0

    # absorb q through W_uk: (dc, H, dn+dv) split
    wkv_b = pa["wkv_b"].reshape(dc, H, dn + dv)
    w_uk = wkv_b[..., :dn]                               # (dc, H, dn)
    w_uv = wkv_b[..., dn:]                               # (dc, H, dv)
    q_eff = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))         # (B,1,H,dc)
    s = (jnp.einsum("bqhc,bkc->bhqk", q_eff,
                    ckv.astype(jnp.float32))
         + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32)))
    s = s * ((dn + dr) ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, -2.0e38)
    w = jax.nn.softmax(s, axis=-1)                       # (B,H,1,C)
    att_c = jnp.einsum("bhqk,bkc->bqhc", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bqhc,chd->bqhd", att_c,
                   w_uv.astype(jnp.float32))             # (B,1,H,dv)
    h = x + (o.reshape(B, 1, H * dv).astype(x.dtype) @ pa["wo"])
    return h, {"ckv": ckv, "kr": kr, "pos": posa}

def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype=jnp.bfloat16, enc_frames: int = 0):
    if spec.kind == "mamba":
        return ssm_mod.init_mamba_state(batch, mamba_spec(cfg), dtype)
    if spec.kind == "rwkv":
        return rwkv_mod.init_rwkv_state(batch, rwkv_spec(cfg), dtype)
    C = min(max_len, cfg.window) if spec.kind == "attn_local" else max_len
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.mla:
        # absorbed-form MLA: cache the COMPRESSED latent (this is MLA's
        # memory contribution), not per-head K/V
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, C, m.kv_lora), dtype),
            "kr": jnp.zeros((batch, C, m.rope_head_dim), dtype),
            "pos": jnp.full((C,), -1, jnp.int32),
        }
    cache = {
        "k": jnp.zeros((batch, C, KV, hd), dtype),
        "v": jnp.zeros((batch, C, KV, hd), dtype),
        "pos": jnp.full((C,), -1, jnp.int32),
    }
    if spec.cross_attn:
        cache["xk"] = jnp.zeros((batch, enc_frames, KV, hd), dtype)
        cache["xv"] = jnp.zeros((batch, enc_frames, KV, hd), dtype)
    return cache


def apply_layer_prefill_chunk(cfg: ModelConfig, spec: LayerSpec, p, x,
                              cache, start):
    """Chunked-prefill twin of :func:`apply_layer_decode` for the GQA
    attention kinds: x (B,T,D), start scalar int32 (absolute position of
    x[:, 0]) -> (x', new_cache). Appends the whole chunk's K/V at cache
    slots start..start+T-1 (the caller guarantees start+T <= C — no ring
    wrap) and attends with an explicit causal ∧ valid ∧ window mask
    through the same score→softmax→PV composition as decode. At T == 1 it
    computes exactly the decode step.

    mamba/rwkv (stateful recurrences) and MLA (absorbed-form cache) have
    no chunked path — callers fall back to the token-by-token loop.
    """
    if spec.kind in ("mamba", "rwkv") or cfg.mla:
        raise NotImplementedError(
            f"chunked prefill supports GQA attention layers only "
            f"(kind={spec.kind!r}, mla={cfg.mla is not None})")
    B, T = x.shape[:2]
    asp = attn_spec(cfg, spec)
    xn = _apply_norm(cfg, p["norm1"], x)
    q, k, v = _gqa_project(cfg, p["attn"], xn)
    qpos = start + jnp.arange(T, dtype=jnp.int32)          # (T,)
    posv = jnp.broadcast_to(qpos[None], (B, T))
    q = apply_rope(q, posv, asp.rope_theta)
    k = apply_rope(k, posv, asp.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), start, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), start, axis=1)
    posa = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], qpos, start, axis=0)
    mask = ((posa >= 0)[None, None, :]
            & (posa[None, None, :] <= posv[:, :, None]))   # (B,T,C)
    if spec.kind == "attn_local" and cfg.window:
        mask &= (posv[:, :, None] - posa[None, None, :]) < cfg.window
    o = masked_decode_attention(q, kc, vc, mask, asp)
    h = x + o.reshape(B, T, -1) @ p["attn"]["wo"]
    new_cache = {"k": kc, "v": vc, "pos": posa}

    if spec.cross_attn:
        hx = _apply_norm(cfg, p["norm_x"], h)
        qx, _, _ = _gqa_project(cfg, p["xattn"], hx)
        Tx = cache["xk"].shape[1]
        ox = masked_decode_attention(
            qx, cache["xk"], cache["xv"], jnp.ones((B, T, Tx), bool),
            asp._replace(causal=False, window=None))
        h = h + ox.reshape(B, T, -1) @ p["xattn"]["wo"]
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]

    y, _ = _ffn_train(cfg, spec, p["ffn"], _apply_norm(cfg, p["norm2"], h))
    return h + y, new_cache


def apply_layer_decode(cfg: ModelConfig, spec: LayerSpec, p, x, cache, pos):
    """x (B,1,D), pos scalar int32 -> (x', new_cache)."""
    if spec.kind == "mamba":
        y, st = ssm_mod.mamba_decode_step(
            {k: v for k, v in p.items() if k != "norm"},
            _apply_norm(cfg, p["norm"], x), cache, mamba_spec(cfg))
        return x + y, st
    if spec.kind == "rwkv":
        rs = rwkv_spec(cfg)
        xn = _apply_norm(cfg, p["norm1"], x)
        tm_out, tm_st = rwkv_mod.time_mix_decode(
            p, xn, {"wkv": cache["wkv"], "shift": cache["tm_shift"]}, rs)
        h = x + tm_out
        hn = _apply_norm(cfg, p["norm2"], h)
        cm_out, cm_st = rwkv_mod.channel_mix_decode(
            p, hn, {"shift": cache["cm_shift"]})
        return h + cm_out, {"wkv": tm_st["wkv"],
                            "tm_shift": tm_st["shift"],
                            "cm_shift": cm_st["shift"]}

    # attention
    B = x.shape[0]
    asp = attn_spec(cfg, spec)
    xn = _apply_norm(cfg, p["norm1"], x)
    if cfg.mla:
        h, new_cache = _mla_decode(cfg, spec, p, x, xn, cache, pos)
        y, _ = _ffn_train(cfg, spec, p["ffn"],
                          _apply_norm(cfg, p["norm2"], h))
        return h + y, new_cache
    q, k, v = _gqa_project(cfg, p["attn"], xn)
    C = cache["k"].shape[1]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv, asp.rope_theta)
    k = apply_rope(k, posv, asp.rope_theta)
    slot = pos % C
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
        cache["k"].dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
        cache["v"].dtype), slot, axis=1)
    posa = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
    valid = posa >= 0
    if spec.kind == "attn_local" and cfg.window:
        valid &= (pos - posa) < cfg.window
    o = decode_attention(q, kc, vc,
                         jnp.broadcast_to(valid[None], (B, C)), asp)
    if cfg.mla:
        o = o[..., : cfg.mla.v_head_dim]
    h = x + o.reshape(B, 1, -1) @ p["attn"]["wo"]
    new_cache = {"k": kc, "v": vc, "pos": posa}

    if spec.cross_attn:
        hx = _apply_norm(cfg, p["norm_x"], h)
        qx, _, _ = _gqa_project(cfg, p["xattn"], hx)
        Tx = cache["xk"].shape[1]
        ox = decode_attention(
            qx, cache["xk"], cache["xv"],
            jnp.ones((B, Tx), bool), asp._replace(causal=False, window=None))
        h = h + ox.reshape(B, 1, -1) @ p["xattn"]["wo"]
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]

    y, _ = _ffn_train(cfg, spec, p["ffn"], _apply_norm(cfg, p["norm2"], h))
    return h + y, new_cache
