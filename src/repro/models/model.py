"""LM: composable decoder-only / encoder-decoder model over LayerSpecs.

Layers are grouped into repeating units (the minimal period of the layer
pattern, e.g. jamba's 8-layer Mamba/attn block or gemma3's 6-layer
local:global cycle); each group's parameters are stacked on a leading
repeat axis and applied with ``lax.scan``. This keeps HLO size O(unit) and
lets ZeRO-3 gather one unit's weights at a time: the optional ``gather``
hook (path, leaf, salt) -> leaf is applied to every parameter leaf at its
point of use — identity for single-host runs, the quantized-VJP FSDP gather
in distributed training.

The cross-entropy loss is computed in sequence chunks (logits for the full
vocab are never materialized for the whole sequence at once).
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import (LayerSpec, apply_layer_decode,
                                 apply_layer_prefill_chunk,
                                 apply_layer_train, attn_spec,
                                 init_layer, init_layer_cache)
from repro.models.layers import (dense_init, embed_init, layer_norm,
                                 rms_norm, shard, softcap)

GatherFn = Callable[[str, jnp.ndarray, Any], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    unit: Tuple[LayerSpec, ...]
    repeats: int
    start: int          # global index of the group's first layer


def _identity_gather(path, leaf, salt):
    del path, salt
    return leaf


def build_layer_specs(cfg: ModelConfig, *, decoder: bool = True):
    specs = []
    for i in range(cfg.num_layers):
        specs.append(LayerSpec(
            kind=cfg.layer_kind(i),
            moe=cfg.layer_is_moe(i),
            d_ff=cfg.layer_ff(i),
            cross_attn=decoder and cfg.encoder is not None,
            causal=decoder,
        ))
    return specs


def build_groups(cfg: ModelConfig, specs) -> Tuple[GroupSpec, ...]:
    groups = []
    i = 0
    if cfg.first_layer_dense_ff:
        groups.append(GroupSpec(unit=(specs[0],), repeats=1, start=0))
        i = 1
    P = math.lcm(len(cfg.layer_pattern), cfg.moe_every or 1)
    main = len(specs) - i
    n_rep, rem = divmod(main, P)
    if n_rep:
        groups.append(GroupSpec(unit=tuple(specs[i:i + P]), repeats=n_rep,
                                start=i))
    if rem:
        start = i + n_rep * P
        groups.append(GroupSpec(unit=tuple(specs[start:]), repeats=1,
                                start=start))
    return tuple(groups)


def _path_salt(path: str) -> int:
    return zlib.crc32(path.encode())


class LM:
    """Decoder-only (or encoder-decoder, if cfg.encoder) language model."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.specs = build_layer_specs(cfg)
        self.groups = build_groups(cfg, self.specs)
        if cfg.encoder:
            enc_cfg = dataclasses.replace(
                cfg, num_layers=cfg.encoder.num_layers, moe_every=0,
                layer_pattern=("attn",), first_layer_dense_ff=0)
            self.enc_cfg = enc_cfg
            self.enc_specs = build_layer_specs(enc_cfg, decoder=False)
            self.enc_groups = build_groups(enc_cfg, self.enc_specs)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _init_group(self, cfg, group: GroupSpec, key):
        out = {}
        for j, spec in enumerate(group.unit):
            keys = jax.random.split(jax.random.fold_in(key, j),
                                    group.repeats)
            out[f"pos{j}"] = jax.vmap(
                lambda k: init_layer(cfg, spec, k))(keys)
        return out

    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params = {
            "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model)),
            "groups": tuple(
                self._init_group(cfg, g, jax.random.fold_in(ks[1], gi))
                for gi, g in enumerate(self.groups)),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)
            if cfg.norm == "rms" else
            {"scale": jnp.ones((cfg.d_model,), jnp.float32),
             "bias": jnp.zeros((cfg.d_model,), jnp.float32)},
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[2],
                                           (cfg.d_model, cfg.vocab_size))
        if cfg.encoder:
            params["encoder"] = {
                "pos_embed": embed_init(ks[3], (cfg.encoder.num_frames,
                                                cfg.d_model)),
                "groups": tuple(
                    self._init_group(self.enc_cfg, g,
                                     jax.random.fold_in(ks[4], gi))
                    for gi, g in enumerate(self.enc_groups)),
                "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                               "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
                if cfg.norm == "ln" else jnp.zeros((cfg.d_model,),
                                                   jnp.float32),
            }
        return params

    # ------------------------------------------------------------------
    # shared machinery
    # ------------------------------------------------------------------
    def _final_norm(self, p, x):
        cfg = self.cfg
        if cfg.norm == "ln":
            return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
        return rms_norm(x, p, cfg.norm_eps)

    compute_dtype = jnp.bfloat16

    def _cast(self, leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(self.compute_dtype)
        return leaf

    def _gather_leaf(self, path, leaf, salt, gather: GatherFn):
        return self._cast(gather(path, leaf, salt))

    def _gather_tree(self, tree, gather: GatherFn, prefix: str, salt):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self._gather_leaf(
                prefix + jax.tree_util.keystr(path), leaf, salt, gather),
            tree)

    def _run_groups(self, cfg, groups, group_params, x, gather: GatherFn,
                    enc_out=None, prefix=""):
        aux_total = jnp.float32(0)
        for gi, (g, gp) in enumerate(zip(groups, group_params)):
            gname = f"{prefix}g{gi}/"

            def body(carry, xs):
                x, aux = carry
                unit_p, idx = xs
                for j, spec in enumerate(g.unit):
                    pj = self._gather_tree(unit_p[f"pos{j}"], gather,
                                           gname + f"pos{j}", idx)
                    x, a = apply_layer_train(cfg, spec, pj, x,
                                             enc_out=enc_out)
                    aux = aux + a
                # keep the scan carry (= the checkpointed residual)
                # sequence-parallel: seq over `model`, batch over dp
                x = shard(x, ("pod", "data"), "model", None)
                return (x, aux), None

            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), (gp, jnp.arange(g.repeats)))
        return x, aux_total

    def param_paths(self, params):
        """Pytree of gather-path strings aligned with ``params`` — the exact
        strings the runtime gather hook receives, for sharding planners."""
        kstr = jax.tree_util.keystr

        def named(prefix, tree):
            return jax.tree_util.tree_map_with_path(
                lambda p, l: prefix + kstr(p), tree)

        def group_paths(groups_p, prefix=""):
            return tuple(
                {k: named(f"{prefix}g{gi}/{k}", gp[k]) for k in gp}
                for gi, gp in enumerate(groups_p))

        out = {
            "embed": "embed",
            "final_norm": named("final_norm", params["final_norm"]),
            "groups": group_paths(params["groups"]),
        }
        if "lm_head" in params:
            out["lm_head"] = "lm_head"
        if "encoder" in params:
            enc = params["encoder"]
            out["encoder"] = {
                "pos_embed": "enc/['pos_embed']",
                "final_norm": named("enc/['final_norm']",
                                    enc["final_norm"]),
                "groups": group_paths(enc["groups"], "enc/"),
            }
        return out

    # ------------------------------------------------------------------
    # encoder (whisper; frontend stub supplies frame embeddings)
    # ------------------------------------------------------------------
    def encode(self, params, enc_embeds, gather: GatherFn = _identity_gather):
        cfg = self.cfg
        ep = self._gather_tree(
            {"pos_embed": params["encoder"]["pos_embed"],
             "final_norm": params["encoder"]["final_norm"]},
            gather, "enc/", 0)
        x = enc_embeds.astype(jnp.bfloat16) + ep["pos_embed"][None].astype(
            jnp.bfloat16)
        x, _ = self._run_groups(self.enc_cfg, self.enc_groups,
                                params["encoder"]["groups"], x, gather,
                                prefix="enc/")
        return self._final_norm(ep["final_norm"], x)

    # ------------------------------------------------------------------
    # training / prefill forward
    # ------------------------------------------------------------------
    def hidden_states(self, params, tokens,
                      gather: GatherFn = _identity_gather,
                      enc_embeds=None):
        cfg = self.cfg
        embed = self._gather_leaf("embed", params["embed"], 0, gather)
        x = jnp.take(embed, tokens, axis=0).astype(jnp.bfloat16)
        if cfg.embed_scale:
            x = x * jnp.bfloat16(math.sqrt(cfg.d_model))
        # sequence-parallel activation layout: batch over dp, seq over model
        # (inside shard_map the dp axes are manual and silently dropped)
        x = shard(x, ("pod", "data"), "model", None)
        enc_out = None
        if cfg.encoder:
            enc_out = self.encode(params, enc_embeds, gather)
        x, aux = self._run_groups(cfg, self.groups, params["groups"], x,
                                  gather, enc_out=enc_out)
        fp = self._gather_tree(params["final_norm"], gather, "final_norm", 0)
        return self._final_norm(fp, x), aux

    def _head(self, params, gather: GatherFn):
        if self.cfg.tie_embeddings:
            return self._gather_leaf("embed", params["embed"], 0, gather).T
        return self._gather_leaf("lm_head", params["lm_head"], 0, gather)

    def logits(self, params, tokens, gather: GatherFn = _identity_gather,
               enc_embeds=None):
        x, aux = self.hidden_states(params, tokens, gather, enc_embeds)
        head = self._head(params, gather)
        lg = (x @ head.astype(x.dtype)).astype(jnp.float32)
        return softcap(lg, self.cfg.final_softcap), aux

    def loss(self, params, batch, gather: GatherFn = _identity_gather,
             *, loss_chunk: int = 512):
        """batch: {tokens (B,S) [, enc_embeds (B,F,D)]}. Next-token xent,
        computed in sequence chunks so (B,S,V) never materializes."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x, aux = self.hidden_states(params, tokens, gather,
                                    batch.get("enc_embeds"))
        head = self._head(params, gather).astype(x.dtype)

        inputs = x[:, :-1]
        targets = tokens[:, 1:]
        T = inputs.shape[1]
        ck = min(loss_chunk, T)
        nc = -(-T // ck)
        pad = nc * ck - T
        inputs = jnp.pad(inputs, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)),
                          constant_values=-1)
        inputs = inputs.reshape(B, nc, ck, -1).swapaxes(0, 1)
        targets = targets.reshape(B, nc, ck).swapaxes(0, 1)

        def chunk_loss(carry, xs):
            xc, tc = xs                                 # (B,ck,D), (B,ck)
            lg = (xc @ head).astype(jnp.float32)
            lg = softcap(lg, cfg.final_softcap)
            lse = jax.nn.logsumexp(lg, axis=-1)
            tgt = jnp.take_along_axis(
                lg, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
            valid = (tc >= 0).astype(jnp.float32)
            nll = (lse - tgt) * valid
            tot, cnt = carry
            return (tot + nll.sum(), cnt + valid.sum()), None

        body = chunk_loss
        if cfg.remat:
            body = jax.checkpoint(body)
        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (inputs, targets))
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss + aux, {"nll": loss, "aux": aux, "tokens": cnt}

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        caches = []
        frames = cfg.encoder.num_frames if cfg.encoder else 0
        for g in self.groups:
            gc = {}
            for j, spec in enumerate(g.unit):
                one = init_layer_cache(cfg, spec, batch, max_len, dtype,
                                       enc_frames=frames)
                gc[f"pos{j}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(
                        x[None], (g.repeats,) + x.shape), one)
            caches.append(gc)
        return tuple(caches)

    def warm_cache(self, params, cache, enc_embeds,
                   gather: GatherFn = _identity_gather):
        """Precompute whisper cross-attention K/V from the encoder output."""
        if not self.cfg.encoder:
            return cache
        from repro.models.blocks import _gqa_project  # noqa: PLC0415
        enc_out = self.encode(params, enc_embeds, gather)
        new = []
        for gi, (g, gp, gc) in enumerate(
                zip(self.groups, params["groups"], cache)):
            gcn = dict(gc)
            for j, spec in enumerate(g.unit):
                if not spec.cross_attn:
                    continue

                def per_rep(unit_p):
                    _, k, v = _gqa_project(self.cfg, unit_p["xattn"],
                                           enc_out)
                    return k, v

                ks, vs = jax.vmap(per_rep)(gp[f"pos{j}"])
                cj = dict(gcn[f"pos{j}"])
                cj["xk"] = ks.astype(cj["xk"].dtype)
                cj["xv"] = vs.astype(cj["xv"].dtype)
                gcn[f"pos{j}"] = cj
            new.append(gcn)
        return tuple(new)

    def decode_step(self, params, cache, tokens, pos,
                    gather: GatherFn = _identity_gather):
        """tokens (B, 1) int32, pos scalar int32 -> (logits (B,1,V), cache)."""
        cfg = self.cfg
        embed = self._gather_leaf("embed", params["embed"], 0, gather)
        x = jnp.take(embed, tokens, axis=0).astype(jnp.bfloat16)
        if cfg.embed_scale:
            x = x * jnp.bfloat16(math.sqrt(cfg.d_model))
        new_caches = []
        for gi, (g, gp, gc) in enumerate(
                zip(self.groups, params["groups"], cache)):
            gname = f"g{gi}/"

            def body(x, xs):
                unit_p, unit_c, idx = xs
                ncs = {}
                for j, spec in enumerate(g.unit):
                    pj = self._gather_tree(unit_p[f"pos{j}"], gather,
                                           gname + f"pos{j}", idx)
                    x, nc = apply_layer_decode(cfg, spec, pj, x,
                                               unit_c[f"pos{j}"], pos)
                    ncs[f"pos{j}"] = nc
                return x, ncs

            x, nc = jax.lax.scan(body, x, (gp, gc, jnp.arange(g.repeats)))
            new_caches.append(nc)
        fp = self._gather_tree(params["final_norm"], gather, "final_norm", 0)
        x = self._final_norm(fp, x)
        head = self._head(params, gather)
        lg = (x @ head.astype(x.dtype)).astype(jnp.float32)
        return softcap(lg, cfg.final_softcap), tuple(new_caches)

    def supports_chunked_prefill(self) -> bool:
        """True when every layer has the chunked-prefill path (GQA
        attention kinds only — mamba/rwkv/MLA fall back to the
        token-by-token ``prefill`` loop)."""
        cfg = self.cfg
        return (cfg.mla is None
                and all(s.kind in ("attn", "attn_local") for s in self.specs))

    def prefill_chunk(self, params, cache, tokens, start,
                      gather: GatherFn = _identity_gather):
        """Chunked prefill: tokens (B, T) at absolute positions
        start..start+T-1 -> (logits (B, T, V), cache). One forward over
        the chunk instead of T decode steps; the caller guarantees
        start+T fits every layer's cache (no ring wrap — for attn_local
        layers the cache must cover the full sequence)."""
        cfg = self.cfg
        embed = self._gather_leaf("embed", params["embed"], 0, gather)
        x = jnp.take(embed, tokens, axis=0).astype(jnp.bfloat16)
        if cfg.embed_scale:
            x = x * jnp.bfloat16(math.sqrt(cfg.d_model))
        new_caches = []
        for gi, (g, gp, gc) in enumerate(
                zip(self.groups, params["groups"], cache)):
            gname = f"g{gi}/"

            def body(x, xs):
                unit_p, unit_c, idx = xs
                ncs = {}
                for j, spec in enumerate(g.unit):
                    pj = self._gather_tree(unit_p[f"pos{j}"], gather,
                                           gname + f"pos{j}", idx)
                    x, nc = apply_layer_prefill_chunk(
                        cfg, spec, pj, x, unit_c[f"pos{j}"], start)
                    ncs[f"pos{j}"] = nc
                return x, ncs

            x, nc = jax.lax.scan(body, x, (gp, gc, jnp.arange(g.repeats)))
            new_caches.append(nc)
        fp = self._gather_tree(params["final_norm"], gather, "final_norm", 0)
        x = self._final_norm(fp, x)
        head = self._head(params, gather)
        lg = (x @ head.astype(x.dtype)).astype(jnp.float32)
        return softcap(lg, cfg.final_softcap), tuple(new_caches)

    def prefill(self, params, cache, tokens,
                gather: GatherFn = _identity_gather, enc_embeds=None):
        """Sequential prefill via decode_step (reference path for tests and
        small-model serving; production prefill lowers the chunked forward)."""
        if self.cfg.encoder:
            cache = self.warm_cache(params, cache, enc_embeds, gather)
        B, S = tokens.shape

        def step(carry, i):
            cache, _ = carry
            lg, cache = self.decode_step(params, cache, tokens[:, i][:, None],
                                         i, gather)
            return (cache, lg), None

        lg0 = jnp.zeros((B, 1, self.cfg.vocab_size), jnp.float32)
        (cache, lg), _ = jax.lax.scan(step, (cache, lg0), jnp.arange(S))
        return lg, cache
