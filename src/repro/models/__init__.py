from repro.models.model import LM, build_groups, build_layer_specs

__all__ = ["LM", "build_groups", "build_layer_specs"]
