"""Mamba (S6) selective state-space block — the Jamba mixer.

Train path: depthwise causal conv + selective scan. The scan is a chunked
linear recurrence: within a chunk the diagonal recurrence
``h_t = a_t * h_{t-1} + b_t`` is evaluated with an associative scan over
time; chunks are chained with a lightweight sequential scan over chunk
boundaries. This bounds the saved-activation footprint to one (B, d_in, N)
carry per chunk instead of per step.

Decode path: O(1) per token — a (d_conv-1) rolling conv window plus the
(d_in, N) SSM state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MambaSpec(NamedTuple):
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def _ssm_scan_project(dt, xc, Bm, Cm, A, h0, chunk: int):
    """Selective scan with chunked state materialization.

    Inputs per token: dt, xc (B, S, d); Bm, Cm (B, S, N); A (d, N) diag.
    The (B, chunk, d, N) discretized transition/input tensors AND the state
    trajectory exist only per chunk — materializing them over the full
    sequence is ~N=16x the hidden-state footprint (≈1 TB/device at jamba
    train_4k scale). Returns (y (B, S, d), h_last).
    """
    B, S, d = dt.shape
    N = A.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S

    def padt(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    def to_chunks(x):
        return x.reshape((B, nc, chunk) + x.shape[2:]).swapaxes(0, 1)

    dt, xc, Bm, Cm = map(lambda x: to_chunks(padt(x)), (dt, xc, Bm, Cm))

    def chunk_step(h, xs):
        dtc, xcc, Bc, Cc = xs                        # (B, chunk, ...)
        da = jnp.exp(dtc[..., None] * A)             # (B, chunk, d, N)
        db = (dtc * xcc)[..., None] * Bc[:, :, None, :]

        def combine(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, b1 * a2 + b2

        aa, bb = jax.lax.associative_scan(combine, (da, db), axis=1)
        h_all = aa * h[:, None] + bb                 # (B, chunk, d, N)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, Cc)
        return h_all[:, -1], y

    h_last, ys = jax.lax.scan(chunk_step, h0, (dt, xc, Bm, Cm))
    y = ys.swapaxes(0, 1).reshape(B, nc * chunk, d)[:, :S]
    return y, h_last


def mamba_forward(p, x, spec: MambaSpec):
    """p: mamba params; x (B, S, D) -> (B, S, D). Training/prefill path."""
    B, S, D = x.shape
    d_in, N = spec.d_inner, spec.d_state

    xu = x @ p["in_proj"]                              # (B, S, 2*d_in)
    xs, z = jnp.split(xu, 2, axis=-1)
    # causal depthwise conv over time
    w = p["conv_w"]                                    # (d_conv, d_in)
    xpad = jnp.pad(xs, ((0, 0), (spec.d_conv - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S] * w[i] for i in range(spec.d_conv))
    xc = jax.nn.silu(xc + p["conv_b"])

    # input-dependent Δ, B, C
    dbc = xc @ p["x_proj"]                             # (B,S,rank+2N)
    dt, Bm, Cm = jnp.split(dbc, [spec.rank, spec.rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (B,S,d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (d_in, N)

    h0 = jnp.zeros((B, d_in, N), dtype=jnp.float32)
    y, _ = _ssm_scan_project(
        dt.astype(jnp.float32), xc.astype(jnp.float32),
        Bm.astype(jnp.float32), Cm.astype(jnp.float32), A, h0, spec.chunk)
    y = y.astype(x.dtype) + xc * p["D"]
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_decode_step(p, x, state, spec: MambaSpec):
    """x (B, 1, D); state {conv (B, d_conv-1, d_in), ssm (B, d_in, N)}."""
    B = x.shape[0]
    d_in, N = spec.d_inner, spec.d_state
    xu = x[:, 0] @ p["in_proj"]
    xs, z = jnp.split(xu, 2, axis=-1)                  # (B, d_in)
    win = jnp.concatenate([state["conv"], xs[:, None]], axis=1)
    w = p["conv_w"]
    xc = (win * w[None]).sum(axis=1)
    xc = jax.nn.silu(xc + p["conv_b"])

    dbc = xc @ p["x_proj"]
    dt, Bm, Cm = jnp.split(dbc, [spec.rank, spec.rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * A)          # (B,d_in,N)
    db = (dt.astype(jnp.float32) * xc.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    h = state["ssm"] * da + db
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32))
    y = y.astype(x.dtype) + xc * p["D"]
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": win[:, 1:], "ssm": h}


def init_mamba_state(batch: int, spec: MambaSpec, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, spec.d_inner), dtype),
        "ssm": jnp.zeros((batch, spec.d_inner, spec.d_state), jnp.float32),
    }
