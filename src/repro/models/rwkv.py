"""RWKV-6 "Finch" block: data-dependent-decay linear attention (attn-free).

Faithful core: token-shift with data-dependent lerp (ddlerp) producing
r/k/v/g/w, per-channel data-dependent decay w_t = exp(-exp(·)), bonus u for
the current token, and the WKV state recurrence per head

    out_t = r_t · (S_{t-1} + u ⊙ kᵀ_t v_t)
    S_t   = diag(w_t) S_{t-1} + kᵀ_t v_t

Train path scans over time in chunks (sequential over chunks, unrolled
matmuls within); decode is O(1) with the (H, hd, hd) state + last-token
shift state. Channel-mix is the RWKV squared-ReLU FFN with token shift.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RWKVSpec(NamedTuple):
    d_model: int
    head_dim: int = 64
    lora_mix: int = 32
    lora_decay: int = 64
    chunk: int = 128

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def _ddlerp(x, x_prev, mu_base, w1, w2):
    """RWKV6 data-dependent lerp producing the 5 mixed inputs (r,k,v,g,w).

    x, x_prev (B,S,D); mu_base (6,D) [0 = token-shift trunk, 1..5 = r,k,v,
    g,w]; w1 (D, 5*L); w2 (5,L,D) -> (5, B, S, D)
    """
    B, S, D = x.shape
    dx = x_prev - x
    xx = x + dx * mu_base[0]
    a = jnp.tanh(xx @ w1).reshape(B, S, 5, -1)          # (B,S,5,L)
    mods = jnp.einsum("bsfl,fld->fbsd", a, w2)          # (5,B,S,D)
    mus = mu_base[1:, None, None, :]                    # (5,1,1,D)
    return x[None] + dx[None] * (mus + mods)


def _wkv_scan_sequential(r, k, v, w, u, chunk: int, s0):
    """Step-by-step WKV reference. r/k/v (B,S,H,hd), w decay in (0,1),
    u (H,hd). s0 (B,H,hd,hd). Returns (out (B,S,H,hd), s_last)."""
    B, S, H, hd = r.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S

    def padt(x, cval=0.0):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=cval)

    r, k, v, w = padt(r), padt(k), padt(v), padt(w, 1.0)

    def to_chunks(x):
        return x.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    def chunk_step(s, xs):
        rb, kb, vb, wb = xs                             # (B, chunk, H, hd)

        def t_step(s, xt):
            rt, kt, vt, wt = xt                         # (B, H, hd)
            kv = kt[..., :, None] * vt[..., None, :]    # (B,H,hd,hd)
            out = jnp.einsum("bhij,bhi->bhj", s + u[..., :, None] * kv, rt)
            s_new = wt[..., :, None] * s + kv
            return s_new, out

        s, outs = jax.lax.scan(
            t_step, s,
            (rb.transpose(1, 0, 2, 3), kb.transpose(1, 0, 2, 3),
             vb.transpose(1, 0, 2, 3), wb.transpose(1, 0, 2, 3)))
        return s, outs.transpose(1, 0, 2, 3)            # (B, chunk, H, hd)

    s_last, outs = jax.lax.scan(chunk_step, s0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, hd)[:, :S]
    return out, s_last


def _wkv_scan(r, k, v, w, u, chunk: int, s0, *, logw=None):
    """Chunked-parallel WKV (beyond-paper perf: EXPERIMENTS.md §Perf
    iteration 1). Exactly equivalent to the sequential recurrence:

        out_t = r_t · (S_{t-1} + u ⊙ k_tᵀ v_t);  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t

    Within a chunk of T tokens, with c_t = Σ_{τ<=t} log w_τ (c_0 = 0):

        A[t,j] = Σ_i r_t[i] k_j[i] exp(c_{t-1}[i] − c_j[i])   (j < t)
        A[t,t] = Σ_i r_t[i] u[i] k_t[i]
        out    = A @ V + (r ⊙ exp(c_{t-1})) @ S_0
        S_T    = diag(exp(c_T)) S_0 + (k ⊙ exp(c_T − c_j))ᵀ @ V

    Every exponent is a sum of log-decays over a FORWARD range, hence <= 0:
    numerically stable with no clamping, unlike the 1/P factored matmul
    form. The per-step (hd × hd) state is read/written once per CHUNK
    instead of once per token — the T-fold HBM-traffic reduction that
    turns rwkv6 training from pathologically memory-bound into
    compute-balanced — and the intra-chunk work is (T,T)@(T,hd) MXU
    matmuls instead of VPU outer products.
    """
    B, S, H, hd = r.shape
    T = min(chunk, S)
    nc = -(-S // T)
    pad = nc * T - S

    def padt(x, cval=0.0):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=cval)

    if logw is None:
        logw = jnp.log(jnp.maximum(w, 1e-38))
    r, k, v, logw = padt(r), padt(k), padt(v), padt(logw)  # pad logw=0: w=1

    def to_chunks(x):
        return x.reshape(B, nc, T, H, hd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))
    tri = jnp.tril(jnp.ones((T, T), dtype=bool), k=-1)

    def chunk_step(s, xs):
        rb, kb, vb, lw = xs                             # (B, T, H, hd)
        cw = jnp.cumsum(lw, axis=1)                     # c_t (inclusive)
        cprev = cw - lw                                 # c_{t-1}
        # pairwise decay factors exp(c_{t-1}[t] - c[j]) — fused into the
        # reduction over i (never materialized at (B,T,T,H,hd) on TPU)
        decay = jnp.exp(cprev[:, :, None] - cw[:, None])   # (B,T,T,H,hd)
        A = jnp.einsum("bthi,bjhi,btjhi->bhtj", rb, kb, decay)
        A = jnp.where(tri[None, None], A, 0.0)
        diag = jnp.einsum("bthi,hi,bthi->bht", rb, u, kb)   # (B,H,T)
        A = A + (jnp.eye(T, dtype=A.dtype)[None, None]
                 * diag[:, :, :, None])
        out = jnp.einsum("bhtj,bjho->btho", A, vb)
        out = out + jnp.einsum("bthi,bhio->btho",
                               rb * jnp.exp(cprev), s)
        c_T = cw[:, -1]                                 # (B, H, hd)
        kp = kb * jnp.exp(c_T[:, None] - cw)
        s_new = (jnp.exp(c_T)[..., None] * s
                 + jnp.einsum("bjhi,bjho->bhio", kp, vb))
        return s_new, out

    s_last, outs = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nc * T, H, hd)[:, :S]
    return out, s_last


def _group_norm(x, scale, n_heads, eps=1e-5):
    """Per-head group norm on (B, S, D) laid out as heads*hd."""
    B, S, D = x.shape
    xh = x.reshape(B, S, n_heads, -1).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(B, S, D) * scale).astype(x.dtype)


def _time_mix_core(p, x, x_prev, spec: RWKVSpec, s0):
    """Shared by train (full seq) and decode (S == 1 with carried x_prev)."""
    B, S, D = x.shape
    H, hd = spec.num_heads, spec.head_dim
    mixed = _ddlerp(x, x_prev, p["tm_mu"], p["tm_w1"], p["tm_w2"])
    xr, xk, xv, xg, xw = mixed
    r = (xr @ p["wr"]).reshape(B, S, H, hd)
    k = (xk @ p["wk"]).reshape(B, S, H, hd)
    v = (xv @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    dec = p["w0"] + jnp.tanh(xw @ p["dec_w1"]) @ p["dec_w2"]
    logw = (-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, H, hd)
    out, s_last = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), None, p["u"], spec.chunk,
                            s0, logw=logw)
    out = out.reshape(B, S, D).astype(x.dtype)
    out = _group_norm(out, p["ln_x"], H) * g
    return out @ p["wo"], s_last


def time_mix(p, x, spec: RWKVSpec):
    """Training path. x (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    s0 = jnp.zeros((B, spec.num_heads, spec.head_dim, spec.head_dim),
                   jnp.float32)
    out, _ = _time_mix_core(p, x, x_prev, spec, s0)
    return out


def channel_mix(p, x, x_prev):
    """RWKV FFN with token shift; squared ReLU."""
    dx = x_prev - x
    xk = x + dx * p["cm_mu_k"]
    xr = x + dx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (k @ p["cv"])


def channel_mix_train(p, x):
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return channel_mix(p, x, x_prev)


def time_mix_decode(p, x, state, spec: RWKVSpec):
    """x (B,1,D); state {wkv (B,H,hd,hd), shift (B,D)}."""
    x_prev = state["shift"][:, None]
    out, s_last = _time_mix_core(p, x, x_prev, spec, state["wkv"])
    return out, {"wkv": s_last, "shift": x[:, 0]}


def channel_mix_decode(p, x, state):
    """x (B,1,D); state {shift (B,D)}."""
    out = channel_mix(p, x, state["shift"][:, None])
    return out, {"shift": x[:, 0]}


def init_rwkv_state(batch: int, spec: RWKVSpec, dtype=jnp.bfloat16):
    return {
        "wkv": jnp.zeros((batch, spec.num_heads, spec.head_dim,
                          spec.head_dim), jnp.float32),
        "tm_shift": jnp.zeros((batch, spec.d_model), dtype),
        "cm_shift": jnp.zeros((batch, spec.d_model), dtype),
    }
