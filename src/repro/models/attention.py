"""Attention: GQA / MLA, full + sliding-window, train and cached decode.

Training/prefill attention is chunked online-softmax (flash-style in XLA
ops): query chunks in an outer scan, key/value chunks in an inner scan with
running (max, sum, acc) — the (S, S) logits matrix is never materialized,
which is what lets prefill_32k lower within HBM on the production mesh.
Sliding-window layers use a *banded* inner scan (only the window-overlapping
KV chunks are visited via dynamic_slice), so SWA compute scales with
S·window, not S².

Decode attends one query token against a (possibly ring-buffered) KV cache;
for long-context decode the cache's sequence dim may be sharded over the
mesh ``data`` axis (auto-SPMD handles the distributed softmax combine —
flash-decoding's split-S scheme, derived by XLA from the sharding).

Head layout: q heads (H) are sharded over ``model``; GQA K/V heads (often 8
< model-axis size) stay replicated — XLA broadcasts them once per step.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, shard, softcap

NEG_INF = -2.0e38


def spec_rope(x, positions, spec):
    """Apply the spec's rope policy to (..., S, H, hd) tensors."""
    if not spec.use_rope:
        return x
    if spec.rope_dims:
        keep, rot = x[..., : -spec.rope_dims], x[..., -spec.rope_dims:]
        return jnp.concatenate(
            [keep, apply_rope(rot, positions, spec.rope_theta)], axis=-1)
    return apply_rope(x, positions, spec.rope_theta)


class AttnSpec(NamedTuple):
    num_heads: int
    num_kv_heads: int
    head_dim: int
    window: Optional[int] = None     # None = full; int = sliding window
    causal: bool = True
    attn_softcap: float = 0.0
    rope_theta: float = 1e4
    q_chunk: int = 512
    kv_chunk: int = 512
    scale: Optional[float] = None    # default hd^-0.5
    use_rope: bool = True            # False: encoder / cross-attention
    rope_dims: int = 0               # >0: rotate only the LAST rope_dims
                                     # (MLA: nope dims stay unrotated)
    probs_bf16: bool = False         # cast softmax probs to bf16 for PV


def _scale(spec: AttnSpec) -> float:
    return spec.scale if spec.scale is not None else spec.head_dim ** -0.5


def _chunk_scores(q, k, spec: AttnSpec):
    """q (B, qc, H, hd), k (B, kc, KV, hd) -> logits (B, H, qc, kc) f32."""
    B, qc, H, hd = q.shape
    kv = k.shape[2]
    g = H // kv
    qg = q.reshape(B, qc, kv, g, hd)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg.astype(jnp.float32),
                   k.astype(jnp.float32), preferred_element_type=jnp.float32)
    s = s * _scale(spec)
    if spec.attn_softcap:
        s = softcap(s, spec.attn_softcap)
    return s.reshape(B, H, qc, k.shape[1])


def _chunk_out(p, v, B, H, qc, *, probs_bf16: bool = False):
    """p (B, H, qc, kc) f32, v (B, kc, KV, hd) -> (B, qc, H, hd) f32."""
    kv = v.shape[2]
    g = H // kv
    pk = p.reshape(B, kv, g, qc, v.shape[1])
    if probs_bf16:
        # beyond-paper memory opt: PV einsum reads bf16 probs/values,
        # accumulates f32 (halves the probability-matrix traffic)
        o = jnp.einsum("bkgqc,bckh->bqkgh", pk.astype(jnp.bfloat16),
                       v.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    else:
        o = jnp.einsum("bkgqc,bckh->bqkgh", pk, v.astype(jnp.float32))
    return o.reshape(B, qc, H, -1)


def chunked_attention(q, k, v, spec: AttnSpec,
                      q_positions=None, kv_positions=None):
    """Flash-style attention. q (B,S,H,hd), k/v (B,T,KV,hd) -> (B,S,H,hd).

    Causal masking uses absolute positions (defaults to arange) so the same
    code serves training (S == T) and chunked prefill. Sliding-window specs
    visit only ceil(window/kv_chunk)+1 KV chunks per query chunk.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    qc = min(spec.q_chunk, S)
    kc = min(spec.kv_chunk, T)
    # pad sequence dims to chunk multiples
    Sp = -(-S // qc) * qc
    Tp = -(-T // kc) * kc
    if q_positions is None:
        q_positions = jnp.arange(S)[None, :].repeat(B, 0)
    if kv_positions is None:
        kv_positions = jnp.arange(T)[None, :].repeat(B, 0)
    qpad, kpad = Sp - S, Tp - T
    q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, qpad)), constant_values=0)
    kpos = jnp.pad(kv_positions, ((0, 0), (0, kpad)),
                   constant_values=np.iinfo(np.int32).max // 2)
    n_q, n_k = Sp // qc, Tp // kc

    banded = spec.window is not None and spec.causal and S == T
    if banded:
        assert qc == kc, "banded SWA requires equal q/kv chunk sizes"
        w_chunks = -(-spec.window // kc)  # banded: visit w_chunks+1 chunks
        n_visit = min(w_chunks + 1, n_k)
    else:
        n_visit = n_k

    def q_block(carry, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(qpos, qi * qc, qc, axis=1)
        qb = spec_rope(qb, qp, spec)

        def kv_block(acc, r):
            m, l, o = acc
            if banded:
                kj = jnp.maximum(qi - r, 0)  # banded, walking backwards
                visit_ok = qi - r >= 0       # clamp duplicates masked below
            else:
                kj = r
                visit_ok = jnp.bool_(True)
            kb = jax.lax.dynamic_slice_in_dim(k, kj * kc, kc, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kpos, kj * kc, kc, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kj * kc, kc, axis=1)
            kb = spec_rope(kb, kp, spec)
            s = _chunk_scores(qb, kb, spec)             # (B,H,qc,kc)
            mask = jnp.ones((B, qc, kc), dtype=bool)
            if spec.causal:
                mask &= qp[:, :, None] >= kp[:, None, :]
            if spec.window is not None:
                mask &= qp[:, :, None] - kp[:, None, :] < spec.window
            mask &= (kp < np.iinfo(np.int32).max // 4)[:, None, :]
            mask &= visit_ok
            s = jnp.where(mask[:, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[:, None], p, 0.0)
            corr = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_safe)
            corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
            l_new = l * corr + p.sum(axis=-1)
            o_new = (o * corr[..., None]
                     + _chunk_out(p, vb, B, H, qc,
                                  probs_bf16=spec.probs_bf16
                                  ).transpose(0, 2, 1, 3))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, qc), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, H, qc), dtype=jnp.float32)
        o0 = jnp.zeros((B, H, qc, hd), dtype=jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0),
                                    jnp.arange(n_visit))
        l = jnp.maximum(l, 1e-30)
        out = (o / l[..., None]).transpose(0, 2, 1, 3)  # (B,qc,H,hd)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(n_q))
    # outs (n_q, B, qc, H, hd) -> (B, S, H, hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, hd)[:, :S]
    return shard(out, None, None, "model", None)


def masked_decode_attention(q, k_cache, v_cache, mask, spec: AttnSpec):
    """Cache attention with a full per-query mask: q (B,T,H,hd) (rope
    already applied); k_cache/v_cache (B,C,KV,hd) (rope applied at
    insert); mask (B,T,C) bool (causal ∧ valid ∧ window, caller-built).
    -> (B,T,H,hd).

    The exact score→mask→softmax→PV composition of
    :func:`decode_attention` generalized to T query tokens — at T == 1
    with ``mask = valid[:, None, :]`` it is the same computation, which is
    what keeps the serving engine's chunked prefill and batched decode
    paths bit-identical to the dense one-token decode loop.
    """
    B, T, H, hd = q.shape
    s = _chunk_scores(q, k_cache, spec)                 # (B,H,T,C)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _chunk_out(p, v_cache, B, H, T)                 # (B,T,H,hd)
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid_mask, spec: AttnSpec):
    """One-token attention. q (B,1,H,hd) (rope already applied);
    k_cache/v_cache (B,C,KV,hd) (rope applied at insert);
    valid_mask (B,C) bool. -> (B,1,H,hd)."""
    return masked_decode_attention(q, k_cache, v_cache,
                                   valid_mask[:, None, :], spec)
