"""Mixture-of-Experts FFN: top-k routing with static capacity, expert-parallel.

Dispatch is gather/scatter based (GShard-style): tokens are placed into an
(E, C, D) buffer by expert id + position-in-expert (cumsum of the one-hot
assignment matrix), experts run as one batched einsum over the expert dim
(sharded over ``model`` — expert parallelism), and outputs are gathered back
with the router combine weights. Tokens beyond capacity are dropped (their
combine weight contribution is zero), matching standard capacity-factor
semantics.

Supports DeepSeek-style shared experts (always-on dense branch) and returns
the switch load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import gated_mlp, shard


class MoESpec(NamedTuple):
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    act: str = "silu"


def capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(n_tokens * spec.top_k * spec.capacity_factor / spec.num_experts)
    return max(8, -(-c // 8) * 8)


def moe_ffn(p, x, spec: MoESpec):
    """p: {router (D,E), wg/wu (E,D,F), wo (E,F,D) [, shared mlp leaves]};
    x (T, D) -> (y (T, D), aux_loss scalar f32)."""
    T, D = x.shape
    E, k = spec.num_experts, spec.top_k
    C = capacity(T, spec)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)              # (T, E)
    topw, topi = jax.lax.top_k(probs, k)                 # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue
    flat_e = topi.reshape(-1)                            # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                 # (T*k, E)
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C

    # scatter tokens into the (E*C, D) dispatch buffer
    dest = jnp.where(keep, flat_e * C + pos_in_e, E * C)  # OOB row dropped
    x_rep = jnp.repeat(x, k, axis=0)                      # (T*k, D)
    buf = jnp.zeros((E * C + 1, D), dtype=x.dtype)
    buf = buf.at[dest].add(x_rep)
    # expert sharding (E over `model` when divisible, via shard()'s
    # divisibility guard; otherwise XLA propagates intra-expert TP from the
    # expert weight shardings)
    buf = buf[: E * C].reshape(E, C, D)
    buf = shard(buf, "model", None, None)

    # batched expert FFN (expert-parallel einsum over E)
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[spec.act](g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_buf = shard(out_buf, "model", None, None)

    # gather back + combine
    flat_out = out_buf.reshape(E * C, D)
    safe = jnp.where(keep, flat_e * C + pos_in_e, 0)
    tok_out = flat_out[safe] * keep[:, None].astype(x.dtype)
    w = topw.reshape(-1)[:, None].astype(x.dtype)
    y = (tok_out * w).reshape(T, k, D).sum(axis=1)

    # shared experts: always-on dense branch (DeepSeek-V2)
    if spec.num_shared > 0:
        y = y + gated_mlp(
            {"wi_gate": p["shared_wg"], "wi_up": p["shared_wu"],
             "wo": p["shared_wo"]}, x, act=spec.act)

    # switch load-balance loss: E * Σ_e f_e · p_e
    frac_tokens = (onehot * keep[:, None]).astype(jnp.float32).mean(0) * k
    mean_prob = probs.mean(0)
    aux = spec.router_aux_weight * E * jnp.sum(frac_tokens * mean_prob)
    return y, aux
