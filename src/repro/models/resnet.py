"""Small CIFAR-scale ResNet (paper §5.1 model family) in pure JAX.

Deviation from the paper noted in DESIGN.md: BatchNorm is replaced by
GroupNorm so the model stays a pure function of (params, batch) — the
quantization comparison is unaffected (the paper broadcasts BN statistics
from worker 0, i.e. they are not part of the gradient exchange either).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    num_classes: int = 10
    width: int = 16
    blocks_per_stage: int = 3      # 3 -> ResNet-20 family
    groups: int = 8


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) / jnp.sqrt(fan)


def conv(x, w, stride=1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def group_norm(x, scale, bias, groups):
    n, h, w, c = x.shape
    xg = x.reshape(n, h, w, groups, c // groups).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = ((xg - mu) ** 2).mean(axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mu) * lax.rsqrt(var + 1e-5)).reshape(n, h, w, c)
    return (xn * scale + bias).astype(x.dtype)


def init_resnet(key, cfg: ResNetConfig):
    ks = iter(jax.random.split(key, 64))
    w = cfg.width
    params = {"stem": {"w": _conv_init(next(ks), 3, 3, 3, w),
                       "gn_s": jnp.ones((w,)), "gn_b": jnp.zeros((w,))}}
    stages = []
    cin = w
    for s, cout in enumerate((w, 2 * w, 4 * w)):
        blocks = []
        for b in range(cfg.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = {
                "w1": _conv_init(next(ks), 3, 3, cin, cout),
                "gn1_s": jnp.ones((cout,)), "gn1_b": jnp.zeros((cout,)),
                "w2": _conv_init(next(ks), 3, 3, cout, cout),
                "gn2_s": jnp.ones((cout,)), "gn2_b": jnp.zeros((cout,)),
            }
            if stride != 1 or cin != cout:
                blk["proj"] = _conv_init(next(ks), 1, 1, cin, cout)
            blocks.append(blk)
            cin = cout
        stages.append(blocks)
    params["stages"] = stages
    params["head"] = {"w": jax.random.normal(next(ks), (cin,
                                                        cfg.num_classes))
                      / jnp.sqrt(cin),
                      "b": jnp.zeros((cfg.num_classes,))}
    return params


def resnet_logits(params, images, cfg: ResNetConfig):
    x = conv(images, params["stem"]["w"])
    x = jax.nn.relu(group_norm(x, params["stem"]["gn_s"],
                               params["stem"]["gn_b"], cfg.groups))
    for s_i, blocks in enumerate(params["stages"]):
        for b_i, blk in enumerate(blocks):
            stride = 2 if (s_i > 0 and b_i == 0) else 1
            h = conv(x, blk["w1"], stride)
            h = jax.nn.relu(group_norm(h, blk["gn1_s"], blk["gn1_b"],
                                       cfg.groups))
            h = conv(h, blk["w2"])
            h = group_norm(h, blk["gn2_s"], blk["gn2_b"], cfg.groups)
            sc = x if "proj" not in blk else conv(x, blk["proj"], stride)
            x = jax.nn.relu(h + sc)
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def resnet_loss(params, batch, cfg: ResNetConfig):
    lg = resnet_logits(params, batch["images"], cfg)
    onehot = jax.nn.one_hot(batch["labels"], cfg.num_classes)
    return -(jax.nn.log_softmax(lg) * onehot).sum(-1).mean()
