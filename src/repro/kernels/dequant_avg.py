"""Pallas TPU kernel: fused decode + average over L workers' payloads.

The "server" side of Algorithm 2 (and the combine stage of the quantized
reduce-scatter): decode L quantized copies of the same gradient slice and
average them. Decoding is a level-table lookup; formulated gather-free as a
one-hot accumulate over the s levels. The grid iterates (row-block, worker)
with the output block revisited across the worker axis, accumulating in
place — each worker's payload is read from HBM exactly once and the f32
output is written once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
LEVEL_PAD = 32


def _dequant_avg_kernel(s: int, L: int, idx_ref, lv_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                      # (1, R, d) int32, worker l
    lv = lv_ref[...]                        # (1, R, LEVEL_PAD)
    val = jnp.zeros(idx.shape, dtype=jnp.float32)
    for j in range(s):                      # static unroll, gather-free decode
        val = val + (idx == j).astype(jnp.float32) * lv[:, :, j][:, :, None]
    out_ref[...] += (val * (1.0 / L))[0]


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def dequant_avg(idx: jnp.ndarray, levels: jnp.ndarray, *, s: int,
                interpret: bool = True) -> jnp.ndarray:
    """(L, nb, d) int32 indices + (L, nb, s) levels -> (nb, d) f32 mean."""
    L, nb, d = idx.shape
    assert levels.shape == (L, nb, s)
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    pad = rows - nb
    ip = jnp.pad(idx, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(levels.astype(jnp.float32),
                 ((0, 0), (0, pad), (0, LEVEL_PAD - s)))
    grid = (rows // ROW_BLOCK, L)
    out = pl.pallas_call(
        functools.partial(_dequant_avg_kernel, s, L),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ROW_BLOCK, d), lambda i, l: (l, i, 0)),
            pl.BlockSpec((1, ROW_BLOCK, LEVEL_PAD), lambda i, l: (l, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i, l: (i, 0)),
        interpret=interpret,
    )(ip, lp)
    return out[:nb]
