"""Pallas TPU kernel: fused multi-level random-rounding quantization.

This is the per-step hot loop of Algorithm 2: every gradient element is
mapped to a level index (interval search + unbiased random rounding, Eq. 7).
On GPU this is a searchsorted + bernoulli; the TPU-native formulation here is
branch/gather-free — the small level table (s ≤ 17, padded to a 32-lane tile)
is kept resident in VMEM and the interval search is an unrolled
compare-accumulate over levels, which maps onto the VPU as dense vector ops.

Tiling: grid over row-blocks of buckets; each step processes an
(ROW_BLOCK, d) value tile (d = bucket size, a multiple of 128 in practice)
plus the matching (ROW_BLOCK, LEVEL_PAD) level tile. Random bits are
precomputed threefry uint32 (bit-identical between interpret mode, TPU, and
the jnp oracle in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
LEVEL_PAD = 32  # level-table tile width (s <= 17 always)
_INV_U32 = float(1.0 / 4294967296.0)


def _quant_rr_kernel(s: int, v_ref, lv_ref, bits_ref, idx_ref):
    v = v_ref[...].astype(jnp.float32)          # (R, d)
    lv = lv_ref[...].astype(jnp.float32)        # (R, LEVEL_PAD)
    u = bits_ref[...].astype(jnp.float32) * _INV_U32

    # interval search: k = (#levels <= v) - 1, clipped to [0, s-2]
    k = jnp.zeros(v.shape, dtype=jnp.int32)
    for j in range(s):                           # static unroll, s <= 17
        lj = lv[:, j][:, None]
        k = k + (v >= lj).astype(jnp.int32)
    k = jnp.clip(k - 1, 0, s - 2)
    # lo = levels[k], hi = levels[k+1] via one-hot select (gather-free)
    lo = jnp.zeros(v.shape, dtype=jnp.float32)
    hi = jnp.zeros(v.shape, dtype=jnp.float32)
    for j in range(s - 1):                       # static unroll
        sel = (k == j).astype(jnp.float32)
        lo = lo + sel * lv[:, j][:, None]
        hi = hi + sel * lv[:, j + 1][:, None]

    vc = jnp.clip(v, lo, hi)
    width = hi - lo
    p_up = jnp.where(width > 0, (vc - lo) / jnp.where(width > 0, width, 1.0),
                     0.0)
    up = (u < p_up).astype(jnp.int32)
    idx_ref[...] = k + up


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def quant_rr(v: jnp.ndarray, levels: jnp.ndarray, bits: jnp.ndarray,
             *, s: int, interpret: bool = True) -> jnp.ndarray:
    """(nb, d) values + (nb, s) levels + (nb, d) uint32 bits -> (nb, d) int32.

    Rows are padded to ROW_BLOCK; the level table is padded to LEVEL_PAD
    lanes (padding lanes replicate the top level so the unrolled compare
    never reads garbage).
    """
    nb, d = v.shape
    assert levels.shape == (nb, s) and bits.shape == (nb, d)
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    pad_r = rows - nb
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad_r), (0, 0)))
    bp = jnp.pad(bits, ((0, pad_r), (0, 0)))
    lvp = jnp.pad(levels.astype(jnp.float32), ((0, pad_r), (0, LEVEL_PAD - s)),
                  mode="edge")
    grid = (rows // ROW_BLOCK,)
    out = pl.pallas_call(
        functools.partial(_quant_rr_kernel, s),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, LEVEL_PAD), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        interpret=interpret,
    )(vp, lvp, bp)
    return out[:nb]
