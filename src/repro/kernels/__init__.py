# Pallas kernel package for the wire-format hot path.
#
#   ops.py            env-flag resolution + jit'd public wrappers
#   ref.py            pure-jnp oracles (bit-exact semantics for every kernel)
#   fused_encode.py   ONE-pass clip->round->pack (encode_fused) and
#                     clip->round->decode (qdq_fused, the EF residual path)
#   fused_bingrad.py  fully-fused BinGrad-b (b0 search + levels + 1-bit pack)
#   fused_decode.py   ONE-pass unpack->dequant->average / per-worker decode
#   quant_rr.py, bitpack.py, dequant_avg.py, bingrad.py
#                     the multi-pass kernels (PR 1-4 pipeline) — kept as the
#                     parity baseline and for callers that need bare stages
#
# Perf is tracked by benchmarks/kernel_bench.py (BENCH_kernels.json); CI
# gates regressions against benchmarks/BENCH_kernels_baseline.json.
