"""Pallas TPU kernel: fully-fused BinGrad-b ENCODE (b₀ search + pack).

BinGrad-b's level fit is moments-only — b₀ = mean(G), then the
conditional means below/above b₀ (Eq. 17), optionally iterated to the
2-means fixed point — so unlike ORQ (which needs a per-bucket sort) the
WHOLE scheme fuses: one VMEM-tiled sweep computes the b₀ search, the
(b₋₁, b₁) level table, the threshold assignment at the level midpoint,
and the 1-bit pack. The gradient tile is read from HBM once; the only
writes are the packed (nb, nw) uint32 words and the tiny (nb, 2) level
table that rides the wire next to them.

This replaces what used to be ≥4 sweeps (masked moments, two conditional
reductions, threshold compare, pack) each materializing (nb, d)
intermediates. Numerics mirror ``levels.bingrad_b_levels`` +
``rounding.threshold_round`` term for term (interpret mode is
bit-identical to the jnp oracle ``ref.encode_bingrad_fused_ref``).

Scheduling follows ``fused_encode``: the optional σ-clip REDUCTION runs
once outside the kernel (the (nb, 1) c·σ limit rides in as a side
input), and the row block adapts so small sweeps run as one grid step
within the VMEM tile budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_encode import _pack_words, clip_limit, row_block

ROW_BLOCK = 8  # row-block quantum (see fused_encode.row_block)
_EPW = 32      # 1 bit per element -> 32 elements per uint32 word


def _bingrad_encode_kernel(lloyd_iters, has_lim, *refs):
    if has_lim:
        v_ref, m_ref, lim_ref, w_ref, lv_ref = refs
    else:
        v_ref, m_ref, w_ref, lv_ref = refs
    v = v_ref[...].astype(jnp.float32)        # (R, d)
    m = m_ref[...].astype(jnp.float32)        # (R, d) validity
    if has_lim:
        lim = lim_ref[...]
        v = jnp.clip(v, -lim, lim)

    cnt = jnp.maximum(m.sum(axis=-1, keepdims=True), 1.0)
    b0 = (v * m).sum(axis=-1, keepdims=True) / cnt      # paper: b₀ = mean(G)

    def cond_means(b0):
        lo = m * (v < b0)
        hi = m * (v >= b0)
        cl = lo.sum(axis=-1, keepdims=True)
        ch = hi.sum(axis=-1, keepdims=True)
        bm = (v * lo).sum(axis=-1, keepdims=True) / jnp.maximum(cl, 1.0)
        bp = (v * hi).sum(axis=-1, keepdims=True) / jnp.maximum(ch, 1.0)
        # empty side: collapse to the other side's mean (degenerate bucket)
        bm = jnp.where(cl > 0, bm, bp)
        bp = jnp.where(ch > 0, bp, bm)
        return bm, bp

    bm, bp = cond_means(b0)
    for _ in range(lloyd_iters):                 # static unroll
        b0 = 0.5 * (bm + bp)
        bm, bp = cond_means(b0)

    thr = 0.5 * (bm + bp)                        # Eq. (17): midpoint rule
    idx = jnp.where(m > 0, (v >= thr).astype(jnp.int32), 0)
    w_ref[...] = _pack_words(idx, 1, _EPW)
    lv_ref[...] = jnp.concatenate([bm, bp], axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("clip_c", "lloyd_iters", "interpret"))
def encode_bingrad_fused(v: jnp.ndarray, mask: jnp.ndarray, *,
                         clip_c: Optional[float] = None,
                         lloyd_iters: int = 0, interpret: bool = True):
    """(nb, d) values + (nb, d) mask -> ((nb, nw) uint32 words,
    (nb, 2) float32 levels), nw = ceil(d / 32). One pallas_call. Columns
    stay at the true bucket width — the moment reductions must sum over
    exactly the elements the jnp oracle sums (``_pack_words`` zero-pads
    the ragged tail in-register)."""
    nb, d = v.shape
    nw = -(-d // _EPW)
    lim = clip_limit(v, mask, clip_c)
    row_bytes = 4 * (3 * d + nw + 2 + (1 if lim is not None else 0))
    rb = row_block(nb, row_bytes)
    rows = -(-nb // rb) * rb
    pr = rows - nb
    vp = jnp.pad(v.astype(jnp.float32), ((0, pr), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.float32), ((0, pr), (0, 0)))
    inputs = [vp, mp]
    in_specs = [
        pl.BlockSpec((rb, d), lambda i: (i, 0)),
        pl.BlockSpec((rb, d), lambda i: (i, 0)),
    ]
    if lim is not None:
        inputs.append(jnp.pad(lim.astype(jnp.float32), ((0, pr), (0, 0))))
        in_specs.append(pl.BlockSpec((rb, 1), lambda i: (i, 0)))
    words, lv = pl.pallas_call(
        functools.partial(_bingrad_encode_kernel, lloyd_iters,
                          lim is not None),
        out_shape=(
            jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 2), jnp.float32),
        ),
        grid=(rows // rb,),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((rb, nw), lambda i: (i, 0)),
            pl.BlockSpec((rb, 2), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(*inputs)
    return words[:nb], lv[:nb]
