"""Pallas TPU kernel: bit-pack level indices into uint32 wire words.

Packs ``epw = 32 // bits`` consecutive indices into each uint32 word via
shift-add (disjoint bit ranges, so addition == OR — avoids any reliance on
integer OR reductions). Unpack is the mirror shift-mask. These run just
before/after the all_to_all so the wire payload is the packed words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _pack_kernel(bits: int, epw: int, idx_ref, out_ref):
    idx = idx_ref[...].astype(jnp.uint32)          # (R, nw*epw)
    r, n = idx.shape
    lanes = idx.reshape(r, n // epw, epw)
    acc = jnp.zeros((r, n // epw), dtype=jnp.uint32)
    for j in range(epw):                            # static unroll
        acc = acc + (lanes[:, :, j] << jnp.uint32(bits * j))
    out_ref[...] = acc


def _unpack_kernel(bits: int, epw: int, w_ref, out_ref):
    w = w_ref[...]                                  # (R, nw)
    mask = jnp.uint32(2 ** bits - 1)
    parts = []
    for j in range(epw):                            # static unroll
        parts.append(((w >> jnp.uint32(bits * j)) & mask).astype(jnp.int32))
    out_ref[...] = jnp.stack(parts, axis=-1).reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack(idx: jnp.ndarray, *, bits: int, interpret: bool = True) -> jnp.ndarray:
    """(nb, d) int32 -> (nb, nw) uint32, nw = ceil(d / (32//bits))."""
    nb, d = idx.shape
    epw = 32 // bits
    nw = -(-d // epw)
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    ip = jnp.pad(idx, ((0, rows - nb), (0, nw * epw - d)))
    out = pl.pallas_call(
        functools.partial(_pack_kernel, bits, epw),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
        grid=(rows // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, nw * epw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, nw), lambda i: (i, 0)),
        interpret=interpret,
    )(ip)
    return out[:nb]


@functools.partial(jax.jit, static_argnames=("bits", "d", "interpret"))
def unpack(words: jnp.ndarray, *, bits: int, d: int,
           interpret: bool = True) -> jnp.ndarray:
    """(nb, nw) uint32 -> (nb, d) int32."""
    nb, nw = words.shape
    epw = 32 // bits
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    wp = jnp.pad(words, ((0, rows - nb), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, bits, epw),
        out_shape=jax.ShapeDtypeStruct((rows, nw * epw), jnp.int32),
        grid=(rows // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, nw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK, nw * epw), lambda i: (i, 0)),
        interpret=interpret,
    )(wp)
    return out[:nb, :d]
