"""Pallas TPU kernel: fused one-pass gradient DECODE.

The wire decode used to be two kernel families with an HBM round-trip in
between: a vmapped ``unpack`` writing full-size (L, nb, d) int32 indices,
then ``dequant_avg`` (or a per-worker dequantize) reading them back. This
module fuses the shift-mask unpack with the gather-free level-table
decode into one VMEM-tiled sweep over the PACKED words — the int32 index
tensor never exists in HBM (a 32/bits traffic shrink on the decode side):

    decode_fused_mean   the 'server' side of Algorithm 2: unpack L
                        workers' payloads, decode, and average, revisiting
                        the output block across the worker grid axis
                        (each payload is read exactly once, the f32 mean
                        written once);
    decode_fused_each   phase 2's deterministic broadcast decode: every
                        worker reconstructs each server's re-quantized
                        chunk -> (L, nb, d) values, no averaging.

Word lane order matches the multi-pass ``bitpack.unpack`` kernel; the
one-hot decode matches ``dequant_avg``, so interpret mode is bit-identical
to both the multi-pass kernels and the jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
LEVEL_PAD = 32


def _unpack_decode(w: jnp.ndarray, lv: jnp.ndarray, s: int, bits: int,
                   epw: int) -> jnp.ndarray:
    """(1, R, nw) uint32 + (1, R, LEVEL_PAD) levels -> (1, R, nw*epw) f32
    decoded values (shift-mask unpack + one-hot level select, all in VMEM)."""
    mask = jnp.uint32(2 ** bits - 1)
    parts = []
    for j in range(epw):                          # static unroll
        parts.append(((w >> jnp.uint32(bits * j)) & mask).astype(jnp.int32))
    idx = jnp.stack(parts, axis=-1).reshape(w.shape[0], w.shape[1], -1)
    val = jnp.zeros(idx.shape, dtype=jnp.float32)
    for j in range(s):                  # static unroll, gather-free decode
        val = val + (idx == j).astype(jnp.float32) * lv[:, :, j][:, :, None]
    return val


def _decode_mean_kernel(s, bits, epw, L, w_ref, lv_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    val = _unpack_decode(w_ref[...], lv_ref[...], s, bits, epw)
    out_ref[...] += (val * (1.0 / L))[0]


def _decode_each_kernel(s, bits, epw, w_ref, lv_ref, out_ref):
    out_ref[...] = _unpack_decode(w_ref[...], lv_ref[...], s, bits, epw)


def _pad3(words, levels, s):
    L, nb, _ = words.shape
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    pad = rows - nb
    wp = jnp.pad(words, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(levels.astype(jnp.float32),
                 ((0, 0), (0, pad), (0, LEVEL_PAD - s)))
    return wp, lp, rows


@functools.partial(jax.jit, static_argnames=("d", "bits", "s", "interpret"))
def decode_fused_mean(words: jnp.ndarray, levels: jnp.ndarray, *, d: int,
                      bits: int, s: int, interpret: bool = True):
    """(L, nb, nw) uint32 + (L, nb, s) levels -> (nb, d) f32 mean values.
    One pallas_call; grid (row-block, worker) accumulating in place."""
    L, nb, nw = words.shape
    assert levels.shape == (L, nb, s), (levels.shape, (L, nb, s))
    epw = 32 // bits
    wp, lp, rows = _pad3(words, levels, s)
    out = pl.pallas_call(
        functools.partial(_decode_mean_kernel, s, bits, epw, L),
        out_shape=jax.ShapeDtypeStruct((rows, nw * epw), jnp.float32),
        grid=(rows // ROW_BLOCK, L),
        in_specs=[
            pl.BlockSpec((1, ROW_BLOCK, nw), lambda i, l: (l, i, 0)),
            pl.BlockSpec((1, ROW_BLOCK, LEVEL_PAD), lambda i, l: (l, i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, nw * epw), lambda i, l: (i, 0)),
        interpret=interpret,
    )(wp, lp)
    return out[:nb, :d]


@functools.partial(jax.jit, static_argnames=("d", "bits", "s", "interpret"))
def decode_fused_each(words: jnp.ndarray, levels: jnp.ndarray, *, d: int,
                      bits: int, s: int, interpret: bool = True):
    """(L, nb, nw) uint32 + (L, nb, s) levels -> (L, nb, d) f32 values
    (no averaging). One pallas_call."""
    L, nb, nw = words.shape
    assert levels.shape == (L, nb, s), (levels.shape, (L, nb, s))
    epw = 32 // bits
    wp, lp, rows = _pad3(words, levels, s)
    out = pl.pallas_call(
        functools.partial(_decode_each_kernel, s, bits, epw),
        out_shape=jax.ShapeDtypeStruct((L, rows, nw * epw), jnp.float32),
        grid=(rows // ROW_BLOCK, L),
        in_specs=[
            pl.BlockSpec((1, ROW_BLOCK, nw), lambda i, l: (l, i, 0)),
            pl.BlockSpec((1, ROW_BLOCK, LEVEL_PAD), lambda i, l: (l, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, ROW_BLOCK, nw * epw),
                               lambda i, l: (l, i, 0)),
        interpret=interpret,
    )(wp, lp)
    return out[:, :nb, :d]
