"""Public wrappers for the Pallas kernels — env-flag resolution + jit'd
dispatch.

Each wrapper is a thin Python dispatcher that resolves the environment
overrides EAGERLY (i.e. at trace time of whatever jit is being built, or
per call when used standalone) and then hands off to a jit'd
implementation — the Pallas kernels are jit'd with static ``bits``/``s``/
``d``/``interpret`` and the pure-jnp reference oracles are jit'd too, so
standalone callers (benchmarks, notebooks) don't re-trace or run eagerly
op-by-op on repeat calls with the same static shapes.

Environment overrides (both read at trace time — set them before the
first jit of a step function; ``tests/test_fused_kernels.py`` pins the
trace-time read) resolve through the central accessor
``repro.utils.env``: ``REPRO_PALLAS_INTERPRET`` (interpret-mode
override; default backend autodetection) and ``REPRO_USE_KERNELS``
(``0`` forces the pure-jnp reference oracle ``ref.py`` for every op —
the CI parity matrix leg).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import bingrad as _bingrad
from repro.kernels import bitpack as _bitpack
from repro.kernels import dequant_avg as _dequant
from repro.kernels import fused_bingrad as _fbin
from repro.kernels import fused_decode as _fdec
from repro.kernels import fused_encode as _fenc
from repro.kernels import fused_kv as _fkv
from repro.kernels import quant_rr as _quant
from repro.kernels import ref as _ref
from repro.utils.env import kernels_enabled  # noqa: F401  (public compat)
from repro.utils.env import pallas_interpret as _interpret


def _use(use_kernels: bool) -> bool:
    return use_kernels and kernels_enabled()


# jit'd reference oracles (static args mirror the kernel wrappers')
_ref_quant_rr = jax.jit(_ref.quant_rr_ref)
_ref_bingrad_pass = jax.jit(_ref.bingrad_pass_ref)
_ref_dequant_avg = jax.jit(_ref.dequant_avg_ref)
_ref_pack = jax.jit(_ref.pack_ref, static_argnums=(1,))
_ref_unpack = jax.jit(_ref.unpack_ref, static_argnums=(1, 2))
_ref_encode_fused = jax.jit(
    _ref.encode_fused_ref, static_argnames=("bits", "clip_c", "mode"))
_ref_qdq_fused = jax.jit(
    _ref.qdq_fused_ref, static_argnames=("clip_c", "mode"))
_ref_encode_bingrad = jax.jit(
    _ref.encode_bingrad_fused_ref, static_argnames=("clip_c", "lloyd_iters"))
_ref_decode_mean = jax.jit(
    _ref.decode_fused_mean_ref, static_argnames=("d", "bits"))
_ref_decode_each = jax.jit(
    _ref.decode_fused_each_ref, static_argnames=("d", "bits"))
_ref_kv_attend = jax.jit(
    _ref.kv_attend_ref,
    static_argnames=("bits", "kv_heads", "scale", "softcap"))


# ---------------------------------------------------------------------------
# multi-pass ops (the PR-1..4 pipeline; kept for parity tests + benchmarks)
# ---------------------------------------------------------------------------

def quant_rr(v, levels, bits, *, use_kernels: bool = True):
    if not _use(use_kernels):
        return _ref_quant_rr(v, levels, bits)
    return _quant.quant_rr(v, levels, bits, s=levels.shape[-1],
                           interpret=_interpret())


def bingrad_pass(v, b0, mask, *, use_kernels: bool = True):
    if not _use(use_kernels):
        return _ref_bingrad_pass(v, b0, mask)
    return _bingrad.bingrad_pass(v, b0, mask, interpret=_interpret())


def dequant_avg(idx, levels, *, use_kernels: bool = True):
    if not _use(use_kernels):
        return _ref_dequant_avg(idx, levels)
    return _dequant.dequant_avg(idx, levels, s=levels.shape[-1],
                                interpret=_interpret())


def pack(idx, bits: int, *, use_kernels: bool = True):
    if not _use(use_kernels):
        return _ref_pack(idx, bits)
    return _bitpack.pack(idx, bits=bits, interpret=_interpret())


def unpack(words, bits: int, d: int, *, use_kernels: bool = True):
    if not _use(use_kernels):
        return _ref_unpack(words, bits, d)
    return _bitpack.unpack(words, bits=bits, d=d, interpret=_interpret())


# ---------------------------------------------------------------------------
# fused one-pass ops (the PR-5 pipeline; wire.py's default path)
# ---------------------------------------------------------------------------

def encode_fused(v, levels, rbits, mask, *, bits: int,
                 clip_c: Optional[float] = None, mode: str = "rr",
                 use_kernels: bool = True):
    """σ-clip + round + mask + bit-pack in ONE pallas_call: (nb, d) values
    -> (nb, nw) uint32 wire words. ``rbits`` is the threefry uint32 stream
    for mode='rr' (None for the deterministic modes)."""
    if not _use(use_kernels):
        return _ref_encode_fused(v, levels, rbits, mask, bits=bits,
                                 clip_c=clip_c, mode=mode)
    return _fenc.encode_fused(v, levels, rbits, mask, bits=bits,
                              s=levels.shape[-1], clip_c=clip_c, mode=mode,
                              interpret=_interpret())


def qdq_fused(v, levels, rbits, mask, *, clip_c: Optional[float] = None,
              mode: str = "rr", use_kernels: bool = True):
    """σ-clip + round + mask + in-register decode in ONE pallas_call:
    (nb, d) values -> (nb, d) dequantized f32 (the error-feedback path)."""
    if not _use(use_kernels):
        return _ref_qdq_fused(v, levels, rbits, mask, clip_c=clip_c,
                              mode=mode)
    return _fenc.qdq_fused(v, levels, rbits, mask, s=levels.shape[-1],
                           clip_c=clip_c, mode=mode, interpret=_interpret())


def encode_bingrad(v, mask, *, clip_c: Optional[float] = None,
                   lloyd_iters: int = 0, use_kernels: bool = True):
    """Fully-fused BinGrad-b: b₀ search + conditional-mean levels +
    threshold + 1-bit pack in ONE pallas_call -> ((nb, nw) words,
    (nb, 2) levels)."""
    if not _use(use_kernels):
        return _ref_encode_bingrad(v, mask, clip_c=clip_c,
                                   lloyd_iters=lloyd_iters)
    return _fbin.encode_bingrad_fused(v, mask, clip_c=clip_c,
                                      lloyd_iters=lloyd_iters,
                                      interpret=_interpret())


def decode_attend(q, kw, klv, vw, vlv, mask, *, bits: int, kv_heads: int,
                  scale: float, softcap: float = 0.0,
                  use_kernels: bool = True):
    """Fused dequant-attention over a quantized KV context in ONE
    pallas_call: q (B, T, H, hd) + packed kw/vw (B, C, nw) + klv/vlv
    (B, C, s) + mask (B, T, C) -> (B, T, H, hd) f32 (the serving engine's
    decode hot path — the dequantized K/V never round-trip HBM)."""
    if not _use(use_kernels):
        return _ref_kv_attend(q, kw, klv, vw, vlv, mask, bits=bits,
                              kv_heads=kv_heads, scale=scale,
                              softcap=softcap)
    return _fkv.decode_attend(q, kw, klv, vw, vlv, mask, bits=bits,
                              kv_heads=kv_heads, scale=scale,
                              softcap=softcap, interpret=_interpret())


def decode_fused_mean(words, levels, d: int, *, bits: int,
                      use_kernels: bool = True):
    """Unpack + dequantize + average L workers' payloads in ONE
    pallas_call: (L, nb, nw) + (L, nb, s) -> (nb, d) f32 mean."""
    if not _use(use_kernels):
        return _ref_decode_mean(words, levels, d=d, bits=bits)
    return _fdec.decode_fused_mean(words, levels, d=d, bits=bits,
                                   s=levels.shape[-1],
                                   interpret=_interpret())


def decode_fused_each(words, levels, d: int, *, bits: int,
                      use_kernels: bool = True):
    """Unpack + dequantize (no averaging) in ONE pallas_call:
    (L, nb, nw) + (L, nb, s) -> (L, nb, d) f32 values."""
    if not _use(use_kernels):
        return _ref_decode_each(words, levels, d=d, bits=bits)
    return _fdec.decode_fused_each(words, levels, d=d, bits=bits,
                                   s=levels.shape[-1],
                                   interpret=_interpret())


@partial(jax.jit, static_argnames=("clip_c",))
def _bucket_stats_impl(bkt, mask, clip_c):
    m = mask.astype(bkt.dtype)
    cnt = jnp.maximum(m.sum(axis=-1, keepdims=True), 1.0)
    mean = (bkt * m).sum(axis=-1, keepdims=True) / cnt
    var = (((bkt - mean) ** 2) * m).sum(axis=-1, keepdims=True) / cnt
    total = jnp.maximum(m.sum(), 1.0)
    # per-bucket variance weighted by valid count: the buffer's variance
    # around its per-bucket means (what the level fit actually sees)
    sigma_sq = (var[:, 0] * m.sum(axis=-1)).sum() / total
    if clip_c is None:
        clip_frac = jnp.zeros((), bkt.dtype)
    else:
        lim = clip_c * jnp.sqrt(var)
        clip_frac = ((jnp.abs(bkt) > lim) * m).sum() / total
    l2_sq = ((bkt * m) ** 2).sum()
    return jnp.stack([sigma_sq, clip_frac, l2_sq]).astype(jnp.float32)


def bucket_stats(bkt, mask, *, clip_c: Optional[float] = None):
    """(nb, d) buckets + validity mask -> (3,) f32 ``[sigma_sq,
    clip_frac, l2_sq]``: the count-weighted mean per-bucket variance,
    the fraction of valid elements a ``clip_c``-sigma clip would clamp,
    and the buffer's squared norm. The cheap statistics feed of the
    adaptive bit-budget controller (``core/policy.BitBudgetController``)
    — reductions only, no pallas_call (XLA fuses them into the step's
    existing HBM pass), so there is no kernel/oracle split to keep in
    parity."""
    return _bucket_stats_impl(bkt, mask, clip_c)
