"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; interpret
mode executes the kernel bodies in Python for correctness validation) and to
False on a real TPU backend. The ``REPRO_PALLAS_INTERPRET`` environment
variable overrides the backend autodetection in either direction
(``1``/``true``/``yes``/``on`` forces interpret mode — e.g. to debug kernel
numerics ON a TPU — and ``0``/``false``/``no``/``off`` forces compiled
kernels); it is read at trace time, so set it before the first jit of a
step function. The wrappers keep kernel use optional: the ``use_kernels``
flag lets the comm layer fall back to the pure-jnp reference path (also the
numerics oracle) — both are tested equal.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import bingrad as _bingrad
from repro.kernels import bitpack as _bitpack
from repro.kernels import dequant_avg as _dequant
from repro.kernels import quant_rr as _quant
from repro.kernels import ref as _ref

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    if env:
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET={env!r}: expected one of "
            f"{_TRUE + _FALSE} (or unset for backend autodetection)")
    return jax.default_backend() != "tpu"


def quant_rr(v, levels, bits, *, use_kernels: bool = True):
    if not use_kernels:
        return _ref.quant_rr_ref(v, levels, bits)
    return _quant.quant_rr(v, levels, bits, s=levels.shape[-1],
                           interpret=_interpret())


def bingrad_pass(v, b0, mask, *, use_kernels: bool = True):
    if not use_kernels:
        return _ref.bingrad_pass_ref(v, b0, mask)
    return _bingrad.bingrad_pass(v, b0, mask, interpret=_interpret())


def dequant_avg(idx, levels, *, use_kernels: bool = True):
    if not use_kernels:
        return _ref.dequant_avg_ref(idx, levels)
    return _dequant.dequant_avg(idx, levels, s=levels.shape[-1],
                                interpret=_interpret())


def pack(idx, bits: int, *, use_kernels: bool = True):
    if not use_kernels:
        return _ref.pack_ref(idx, bits)
    return _bitpack.pack(idx, bits=bits, interpret=_interpret())


def unpack(words, bits: int, d: int, *, use_kernels: bool = True):
    if not use_kernels:
        return _ref.unpack_ref(words, bits, d)
    return _bitpack.unpack(words, bits=bits, d=d, interpret=_interpret())
