"""Pallas TPU kernel: fused one-pass gradient ENCODE (and local QDQ).

The wire encode used to be 3-4 separate sweeps over the flat buffer —
σ-clip, ``quant_rr`` (its own pallas_call), a masked select, and ``pack``
(another pallas_call) — each materializing a full-size ``(nb, d)``
intermediate in HBM between kernels. This module fuses the whole
per-bucket pipeline into ONE VMEM-tiled sweep:

    encode_fused   σ-clip -> interval search -> random rounding
                   -> mask -> uint32 bit-pack, one ``pallas_call``; the
                   only HBM write is the packed ``(nb, nw)`` wire words
                   (a 32/bits shrink vs the old int32 idx intermediate).
    qdq_fused      the error-feedback hot path: the same clip/round stage
                   followed by an in-register level-table decode — the
                   dequantized ``(nb, d)`` values come straight out, no
                   idx tensor and no pack/unpack round-trip.

Rounding modes (static):
    "rr"    unbiased random rounding (Eq. 7) — orq / terngrad / qsgd /
            linear / minmax2 / bingrad_pb; consumes precomputed threefry
            uint32 bits so the output is bit-identical to the multi-pass
            kernels and the jnp oracle (``ref.encode_fused_ref``).
    "bin"   BinGrad-b threshold at the level midpoint (Eq. 17).
    "sign"  scaled SignSGD threshold at 0 (Eq. 13).

The level FIT for the rr schemes stays outside the kernel (ORQ's Alg. 1
needs a per-bucket sort — cheap jnp, no pallas_call); the BinGrad-b fit
is moments-only and fuses completely — see ``fused_bingrad.py``.

Scheduling (the PR-6 tiling fix):

* The σ-clip REDUCTION runs once, outside the kernel: the per-bucket
  clip limit c·σ is a tiny ``(nb, 1)`` side input computed with the same
  jnp reduction the level fit already performs (XLA CSEs the two), so
  the kernel applies a single ``clip`` instead of re-reducing masked
  moments on every tile. Reduce once, then quantize — not
  reduce-per-block.
* The interval search and the lo/hi neighbour-level selection share one
  unrolled sweep over the (ascending) level table via running selects —
  no second one-hot pass over ``s`` levels.
* The row block adapts to the problem: as many ROW_BLOCK-multiples of
  bucket rows per grid step as fit a VMEM tile budget, so small sweeps
  run as a single grid step instead of paying per-step scheduling
  overhead, while big sweeps still tile within VMEM.

Level tables are padded to a LEVEL_PAD lane tile (edge-replicated so the
unrolled compares never read garbage). Columns stay at the true bucket
width ``d`` and are zero-padded in-register to a whole number of wire
words; the padding is masked so it packs as index 0, exactly like the
zero-pad in the multi-pass ``pack``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8   # row-block quantum (f32 sublane tile)
LEVEL_PAD = 32  # level-table tile width (s <= 17 always)
#: VMEM budget per grid-step tile (all operands + outputs). Kept well
#: under the ~16 MB/core VMEM so double-buffered in/out windows fit.
VMEM_TILE_BYTES = 2 * 1024 * 1024
_INV_U32 = float(1.0 / 4294967296.0)

#: rounding modes the fused stage understands
MODES = ("rr", "bin", "sign")


def row_block(nb: int, row_bytes: int) -> int:
    """Rows per grid step: the largest ROW_BLOCK multiple whose tile
    (``row_bytes`` per bucket row across every operand) fits the VMEM
    budget, capped at the padded row count. One grid step whenever the
    whole sweep fits."""
    cap = max(VMEM_TILE_BYTES // max(row_bytes, 1), ROW_BLOCK)
    cap = (cap // ROW_BLOCK) * ROW_BLOCK
    need = -(-nb // ROW_BLOCK) * ROW_BLOCK
    return min(cap, need)


def clip_limit(v: jnp.ndarray, mask: jnp.ndarray,
               clip_c: Optional[float]) -> Optional[jnp.ndarray]:
    """Per-bucket TernGrad clip limit c·σ as an (nb, 1) f32 array (None
    when clipping is off). Mirrors ``clipping.sigma_clip`` term for term
    — the SAME jnp reduction the level fit runs, so inside one jit XLA
    computes it once; the kernels then clip against the precomputed
    limit instead of re-reducing σ per tile."""
    if clip_c is None:
        return None
    m = mask.astype(jnp.float32)
    v = v.astype(jnp.float32)
    cnt = jnp.maximum(m.sum(axis=-1, keepdims=True), 1.0)
    mean = (v * m).sum(axis=-1, keepdims=True) / cnt
    var = (((v - mean) ** 2) * m).sum(axis=-1, keepdims=True) / cnt
    return clip_c * jnp.sqrt(var)


def _clip_round(s: int, mode: str, v: jnp.ndarray, lv: jnp.ndarray,
                m: jnp.ndarray, u: Optional[jnp.ndarray],
                lim: Optional[jnp.ndarray]) -> jnp.ndarray:
    """The shared in-VMEM stage: clip -> round -> mask. All operands are
    (R, d) tiles (lv is (R, LEVEL_PAD), lim is (R, 1) or None); returns
    masked int32 indices.

    Numerics mirror ``clipping.sigma_clip`` + ``rounding.random_round`` /
    ``rounding.threshold_round`` term for term so interpret mode is
    bit-identical to the jnp oracle."""
    if lim is not None:
        v = jnp.clip(v, -lim, lim)
    if mode == "rr":
        # Interval search fused with neighbour-level selection. Level
        # tables are ascending, so (v >= lv_j) is a prefix predicate and
        # the running selects land on exactly levels[k] / levels[k+1]
        # for k = clip(#(levels <= v) - 1, 0, s-2) — the same
        # count-and-gather as ``rounding.find_interval`` +
        # ``select_levels``, in one sweep with no one-hot second pass.
        k = jnp.zeros(v.shape, dtype=jnp.int32)
        lo = jnp.broadcast_to(lv[:, 0][:, None], v.shape)
        hi = jnp.broadcast_to(lv[:, 1][:, None], v.shape)
        ge_prev = None
        for j in range(s):                       # static unroll, s <= 17
            ge = v >= lv[:, j][:, None]
            k = k + ge.astype(jnp.int32)
            if 1 <= j <= s - 2:
                lo = jnp.where(ge, lv[:, j][:, None], lo)
            if j >= 2:
                hi = jnp.where(ge_prev, lv[:, j][:, None], hi)
            ge_prev = ge
        k = jnp.clip(k - 1, 0, s - 2)
        vc = jnp.clip(v, lo, hi)
        width = hi - lo
        p_up = jnp.where(width > 0,
                         (vc - lo) / jnp.where(width > 0, width, 1.0), 0.0)
        idx = k + (u < p_up).astype(jnp.int32)
    elif mode == "bin":
        thr = 0.5 * (lv[:, 0] + lv[:, 1])[:, None]
        idx = (v >= thr).astype(jnp.int32)
    elif mode == "sign":
        idx = (v >= 0.0).astype(jnp.int32)
    else:
        raise ValueError(f"unknown rounding mode {mode!r}")
    return jnp.where(m > 0, idx, 0)


def _pack_words(idx: jnp.ndarray, bits: int, epw: int) -> jnp.ndarray:
    """(R, d) int32 -> (R, ceil(d/epw)) uint32 shift-add pack (add == OR
    on disjoint bit ranges; same lane order as the multi-pass pack
    kernel). The ragged tail is zero-padded IN-REGISTER — padding the
    kernel INPUTS instead would widen the row reductions (the BinGrad
    conditional means) and shift their rounding by an ulp vs the jnp
    oracle."""
    r, d = idx.shape
    dp = -(-d // epw) * epw
    if dp != d:
        idx = jnp.concatenate(
            [idx, jnp.zeros((r, dp - d), dtype=idx.dtype)], axis=-1)
    lanes = idx.astype(jnp.uint32).reshape(r, dp // epw, epw)
    acc = jnp.zeros((r, dp // epw), dtype=jnp.uint32)
    for j in range(epw):                          # static unroll
        acc = acc + (lanes[:, :, j] << jnp.uint32(bits * j))
    return acc


def _encode_kernel(s, bits, epw, has_lim, mode, *refs):
    refs = list(refs)
    v_ref, lv_ref, m_ref = refs[:3]
    rest = refs[3:]
    lim = rest.pop(0)[...] if has_lim else None
    if mode == "rr":
        u = rest.pop(0)[...].astype(jnp.float32) * _INV_U32
    else:
        u = None
    (w_ref,) = rest
    v = v_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    idx = _clip_round(s, mode, v, lv, m, u, lim)
    w_ref[...] = _pack_words(idx, bits, epw)


def _qdq_kernel(s, has_lim, mode, *refs):
    refs = list(refs)
    v_ref, lv_ref, m_ref = refs[:3]
    rest = refs[3:]
    lim = rest.pop(0)[...] if has_lim else None
    if mode == "rr":
        u = rest.pop(0)[...].astype(jnp.float32) * _INV_U32
    else:
        u = None
    (o_ref,) = rest
    v = v_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    idx = _clip_round(s, mode, v, lv, m, u, lim)
    val = jnp.zeros(v.shape, dtype=jnp.float32)
    for j in range(s):                  # static unroll, gather-free decode
        val = val + (idx == j).astype(jnp.float32) * lv[:, j][:, None]
    o_ref[...] = val


def _padded(v, levels, bits_arr, mask, lim, *, s: int, mode: str,
            out_cols: int):
    """Pad rows to an adaptive VMEM-budgeted row block and the level
    table to LEVEL_PAD lanes. Columns stay at the true bucket width
    ``d``. Returns (inputs, in_specs, rows, rb)."""
    nb, d = v.shape
    n_wide = 3 if mode == "rr" else 2            # (nb, d)-wide operands + v
    row_bytes = 4 * ((n_wide + 1) * d + LEVEL_PAD + out_cols
                     + (1 if lim is not None else 0))
    rb = row_block(nb, row_bytes)
    rows = -(-nb // rb) * rb
    pr = rows - nb
    vp = jnp.pad(v.astype(jnp.float32), ((0, pr), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.float32), ((0, pr), (0, 0)))
    lvp = jnp.pad(levels.astype(jnp.float32),
                  ((0, pr), (0, LEVEL_PAD - s)), mode="edge")
    inputs = [vp, lvp, mp]
    in_specs = [
        pl.BlockSpec((rb, d), lambda i: (i, 0)),
        pl.BlockSpec((rb, LEVEL_PAD), lambda i: (i, 0)),
        pl.BlockSpec((rb, d), lambda i: (i, 0)),
    ]
    if lim is not None:
        inputs.append(jnp.pad(lim.astype(jnp.float32), ((0, pr), (0, 0))))
        in_specs.append(pl.BlockSpec((rb, 1), lambda i: (i, 0)))
    if mode == "rr":
        inputs.append(jnp.pad(bits_arr, ((0, pr), (0, 0))))
        in_specs.append(pl.BlockSpec((rb, d), lambda i: (i, 0)))
    return inputs, in_specs, rows, rb


@functools.partial(jax.jit,
                   static_argnames=("bits", "s", "clip_c", "mode",
                                    "interpret"))
def encode_fused(v: jnp.ndarray, levels: jnp.ndarray,
                 rbits: Optional[jnp.ndarray], mask: jnp.ndarray, *,
                 bits: int, s: int, clip_c: Optional[float] = None,
                 mode: str = "rr", interpret: bool = True) -> jnp.ndarray:
    """(nb, d) values + (nb, s) levels [+ (nb, d) uint32 bits] + (nb, d)
    mask -> (nb, nw) packed uint32 wire words, nw = ceil(d / (32//bits)).

    One ``pallas_call``: the clip, interval search, rounding, masking and
    bit-pack all happen on the VMEM tile; nothing (nb, d)-sized is written
    back to HBM."""
    nb, d = v.shape
    assert levels.shape == (nb, s), (levels.shape, (nb, s))
    assert mode in MODES, mode
    epw = 32 // bits
    nw = -(-d // epw)
    lim = clip_limit(v, mask, clip_c)
    inputs, in_specs, rows, rb = _padded(v, levels, rbits, mask, lim,
                                         s=s, mode=mode, out_cols=nw)
    out = pl.pallas_call(
        functools.partial(_encode_kernel, s, bits, epw, lim is not None,
                          mode),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
        grid=(rows // rb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rb, nw), lambda i: (i, 0)),
        interpret=interpret,
    )(*inputs)
    return out[:nb]


@functools.partial(jax.jit,
                   static_argnames=("s", "clip_c", "mode", "interpret"))
def qdq_fused(v: jnp.ndarray, levels: jnp.ndarray,
              rbits: Optional[jnp.ndarray], mask: jnp.ndarray, *,
              s: int, clip_c: Optional[float] = None, mode: str = "rr",
              interpret: bool = True) -> jnp.ndarray:
    """Fused local quantize->dequantize: same clip/round stage as
    ``encode_fused`` but decoded in-register -> (nb, d) float32 values
    (masked-out slots decode to level 0, like the multi-pass path). The
    error-feedback residual hot loop — one pallas_call, no idx/pack."""
    nb, d = v.shape
    assert levels.shape == (nb, s), (levels.shape, (nb, s))
    assert mode in MODES, mode
    lim = clip_limit(v, mask, clip_c)
    inputs, in_specs, rows, rb = _padded(v, levels, rbits, mask, lim,
                                         s=s, mode=mode, out_cols=d)
    out = pl.pallas_call(
        functools.partial(_qdq_kernel, s, lim is not None, mode),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        grid=(rows // rb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        interpret=interpret,
    )(*inputs)
    return out[:nb]
