"""Pallas TPU kernel: fused one-pass gradient ENCODE (and local QDQ).

The wire encode used to be 3-4 separate sweeps over the flat buffer —
σ-clip, ``quant_rr`` (its own pallas_call), a masked select, and ``pack``
(another pallas_call) — each materializing a full-size ``(nb, d)``
intermediate in HBM between kernels. This module fuses the whole
per-bucket pipeline into ONE VMEM-tiled sweep:

    encode_fused   σ-estimate/clip -> interval search -> random rounding
                   -> mask -> uint32 bit-pack, one ``pallas_call``; the
                   only HBM write is the packed ``(nb, nw)`` wire words
                   (a 32/bits shrink vs the old int32 idx intermediate).
    qdq_fused      the error-feedback hot path: the same clip/round stage
                   followed by an in-register level-table decode — the
                   dequantized ``(nb, d)`` values come straight out, no
                   idx tensor and no pack/unpack round-trip.

Rounding modes (static):
    "rr"    unbiased random rounding (Eq. 7) — orq / terngrad / qsgd /
            linear / minmax2 / bingrad_pb; consumes precomputed threefry
            uint32 bits so the output is bit-identical to the multi-pass
            kernels and the jnp oracle (``ref.encode_fused_ref``).
    "bin"   BinGrad-b threshold at the level midpoint (Eq. 17).
    "sign"  scaled SignSGD threshold at 0 (Eq. 13).

The level FIT for the rr schemes stays outside the kernel (ORQ's Alg. 1
needs a per-bucket sort — cheap jnp, no pallas_call); the BinGrad-b fit
is moments-only and fuses completely — see ``fused_bingrad.py``.

Tiling matches the rest of the package: grid over ROW_BLOCK bucket rows,
full bucket width per tile, level tables padded to a LEVEL_PAD lane tile
(edge-replicated so the unrolled compares never read garbage). Columns
are padded to a whole number of wire words; the padding is masked so it
packs as index 0, exactly like the zero-pad in the multi-pass ``pack``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8
LEVEL_PAD = 32  # level-table tile width (s <= 17 always)
_INV_U32 = float(1.0 / 4294967296.0)

#: rounding modes the fused stage understands
MODES = ("rr", "bin", "sign")


def _sigma_clip_tile(v: jnp.ndarray, m: jnp.ndarray,
                     clip_c: Optional[float]) -> jnp.ndarray:
    """In-VMEM σ-clip on an (R, d) tile, mirroring ``clipping.sigma_clip``
    term for term (masked moments around the masked mean, clip to ±c·σ).
    The single definition shared by every fused kernel — the bit-identity
    story depends on these ops matching the jnp oracle exactly."""
    if clip_c is None:
        return v
    cnt = jnp.maximum(m.sum(axis=-1, keepdims=True), 1.0)
    mean = (v * m).sum(axis=-1, keepdims=True) / cnt
    var = (((v - mean) ** 2) * m).sum(axis=-1, keepdims=True) / cnt
    lim = clip_c * jnp.sqrt(var)
    return jnp.clip(v, -lim, lim)


def _clip_round(s: int, clip_c: Optional[float], mode: str,
                v: jnp.ndarray, lv: jnp.ndarray, m: jnp.ndarray,
                u: Optional[jnp.ndarray]) -> jnp.ndarray:
    """The shared in-VMEM stage: σ-clip -> round -> mask. All operands are
    (R, d) tiles (lv is (R, LEVEL_PAD)); returns masked int32 indices.

    Numerics mirror ``clipping.sigma_clip`` + ``rounding.random_round`` /
    ``rounding.threshold_round`` term for term so interpret mode is
    bit-identical to the jnp oracle."""
    v = _sigma_clip_tile(v, m, clip_c)
    if mode == "rr":
        # interval search: k = (#levels <= v) - 1, clipped to [0, s-2]
        k = jnp.zeros(v.shape, dtype=jnp.int32)
        for j in range(s):                       # static unroll, s <= 17
            k = k + (v >= lv[:, j][:, None]).astype(jnp.int32)
        k = jnp.clip(k - 1, 0, s - 2)
        # lo = levels[k], hi = levels[k+1] via one-hot select (gather-free)
        lo = jnp.zeros(v.shape, dtype=jnp.float32)
        hi = jnp.zeros(v.shape, dtype=jnp.float32)
        for j in range(s - 1):                   # static unroll
            sel = (k == j).astype(jnp.float32)
            lo = lo + sel * lv[:, j][:, None]
            hi = hi + sel * lv[:, j + 1][:, None]
        vc = jnp.clip(v, lo, hi)
        width = hi - lo
        p_up = jnp.where(width > 0,
                         (vc - lo) / jnp.where(width > 0, width, 1.0), 0.0)
        idx = k + (u < p_up).astype(jnp.int32)
    elif mode == "bin":
        thr = 0.5 * (lv[:, 0] + lv[:, 1])[:, None]
        idx = (v >= thr).astype(jnp.int32)
    elif mode == "sign":
        idx = (v >= 0.0).astype(jnp.int32)
    else:
        raise ValueError(f"unknown rounding mode {mode!r}")
    return jnp.where(m > 0, idx, 0)


def _pack_words(idx: jnp.ndarray, bits: int, epw: int) -> jnp.ndarray:
    """(R, d) int32 -> (R, ceil(d/epw)) uint32 shift-add pack (add == OR
    on disjoint bit ranges; same lane order as the multi-pass pack
    kernel). The ragged tail is zero-padded IN-REGISTER — padding the
    kernel INPUTS instead would widen the row reductions (σ moments, the
    BinGrad conditional means) and shift their rounding by an ulp vs the
    jnp oracle."""
    r, d = idx.shape
    dp = -(-d // epw) * epw
    if dp != d:
        idx = jnp.concatenate(
            [idx, jnp.zeros((r, dp - d), dtype=idx.dtype)], axis=-1)
    lanes = idx.astype(jnp.uint32).reshape(r, dp // epw, epw)
    acc = jnp.zeros((r, dp // epw), dtype=jnp.uint32)
    for j in range(epw):                          # static unroll
        acc = acc + (lanes[:, :, j] << jnp.uint32(bits * j))
    return acc


def _encode_kernel(s, bits, epw, clip_c, mode, *refs):
    if mode == "rr":
        v_ref, lv_ref, m_ref, u_ref, w_ref = refs
        u = u_ref[...].astype(jnp.float32) * _INV_U32
    else:
        v_ref, lv_ref, m_ref, w_ref = refs
        u = None
    v = v_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    idx = _clip_round(s, clip_c, mode, v, lv, m, u)
    w_ref[...] = _pack_words(idx, bits, epw)


def _qdq_kernel(s, clip_c, mode, *refs):
    if mode == "rr":
        v_ref, lv_ref, m_ref, u_ref, o_ref = refs
        u = u_ref[...].astype(jnp.float32) * _INV_U32
    else:
        v_ref, lv_ref, m_ref, o_ref = refs
        u = None
    v = v_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    idx = _clip_round(s, clip_c, mode, v, lv, m, u)
    val = jnp.zeros(v.shape, dtype=jnp.float32)
    for j in range(s):                  # static unroll, gather-free decode
        val = val + (idx == j).astype(jnp.float32) * lv[:, j][:, None]
    o_ref[...] = val


def _padded(v, levels, bits_arr, mask, *, s: int, mode: str):
    """Pad rows to ROW_BLOCK and the level table to LEVEL_PAD lanes.
    Columns stay at the true bucket width ``d`` — row reductions inside
    the kernel (σ moments) must run over exactly the elements the jnp
    oracle sums. Returns (inputs, in_specs, rows)."""
    nb, d = v.shape
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    pr = rows - nb
    vp = jnp.pad(v.astype(jnp.float32), ((0, pr), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.float32), ((0, pr), (0, 0)))
    lvp = jnp.pad(levels.astype(jnp.float32),
                  ((0, pr), (0, LEVEL_PAD - s)), mode="edge")
    inputs = [vp, lvp, mp]
    in_specs = [
        pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        pl.BlockSpec((ROW_BLOCK, LEVEL_PAD), lambda i: (i, 0)),
        pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
    ]
    if mode == "rr":
        inputs.append(jnp.pad(bits_arr, ((0, pr), (0, 0))))
        in_specs.append(pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)))
    return inputs, in_specs, rows


@functools.partial(jax.jit,
                   static_argnames=("bits", "s", "clip_c", "mode",
                                    "interpret"))
def encode_fused(v: jnp.ndarray, levels: jnp.ndarray,
                 rbits: Optional[jnp.ndarray], mask: jnp.ndarray, *,
                 bits: int, s: int, clip_c: Optional[float] = None,
                 mode: str = "rr", interpret: bool = True) -> jnp.ndarray:
    """(nb, d) values + (nb, s) levels [+ (nb, d) uint32 bits] + (nb, d)
    mask -> (nb, nw) packed uint32 wire words, nw = ceil(d / (32//bits)).

    One ``pallas_call``: the clip, interval search, rounding, masking and
    bit-pack all happen on the VMEM tile; nothing (nb, d)-sized is written
    back to HBM."""
    nb, d = v.shape
    assert levels.shape == (nb, s), (levels.shape, (nb, s))
    assert mode in MODES, mode
    epw = 32 // bits
    nw = -(-d // epw)
    inputs, in_specs, rows = _padded(v, levels, rbits, mask, s=s, mode=mode)
    out = pl.pallas_call(
        functools.partial(_encode_kernel, s, bits, epw, clip_c, mode),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
        grid=(rows // ROW_BLOCK,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ROW_BLOCK, nw), lambda i: (i, 0)),
        interpret=interpret,
    )(*inputs)
    return out[:nb]


@functools.partial(jax.jit,
                   static_argnames=("s", "clip_c", "mode", "interpret"))
def qdq_fused(v: jnp.ndarray, levels: jnp.ndarray,
              rbits: Optional[jnp.ndarray], mask: jnp.ndarray, *,
              s: int, clip_c: Optional[float] = None, mode: str = "rr",
              interpret: bool = True) -> jnp.ndarray:
    """Fused local quantize->dequantize: same clip/round stage as
    ``encode_fused`` but decoded in-register -> (nb, d) float32 values
    (masked-out slots decode to level 0, like the multi-pass path). The
    error-feedback residual hot loop — one pallas_call, no idx/pack."""
    nb, d = v.shape
    assert levels.shape == (nb, s), (levels.shape, (nb, s))
    assert mode in MODES, mode
    inputs, in_specs, rows = _padded(v, levels, rbits, mask, s=s, mode=mode)
    out = pl.pallas_call(
        functools.partial(_qdq_kernel, s, clip_c, mode),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        grid=(rows // ROW_BLOCK,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        interpret=interpret,
    )(*inputs)
    return out[:nb]
