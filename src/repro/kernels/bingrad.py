"""Pallas TPU kernel: fused BinGrad statistics + binary assignment.

BinGrad-b (Eq. 16/17) needs, per bucket: the conditional means below/above a
threshold b₀ and the deterministic assignment v >= b₀. A naive implementation
reads the gradient three times (mean, masked sums, compare); this kernel
fuses the conditional reductions with the assignment into a single pass over
VMEM-resident tiles — one HBM read of the gradient, one int8 write plus a
tiny (rows, 4) partials write.

The bucket mean (b₀) is computed by the caller (a single cheap row-reduce the
XLA fuses with the preceding grad cast); the kernel does the heavy fused pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _bingrad_kernel(v_ref, b0_ref, m_ref, idx_ref, part_ref):
    v = v_ref[...].astype(jnp.float32)        # (R, d)
    b0 = b0_ref[...].astype(jnp.float32)      # (R, 1)
    m = m_ref[...].astype(jnp.float32)        # (R, d) validity mask
    hi = (v >= b0).astype(jnp.float32) * m
    lo = (1.0 - (v >= b0).astype(jnp.float32)) * m
    idx_ref[...] = (hi > 0).astype(jnp.int32)
    part_ref[:, 0] = (v * lo).sum(axis=-1)
    part_ref[:, 1] = lo.sum(axis=-1)
    part_ref[:, 2] = (v * hi).sum(axis=-1)
    part_ref[:, 3] = hi.sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bingrad_pass(v: jnp.ndarray, b0: jnp.ndarray, mask: jnp.ndarray,
                 *, interpret: bool = True):
    """Fused conditional-sums + assignment.

    v (nb, d), b0 (nb, 1), mask (nb, d) -> (idx (nb, d) int32,
    partials (nb, 4) = [sum_lo, cnt_lo, sum_hi, cnt_hi]).
    """
    nb, d = v.shape
    rows = -(-nb // ROW_BLOCK) * ROW_BLOCK
    pad = rows - nb
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad), (0, 0)))
    bp = jnp.pad(b0.astype(jnp.float32), ((0, pad), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.float32), ((0, pad), (0, 0)))
    grid = (rows // ROW_BLOCK,)
    idx, part = pl.pallas_call(
        _bingrad_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((rows, d), jnp.int32),
            jax.ShapeDtypeStruct((rows, 4), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, 4), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(vp, bp, mp)
    return idx[:nb], part[:nb]
