"""Pure-jnp oracles for every Pallas kernel (bit-exact semantics).

The fused-kernel oracles (``encode_fused_ref`` & co.) are the LEGACY
multi-pass compositions — σ-clip, round, mask, pack as separate jnp
sweeps — kept as the single source of truth the one-pass kernels are
tested bit-identical against (``use_kernels=False`` / ``REPRO_USE_KERNELS=0``
select them at runtime).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_INV_U32 = jnp.float32(1.0 / 4294967296.0)


def quant_rr_ref(v: jnp.ndarray, levels: jnp.ndarray,
                 bits: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.quant_rr.quant_rr."""
    s = levels.shape[-1]
    v = v.astype(jnp.float32)
    lv = levels.astype(jnp.float32)
    k = (v[..., None] >= lv[:, None, :]).sum(-1).astype(jnp.int32) - 1
    k = jnp.clip(k, 0, s - 2)
    lo = jnp.take_along_axis(lv, k, axis=-1)
    hi = jnp.take_along_axis(lv, k + 1, axis=-1)
    vc = jnp.clip(v, lo, hi)
    width = hi - lo
    p_up = jnp.where(width > 0, (vc - lo) / jnp.where(width > 0, width, 1.0),
                     0.0)
    u = bits.astype(jnp.float32) * _INV_U32
    return k + (u < p_up).astype(jnp.int32)


def bingrad_pass_ref(v: jnp.ndarray, b0: jnp.ndarray, mask: jnp.ndarray):
    """Oracle for kernels.bingrad.bingrad_pass."""
    v = v.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    hi = (v >= b0).astype(jnp.float32) * m
    lo = (1.0 - (v >= b0).astype(jnp.float32)) * m
    idx = (hi > 0).astype(jnp.int32)
    part = jnp.stack(
        [(v * lo).sum(-1), lo.sum(-1), (v * hi).sum(-1), hi.sum(-1)], axis=-1
    )
    return idx, part


def dequant_avg_ref(idx: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.dequant_avg.dequant_avg."""
    L = idx.shape[0]
    vals = jnp.take_along_axis(levels.astype(jnp.float32), idx, axis=-1)
    return vals.sum(0) * (1.0 / L)


def pack_ref(idx: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Oracle for kernels.bitpack.pack."""
    from repro.core import encode

    return encode.pack(idx, bits)


def unpack_ref(words: jnp.ndarray, bits: int, d: int) -> jnp.ndarray:
    """Oracle for kernels.bitpack.unpack."""
    from repro.core import encode

    return encode.unpack(words, bits, d)


# ---------------------------------------------------------------------------
# fused-pipeline oracles (the legacy multi-pass compositions)
# ---------------------------------------------------------------------------

def _round_ref(v: jnp.ndarray, levels: jnp.ndarray,
               rbits: Optional[jnp.ndarray], mask: jnp.ndarray,
               clip_c: Optional[float], mode: str) -> jnp.ndarray:
    """Shared clip+round stage: masked int32 level indices (the exact
    legacy ``wire.assign`` + masked-select composition)."""
    from repro.core import clipping

    v = v.astype(jnp.float32)
    if clip_c is not None:
        v = clipping.sigma_clip(v, mask, clip_c)
    if mode == "rr":
        idx = quant_rr_ref(v, levels, rbits)
    elif mode == "bin":
        b0 = 0.5 * (levels[:, :1] + levels[:, 1:2])   # Eq. (17): midpoint
        idx = (v >= b0).astype(jnp.int32)
    elif mode == "sign":
        idx = (v >= jnp.zeros((v.shape[0], 1))).astype(jnp.int32)
    else:
        raise ValueError(f"unknown rounding mode {mode!r}")
    return jnp.where(mask, idx, 0)


def encode_fused_ref(v: jnp.ndarray, levels: jnp.ndarray,
                     rbits: Optional[jnp.ndarray], mask: jnp.ndarray, *,
                     bits: int, clip_c: Optional[float] = None,
                     mode: str = "rr") -> jnp.ndarray:
    """Oracle for kernels.fused_encode.encode_fused."""
    return pack_ref(_round_ref(v, levels, rbits, mask, clip_c, mode), bits)


def qdq_fused_ref(v: jnp.ndarray, levels: jnp.ndarray,
                  rbits: Optional[jnp.ndarray], mask: jnp.ndarray, *,
                  clip_c: Optional[float] = None,
                  mode: str = "rr") -> jnp.ndarray:
    """Oracle for kernels.fused_encode.qdq_fused."""
    idx = _round_ref(v, levels, rbits, mask, clip_c, mode)
    return jnp.take_along_axis(levels.astype(jnp.float32), idx, axis=-1)


def encode_bingrad_fused_ref(v: jnp.ndarray, mask: jnp.ndarray, *,
                             clip_c: Optional[float] = None,
                             lloyd_iters: int = 0):
    """Oracle for kernels.fused_bingrad.encode_bingrad_fused."""
    from repro.core import clipping
    from repro.core import levels as L

    v = v.astype(jnp.float32)
    if clip_c is not None:
        v = clipping.sigma_clip(v, mask, clip_c)
    lv = L.bingrad_b_levels(v, mask, lloyd_iters=lloyd_iters)
    idx = _round_ref(v, lv, None, mask, None, "bin")
    return pack_ref(idx, 1), lv


# ---------------------------------------------------------------------------
# quantized-KV serving oracles (kernels/fused_kv.py)
# ---------------------------------------------------------------------------

_NEG_INF = -2.0e38


def _kv_decode(w: jnp.ndarray, lv: jnp.ndarray, bits: int, s: int,
               d: int) -> jnp.ndarray:
    """(C, nw) uint32 packed words + (C, s) levels -> (C, d) f32 values:
    shift-mask unpack + gather-free one-hot level decode (the exact
    composition of ``fused_decode._unpack_decode``, 2-D)."""
    epw = 32 // bits
    m = jnp.uint32(2 ** bits - 1)
    parts = []
    for j in range(epw):                          # static unroll
        parts.append(((w >> jnp.uint32(bits * j)) & m).astype(jnp.int32))
    idx = jnp.stack(parts, axis=-1).reshape(w.shape[0], -1)[:, :d]
    val = jnp.zeros(idx.shape, dtype=jnp.float32)
    for j in range(s):                  # static unroll, gather-free decode
        val = val + ((idx == j).astype(jnp.float32)
                     * lv[:, j].astype(jnp.float32)[:, None])
    return val


def kv_attend_block(q: jnp.ndarray, kw: jnp.ndarray, klv: jnp.ndarray,
                    vw: jnp.ndarray, vlv: jnp.ndarray, mask: jnp.ndarray, *,
                    bits: int, kv_heads: int, scale: float,
                    softcap: float = 0.0) -> jnp.ndarray:
    """One sequence of fused dequant-attention: q (T, H, hd) against a
    quantized KV context kw/vw (C, nw) uint32 + klv/vlv (C, s) levels with
    mask (T, C) in {0, 1} -> (T, H, hd) f32.

    This is THE definition of the math: the Pallas kernel body in
    ``fused_kv.py`` calls this very function on its VMEM tile, and the
    oracle ``kv_attend_ref`` vmaps it over the batch — bit-identity between
    kernel and oracle is by construction, not by mirroring."""
    T, H, hd = q.shape
    d = kv_heads * hd
    s = klv.shape[-1]
    k = _kv_decode(kw, klv, bits, s, d).reshape(-1, kv_heads, hd)
    v = _kv_decode(vw, vlv, bits, s, d).reshape(-1, kv_heads, hd)
    g = H // kv_heads
    qg = q.astype(jnp.float32).reshape(T, kv_heads, g, hd)
    sc = jnp.einsum("tkgh,ckh->kgtc", qg, k,
                    preferred_element_type=jnp.float32) * scale
    sc = sc.reshape(H, T, -1)
    if softcap:
        sc = jnp.tanh(sc / softcap) * softcap
    sc = jnp.where(mask[None, :, :] > 0, sc, _NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)                       # (H, T, C)
    o = jnp.einsum("kgtc,ckh->tkgh", p.reshape(kv_heads, g, T, -1), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(T, H, hd)


def kv_attend_ref(q: jnp.ndarray, kw: jnp.ndarray, klv: jnp.ndarray,
                  vw: jnp.ndarray, vlv: jnp.ndarray, mask: jnp.ndarray, *,
                  bits: int, kv_heads: int, scale: float,
                  softcap: float = 0.0) -> jnp.ndarray:
    """Oracle for kernels.fused_kv.decode_attend: vmap of
    :func:`kv_attend_block` over the batch dim. q (B, T, H, hd), kw/vw
    (B, C, nw), klv/vlv (B, C, s), mask (B, T, C) -> (B, T, H, hd) f32."""
    import functools

    fn = functools.partial(kv_attend_block, bits=bits, kv_heads=kv_heads,
                           scale=scale, softcap=softcap)
    return jax.vmap(fn)(q.astype(jnp.float32), kw, klv, vw, vlv,
                        mask.astype(jnp.float32))


def decode_fused_mean_ref(words: jnp.ndarray, levels: jnp.ndarray, *,
                          d: int, bits: int) -> jnp.ndarray:
    """Oracle for kernels.fused_decode.decode_fused_mean."""
    idx = jax.vmap(lambda w: unpack_ref(w, bits, d))(words)
    return dequant_avg_ref(idx, levels)


def decode_fused_each_ref(words: jnp.ndarray, levels: jnp.ndarray, *,
                          d: int, bits: int) -> jnp.ndarray:
    """Oracle for kernels.fused_decode.decode_fused_each."""
    idx = jax.vmap(lambda w: unpack_ref(w, bits, d))(words)
    return jnp.take_along_axis(levels, idx.astype(jnp.int32), axis=-1)
