"""Pure-jnp oracles for every Pallas kernel (bit-exact semantics)."""
from __future__ import annotations

import jax.numpy as jnp

_INV_U32 = jnp.float32(1.0 / 4294967296.0)


def quant_rr_ref(v: jnp.ndarray, levels: jnp.ndarray,
                 bits: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.quant_rr.quant_rr."""
    s = levels.shape[-1]
    v = v.astype(jnp.float32)
    lv = levels.astype(jnp.float32)
    k = (v[..., None] >= lv[:, None, :]).sum(-1).astype(jnp.int32) - 1
    k = jnp.clip(k, 0, s - 2)
    lo = jnp.take_along_axis(lv, k, axis=-1)
    hi = jnp.take_along_axis(lv, k + 1, axis=-1)
    vc = jnp.clip(v, lo, hi)
    width = hi - lo
    p_up = jnp.where(width > 0, (vc - lo) / jnp.where(width > 0, width, 1.0),
                     0.0)
    u = bits.astype(jnp.float32) * _INV_U32
    return k + (u < p_up).astype(jnp.int32)


def bingrad_pass_ref(v: jnp.ndarray, b0: jnp.ndarray, mask: jnp.ndarray):
    """Oracle for kernels.bingrad.bingrad_pass."""
    v = v.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    hi = (v >= b0).astype(jnp.float32) * m
    lo = (1.0 - (v >= b0).astype(jnp.float32)) * m
    idx = (hi > 0).astype(jnp.int32)
    part = jnp.stack(
        [(v * lo).sum(-1), lo.sum(-1), (v * hi).sum(-1), hi.sum(-1)], axis=-1
    )
    return idx, part


def dequant_avg_ref(idx: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.dequant_avg.dequant_avg."""
    L = idx.shape[0]
    vals = jnp.take_along_axis(levels.astype(jnp.float32), idx, axis=-1)
    return vals.sum(0) * (1.0 / L)


def pack_ref(idx: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Oracle for kernels.bitpack.pack."""
    from repro.core import encode

    return encode.pack(idx, bits)


def unpack_ref(words: jnp.ndarray, bits: int, d: int) -> jnp.ndarray:
    """Oracle for kernels.bitpack.unpack."""
    from repro.core import encode

    return encode.unpack(words, bits, d)
