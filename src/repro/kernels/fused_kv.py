"""Pallas TPU kernels for the quantized-KV serving engine.

Two hot paths, one ``pallas_call`` each:

    append_kv      quantize a batch of new tokens' K/V rows into wire
                   format in one sweep. K and V rows are stacked into a
                   single (2R, d) bucket matrix and pushed through
                   ``wire.encode`` — so the whole σ-fit → level-search →
                   round → pack pipeline is the SAME one-pass kernel the
                   training exchange uses (``fused_encode`` for the
                   random-round/sign schemes, ``fused_bingrad`` for
                   BinGrad-b) and inherits its oracles and env overrides
                   for free.

    decode_attend  decode-side fused dequant-attention: unpack the packed
                   uint32 context words + one-hot level decode feeding the
                   GQA attention inner loop, all inside one VMEM block per
                   sequence — the dequantized (C, d) K/V tensors never
                   round-trip HBM. The kernel body calls
                   ``ref.kv_attend_block`` on its tile, the SAME function
                   the jnp oracle (``ref.kv_attend_ref``) vmaps over the
                   batch, so kernel/oracle bit-identity holds by
                   construction.

Dispatch (env overrides, ``REPRO_USE_KERNELS=0`` oracle leg) lives in
``kernels/ops.decode_attend``; ``append_kv`` dispatches through
``wire.encode`` like every other encode caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _attend_kernel(bits, kv_heads, hd, scale, softcap, T, H,
                   q_ref, kw_ref, klv_ref, vw_ref, vlv_ref, m_ref, o_ref):
    q = q_ref[...][0].reshape(T, H, hd)
    out = _ref.kv_attend_block(
        q, kw_ref[...][0], klv_ref[...][0], vw_ref[...][0], vlv_ref[...][0],
        m_ref[...][0], bits=bits, kv_heads=kv_heads, scale=scale,
        softcap=softcap)
    o_ref[...] = out.reshape(1, T, H * hd)


@functools.partial(jax.jit, static_argnames=("bits", "kv_heads", "scale",
                                             "softcap", "interpret"))
def decode_attend(q: jnp.ndarray, kw: jnp.ndarray, klv: jnp.ndarray,
                  vw: jnp.ndarray, vlv: jnp.ndarray, mask: jnp.ndarray, *,
                  bits: int, kv_heads: int, scale: float,
                  softcap: float = 0.0, interpret: bool = True):
    """Fused dequant-attention over a quantized KV context.

    q (B, T, H, hd) queries; kw/vw (B, C, nw) uint32 packed context words;
    klv/vlv (B, C, s) per-token level tables; mask (B, T, C) attention
    validity (causal ∧ allocated ∧ window, computed by the caller) ->
    (B, T, H, hd) f32 attention output. One ``pallas_call``, grid over the
    batch: each program unpacks + decodes its sequence's full context in
    VMEM and runs the masked-softmax GQA attention on it.
    """
    B, T, H, hd = q.shape
    C, nw = kw.shape[1], kw.shape[2]
    s = klv.shape[-1]
    q2 = q.astype(jnp.float32).reshape(B, T, H * hd)
    mf = mask.astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_attend_kernel, bits, kv_heads, hd, scale,
                          softcap, T, H),
        out_shape=jax.ShapeDtypeStruct((B, T, H * hd), jnp.float32),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, H * hd), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, nw), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, s), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, nw), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, s), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, T, C), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, H * hd), lambda b: (b, 0, 0)),
        interpret=interpret,
    )(q2, kw, klv.astype(jnp.float32), vw, vlv.astype(jnp.float32), mf)
    return out.reshape(B, T, H, hd)


def append_kv(qz, k_rows: jnp.ndarray, v_rows: jnp.ndarray, rbits, *,
              use_kernels: bool = True):
    """Quantize R new tokens' K and V rows to wire format in ONE
    ``pallas_call``: k_rows/v_rows (R, d) f32 (d = kv_heads*head_dim, one
    bucket per token spanning all KV heads) -> (kw, klv, vw, vlv) with
    kw/vw (R, nw) uint32 and klv/vlv (R, s) f32.

    ``rbits`` is the caller's deterministic (2R, d) uint32 rounding stream
    for the random-round schemes — K rows first, then V rows, matching the
    internal stacking — or None for the deterministic modes. Every encode
    stage is independent per bucket row, so stacking K and V into one
    (2R, d) matrix changes nothing about each row's bits while halving the
    kernel launches.
    """
    from repro.core.comm import wire

    if not wire._fused_mode(qz):
        raise ValueError(
            f"kv scheme {qz.method!r} has no fused one-pass encode; "
            f"supported: random-round schemes, bingrad-b, signsgd")
    R = k_rows.shape[0]
    stacked = jnp.concatenate(
        [k_rows.astype(jnp.float32), v_rows.astype(jnp.float32)], axis=0)
    mask = jnp.ones(stacked.shape, dtype=bool)
    words, levels = wire.encode(qz, stacked, mask, None, rbits=rbits,
                                use_kernels=use_kernels)
    return words[:R], levels[:R], words[R:], levels[R:]
