"""Optimizers from scratch (no optax in this container).

Interface mirrors optax: ``init(params) -> state``, ``update(grads, state,
params, lr) -> (updates, state)``; apply with ``apply_updates``. The paper
trains with SGD + momentum 0.9 and weight decay (5e-4 CIFAR / 1e-4
ImageNet), so that is the default optimizer throughout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params, lr) -> (upd, state)


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0,
                 nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        gw = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p, grads, params)
        new_state = jax.tree_util.tree_map(
            lambda g, m: momentum * m + g, gw, state)
        if nesterov:
            updates = jax.tree_util.tree_map(
                lambda g, m: -lr * (g + momentum * m), gw, new_state)
        else:
            updates = jax.tree_util.tree_map(lambda m: -lr * m, new_state)
        return updates, new_state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return AdamState(mu=z, nu=jax.tree_util.tree_map(jnp.zeros_like,
                                                         params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state.nu, grads)
        upd = jax.tree_util.tree_map(
            lambda m, v, p: -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                   + weight_decay * p),
            mu, nu, params)
        return upd, AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)
