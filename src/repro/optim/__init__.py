from repro.optim.optimizers import adamw, sgd_momentum
from repro.optim.schedule import constant_lr, step_decay, warmup_cosine

__all__ = ["sgd_momentum", "adamw", "warmup_cosine", "step_decay",
           "constant_lr"]
