"""Learning-rate schedules (paper §5: base 0.1, x0.1 step decays, and a
linear warm-up from base/10 over 5 epochs used with clipping)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def step_decay(base: float, boundaries, factor: float = 0.1):
    bounds = list(boundaries)

    def fn(step):
        lr = jnp.float32(base)
        for b in bounds:
            lr = jnp.where(step >= b, lr * factor, lr)
        return lr

    return fn


def warmup_cosine(base: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def fn(step):
        step = jnp.float32(step)
        warm = base * (0.1 + 0.9 * step / max(warmup_steps, 1))
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base * (min_ratio + (1 - min_ratio)
                      * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
