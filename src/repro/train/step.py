"""Distributed train step: shard_map(manual=dp axes, auto=model) with the
paper's quantized gradient exchange at the FSDP boundary.

Layout (ZeRO-3):
  * every f32 master-param leaf is sharded over the combined dp axes
    (``pod`` x ``data``) along its d_model-sized dim, and over ``model``
    along its largest remaining dim (tensor/expert parallelism — XLA auto);
  * with the FUSED exchange (``fused_exchange=True``, pure-dp meshes) the
    whole parameter tree is gathered bf16 up front through ONE custom-VJP
    (``core/comm/fsdp_exchange.py``): forward = one fused all-gather per
    policy group, backward = one fused quantized reduce-scatter per
    sharded group (+ one fused quantized all-reduce per replicated group)
    with an error-feedback residual stream persisted in ``TrainState.ef``
    — O(#policy groups) gradient collectives per step;
  * with the per-leaf fallback (``fused_exchange=False``, or whenever
    ``model`` parallelism is active — flattening TP-sharded cotangents
    into a dp buffer would replicate them over ``model``) each leaf is
    gathered bf16 at its point of use (per scanned layer group) through a
    custom-VJP whose backward is the quantized reduce-scatter;
  * leaves with no dp-divisible dim stay replicated and exchange gradients
    through the quantized all-reduce (Algorithm 2 incl. server re-quant).

``mode='replicated'`` keeps all parameters replicated and is the
paper-faithful Algorithm 2 loop used by the convergence benchmarks (with a
1-device mesh it degenerates to the paper's single-machine experiments:
the gradient is quantize->dequantized locally every step).

On multi-pod meshes ``TrainConfig.hierarchy`` ("auto" by default) selects
the two-level ICI/DCN topology: every fused exchange first averages in
full precision over the fast intra-pod ``data`` axis and runs the
quantized phases only over the slow inter-pod ``pod`` axis, with EF
residuals living on the quantized intra-shard (see
``core/comm/hierarchical.py`` and EXPERIMENTS.md).

Quantization is configured through ``TrainConfig.policy`` (a
``repro.core.QuantPolicy`` or anything coercible to one): each leaf's
scheme is resolved from its gather path, the replicated fused exchange
partitions leaves into per-policy-group segments (O(#groups) collectives
per step), and fsdp gathers quantize each leaf's backward with its
resolved quantizer. (The historical ``TrainConfig.quant`` uniform alias
is gone — passing it raises with a pointer at ``policy=``.)

ADAPTIVE BIT BUDGET: ``ScheduledTrainStep`` drives a ``BitSchedule`` /
``BitBudgetController`` (``repro.core.policy``) over this machinery —
per-group wire bit-width becomes a function of the training step via a
recompile-on-phase-boundary design: one bits-independent engine skeleton
(leaves grouped by policy RULE, so EF-residual shapes are invariant),
specialized per phase into concrete engines held in an LRU keyed by the
bits tuple. Within a phase the step is bit-identical to the equivalent
static policy; bit-width is never traced, so the one-``pallas_call``
property is untouched.
"""
from __future__ import annotations

import dataclasses
import warnings
import zlib
from collections import OrderedDict
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import QuantConfig, QuantPolicy, comm
from repro.models.model import LM
from repro.optim import optimizers as opt_lib
from repro.optim.schedule import constant_lr
from repro.train.state import OuterState, TrainState
from repro.utils.compat import shard_map
from repro.utils.sharding import (choose_fsdp_dim, dp_axis_names,
                                  spec_dp_dim)

# key-fold salt separating the fused whole-tree exchange stream from the
# legacy per-leaf (crc32-of-path) streams
_FUSED_SALT = zlib.crc32(b"fused_exchange") & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    # ``policy`` is the sole quantization surface: a QuantPolicy (or
    # anything QuantPolicy.coerce accepts — policy string, dict,
    # QuantConfig). The historical ``quant`` uniform alias is REMOVED;
    # the sentinel below turns old call sites into a clear error.
    policy: Optional[Any] = None
    quant: Any = None               # REMOVED — kept only to fail loudly
    mode: str = "fsdp"              # fsdp | replicated
    hierarchy: str = "auto"         # flat | two_level | two_level_async |
                                    # auto: two_level quantizes only over
                                    # the slow inter-pod ("pod", DCN) axes
                                    # after a full-precision intra-pod
                                    # mean — "auto" switches it on
                                    # whenever the dp mesh has >= 2 axes;
                                    # two_level_async additionally makes
                                    # the hierarchy TEMPORAL (see
                                    # local_steps below and
                                    # core/comm/hierarchical.py)
    local_steps: int = 1            # two_level_async window H: run H
                                    # inner optimizer steps synced only
                                    # over the fast intra (ICI) axes,
                                    # then ONE quantized outer exchange
                                    # of the window's parameter delta
                                    # over the DCN axes feeding the outer
                                    # optimizer below. H=1 resolves to
                                    # the literal two_level path
                                    # (bit-identity by construction).
    optimizer: str = "sgd"          # sgd | adamw  (paper: SGD+momentum 0.9)
    momentum: float = 0.9
    weight_decay: float = 0.0
    outer_optimizer: str = "nesterov"   # nesterov | sgd — applied to the
                                        # outer pseudo-gradient
                                        # (anchor - local params) at sync
                                        # steps (two_level_async only)
    outer_lr: float = 0.7           # DiLoCo-style outer step size
    outer_momentum: float = 0.9
    use_kernels: bool = True
    error_feedback: bool = False    # beyond-paper: EF residual accumulation
                                    # (replicated mode + fused fsdp;
                                    # see EXPERIMENTS.md)
    fused_exchange: bool = True     # one flat-buffer collective per policy
                                    # group per step (False = legacy
                                    # per-leaf exchange; fsdp also falls
                                    # back per-leaf when n_model > 1)
    exchange_chunk_elems: Optional[int] = None  # size cap per fused
                                                # collective (memory knob)
    pipeline_chunks: int = 1        # split each fused exchange into K
                                    # bucket-row chunks so chunk k's
                                    # collective overlaps chunk k+1's
                                    # encode — bit-identical to K=1
                                    # (latency knob; see
                                    # core/comm/collectives.py)
    group_by_rule: bool = False     # key fused-exchange groups on the
                                    # policy RULE index instead of the
                                    # resolved QuantConfig: same partition
                                    # when configs are all distinct, but
                                    # invariant under per-phase config
                                    # re-materialization — what the
                                    # bit-schedule skeleton/specialize
                                    # machinery needs so EF shapes survive
                                    # phase boundaries
    collect_stats: bool = False     # emit an ``exchange_stats`` metric:
                                    # (n_groups, 3) f32 [sigma_sq,
                                    # clip_frac, ef_norm_sq] per policy
                                    # group, pmean'd over dp — the
                                    # BitBudgetController's feed (fused
                                    # paths only; per-leaf paths have no
                                    # group buffers to measure)
    compute_dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if self.quant is not None:
            raise ValueError(
                "TrainConfig.quant was removed — pass policy= instead "
                "(QuantPolicy.coerce accepts a QuantConfig, a scheme "
                "name, a policy string like 'embed=fp,default=orq-9', or "
                "a dict); a uniform policy is just "
                "policy=QuantConfig(name=...)")
        if self.local_steps < 1:
            raise ValueError(
                f"local_steps must be >= 1, got {self.local_steps}")
        if self.local_steps > 1 and self.hierarchy != "two_level_async":
            raise ValueError(
                "local_steps > 1 is the two_level_async inner-window "
                "length — set hierarchy='two_level_async' (got "
                f"hierarchy={self.hierarchy!r})")
        if self.hierarchy == "two_level_async":
            # the temporal tier rides the fused replicated two-level
            # machinery; silently falling back to a per-step exchange
            # would change training semantics, so validation is strict
            if self.mode != "replicated":
                raise ValueError(
                    "hierarchy='two_level_async' needs mode='replicated' "
                    "(the outer delta exchange rides the fused replicated "
                    f"engines), got mode={self.mode!r}")
            if not self.fused_exchange:
                raise ValueError(
                    "hierarchy='two_level_async' needs the fused exchange "
                    "(fused_exchange=True)")
        if self.outer_optimizer not in ("nesterov", "sgd"):
            raise ValueError(
                "outer_optimizer must be 'nesterov' or 'sgd', got "
                f"{self.outer_optimizer!r}")

    def resolved_policy(self) -> QuantPolicy:
        """The effective QuantPolicy (``policy``, else uniform fp)."""
        if self.policy is None:
            return QuantPolicy.uniform(QuantConfig(name="fp"))
        return QuantPolicy.coerce(self.policy)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    specs: Any                      # pytree of PartitionSpec, aligned to params
    paths: Any                      # pytree of path strings
    gather_dims: Dict[str, Optional[int]]   # path -> fsdp dim (slice coords)
    tp_dims: Dict[str, Optional[int]]       # path -> TP dim (slice coords)
    dp_axes: Tuple[str, ...]
    n_dp: int
    n_model: int

    def shardings(self, mesh):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), self.specs)

    def manual_specs(self):
        """in_specs for shard_map: only the manual (dp) part of each spec."""
        dp = set(self.dp_axes)

        def strip(spec):
            ent = []
            for e in spec:
                if isinstance(e, (tuple, list)):
                    kept = tuple(a for a in e if a in dp)
                    ent.append(kept if kept else None)
                else:
                    ent.append(e if e in dp else None)
            return P(*ent)

        return jax.tree_util.tree_map(
            strip, self.specs, is_leaf=lambda x: isinstance(x, P))

    def full_shard_dims(self) -> Dict[str, Optional[int]]:
        """path -> dp-shard dim in FULL leaf coordinates (stacked leading
        dims included; ``gather_dims`` is in per-repeat slice coords). The
        fused fsdp exchange lays its group buffers out by these."""
        specs = jax.tree_util.tree_leaves(
            self.specs, is_leaf=lambda x: isinstance(x, P))
        paths = jax.tree_util.tree_leaves(self.paths)
        return {p: spec_dp_dim(s, self.dp_axes)
                for p, s in zip(paths, specs)}


def _dp_axes(mesh) -> Tuple[str, ...]:
    # the single shared dp-axis selection (utils/sharding.dp_axis_names):
    # the hierarchy split below relies on this exact ordering, so per-file
    # copies of the tuple comprehension are an actual correctness bug
    return dp_axis_names(mesh)


def _async_local_steps(tcfg: TrainConfig, dp_axes) -> int:
    """Effective inner-window length H: > 1 only when the temporal
    ``two_level_async`` hierarchy is active after resolution (H=1 resolves
    to the literal ``two_level`` path, so everything below behaves as if
    the temporal tier didn't exist — the bit-identity anchor)."""
    if comm.resolve_hierarchy(tcfg.hierarchy, dp_axes,
                              tcfg.local_steps) == "two_level_async":
        return tcfg.local_steps
    return 1


def _exchange_axes(tcfg: TrainConfig, dp_axes: Tuple[str, ...], mesh,
                   plan: Optional["ShardingPlan"] = None
                   ) -> Tuple[Tuple[str, ...], Tuple[str, ...], int]:
    """Resolve ``tcfg.hierarchy`` against the mesh and the active exchange
    path: ``(intra_axes, inter_axes, n_intra)``. Flat mode (and every
    degenerate case) returns ``((), dp_axes, 1)``.

    Two-level needs the fused engines (the per-leaf fallbacks keep the
    flat combined-axis exchange): an explicitly requested "two_level" that
    cannot run warns; "auto" falls back silently.

    ``two_level_async`` with H > 1 validates strictly instead of falling
    back: it needs an inter-pod axis to run the outer sync over, and
    dropping the sync silently would train the pods independently. When
    the intra half degenerates (no ``data`` axis, or size 1) the OUTER
    exchange runs flat over all dp axes — inner steps then sync over
    nothing, which is plain DiLoCo local SGD.
    """
    flat = (), tuple(dp_axes), 1
    if _async_local_steps(tcfg, dp_axes) > 1:
        if not dp_axes or not any(a in comm.INTER_AXIS_NAMES
                                  for a in dp_axes):
            raise ValueError(
                "hierarchy='two_level_async' with local_steps="
                f"{tcfg.local_steps} needs an inter-pod dp axis "
                f"({comm.INTER_AXIS_NAMES}) to run the outer sync over — "
                f"dp axes are {tuple(dp_axes)}; build the mesh with "
                "--pods >= 2")
        intra, inter = comm.split_dp_axes(dp_axes, "two_level")
        if not intra:
            return flat
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_intra = int(np.prod([sizes[a] for a in intra]))
        return flat if n_intra <= 1 else (intra, inter, n_intra)
    if not dp_axes:
        return flat
    intra, inter = comm.split_dp_axes(dp_axes, tcfg.hierarchy)
    if not intra:
        return flat
    if tcfg.mode == "replicated":
        fused_ok = tcfg.fused_exchange
        why = "fused_exchange=False (per-leaf replicated exchange)"
    else:
        fused_ok = plan is not None and _fused_fsdp_active(tcfg, plan)
        why = "the per-leaf fsdp gather path (fused_exchange=False or " \
              "model parallelism active)"
    if not fused_ok:
        if tcfg.hierarchy == "two_level":
            warnings.warn(
                f"hierarchy='two_level' needs the fused exchange but {why} "
                f"is selected — falling back to the flat combined-axis "
                f"exchange", stacklevel=2)
        return flat
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_intra = int(np.prod([sizes[a] for a in intra]))
    if n_intra <= 1:
        return flat
    return intra, inter, n_intra


def plan_sharding(model: LM, aparams, mesh) -> ShardingPlan:
    """Choose per-leaf FSDP + TP dims from abstract parameter shapes."""
    return plan_sharding_shapes(
        model, aparams, dp_axes=_dp_axes(mesh),
        axis_sizes=dict(zip(mesh.axis_names, mesh.devices.shape)))


def plan_sharding_shapes(model: LM, aparams, *, dp_axes: Tuple[str, ...],
                         axis_sizes: Dict[str, int]) -> ShardingPlan:
    """Mesh-free core of :func:`plan_sharding`: the plan depends only on
    the axis names/sizes, so static accounting callers (benchmarks) can
    build one without constructing a device mesh."""
    cfg = model.cfg
    n_dp = int(np.prod([axis_sizes[a] for a in dp_axes])) if dp_axes else 1
    n_model = axis_sizes.get("model", 1)
    paths = model.param_paths(aparams)
    gather_dims: Dict[str, Optional[int]] = {}
    tp_dims: Dict[str, Optional[int]] = {}

    def leaf_spec(path: str, leaf):
        shape = leaf.shape
        stacked = path.startswith("g") or path.startswith("enc/g")
        off = 1 if stacked else 0
        slice_shape = shape[off:]
        # no dp axes (e.g. a model-only mesh) -> nothing to shard over
        fdim = (choose_fsdp_dim(slice_shape, n_dp,
                                prefer_sizes=(cfg.d_model,))
                if dp_axes else None)
        gather_dims[path] = fdim
        # TP dim: prefer the experts dim, else the largest remaining dim
        tp_candidates = [
            i for i, s in enumerate(slice_shape)
            if i != fdim and s % n_model == 0 and s >= n_model
        ]
        tdim = None
        if tp_candidates:
            n_exp = cfg.moe.num_experts if cfg.moe else -1
            pref = [i for i in tp_candidates if slice_shape[i] == n_exp]
            tdim = pref[0] if pref else max(tp_candidates,
                                            key=lambda i: slice_shape[i])
        tp_dims[path] = tdim if n_model > 1 else None
        ent = [None] * len(shape)
        if fdim is not None:
            ent[off + fdim] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        if tdim is not None and n_model > 1:
            ent[off + tdim] = "model"
        return P(*ent)

    specs = jax.tree_util.tree_map(leaf_spec, paths, aparams)
    return ShardingPlan(specs=specs, paths=paths, gather_dims=gather_dims,
                        tp_dims=tp_dims, dp_axes=dp_axes, n_dp=n_dp,
                        n_model=n_model)


def _make_optimizer(tcfg: TrainConfig):
    if tcfg.optimizer == "sgd":
        return opt_lib.sgd_momentum(momentum=tcfg.momentum,
                                    weight_decay=tcfg.weight_decay)
    if tcfg.optimizer == "adamw":
        return opt_lib.adamw(weight_decay=tcfg.weight_decay)
    raise ValueError(tcfg.optimizer)


def _fused_fsdp_active(tcfg: TrainConfig, plan: ShardingPlan) -> bool:
    """Whether the fused whole-tree fsdp exchange runs. Pure-dp meshes
    only: flattening TP-sharded cotangents into a dp buffer would force
    XLA to replicate them over ``model``, so TP keeps the per-leaf gather
    (with its nested-manual trick)."""
    return (tcfg.mode == "fsdp" and tcfg.fused_exchange
            and bool(plan.dp_axes) and plan.n_model == 1)


def _ef_group_sizes(aparams, tcfg: TrainConfig, plan: ShardingPlan,
                    mesh) -> Optional[Tuple[Optional[int], ...]]:
    """Group-aligned per-worker residual-buffer sizes for the TUPLE form
    of error feedback (fused fsdp, and the two-level fused replicated
    exchange whose residuals live on the quantized inter axis), with None
    entries for identity groups. Returns None overall when EF is off, a
    fully-fp policy leaves nothing to feed back, or EF rides the
    params-shaped tree instead (flat replicated mode)."""
    if not tcfg.error_feedback:
        return None
    intra, inter, n_intra = _exchange_axes(tcfg, plan.dp_axes, mesh, plan)
    if tcfg.mode == "fsdp":
        if not _fused_fsdp_active(tcfg, plan):
            return None
        fex = comm.FsdpExchange.build(
            tcfg.resolved_policy(), aparams, plan.dp_axes, paths=plan.paths,
            shard_dims=plan.full_shard_dims(), n_shards=plan.n_dp,
            intra_axes=intra, n_intra=n_intra, by_rule=tcfg.group_by_rule)
        sizes = fex.ef_group_sizes()
        return sizes if any(n is not None for n in sizes) else None
    if not intra and _async_local_steps(tcfg, plan.dp_axes) <= 1:
        return None          # flat replicated EF stays params-shaped
    # two-level shards — or, in two_level_async mode with a degenerate
    # intra half (n_intra == 1), full per-worker group buffers: the outer
    # delta stream only exists at sync steps, so its residuals live in
    # group-aligned buffers either way, never a params-shaped tree
    pex = comm.PartitionedExchange.build(
        tcfg.resolved_policy(), aparams, inter, paths=plan.paths,
        intra_axes=intra, by_rule=tcfg.group_by_rule)
    sizes = pex.ef_shard_sizes(n_intra)
    return sizes if any(n is not None for n in sizes) else None


def init_state(model: LM, mesh, tcfg: TrainConfig, key) -> TrainState:
    """Initialize TrainState with plan-consistent shardings.

    In ``two_level_async`` mode (H > 1) params/opt leaves are STACKED with
    a leading worker axis sharded over the dp axes: inner steps make them
    pod-divergent, and the stacked layout keeps every pod's copy visible
    to shardings, ``device_get`` and checkpoints (required for bit-exact
    mid-window resume). The replicated outer anchor/momentum live in
    ``TrainState.outer``.
    """
    aparams = jax.eval_shape(model.init, key)
    plan = plan_sharding(model, aparams, mesh)
    optimizer = _make_optimizer(tcfg)
    ef_sizes = _ef_group_sizes(aparams, tcfg, plan, mesh)
    dp_ent = (plan.dp_axes if len(plan.dp_axes) > 1
              else (plan.dp_axes[0] if plan.dp_axes else None))
    h_async = _async_local_steps(tcfg, plan.dp_axes)
    if h_async > 1:
        _exchange_axes(tcfg, plan.dp_axes, mesh, plan)  # strict validation

    def build(key):
        params = model.init(key)
        if ef_sizes is not None:
            # per-worker residual buffers, stacked over the dp axes
            # (group-aligned; identity groups carry None). Covers fused
            # fsdp AND the two-level replicated exchange, whose residuals
            # are intra shards on the quantized inter axis.
            ef = tuple(None if n is None
                       else jnp.zeros((plan.n_dp * n,), jnp.float32)
                       for n in ef_sizes)
        elif (tcfg.error_feedback and tcfg.mode == "replicated"
              and h_async <= 1):
            ef = jax.tree_util.tree_map(jnp.zeros_like, params)
        else:
            ef = None
        if h_async > 1:
            def stack(t):
                return jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None],
                                               (plan.n_dp,) + x.shape), t)

            return TrainState(
                params=stack(params), opt=stack(optimizer.init(params)),
                step=jnp.int32(0), ef=ef,
                outer=OuterState(
                    anchor=params,
                    mom=jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32),
                        params)))
        return TrainState(params=params, opt=optimizer.init(params),
                          step=jnp.int32(0), ef=ef)

    if tcfg.mode == "replicated":
        out_sh = None
        if h_async > 1:
            rep = NamedSharding(mesh, P())
            stk = NamedSharding(mesh, P(dp_ent))
            aout = jax.eval_shape(build, key)
            out_sh = jax.tree_util.tree_map(lambda _: rep, aout)
            out_sh = out_sh._replace(
                params=jax.tree_util.tree_map(lambda _: stk, aout.params),
                opt=jax.tree_util.tree_map(lambda _: stk, aout.opt),
                ef=(None if aout.ef is None else jax.tree_util.tree_map(
                    lambda _: stk, aout.ef)))
    else:
        psh = plan.shardings(mesh)
        out_sh = TrainState(params=psh,
                            opt=jax.tree_util.tree_map(lambda s: s, psh),
                            step=NamedSharding(mesh, P()))
        if tcfg.optimizer == "adamw":
            out_sh = out_sh._replace(opt=opt_lib.AdamState(
                mu=psh, nu=psh, count=NamedSharding(mesh, P())))
        if ef_sizes is not None:
            out_sh = out_sh._replace(ef=tuple(
                None if n is None else NamedSharding(mesh, P(dp_ent))
                for n in ef_sizes))
    return jax.jit(build, out_shardings=out_sh)(key)


class ExchangeEngines(NamedTuple):
    """The exchange machinery one train step is built around. Produced
    by :func:`exchange_engines` and consumed by both
    :func:`make_train_step` and the ``repro.analysis`` auditor — the
    collective-budget expectations are derived from these SAME objects,
    so the accounting and the traced step cannot drift apart."""

    pex: Any                        # PartitionedExchange (replicated path)
    fex: Any                        # FsdpExchange | None (fused fsdp path)
    plan: Any                       # ShardingPlan
    policy: Any                     # resolved QuantPolicy
    intra_axes: Tuple[str, ...]     # fast fp (ICI) axes; () = flat
    inter_axes: Tuple[str, ...]     # quantized (DCN) axes
    n_intra: int
    fused_fsdp: bool


def exchange_engines(model: LM, mesh, tcfg: TrainConfig,
                     aparams=None) -> ExchangeEngines:
    """Build the exchange engines exactly as :func:`make_train_step`
    wires them (same policy resolution, hierarchy split, chunking)."""
    dp_axes = _dp_axes(mesh)
    if aparams is None:
        aparams = jax.eval_shape(model.init, jax.random.key(0))
    plan = plan_sharding(model, aparams, mesh)
    policy = tcfg.resolved_policy()
    # hierarchy resolution: two_level splits the dp axes into fast intra
    # (ICI, full-precision mean) and slow inter (DCN, quantized Algorithm
    # 2) halves; flat (and every degenerate case) keeps intra empty and
    # the engines behave exactly as before
    intra_axes, inter_axes, n_intra = _exchange_axes(tcfg, dp_axes, mesh,
                                                     plan)
    # partitioned fused engine: leaves grouped by resolved quantizer into
    # contiguous segments, one fused exchange per policy group (a uniform
    # policy degenerates to the single-group engine, bit-identical to the
    # pre-policy fused exchange)
    pex = comm.PartitionedExchange.build(
        policy, aparams, inter_axes, paths=plan.paths,
        use_kernels=tcfg.use_kernels,
        max_chunk_elems=tcfg.exchange_chunk_elems,
        intra_axes=intra_axes,
        pipeline_chunks=tcfg.pipeline_chunks,
        by_rule=tcfg.group_by_rule)
    # fused fsdp engine: ONE custom-VJP over the whole sharded tree whose
    # forward is a fused per-group parameter all-gather and whose backward
    # is one fused quantized reduce-scatter per sharded policy group (+
    # one fused all-reduce per replicated group) with the EF residual
    # stream riding the residual-buffer cotangent — O(#groups) gradient
    # collectives per step (see core/comm/fsdp_exchange.py)
    fused_fsdp = _fused_fsdp_active(tcfg, plan)
    fex = None
    if fused_fsdp:
        fex = comm.FsdpExchange.build(
            policy, aparams, dp_axes, paths=plan.paths,
            shard_dims=plan.full_shard_dims(), n_shards=plan.n_dp,
            use_kernels=tcfg.use_kernels,
            max_chunk_elems=tcfg.exchange_chunk_elems,
            intra_axes=intra_axes, n_intra=n_intra,
            pipeline_chunks=tcfg.pipeline_chunks,
            by_rule=tcfg.group_by_rule)
    return ExchangeEngines(pex=pex, fex=fex, plan=plan, policy=policy,
                           intra_axes=intra_axes, inter_axes=inter_axes,
                           n_intra=n_intra, fused_fsdp=fused_fsdp)


def specialize_engines(eng: ExchangeEngines,
                       policy: QuantPolicy) -> ExchangeEngines:
    """Re-materialize a by-rule-grouped engine bundle for a new concrete
    policy WITHOUT rebuilding layouts: same groups, same order, same EF
    shapes — only the per-group QuantConfigs/quantizers change. This is
    the per-phase specialization step of the adaptive bit schedule."""
    pex = eng.pex.specialize(policy)
    fex = eng.fex.specialize(policy) if eng.fex is not None else None
    return eng._replace(pex=pex, fex=fex, policy=policy)


def make_train_step(model: LM, mesh, tcfg: TrainConfig, lr_fn=None,
                    aparams=None, engines: Optional[ExchangeEngines] = None):
    """Returns (step_fn, plan). step_fn(state, batch, key) ->
    (state, metrics); jit-compiled shard_map over the dp axes.

    ``engines`` optionally supplies a prebuilt :class:`ExchangeEngines`
    (e.g. a specialized per-phase bundle from :func:`specialize_engines`);
    its policy must match ``tcfg.resolved_policy()``."""
    lr_fn = lr_fn or constant_lr(0.1)
    cfg = model.cfg
    dp_axes = _dp_axes(mesh)
    if aparams is None:
        aparams = jax.eval_shape(model.init, jax.random.key(0))
    eng = (engines if engines is not None
           else exchange_engines(model, mesh, tcfg, aparams=aparams))
    plan, policy = eng.plan, eng.policy
    optimizer = _make_optimizer(tcfg)
    intra_axes, inter_axes, n_intra = (eng.intra_axes, eng.inter_axes,
                                       eng.n_intra)
    two_level = bool(intra_axes)
    pex, fex, fused_fsdp = eng.pex, eng.fex, eng.fused_fsdp
    tree_gather = None
    if fused_fsdp:
        if fex.layout.size > 1_000_000_000:
            # the fused path holds the whole gathered bf16 tree + full
            # f32 cotangent buffers per device during the step, vs the
            # per-leaf path's one-scanned-layer-group residency — make
            # the trade-off visible before a 27B+ config OOMs on it
            warnings.warn(
                f"fused fsdp exchange gathers all {fex.layout.size:.2e} "
                f"parameters per device each step (O(full model) live "
                f"memory); if parameter-memory-bound, set "
                f"fused_exchange=False for per-layer-group ZeRO-3 "
                f"residency (see EXPERIMENTS.md)", stacklevel=2)
        tree_gather = comm.make_fused_tree_gather(
            fex, compute_dtype=tcfg.compute_dtype)
    # a fully-fp policy has nothing to feed back: no ef buffers at all
    # (matches _fsdp_ef_group_sizes / init_state)
    use_fsdp_ef = (tcfg.error_feedback and fused_fsdp
                   and not fex.is_identity)
    if tcfg.error_feedback and tcfg.mode == "fsdp" and not fused_fsdp:
        warnings.warn(
            "error_feedback needs the fused fsdp exchange (fused_exchange="
            "True on a pure-dp mesh); the per-leaf fsdp path has no "
            "residual stream — ignoring error_feedback", stacklevel=2)
    collect_stats = tcfg.collect_stats
    if collect_stats and not (
            fused_fsdp or (tcfg.mode == "replicated"
                           and tcfg.fused_exchange)):
        warnings.warn(
            "collect_stats needs a fused exchange path (there are no "
            "per-group wire buffers to measure on the per-leaf paths) — "
            "ignoring collect_stats", stacklevel=2)
        collect_stats = False

    leaf_qz_cache: Dict[QuantConfig, Any] = {}

    def resolve_leaf(path):
        """(QuantConfig, Quantizer) for one leaf path under the policy."""
        cfg = policy.resolve(path)
        if cfg not in leaf_qz_cache:
            leaf_qz_cache[cfg] = cfg.to_quantizer()
        return cfg, leaf_qz_cache[cfg]

    def make_gather_fn(step_key):
        if tcfg.mode == "replicated":
            return None  # identity gather inside model

        cache: Dict[str, Any] = {}

        def gather(path, leaf, salt):
            dim = plan.gather_dims.get(path)
            if path not in cache:
                # each leaf's backward quantizes with its POLICY-resolved
                # quantizer (mixed-precision gradient compression in fsdp
                # mode rides the per-leaf gather)
                cfg_l, qz_l = resolve_leaf(path)
                if dim is None:
                    cache[path] = comm.make_replicated_gather(
                        qz_l, dp_axes, compute_dtype=tcfg.compute_dtype,
                        server_requant=cfg_l.server_requant,
                        use_kernels=tcfg.use_kernels)
                else:
                    cache[path] = comm.make_fsdp_gather(
                        qz_l, dp_axes, dim=dim,
                        tp_dim=plan.tp_dims.get(path),
                        compute_dtype=tcfg.compute_dtype,
                        use_kernels=tcfg.use_kernels)
            key = jax.random.fold_in(step_key,
                                     zlib.crc32(path.encode()) & 0x7FFFFFFF)
            key = jax.random.fold_in(key, salt)
            return cache[path](leaf, key)

        return gather

    def local_step(state: TrainState, batch, key):
        step_key = jax.random.fold_in(key, state.step)

        if fused_fsdp:
            # whole-tree fused gather/exchange: grads come back aligned
            # with the STORED parameter shards; the new EF residuals ride
            # the cotangent of the residual-buffer argument
            k = jax.random.fold_in(step_key, _FUSED_SALT)

            def fsdp_loss_fn(params, ef_bufs):
                return model.loss(tree_gather(params, ef_bufs, k), batch)

            if use_fsdp_ef:
                (loss, metrics), (grads, new_ef) = jax.value_and_grad(
                    fsdp_loss_fn, argnums=(0, 1), has_aux=True)(
                        state.params, state.ef)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    fsdp_loss_fn, has_aux=True)(state.params, None)
                new_ef = state.ef
            stats = None
            if collect_stats:
                # post-exchange approximation from the stored shards (the
                # pre-exchange cotangent buffers live inside the custom
                # VJP); pmean over dp in _finish gives the fleet view
                stats = fex.group_stats_stored(
                    grads, new_ef if use_fsdp_ef else None)
            return _finish(state, grads, new_ef, loss, metrics, stats)

        gather = make_gather_fn(step_key)

        def loss_fn(params):
            if gather is None:
                return model.loss(params, batch)
            return model.loss(params, batch, gather=gather)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)

        new_ef = state.ef
        stats = None
        use_ef = (tcfg.error_feedback and state.ef is not None
                  and not pex.is_identity)
        if use_ef and not two_level:
            # error feedback: compensate last step's local quantization
            # error before quantizing (Karimireddy et al. line of work,
            # cited by the paper as complementary). Two-level residuals
            # are intra SHARDS (added after the fp intra scatter below),
            # not a params-shaped tree.
            grads = jax.tree_util.tree_map(
                lambda g, e: g + e.astype(g.dtype), grads, state.ef)

        if tcfg.mode == "replicated" and dp_axes:
            if tcfg.fused_exchange and two_level:
                # two-level fused exchange: fp intra-pod scatter-mean ->
                # quantized Algorithm 2 on the shard over the inter (pod)
                # axes only -> fp intra gather. EF residuals live on the
                # quantized shard (per-group tuple in TrainState.ef).
                k = jax.random.fold_in(step_key, _FUSED_SALT)
                bufs = pex.layout.flatten_groups(grads)
                shards, valids = pex.intra_scatter_parts(bufs)
                if use_ef:
                    shards = tuple(s if e is None else s + e
                                   for s, e in zip(shards, state.ef))
                    local = pex.local_qdq_shard_parts(shards, k, valids)
                    new_ef = tuple(None if e is None else s - l
                                   for e, s, l in zip(state.ef, shards,
                                                      local))
                if collect_stats:
                    # measured on the EF-compensated intra shards — what
                    # the quantized inter exchange actually encodes
                    stats = pex.group_stats(
                        shards, new_ef if use_ef else None)
                mean_shards = pex.exchange_shard_parts(shards, k, valids)
                grads = pex.layout.unflatten_groups(
                    pex.intra_gather_parts(mean_shards))
            elif tcfg.fused_exchange:
                # partitioned fused Algorithm 2: leaves grouped by resolved
                # quantizer into contiguous segments, one fused quantized
                # all-reduce per policy group — O(#groups) collectives per
                # step, never O(#leaves) (see core/comm/exchange.py)
                k = jax.random.fold_in(step_key, _FUSED_SALT)
                bufs = pex.layout.flatten_groups(grads)
                ef_bufs = None
                if use_ef:
                    local = pex.local_qdq_parts(bufs, k)
                    ef_bufs = [f - l for f, l in zip(bufs, local)]
                    new_ef = pex.layout.unflatten_groups(
                        ef_bufs, restore_dtype=False)
                if collect_stats:
                    stats = pex.group_stats(bufs, ef_bufs)
                grads = pex.layout.unflatten_groups(
                    pex.exchange_parts(bufs, k))
            else:
                # legacy per-leaf quantized all-reduce of local grads
                def exchange(path, g):
                    cfg_l, qz_l = resolve_leaf(path)
                    flat = g.astype(jnp.float32).reshape(-1)
                    k = jax.random.fold_in(
                        step_key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
                    out = comm.quantized_all_reduce_mean(
                        flat, qz_l, k, dp_axes,
                        server_requant=cfg_l.server_requant,
                        use_kernels=tcfg.use_kernels)
                    return out.reshape(g.shape).astype(g.dtype)

                if use_ef:
                    def residual(path, g):
                        _, qz_l = resolve_leaf(path)
                        if qz_l.is_identity:
                            return jnp.zeros(g.shape, jnp.float32)
                        flat = g.astype(jnp.float32).reshape(-1)
                        k = jax.random.fold_in(
                            step_key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
                        local = comm.local_qdq_comm_layout(
                            flat, qz_l, k, dp_axes,
                            use_kernels=tcfg.use_kernels)
                        return (flat - local).reshape(g.shape)

                    new_ef = jax.tree_util.tree_map(
                        residual, model.param_paths(state.params), grads)
                grads = jax.tree_util.tree_map(
                    exchange, model.param_paths(state.params), grads)
        elif tcfg.mode == "replicated" and not dp_axes:
            # single-machine Algorithm 2: quantize->dequantize locally
            if not pex.is_identity and tcfg.fused_exchange:
                k = jax.random.fold_in(step_key, _FUSED_SALT)
                bufs = pex.layout.flatten_groups(grads)
                qbufs = pex.qdq_local_parts(bufs, k)
                ef_bufs = None
                if use_ef:
                    ef_bufs = [f - q for f, q in zip(bufs, qbufs)]
                    new_ef = pex.layout.unflatten_groups(
                        ef_bufs, restore_dtype=False)
                if collect_stats:
                    stats = pex.group_stats(bufs, ef_bufs)
                grads = pex.layout.unflatten_groups(qbufs)
            elif not pex.is_identity:
                def qdq(path, g):
                    _, qz_l = resolve_leaf(path)
                    if qz_l.is_identity:
                        return g
                    k = jax.random.fold_in(
                        step_key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
                    return qz_l.qdq(g.astype(jnp.float32).reshape(-1), k
                                    ).reshape(g.shape).astype(g.dtype)

                quantized = jax.tree_util.tree_map(
                    qdq, model.param_paths(state.params), grads)
                if use_ef:
                    new_ef = jax.tree_util.tree_map(
                        lambda g, q: (g - q).astype(jnp.float32),
                        grads, quantized)
                grads = quantized

        return _finish(state, grads, new_ef, loss, metrics, stats)

    def _finish(state: TrainState, grads, new_ef, loss, metrics,
                stats=None):
        lr = lr_fn(state.step)
        updates, new_opt = optimizer.update(grads, state.opt, state.params,
                                            lr)
        new_params = opt_lib.apply_updates(state.params, updates)
        if stats is not None:
            # (n_groups, 3) controller feed; pmean'd with the rest below
            metrics = dict(metrics, exchange_stats=stats)
        if dp_axes:
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, dp_axes), metrics)
            loss = jax.lax.pmean(loss, dp_axes)
        metrics = dict(metrics, loss=loss, lr=lr)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1, ef=new_ef), metrics

    # NOTE both jit paths donate the train state (params + optimizer + EF
    # residuals update in place); axis_names is an ORDERED tuple end-to-end
    # — a set would iterate in PYTHONHASHSEED-dependent order and
    # multi-process workers could lower collectives with different axis
    # orderings (see core/comm/collectives._names).
    if not dp_axes or tcfg.mode == "replicated":
        # replicated mode still runs under shard_map for the dp collectives
        if not dp_axes:
            return jax.jit(local_step, donate_argnums=(0,)), plan
        if _async_local_steps(tcfg, dp_axes) > 1:
            # temporal two_level_async hierarchy: H inner steps synced
            # only over the intra (ICI) axes, then ONE quantized outer
            # exchange of the window's parameter delta — a two-function
            # dispatcher instead of a single compiled step
            return _make_async_train_step(model, mesh, tcfg, lr_fn,
                                          optimizer, eng, collect_stats,
                                          aparams), plan
        pspec = jax.tree_util.tree_map(lambda _: P(), aparams)
        rep_ef_sizes = None
        if tcfg.error_feedback and two_level:
            # two-level EF: per-group intra-shard buffers stacked over the
            # dp axes (mirrors _ef_group_sizes / init_state)
            sizes = pex.ef_shard_sizes(n_intra)
            rep_ef_sizes = (sizes if any(n is not None for n in sizes)
                            else None)
        rep_dp_ent = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        if rep_ef_sizes is not None:
            ef_specs = tuple(None if n is None else P(rep_dp_ent)
                             for n in rep_ef_sizes)
        else:
            ef_specs = pspec if tcfg.error_feedback else None
        state_specs = TrainState(
            params=pspec, opt=_opt_specs(optimizer, tcfg, pspec), step=P(),
            ef=ef_specs)
        batch_specs = {"tokens": P(dp_axes if len(dp_axes) > 1
                                   else dp_axes[0])}
        if cfg.encoder:
            batch_specs["enc_embeds"] = P(dp_axes if len(dp_axes) > 1
                                          else dp_axes[0])
        rep_metric_specs = {"nll": P(), "aux": P(), "tokens": P(),
                            "loss": P(), "lr": P()}
        if collect_stats:
            rep_metric_specs["exchange_stats"] = P()
        fn = shard_map(local_step, mesh=mesh,
                       in_specs=(state_specs, batch_specs, P()),
                       out_specs=(state_specs, rep_metric_specs),
                       axis_names=dp_axes, check_vma=False)
        return jax.jit(fn, donate_argnums=(0,)), plan

    # fsdp mode
    manual = plan.manual_specs()
    dp_ent = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    state_specs = TrainState(
        params=manual, opt=_opt_specs(optimizer, tcfg, manual), step=P(),
        ef=(tuple(None if n is None else P(dp_ent)
                  for n in fex.ef_group_sizes())
            if use_fsdp_ef else None))
    batch_specs = {"tokens": P(dp_ent)}
    if cfg.encoder:
        batch_specs["enc_embeds"] = P(dp_ent)
    metric_specs = {"nll": P(), "aux": P(), "tokens": P(), "loss": P(),
                    "lr": P()}
    if collect_stats:
        metric_specs["exchange_stats"] = P()
    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(state_specs, batch_specs, P()),
                   out_specs=(state_specs, metric_specs),
                   axis_names=dp_axes, check_vma=False)
    return jax.jit(fn, donate_argnums=(0,)), plan


def _opt_specs(optimizer, tcfg: TrainConfig, pspec):
    if tcfg.optimizer == "adamw":
        return opt_lib.AdamState(mu=pspec, nu=pspec, count=P())
    return pspec  # sgd momentum mirrors params


class AsyncTrainStep:
    """Two-time-scale ``step_fn(state, batch, key)`` for the temporal
    ``two_level_async`` hierarchy: a host-side dispatcher over TWO
    compiled shard_maps —

      ``inner_fn``   one inner optimizer step on the worker's local
                     (stacked) params, gradients pmean'd over the fast
                     intra (ICI) axes only: ZERO wire collectives, no
                     rounding-stream draws, the DCN tier is never touched;
      ``sync_fn``    the window's H-th inner update followed by ONE
                     quantized Algorithm-2 exchange of the outer
                     pseudo-gradient (``anchor - local_params``) over the
                     DCN axes through the same fused engines the spatial
                     two_level step uses (policy groups, EF residuals,
                     ``pipeline_chunks`` all compose), feeding the outer
                     SGD-momentum/Nesterov optimizer in
                     ``TrainState.outer`` — after which every worker holds
                     the identical new anchor.

    The window position is read host-side from the ABSOLUTE step counter
    (like :class:`ScheduledTrainStep` reads its phase), so a checkpoint
    restored mid-window resumes at the right phase with no extra
    bookkeeping: sync fires on steps H-1, 2H-1, ... — the H-th update of
    every window."""

    def __init__(self, inner_fn, sync_fn, local_steps: int):
        self.inner_fn, self.sync_fn = inner_fn, sync_fn
        self.local_steps = int(local_steps)

    def is_sync_step(self, step: int) -> bool:
        return (int(step) + 1) % self.local_steps == 0

    def __call__(self, state: TrainState, batch, key):
        if self.is_sync_step(int(state.step)):
            return self.sync_fn(state, batch, key)
        return self.inner_fn(state, batch, key)


def _make_async_train_step(model: LM, mesh, tcfg: TrainConfig, lr_fn,
                           optimizer, eng: ExchangeEngines, collect_stats,
                           aparams) -> AsyncTrainStep:
    """Build the two compiled halves of :class:`AsyncTrainStep`.

    State layout (see :func:`init_state`): params/opt leaves carry a
    leading worker axis sharded over the dp axes (inner steps make them
    pod-divergent; the stacked layout keeps that divergence honest in
    shardings and checkpoints — each worker sees its own ``leaf[0]``
    slice inside the shard_map), while ``outer.anchor``/``outer.mom`` are
    truly replicated (rewritten only at sync steps from the exchange's
    identical output)."""
    cfg = model.cfg
    dp_axes = eng.plan.dp_axes
    pex, intra_axes, n_intra = eng.pex, eng.intra_axes, eng.n_intra
    two_level = bool(intra_axes)
    nesterov = tcfg.outer_optimizer == "nesterov"
    outer_lr, outer_mu = tcfg.outer_lr, tcfg.outer_momentum
    ef_sizes = pex.ef_shard_sizes(n_intra)
    use_ef = (tcfg.error_feedback
              and any(s is not None for s in ef_sizes))

    def unstack(t):
        return jax.tree_util.tree_map(lambda x: x[0], t)

    def stack(t):
        return jax.tree_util.tree_map(lambda x: x[None], t)

    def _inner_update(state: TrainState, batch):
        """The shared inner computation: pod-synchronous gradient + one
        inner optimizer step on this worker's local parameter view."""
        params = unstack(state.params)
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        if intra_axes:
            # ONE multi-operand psum over the fast ICI axes; inner steps
            # never touch the DCN tier (the point of the temporal split)
            grads = jax.lax.pmean(grads, intra_axes)
        lr = lr_fn(state.step)
        updates, new_opt = optimizer.update(grads, unstack(state.opt),
                                            params, lr)
        return (opt_lib.apply_updates(params, updates), new_opt, loss,
                metrics, lr)

    def _pack(state, new_params, new_opt, new_ef, outer, loss, metrics,
              lr, stats=None):
        if stats is not None:
            metrics = dict(metrics, exchange_stats=stats)
        # scalar logging reductions over the FULL dp mesh (negligible
        # bytes; the gradient payload itself never crosses pods here)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp_axes), metrics)
        loss = jax.lax.pmean(loss, dp_axes)
        metrics = dict(metrics, loss=loss, lr=lr)
        return TrainState(params=stack(new_params), opt=stack(new_opt),
                          step=state.step + 1, ef=new_ef,
                          outer=outer), metrics

    def inner_step(state: TrainState, batch, key):
        del key              # inner steps draw no rounding bits at all
        new_params, new_opt, loss, metrics, lr = _inner_update(state,
                                                               batch)
        return _pack(state, new_params, new_opt, state.ef, state.outer,
                     loss, metrics, lr)

    def sync_step(state: TrainState, batch, key):
        new_params, new_opt, loss, metrics, lr = _inner_update(state,
                                                               batch)
        # outer pseudo-gradient: the window's parameter delta — identical
        # within a pod (inner grads are intra-pmean'd), divergent across
        # pods; exactly the arbitrary-distribution input the optimal-
        # condition level fits are built for
        delta = jax.tree_util.tree_map(
            lambda a, p: (a - p).astype(jnp.float32),
            state.outer.anchor, new_params)
        step_key = jax.random.fold_in(key, state.step)
        k = jax.random.fold_in(step_key, _FUSED_SALT)
        bufs = pex.layout.flatten_groups(delta)
        new_ef = state.ef
        stats = None
        if two_level:
            # the literal two_level wire path, fed the delta: fp intra
            # scatter -> EF add on the shard -> quantized Algorithm 2
            # over the pod axes only -> fp intra gather
            shards, valids = pex.intra_scatter_parts(bufs)
            if use_ef:
                shards = tuple(s if e is None else s + e
                               for s, e in zip(shards, state.ef))
                local = pex.local_qdq_shard_parts(shards, k, valids)
                new_ef = tuple(None if e is None else s - l
                               for e, s, l in zip(state.ef, shards,
                                                  local))
            if collect_stats:
                stats = pex.group_stats(shards, new_ef if use_ef else None)
            mean_shards = pex.exchange_shard_parts(shards, k, valids)
            delta_mean = pex.layout.unflatten_groups(
                pex.intra_gather_parts(mean_shards), restore_dtype=False)
        else:
            # degenerate intra half (pods-only dp mesh): the outer
            # exchange runs flat over all dp axes, EF on the full buffers
            if use_ef:
                bufs = tuple(b if e is None else b + e
                             for b, e in zip(bufs, state.ef))
                local = pex.local_qdq_parts(bufs, k)
                new_ef = tuple(None if e is None else b - l
                               for e, b, l in zip(state.ef, bufs, local))
            if collect_stats:
                stats = pex.group_stats(bufs, new_ef if use_ef else None)
            delta_mean = pex.layout.unflatten_groups(
                pex.exchange_parts(bufs, k), restore_dtype=False)
        # outer optimizer on the exchanged mean pseudo-gradient; its
        # output is globally identical, so anchor/mom stay replicated
        mom = jax.tree_util.tree_map(
            lambda m, d: outer_mu * m + d, state.outer.mom, delta_mean)
        upd = (jax.tree_util.tree_map(
                   lambda d, m: d + outer_mu * m, delta_mean, mom)
               if nesterov else mom)
        outer_params = jax.tree_util.tree_map(
            lambda a, u: (a - outer_lr * u).astype(a.dtype),
            state.outer.anchor, upd)
        outer = OuterState(anchor=outer_params, mom=mom)
        return _pack(state, outer_params, new_opt, new_ef, outer, loss,
                     metrics, lr, stats)

    dp_ent = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    stacked = jax.tree_util.tree_map(lambda _: P(dp_ent), aparams)
    aopt = jax.eval_shape(optimizer.init, aparams)
    rep = lambda t: jax.tree_util.tree_map(lambda _: P(), t)  # noqa: E731
    state_specs = TrainState(
        params=stacked,
        opt=jax.tree_util.tree_map(lambda _: P(dp_ent), aopt),
        step=P(),
        ef=(tuple(None if s is None else P(dp_ent) for s in ef_sizes)
            if use_ef else None),
        outer=OuterState(anchor=rep(aparams), mom=rep(aparams)))
    batch_specs = {"tokens": P(dp_ent)}
    if cfg.encoder:
        batch_specs["enc_embeds"] = P(dp_ent)
    inner_metric_specs = {"nll": P(), "aux": P(), "tokens": P(),
                          "loss": P(), "lr": P()}
    sync_metric_specs = dict(inner_metric_specs)
    if collect_stats:
        sync_metric_specs["exchange_stats"] = P()
    inner_fn = jax.jit(
        shard_map(inner_step, mesh=mesh,
                  in_specs=(state_specs, batch_specs, P()),
                  out_specs=(state_specs, inner_metric_specs),
                  axis_names=dp_axes, check_vma=False),
        donate_argnums=(0,))
    sync_fn = jax.jit(
        shard_map(sync_step, mesh=mesh,
                  in_specs=(state_specs, batch_specs, P()),
                  out_specs=(state_specs, sync_metric_specs),
                  axis_names=dp_axes, check_vma=False),
        donate_argnums=(0,))
    return AsyncTrainStep(inner_fn, sync_fn, tcfg.local_steps)


class ScheduledTrainStep:
    """Host-side driver of the adaptive bit budget: a drop-in
    ``step_fn(state, batch, key)`` whose per-group wire bit-width follows
    a :class:`~repro.core.policy.BitBudgetController`.

    Design (recompile-on-phase-boundary, NEVER traced bit-width):

      * ONE bits-independent engine skeleton is built up front with
        ``group_by_rule=True`` — leaves partition by policy RULE index,
        so the group structure (and every EF-residual shape) is identical
        for every bits assignment the schedule can produce;
      * each phase's assignment is materialized into a concrete static
        ``QuantPolicy`` (``schedule.policy_at``), the skeleton is
        re-specialized (:func:`specialize_engines` — swaps quantizers,
        keeps layouts) and compiled into a normal :func:`make_train_step`
        function, held in an LRU keyed by the bits tuple;
      * within a phase the compiled step is BIT-IDENTICAL to a static
        run at that policy (same layouts, same PRNG streams, same single
        ``pallas_call`` encode); a schedule that never changes bits
        compiles exactly one engine and reproduces the static run's
        params stream exactly;
      * with ``tcfg.collect_stats`` the step emits the per-group
        ``exchange_stats`` metric, which is folded per schedule entry and
        fed back to ``controller.observe`` so the next phase's
        water-filling solve is statistics-driven.

    The step counter is read host-side from ``state.step`` — callers must
    keep it consistent with the training loop (the launcher does)."""

    def __init__(self, model: LM, mesh, tcfg: TrainConfig, controller,
                 lr_fn=None, *, aparams=None, max_engines: int = 4):
        if tcfg.policy is not None:
            raise ValueError(
                "ScheduledTrainStep derives the per-phase policy from the "
                "controller's BitSchedule — leave TrainConfig.policy unset")
        self.model, self.mesh, self.lr_fn = model, mesh, lr_fn
        self.controller = controller
        self.schedule = controller.schedule
        # skeleton at the ceiling assignment: any valid assignment yields
        # the same layouts/EF shapes (by-rule grouping), the ceiling just
        # makes the warning-size accounting conservative
        base_policy = self.schedule.policy_at(
            self.schedule.ceil_assignment())
        self.tcfg = dataclasses.replace(tcfg, policy=base_policy,
                                        group_by_rule=True)
        if aparams is None:
            aparams = jax.eval_shape(model.init, jax.random.key(0))
        self.aparams = aparams
        self.skeleton = exchange_engines(model, mesh, self.tcfg,
                                         aparams=aparams)
        self.plan = self.skeleton.plan
        groups = (self.skeleton.fex.layout.groups
                  if self.skeleton.fused_fsdp
                  else self.skeleton.pex.layout.groups)
        self._group_rules = tuple(g.rule_id for g in groups)
        self._group_sizes = tuple(g.size for g in groups)
        if self.controller.group_sizes is None:
            sizes = [0] * self.schedule.n_entries
            for rid, size in zip(self._group_rules, self._group_sizes):
                sizes[rid] += size
            self.controller.group_sizes = tuple(sizes)
        self.max_engines = max(1, int(max_engines))
        self._cache: "OrderedDict[Tuple[Optional[int], ...], Any]" = \
            OrderedDict()
        self.last_assignment: Optional[Tuple[Optional[int], ...]] = None

    @property
    def init_config(self) -> TrainConfig:
        """TrainConfig to ``init_state`` with: by-rule grouping + a
        concrete schedule policy, so EF buffers come out with the (bits-
        invariant) shapes every phase's compiled step expects."""
        return self.tcfg

    @property
    def decisions(self):
        return self.controller.decisions

    def step_fn(self, assignment) -> Any:
        """The compiled step function for one bits assignment (LRU'd)."""
        key = tuple(assignment)
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        policy = self.schedule.policy_at(key)
        eng = specialize_engines(self.skeleton, policy)
        fn, _ = make_train_step(
            self.model, self.mesh,
            dataclasses.replace(self.tcfg, policy=policy), self.lr_fn,
            aparams=self.aparams, engines=eng)
        self._cache[key] = fn
        while len(self._cache) > self.max_engines:
            self._cache.popitem(last=False)
        return fn

    def entry_stats(self, group_stats) -> Tuple[Dict[str, float], ...]:
        """Fold the (n_groups, 3) ``exchange_stats`` metric into one row
        per schedule entry (size-weighted means for sigma_sq/clip_frac,
        summed ef_norm_sq — fsdp splits one rule into sharded +
        replicated groups)."""
        g = np.asarray(jax.device_get(group_stats), dtype=np.float64)
        n = self.schedule.n_entries
        acc, w = np.zeros((n, 3)), np.zeros(n)
        for rid, size, row in zip(self._group_rules, self._group_sizes, g):
            acc[rid, 0] += row[0] * size
            acc[rid, 1] += row[1] * size
            acc[rid, 2] += row[2]
            w[rid] += size
        nz = w > 0
        acc[nz, 0] /= w[nz]
        acc[nz, 1] /= w[nz]
        return tuple({"sigma_sq": float(r[0]), "clip_frac": float(r[1]),
                      "ef_norm_sq": float(r[2])} for r in acc)

    def __call__(self, state: TrainState, batch, key):
        step = int(state.step)
        assignment = self.controller.assignment_at(step)
        self.last_assignment = assignment
        state, metrics = self.step_fn(assignment)(state, batch, key)
        if "exchange_stats" in metrics:
            self.controller.observe(
                self.entry_stats(metrics["exchange_stats"]))
        return state, metrics
