from repro.train.state import OuterState, TrainState
from repro.train.step import (AsyncTrainStep, ShardingPlan, TrainConfig,
                              make_train_step, plan_sharding)

__all__ = ["TrainState", "OuterState", "TrainConfig", "AsyncTrainStep",
           "make_train_step", "plan_sharding", "ShardingPlan"]
