from repro.train.state import TrainState
from repro.train.step import ShardingPlan, TrainConfig, make_train_step, plan_sharding

__all__ = ["TrainState", "TrainConfig", "make_train_step", "plan_sharding",
           "ShardingPlan"]
