"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any                 # f32 master weights (ZeRO-3 sharded slices
                                # in fsdp mode; replicated otherwise)
    opt: Any                    # optimizer state, sharded like params
    step: jnp.ndarray           # scalar int32
    ef: Any = None              # error-feedback residuals (beyond-paper;
                                # TrainConfig.error_feedback). Replicated
                                # mode: a params-shaped f32 tree. Fused
                                # fsdp mode: one flat f32 buffer per policy
                                # group, stacked over the dp axes (each
                                # worker's slice is the residual of its own
                                # local contribution) — checkpointed and
                                # donated with the rest of the state.
