"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any                 # f32 master weights (ZeRO-3 sharded slices
                                # in fsdp mode; replicated otherwise)
    opt: Any                    # optimizer state, sharded like params
    step: jnp.ndarray           # scalar int32
    ef: Any = None              # error-feedback residuals (beyond-paper;
                                # replicated mode, TrainConfig.error_feedback)
