"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any                 # f32 master weights (ZeRO-3 sharded slices
                                # in fsdp mode; replicated otherwise)
    opt: Any                    # optimizer state, sharded like params
    step: jnp.ndarray           # scalar int32
    ef: Any = None              # error-feedback residuals (beyond-paper;
                                # TrainConfig.error_feedback). Flat
                                # replicated mode: a params-shaped f32
                                # tree. Fused fsdp AND two-level
                                # replicated mode: one flat f32 buffer per
                                # policy group, stacked over the dp axes
                                # (each worker's slice is the residual of
                                # its own quantizer input — the full local
                                # contribution in flat fsdp, the 1/L_intra
                                # intra shard in two-level mode) —
                                # checkpointed and donated with the rest
                                # of the state.
