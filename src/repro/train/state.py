"""Training state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class OuterState(NamedTuple):
    """Outer-optimizer state of the temporal two_level_async hierarchy.

    ``anchor`` is the globally agreed parameter tree the current H-step
    inner window started from — the outer pseudo-gradient is
    ``anchor - local_params`` at the window's end, and every worker holds
    the identical anchor (it is only rewritten at sync steps from the
    quantized all-reduce's identical output). ``mom`` is the outer
    SGD-momentum/Nesterov buffer, params-shaped f32, equally replicated.
    """
    anchor: Any                 # params-shaped window start (replicated)
    mom: Any                    # params-shaped f32 outer momentum


class TrainState(NamedTuple):
    params: Any                 # f32 master weights (ZeRO-3 sharded slices
                                # in fsdp mode; replicated otherwise; in
                                # two_level_async mode each leaf carries a
                                # leading worker axis — inner steps make
                                # params pod-divergent, and the stacked
                                # layout keeps that divergence honest in
                                # shardings, checkpoints and digests)
    opt: Any                    # optimizer state, sharded like params
    step: jnp.ndarray           # scalar int32
    ef: Any = None              # error-feedback residuals (beyond-paper;
                                # TrainConfig.error_feedback). Flat
                                # replicated mode: a params-shaped f32
                                # tree. Fused fsdp AND two-level
                                # replicated mode: one flat f32 buffer per
                                # policy group, stacked over the dp axes
                                # (each worker's slice is the residual of
                                # its own quantizer input — the full local
                                # contribution in flat fsdp, the 1/L_intra
                                # intra shard in two-level mode) —
                                # checkpointed and donated with the rest
                                # of the state.
    outer: Any = None           # OuterState in two_level_async mode; None
                                # for every single-time-scale hierarchy.
