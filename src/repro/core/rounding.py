"""Rounding rules mapping gradient values to level indices.

* ``random_round`` — unbiased random rounding (Eq. 7): v in [b_{k-1}, b_k]
  goes up with probability (v − b_{k-1})/(b_k − b_{k-1}). Values outside the
  level range are clipped to the end levels first (for ORQ the ends are the
  bucket min/max so nothing clips; for BinGrad-pb this clip IS the partially
  biased part of Eq. 14).
* ``nearest_round`` / ``threshold_round`` — deterministic rules (BinGrad-b
  Eq. 16, scaled SignSGD).

Uniform randomness is supplied as uint32 counter-based bits from
``jax.random`` so CPU (interpret-mode) and TPU runs are bit-identical; the
Pallas kernels consume the same bits (see kernels/quant_rr.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INV_U32 = jnp.float32(1.0 / 4294967296.0)  # 2**-32


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 -> [0, 1) float32 (multiplicative, matches kernel)."""
    return bits.astype(jnp.float32) * _INV_U32


def find_interval(bkt: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Index k of the *lower* level of v's interval: levels[k] <= v < levels[k+1].

    bkt (nb, d), levels (nb, s) ascending -> (nb, d) int32 in [0, s-2].
    Values below levels[0] map to 0; above levels[-1] map to s-2 (they are
    clipped by the rounding probability computation).

    Computed as a static unrolled compare-accumulate over the s levels
    (s <= 17), matching the Pallas kernel formulation — an (nb, d, s)
    broadcast would dominate peak memory on multi-billion-element leaves.
    """
    v = bkt.astype(jnp.float32)
    s = levels.shape[-1]
    lv = levels.astype(jnp.float32)
    k = jnp.zeros(v.shape, dtype=jnp.int32)
    for j in range(s):
        k = k + (v >= lv[:, j][:, None]).astype(jnp.int32)
    return jnp.clip(k - 1, 0, s - 2)


def select_levels(levels: jnp.ndarray, k: jnp.ndarray):
    """(lo, hi) = (levels[k], levels[k+1]) via one-hot accumulate (gather-
    free, matches the kernel; avoids take_along_axis relayouts on sharded
    operands)."""
    s = levels.shape[-1]
    lv = levels.astype(jnp.float32)
    lo = jnp.zeros(k.shape, jnp.float32)
    hi = jnp.zeros(k.shape, jnp.float32)
    for j in range(s - 1):
        sel = (k == j).astype(jnp.float32)
        lo = lo + sel * lv[:, j][:, None]
        hi = hi + sel * lv[:, j + 1][:, None]
    return lo, hi


def random_round(
    bkt: jnp.ndarray,
    levels: jnp.ndarray,
    bits: jnp.ndarray,
) -> jnp.ndarray:
    """Unbiased random rounding to level indices. Returns (nb, d) int32 idx."""
    k = find_interval(bkt, levels)
    lo, hi = select_levels(levels, k)
    v = jnp.clip(bkt.astype(jnp.float32), lo, hi)
    width = hi - lo
    p_up = jnp.where(width > 0, (v - lo) / jnp.where(width > 0, width, 1.0), 0.0)
    up = (uniform_from_bits(bits) < p_up).astype(jnp.int32)
    return k + up


def nearest_round(bkt: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Deterministic nearest-level rounding (midpoint thresholds)."""
    k = find_interval(bkt, levels)
    lo, hi = select_levels(levels, k)
    v = jnp.clip(bkt.astype(jnp.float32), lo, hi)
    up = (v - lo > hi - v).astype(jnp.int32)
    return k + up


def threshold_round(bkt: jnp.ndarray, b0: jnp.ndarray) -> jnp.ndarray:
    """Binary deterministic rule (Eq. 16): idx = 1 iff v >= b0. b0: (nb, 1)."""
    return (bkt.astype(jnp.float32) >= b0).astype(jnp.int32)


def dequantize(idx: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Level indices back to values: (nb, d) idx + (nb, s) levels -> (nb, d)."""
    return jnp.take_along_axis(levels, idx.astype(jnp.int32), axis=-1)


def random_bits(key: jax.Array, shape) -> jnp.ndarray:
    """Counter-based uint32 bits for the rounding decision."""
    return jax.random.bits(key, shape, dtype=jnp.uint32)
