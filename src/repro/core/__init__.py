"""The paper's contribution: optimal gradient quantization (BinGrad / ORQ).

Public surface:
    QuantConfig, QuantPolicy, PolicyRule — config + per-group policy
    make_quantizer, register_scheme, all_methods — pluggable scheme registry
    Quantizer, QuantizedTensor — the stateless recipe
    quantized collectives live in repro.core.comm
"""
from repro.core.api import (QuantConfig, all_methods, make_quantizer,
                            register_scheme, registered_schemes,
                            unregister_scheme)
from repro.core.policy import (BitBudgetController, BitRamp, BitSchedule,
                               PolicyRule, QuantPolicy, ramp_levels)
from repro.core.quantizers import QuantizedTensor, Quantizer

__all__ = [
    "ALL_METHODS",
    "QuantConfig",
    "QuantPolicy",
    "PolicyRule",
    "BitRamp",
    "BitSchedule",
    "BitBudgetController",
    "ramp_levels",
    "all_methods",
    "make_quantizer",
    "register_scheme",
    "registered_schemes",
    "unregister_scheme",
    "Quantizer",
    "QuantizedTensor",
]


def __getattr__(name: str):
    # derived from the live scheme registry, never a stale snapshot
    if name == "ALL_METHODS":
        return all_methods()
    raise AttributeError(name)
