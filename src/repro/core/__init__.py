"""The paper's contribution: optimal gradient quantization (BinGrad / ORQ).

Public surface:
    QuantConfig, make_quantizer, Quantizer, QuantizedTensor
    quantized collectives live in repro.core.comm
"""
from repro.core.api import ALL_METHODS, QuantConfig, make_quantizer
from repro.core.quantizers import QuantizedTensor, Quantizer

__all__ = [
    "ALL_METHODS",
    "QuantConfig",
    "make_quantizer",
    "Quantizer",
    "QuantizedTensor",
]
