"""Quantized collectives: the distributed half of Algorithm 2, TPU-native.

The paper's parameter-server exchange maps onto two collective phases inside
``shard_map`` (manual axes = the data-parallel mesh axes):

  phase 1 (worker -> server)  ``quantized_reduce_scatter_mean``:
      each worker fits levels on its *local* gradient (the paper's runtime
      level selection), quantizes, bit-packs, and ``all_to_all``s the uint32
      payload + f32 level tables. Every worker then decodes the L received
      copies of its own chunk and averages — it *is* the server for that
      chunk. Wire bytes shrink by ~32/bits vs an f32 reduce-scatter.

  phase 2 (server -> worker)  inside ``quantized_all_reduce_mean``:
      the averaged chunk is re-quantized (fresh levels) and ``all_gather``ed
      — the paper's §4 option (b) "quantize the averaged gradient that the
      server sends back". Decoding is deterministic, so all workers
      reconstruct identical full gradients and replicated parameters stay
      in sync. ``server_requant=False`` gathers the f32 chunk instead
      (exact broadcast, 32-bit downlink).

For ZeRO-3 training the exchange rides the FSDP parameter gather:
``make_fsdp_gather`` returns an all_gather whose custom-VJP backward is the
phase-1 quantized reduce-scatter — exactly where the data-parallel gradient
communication lives.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.quantizers import Quantizer
from repro.kernels import ops


def _names(axis_names) -> Tuple[str, ...]:
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def axis_size(axis_names) -> int:
    n = 1
    for a in _names(axis_names):
        n *= lax.axis_size(a)
    return n


def _bucket_len(chunk: int, d: int) -> int:
    return min(d, max(chunk, 1))


# ---------------------------------------------------------------------------
# phase 1 core: quantized reduce-scatter over explicit (L, chunk) parts
# ---------------------------------------------------------------------------

def _assign(qz: Quantizer, bkt, levels, key, use_kernels: bool):
    """Rounding dispatch: random-rounding methods go through the Pallas
    quant_rr kernel (VMEM-tiled; never materializes an (nb, d, s) tensor)."""
    from repro.core import clipping, rounding as R

    if qz.method in ("orq", "terngrad", "qsgd", "linear", "minmax2",
                     "bingrad_pb"):
        if qz.clip_c is not None:
            mask = jnp.ones(bkt.shape, dtype=bool)
            bkt = clipping.sigma_clip(bkt, mask, qz.clip_c)
        bits = R.random_bits(key, bkt.shape)
        return ops.quant_rr(bkt, levels, bits, use_kernels=use_kernels)
    return qz.assign(bkt, levels, key)


def _rs_mean_parts(parts, valid, qz: Quantizer, key, names, use_kernels):
    """parts (L, chunk) local contributions, one row per destination worker;
    valid (L, chunk) bool. Returns this worker's (chunk,) mean slice.

    ``key`` must already be folded per-worker (callers fold in the dp axis
    index OUTSIDE any nested manual region — axis_index of an outer-manual
    axis cannot lower inside a nested shard_map)."""
    L, chunk = parts.shape
    d_eff = _bucket_len(chunk, qz.bucket_size)
    pad = -(-chunk // d_eff) * d_eff - chunk
    parts = jnp.pad(parts.astype(jnp.float32), ((0, 0), (0, pad)))
    valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nbc = parts.shape[1] // d_eff

    bkt = parts.reshape(L * nbc, d_eff)
    mask = valid.reshape(L * nbc, d_eff)
    levels = qz.fit(bkt, mask)                           # runtime levels
    idx = jnp.where(mask, _assign(qz, bkt, levels, key, use_kernels), 0)

    bits = qz.wire_bits_per_element
    words = ops.pack(idx, bits, use_kernels=use_kernels)  # (L*nbc, nw) u32
    words = words.reshape(L, nbc, -1)
    levels = levels.reshape(L, nbc, -1)
    # the wire: uint32 payload + f32 level tables
    words = lax.all_to_all(words, names, split_axis=0, concat_axis=0)
    levels = lax.all_to_all(levels, names, split_axis=0, concat_axis=0)
    idx_all = jax.vmap(
        lambda w: ops.unpack(w, bits, d_eff, use_kernels=use_kernels)
    )(words)                                              # (L, nbc, d_eff)
    mean_bkt = ops.dequant_avg(idx_all, levels, use_kernels=use_kernels)
    return mean_bkt.reshape(-1)[:chunk]


def quantized_reduce_scatter_mean(
    flat: jnp.ndarray,
    qz: Quantizer,
    key: jax.Array,
    axis_names,
    *,
    worker_id=None,
    use_kernels: bool = True,
) -> jnp.ndarray:
    """Each worker holds a full local gradient ``flat`` (n,). Returns this
    worker's (chunk,) slice of the across-worker *mean*, chunk = ceil(n/L).
    FP scheme short-circuits to a plain psum_scatter.

    ``worker_id`` defaults to ``axis_index`` of the dp axes; custom-VJP
    backward callers must pass it explicitly (axis_index cannot lower from
    transposed/hoisted contexts)."""
    n = flat.shape[0]
    names = _names(axis_names)
    L = axis_size(names)
    chunk = -(-n // L)
    padded = jnp.pad(flat, (0, L * chunk - n))
    if qz.is_identity:
        return lax.psum_scatter(
            padded.reshape(L, chunk), names, scatter_dimension=0,
            tiled=False) / L
    valid = (jnp.arange(L * chunk) < n).reshape(L, chunk)
    if worker_id is None:
        worker_id = lax.axis_index(names)
    key = jax.random.fold_in(key, worker_id)
    return _rs_mean_parts(padded.reshape(L, chunk), valid, qz, key, names,
                          use_kernels)


# ---------------------------------------------------------------------------
# phase 1 + 2: quantized all-reduce (mean), replicated-parameter mode
# ---------------------------------------------------------------------------

def local_qdq_comm_layout(
    flat: jnp.ndarray,
    qz: Quantizer,
    key: jax.Array,
    axis_names,
    *,
    worker_id=None,
    use_kernels: bool = True,
) -> jnp.ndarray:
    """This worker's own dequantized gradient, bit-identical to what it
    contributed to ``quantized_reduce_scatter_mean`` (same chunk/bucket
    layout, same folded key). Used by error feedback: e ← g − Q⁻¹(Q(g))."""
    n = flat.shape[0]
    names = _names(axis_names)
    L = axis_size(names)
    chunk = -(-n // L)
    padded = jnp.pad(flat.astype(jnp.float32), (0, L * chunk - n))
    d_eff = _bucket_len(chunk, qz.bucket_size)
    pad2 = -(-chunk // d_eff) * d_eff - chunk
    parts = jnp.pad(padded.reshape(L, chunk), ((0, 0), (0, pad2)))
    valid = jnp.pad((jnp.arange(L * chunk) < n).reshape(L, chunk),
                    ((0, 0), (0, pad2)))
    bkt = parts.reshape(-1, d_eff)
    mask = valid.reshape(-1, d_eff)
    levels = qz.fit(bkt, mask)
    if worker_id is None:
        worker_id = lax.axis_index(names)
    key = jax.random.fold_in(key, worker_id)
    idx = jnp.where(mask, _assign(qz, bkt, levels, key, use_kernels), 0)
    vals = Quantizer.decode(idx, levels)
    return vals.reshape(L, -1)[:, :chunk].reshape(-1)[:n]


def quantized_all_reduce_mean(
    flat: jnp.ndarray,
    qz: Quantizer,
    key: jax.Array,
    axis_names,
    *,
    worker_id=None,
    server_requant: bool = True,
    use_kernels: bool = True,
) -> jnp.ndarray:
    """Full Algorithm 2 exchange. Returns the (n,) mean gradient, identical
    on every worker (the phase-2 decode is deterministic)."""
    n = flat.shape[0]
    names = _names(axis_names)
    L = axis_size(names)
    if qz.is_identity:
        return lax.pmean(flat, names)

    chunk = -(-n // L)
    mean_chunk = quantized_reduce_scatter_mean(
        flat, qz, key, names, worker_id=worker_id, use_kernels=use_kernels)

    if not server_requant:
        full = lax.all_gather(mean_chunk, names, axis=0, tiled=False)
        return full.reshape(-1)[:n].astype(flat.dtype)

    # phase 2: re-quantize the averaged chunk; broadcast payload + levels.
    me = lax.axis_index(names) if worker_id is None else worker_id
    d_eff = _bucket_len(chunk, qz.bucket_size)
    pad = -(-chunk // d_eff) * d_eff - chunk
    bkt = jnp.pad(mean_chunk, (0, pad)).reshape(-1, d_eff)
    pos = me * chunk + jnp.arange(chunk + pad)
    mask = ((pos < n) & (jnp.arange(chunk + pad) < chunk)).reshape(-1, d_eff)
    levels = qz.fit(bkt, mask)
    key2 = jax.random.fold_in(jax.random.fold_in(key, 0x5EC0), me)
    idx = jnp.where(mask, _assign(qz, bkt, levels, key2, use_kernels), 0)
    bits = qz.wire_bits_per_element
    words = ops.pack(idx, bits, use_kernels=use_kernels)
    words = lax.all_gather(words, names, axis=0, tiled=False)
    levels_all = lax.all_gather(levels, names, axis=0, tiled=False)
    idx_all = jax.vmap(
        lambda w: ops.unpack(w, bits, d_eff, use_kernels=use_kernels)
    )(words)                                              # (L, nbc, d_eff)
    vals = jax.vmap(Quantizer.decode)(idx_all, levels_all)  # (L, nbc, d_eff)
    vals = vals.reshape(L, -1)[:, :chunk]
    return vals.reshape(-1)[:n].astype(flat.dtype)


# ---------------------------------------------------------------------------
# ZeRO-3: FSDP gather with quantized-gradient backward
# ---------------------------------------------------------------------------

def make_fsdp_gather(
    qz: Quantizer,
    axis_names,
    *,
    dim: int,
    tp_dim: Optional[int] = None,
    tp_axis: str = "model",
    compute_dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    use_kernels: bool = True,
):
    """Returns gather(w_slice, key) -> full ``compute_dtype`` leaf.

    fwd: cast + all_gather along ``dim`` over the dp axes (the FSDP
         parameter broadcast; bf16 wire).
    bwd: the paper — quantized reduce-scatter of the full-size local
         gradient cotangent; the f32 slice matches the stored shard.

    When the leaf is also tensor-parallel (``tp_dim`` over the auto
    ``tp_axis``), the backward runs inside a NESTED manual shard_map over
    that axis: every device quantizes its own contiguous gradient shard and
    the all_to_all stays within the dp axes. Without this, XLA has to
    replicate the strided flatten of a TP-sharded cotangent — terabytes of
    involuntary all-gather on 100B-parameter models.
    """
    names = _names(axis_names)

    @jax.custom_vjp
    def gather(w, key):
        del key
        return lax.all_gather(w.astype(compute_dtype), names, axis=dim,
                              tiled=True)

    def fwd(w, key):
        # capture the worker id in the PRIMAL context: axis_index cannot
        # lower from the transposed/hoisted backward context
        wid = lax.axis_index(names)
        return gather(w, key), (key, wid)

    def _local_rs(g, key):
        """Quantized RS of one (possibly per-tp-shard) cotangent block."""
        L = axis_size(names)
        gm = jnp.moveaxis(g.astype(jnp.float32), dim, 0)
        lead, rest = gm.shape[0], gm.shape[1:]
        chunk = (lead // L) * int(np.prod(rest)) if rest else lead // L
        parts = gm.reshape(L, chunk)
        if qz.is_identity:
            mean_chunk = lax.psum_scatter(
                parts, names, scatter_dimension=0, tiled=False) / L
        else:
            valid = jnp.ones((L, chunk), dtype=bool)
            mean_chunk = _rs_mean_parts(parts, valid, qz, key, names,
                                        use_kernels)
        out = mean_chunk.reshape((lead // L,) + rest)
        return jnp.moveaxis(out, 0, dim).astype(param_dtype)

    def bwd(res, g):
        key, wid = res
        key_w = jax.random.fold_in(key, wid)
        if tp_dim is not None:
            spec = [None] * g.ndim
            spec[tp_dim] = tp_axis
            pspec = jax.sharding.PartitionSpec(*spec)

            # NOTE: the rounding bits are shared across tp shards (the
            # shards quantize disjoint data, so unbiasedness is unaffected)
            out = jax.shard_map(
                _local_rs,
                in_specs=(pspec, jax.sharding.PartitionSpec()),
                out_specs=pspec, axis_names={tp_axis},
                check_vma=False)(g, key_w)
        else:
            out = _local_rs(g, key_w)
        key_ct = np.zeros(key.shape, dtype=jax.dtypes.float0)
        return out, key_ct

    gather.defvjp(fwd, bwd)
    return gather


def make_replicated_gather(
    qz: Quantizer,
    axis_names,
    *,
    compute_dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    server_requant: bool = True,
    use_kernels: bool = True,
):
    """Identity 'gather' for dp-replicated leaves whose backward runs the
    full Algorithm 2 quantized all-reduce (leaves too small / indivisible to
    FSDP-shard still need their gradients exchanged and must stay bit-
    identical across workers — the deterministic phase-2 decode guarantees
    that)."""
    names = _names(axis_names)

    @jax.custom_vjp
    def gather(w, key):
        del key
        return w.astype(compute_dtype)

    def fwd(w, key):
        wid = lax.axis_index(names)   # primal context (see make_fsdp_gather)
        return gather(w, key), (key, wid)

    def bwd(res, g):
        key, wid = res
        flat = g.astype(jnp.float32).reshape(-1)
        if qz.is_identity:
            mean = lax.pmean(flat, names)
        else:
            mean = quantized_all_reduce_mean(
                flat, qz, key, names, worker_id=wid,
                server_requant=server_requant, use_kernels=use_kernels)
        out = mean.reshape(g.shape).astype(param_dtype)
        key_ct = np.zeros(key.shape, dtype=jax.dtypes.float0)
        return out, key_ct

    gather.defvjp(fwd, bwd)
    return gather


def psum_mean_tree(tree, axis_names):
    """FP baseline: plain pmean over the dp axes for a whole pytree."""
    return jax.tree_util.tree_map(lambda x: lax.pmean(x, axis_names), tree)
