"""TernGrad-style gradient clipping (paper §5): clip(v) = sign(v)·min(|v|, c·σ).

σ² is the per-bucket gradient variance; c is a positive constant (paper uses
2.5, also sweeps 1.7 in Table 4). Applied *before* level fitting/quantization.
"""
from __future__ import annotations

import jax.numpy as jnp


def masked_moments(bkt: jnp.ndarray, mask: jnp.ndarray):
    """Per-bucket (mean, std) over valid elements. Returns ((nb,1), (nb,1))."""
    m = mask.astype(bkt.dtype)
    cnt = jnp.maximum(m.sum(axis=-1, keepdims=True), 1.0)
    mean = (bkt * m).sum(axis=-1, keepdims=True) / cnt
    var = (((bkt - mean) ** 2) * m).sum(axis=-1, keepdims=True) / cnt
    return mean, jnp.sqrt(var)


def sigma_clip(bkt: jnp.ndarray, mask: jnp.ndarray, c: float) -> jnp.ndarray:
    """Clip each element to ±c·σ of its bucket (σ computed around 0-mean,
    matching TernGrad which clips magnitudes)."""
    _, std = masked_moments(bkt, mask)
    lim = c * std
    return jnp.clip(bkt, -lim, lim)
