"""QuantPolicy: declarative per-parameter-group quantization policy.

The paper's optimal-condition machinery picks optimal *levels* per bucket,
but which leaves get quantized at all is a modelling decision: TernGrad
leaves small/sensitive layers (biases, norms) in full precision, and
Adaptive Gradient Quantization adapts levels per tensor group. A
``QuantPolicy`` captures that as an ordered list of

    (path-pattern  ->  QuantConfig)

rules plus a default, resolved against each parameter leaf's path string
(the same strings ``model.param_paths`` / the gather hook see). The first
matching rule wins; unmatched leaves get the default.

Grammar (launcher ``--quant``, arch configs, JSON):

    POLICY  := SCHEME                      # uniform shorthand
             | RULE ("," RULE)*
    RULE    := PATTERN "=" SCHEME
             | "default" "=" SCHEME
    PATTERN := python regex, matched with re.search against the leaf path
    SCHEME  := any registered scheme name (repro.core.api.all_methods)

Examples:

    "orq-9"                                    # uniform (back-compat)
    "norm|bias=fp, embed=bingrad-b, default=orq-9"
    '{"norm|bias": "fp", "default": "orq-9"}'  # JSON form of the same

ADAPTIVE BIT BUDGET (``BitSchedule`` / ``BitBudgetController``): the same
grammar also carries per-group bit RAMPS — ``family@HI..LO`` tokens whose
wire bit-width is a function of the training step instead of a constant:

    "embed=orq@5..3,default=orq@4..1"

``BitSchedule.parse`` understands both ramp tokens and plain scheme names
(static entries); ``QuantPolicy.parse`` rejects ramp tokens with a pointer
here. A schedule materializes into an ordinary static ``QuantPolicy`` per
PHASE via :meth:`BitSchedule.policy_at` — the exchange engines recompile
at phase boundaries (see ``train/step.py:ScheduledTrainStep``) rather
than tracing bit-width, which preserves the one-``pallas_call`` property
and bit-identity within each phase. ``BitBudgetController`` re-solves the
per-group bits every ``resolve_every`` steps from the fused encode's
cheap statistics (per-bucket sigma^2, clip fraction, EF-residual norm)
under a global DCN-bytes/step budget.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple, Union

from repro.core.api import QuantConfig

_GRAMMAR = ("policy grammar: 'pattern=scheme[,pattern=scheme...]"
            "[,default=scheme]' (regex patterns, first match wins) "
            "or a single scheme name for a uniform policy")


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One ordered rule: regex ``pattern`` (re.search) -> ``cfg``."""

    pattern: str
    cfg: QuantConfig

    def __post_init__(self):
        if not self.pattern.strip():
            # re.search("") matches every path — a stray '=' would
            # silently hijack the whole policy
            raise ValueError(f"empty policy pattern; {_GRAMMAR}")
        try:
            re.compile(self.pattern)
        except re.error as e:
            raise ValueError(
                f"bad policy pattern {self.pattern!r}: {e}; {_GRAMMAR}"
            ) from e

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered rules + default, resolvable against any model's param paths."""

    rules: Tuple[PolicyRule, ...] = ()
    default: QuantConfig = QuantConfig(name="fp")

    # -- constructors ------------------------------------------------------
    @classmethod
    def uniform(cls, cfg) -> "QuantPolicy":
        """Back-compat shorthand: every leaf gets ``cfg`` (a QuantConfig or
        a scheme name)."""
        if isinstance(cfg, str):
            cfg = QuantConfig(name=cfg)
        return cls(rules=(), default=cfg)

    @classmethod
    def parse(cls, spec: str, **defaults) -> "QuantPolicy":
        """Parse a policy string (see module grammar). ``defaults`` are
        extra QuantConfig fields (bucket_size, clip_c, ...) applied to
        every rule built from a bare scheme name."""
        spec = spec.strip()
        if spec.startswith("{"):
            try:
                d = json.loads(spec)
            except json.JSONDecodeError as e:
                raise ValueError(f"bad policy JSON {spec!r}: {e}") from e
            return cls.from_dict(d, **defaults)
        if "=" not in spec:
            return cls.uniform(_cfg(spec, defaults))
        rules, default = [], None
        for entry in _split_entries(spec):
            # split on the LAST '=': the scheme never contains one, so
            # regex patterns with lookarounds (e.g. ``norm(?=\d)``) work
            pattern, scheme = (s.strip() for s in entry.rsplit("=", 1))
            if pattern == "default":
                if default is not None:
                    raise ValueError(
                        f"duplicate 'default' entry in policy {spec!r}")
                default = _cfg(scheme, defaults)
            else:
                rules.append(PolicyRule(pattern, _cfg(scheme, defaults)))
        if default is None:
            default = _cfg("fp", defaults)
        return cls(rules=tuple(rules), default=default)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any], **defaults) -> "QuantPolicy":
        """Dict/JSON form: {pattern: scheme-or-config-dict, ...,
        'default': ...}. Insertion order is rule order."""
        rules, default = [], None
        for pattern, val in d.items():
            if isinstance(val, str):
                cfg = _cfg(val, defaults)
            elif isinstance(val, QuantConfig):
                cfg = val
            elif isinstance(val, Mapping):
                cfg = _cfg_from_dict(val, defaults)
            else:
                raise ValueError(
                    f"bad policy value {val!r} for pattern {pattern!r}: "
                    f"expected a scheme name, QuantConfig, or field dict; "
                    f"{_GRAMMAR}")
            if pattern == "default":
                default = cfg
            else:
                rules.append(PolicyRule(pattern, cfg))
        return cls(rules=tuple(rules),
                   default=default if default is not None
                   else _cfg("fp", defaults))

    @classmethod
    def coerce(cls, obj, **defaults) -> "QuantPolicy":
        """Anything-to-policy: QuantPolicy (as-is), QuantConfig (uniform),
        str (parse), Mapping (from_dict)."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, QuantConfig):
            return cls.uniform(obj)
        if isinstance(obj, str):
            return cls.parse(obj, **defaults)
        if isinstance(obj, Mapping):
            return cls.from_dict(obj, **defaults)
        raise TypeError(f"cannot build a QuantPolicy from {type(obj)!r}")

    # -- resolution --------------------------------------------------------
    @property
    def is_uniform(self) -> bool:
        return not self.rules

    def resolve(self, path: str) -> QuantConfig:
        """First matching rule's config, else the default."""
        for rule in self.rules:
            if rule.matches(path):
                return rule.cfg
        return self.default

    def resolve_ix(self, path: str) -> int:
        """Index of the first matching rule; ``len(rules)`` means the
        default. Grouping leaves by THIS (``PolicyLayout.from_tree(
        by_rule=True)``) instead of by resolved config keeps group
        structure — and therefore EF-residual buffer shapes — invariant
        when a ``BitSchedule`` re-materializes the configs per phase."""
        for i, rule in enumerate(self.rules):
            if rule.matches(path):
                return i
        return len(self.rules)

    def cfg_for_rule(self, rule_ix: int) -> QuantConfig:
        """The config a ``resolve_ix`` result maps to under THIS policy
        (phase specialization: same rule structure, new configs)."""
        if rule_ix == len(self.rules):
            return self.default
        return self.rules[rule_ix].cfg

    def unmatched_rules(self, paths) -> Tuple[str, ...]:
        """Patterns that match NONE of ``paths`` — a typo'd or misspelled
        pattern silently falls through to the default otherwise, so
        resolvers (PolicyLayout.from_tree) warn on these."""
        paths = list(paths)
        return tuple(r.pattern for r in self.rules
                     if not any(r.matches(p) for p in paths))

    def describe(self) -> str:
        parts = [f"{r.pattern}={r.cfg.name}" for r in self.rules]
        parts.append(f"default={self.default.name}")
        return ",".join(parts)


# a scheme token, optionally carrying a bit-ramp suffix ``@HI..LO`` (or
# the constant shorthand ``@B``) — only BitSchedule.parse accepts ramps,
# but _split_entries is shared so both grammars agree on entry boundaries
_SCHEME_TOKEN = re.compile(r"[A-Za-z0-9_\-]+(?:@\d+(?:\.\.\d+)?)?")


def _split_entries(spec: str) -> list:
    """Split a policy string into 'pattern=scheme' entries. Commas and '='
    INSIDE a pattern (regex quantifiers like ``{1,2}``, lookarounds like
    ``(?=x)``) are kept: segments are merged until the text after the last
    '=' looks like a bare scheme token — only then is the entry complete."""
    entries, buf = [], ""
    for seg in spec.split(","):
        if not buf and not seg.strip():
            continue
        buf = f"{buf},{seg}" if buf else seg
        if "=" in buf and _SCHEME_TOKEN.fullmatch(
                buf.rsplit("=", 1)[1].strip()):
            entries.append(buf.strip())
            buf = ""
    if buf.strip():
        raise ValueError(
            f"bad policy entry {buf.strip()!r} (missing '=scheme'); "
            f"{_GRAMMAR}")
    return entries


def _cfg(scheme: str, defaults: Mapping[str, Any]) -> QuantConfig:
    if "@" in scheme:
        raise ValueError(
            f"{scheme.strip()!r} is a bit-ramp token (family@HI..LO); "
            f"bit ramps are step-dependent and belong to BitSchedule.parse "
            f"(launcher --bit-schedule), not a static QuantPolicy")
    cfg = QuantConfig(name=scheme.strip().lower().replace("_", "-"),
                      **defaults)
    try:
        cfg.to_quantizer()   # validate the name against the registry now
        # (make_quantizer's error already names the valid schemes)
    except ValueError as e:
        raise ValueError(
            f"bad scheme {scheme!r} in policy: {e}; {_GRAMMAR}") from e
    return cfg


def _cfg_from_dict(val: Mapping[str, Any],
                   defaults: Mapping[str, Any]) -> QuantConfig:
    kw = dict(defaults)
    kw.update(val)
    name = kw.pop("name", "fp")
    fields = {f.name for f in dataclasses.fields(QuantConfig)}
    bad = sorted(set(kw) - fields)
    if bad:
        # a plain ValueError so launchers surface it as a clean parse
        # error instead of a TypeError traceback
        raise ValueError(
            f"unknown QuantConfig field(s) {bad} in policy entry; valid "
            f"fields: {sorted(fields)}")
    return _cfg(name, kw)


# ---------------------------------------------------------------------------
# adaptive bit budget: BitRamp / BitSchedule / BitBudgetController
# ---------------------------------------------------------------------------

_SCHED_GRAMMAR = (
    "bit-schedule grammar: 'pattern=ITEM[,pattern=ITEM...][,default=ITEM]' "
    "where ITEM is a static scheme name OR a ramp 'family@HI..LO' "
    "(wire bits decaying linearly from HI at step 0 to LO at the last "
    "step; 'family@B' is the constant shorthand B..B), e.g. "
    "'embed=orq@5..3,default=orq@4..1'")

_RAMP_RE = re.compile(r"^([A-Za-z0-9_\-]+)@(\d+)(?:\.\.(\d+))?$")


def ramp_levels(bits: int) -> int:
    """Level count a ``bits``-wide wire element carries for the odd-level
    families: s = 2^(b-1)+1 (so ceil(log2 s) == b, see
    ``encode.bits_for_levels``). 1 bit has no odd-level scheme; ramps map
    it to ``minmax2`` (the 2-level unbiased degenerate, Corollary 1.1)."""
    if bits < 1:
        raise ValueError(f"wire bits must be >= 1, got {bits}")
    return 2 if bits == 1 else 2 ** (bits - 1) + 1


@dataclasses.dataclass(frozen=True)
class BitRamp:
    """A step-dependent scheme: ``family`` at ``hi`` wire bits decaying
    linearly to ``lo`` bits over the run. Materializes to a concrete
    ``QuantConfig`` per phase via :meth:`config` (b=1 -> ``minmax2``,
    else ``{family}-{2^(b-1)+1}``)."""

    family: str
    hi: int
    lo: int

    def __post_init__(self):
        if not (1 <= self.lo <= self.hi):
            raise ValueError(
                f"bad bit ramp {self.family}@{self.hi}..{self.lo}: need "
                f"1 <= LO <= HI; {_SCHED_GRAMMAR}")
        if self.hi > 5:
            # the fused kernels tile level tables at 32 lanes (LEVEL_PAD,
            # "s <= 17 always"): 5 bits -> s=17 is the largest table the
            # one-pallas_call encode/decode path supports
            raise ValueError(
                f"bad bit ramp {self.family}@{self.hi}..{self.lo}: HI must "
                f"be <= 5 (s=17 levels, the fused kernels' level-tile "
                f"contract); {_SCHED_GRAMMAR}")

    def bits_at(self, frac: float) -> int:
        """Linear interpolation: hi at frac=0, lo at frac=1 (round to
        nearest, clamped)."""
        frac = min(max(float(frac), 0.0), 1.0)
        b = int(round(self.hi + (self.lo - self.hi) * frac))
        return max(self.lo, min(self.hi, b))

    def config(self, bits: int, defaults: Mapping[str, Any]) -> QuantConfig:
        bits = max(self.lo, min(self.hi, int(bits)))
        name = ("minmax2" if bits == 1
                else f"{self.family}-{ramp_levels(bits)}")
        return _cfg(name, defaults)

    def describe(self) -> str:
        return (f"{self.family}@{self.hi}" if self.hi == self.lo
                else f"{self.family}@{self.hi}..{self.lo}")


def _sched_item(token: str,
                defaults: Mapping[str, Any]) -> Union[QuantConfig, BitRamp]:
    """One schedule ITEM: a ramp token or a static scheme name."""
    token = token.strip()
    m = _RAMP_RE.fullmatch(token)
    if m is None:
        return _cfg(token, defaults)
    family = m.group(1).strip().lower().replace("_", "-")
    hi = int(m.group(2))
    lo = int(m.group(3)) if m.group(3) is not None else hi
    ramp = BitRamp(family=family, hi=hi, lo=lo)
    # validate both endpoints against the registry NOW (e.g. a family
    # whose level count must be 2^K+1 — _cfg's error names the schemes)
    ramp.config(hi, defaults)
    ramp.config(lo, defaults)
    return ramp


def _check_pattern(pattern: str):
    if not pattern.strip():
        raise ValueError(f"empty schedule pattern; {_SCHED_GRAMMAR}")
    try:
        re.compile(pattern)
    except re.error as e:
        raise ValueError(
            f"bad schedule pattern {pattern!r}: {e}; {_SCHED_GRAMMAR}"
        ) from e


@dataclasses.dataclass(frozen=True)
class BitSchedule:
    """Ordered (pattern -> QuantConfig | BitRamp) rules + default: a
    ``QuantPolicy`` whose per-group wire bit-width is a function of the
    training step.

    The schedule never traces bit-width into a jaxpr. It materializes a
    concrete static ``QuantPolicy`` per PHASE (:meth:`policy_at` on the
    :meth:`assignment` bits tuple); the train step recompiles at phase
    boundaries and is bit-identical to the equivalent static policy
    within each phase. ``defaults`` are the extra QuantConfig fields
    (bucket_size, clip_c, ...) applied when a ramp materializes."""

    rules: Tuple[Tuple[str, Union[QuantConfig, BitRamp]], ...] = ()
    default: Union[QuantConfig, BitRamp] = QuantConfig(name="fp")
    defaults: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        for pattern, _ in self.rules:
            _check_pattern(pattern)

    # -- constructors ------------------------------------------------------
    @classmethod
    def parse(cls, spec: str, **defaults) -> "BitSchedule":
        """Parse a schedule string (see ``_SCHED_GRAMMAR``); plain scheme
        names make static entries, ``family@HI..LO`` tokens make ramps."""
        spec = spec.strip()
        dflt = tuple(sorted(defaults.items()))
        if "=" not in spec:
            return cls(rules=(), default=_sched_item(spec, defaults),
                       defaults=dflt)
        rules: List[Tuple[str, Any]] = []
        default = None
        for entry in _split_entries(spec):
            pattern, token = (s.strip() for s in entry.rsplit("=", 1))
            if pattern == "default":
                if default is not None:
                    raise ValueError(
                        f"duplicate 'default' entry in schedule {spec!r}")
                default = _sched_item(token, defaults)
            else:
                rules.append((pattern, _sched_item(token, defaults)))
        if default is None:
            default = _cfg("fp", defaults)
        return cls(rules=tuple(rules), default=default, defaults=dflt)

    # -- structure ---------------------------------------------------------
    @property
    def items(self) -> Tuple[Union[QuantConfig, BitRamp], ...]:
        """All entries in rule order, the default LAST — the canonical
        per-entry axis every bits tuple (assignment) aligns with."""
        return tuple(it for _, it in self.rules) + (self.default,)

    @property
    def n_entries(self) -> int:
        return len(self.rules) + 1

    @property
    def is_static(self) -> bool:
        """True when no entry's bit-width actually moves (every ramp is
        degenerate HI==LO) — a single engine serves the whole run."""
        return all(not isinstance(it, BitRamp) or it.hi == it.lo
                   for it in self.items)

    # -- resolution --------------------------------------------------------
    def _frac(self, step: int, total_steps: int) -> float:
        if total_steps <= 1:
            return 0.0
        return min(max(step, 0), total_steps - 1) / (total_steps - 1)

    def assignment(self, step: int, total_steps: int
                   ) -> Tuple[Optional[int], ...]:
        """Per-entry wire bits at ``step`` (None for static entries) —
        the tuple that keys the compiled-engine LRU."""
        frac = self._frac(step, total_steps)
        return tuple(it.bits_at(frac) if isinstance(it, BitRamp) else None
                     for it in self.items)

    def floor_assignment(self) -> Tuple[Optional[int], ...]:
        return tuple(it.lo if isinstance(it, BitRamp) else None
                     for it in self.items)

    def ceil_assignment(self) -> Tuple[Optional[int], ...]:
        return tuple(it.hi if isinstance(it, BitRamp) else None
                     for it in self.items)

    def policy_at(self, assignment: Tuple[Optional[int], ...]
                  ) -> QuantPolicy:
        """Materialize the concrete static QuantPolicy for one bits
        tuple. All phases share the SAME rule patterns in the SAME order,
        so engines grouped by rule (``PolicyLayout.from_tree(by_rule=
        True)``) keep identical group structure across phases."""
        if len(assignment) != self.n_entries:
            raise ValueError(
                f"assignment length {len(assignment)} != schedule entries "
                f"{self.n_entries}")
        dflt = dict(self.defaults)

        def cfg_of(item, bits):
            if isinstance(item, BitRamp):
                if bits is None:
                    raise ValueError("ramp entry needs a bits value")
                return item.config(bits, dflt)
            return item

        rules = tuple(
            PolicyRule(pattern, cfg_of(item, bits))
            for (pattern, item), bits in zip(self.rules, assignment))
        return QuantPolicy(rules=rules,
                           default=cfg_of(self.default, assignment[-1]))

    def phases(self, total_steps: int, resolve_every: int
               ) -> List[Tuple[int, Tuple[Optional[int], ...]]]:
        """Deduplicated [(start_step, assignment), ...] for the
        deterministic schedule: one entry per distinct compiled engine,
        in execution order. Audit + accounting iterate these."""
        if resolve_every < 1:
            raise ValueError(
                f"resolve_every must be >= 1, got {resolve_every}")
        out: List[Tuple[int, Tuple[Optional[int], ...]]] = []
        for start in range(0, max(total_steps, 1), resolve_every):
            a = self.assignment(start, total_steps)
            if not out or out[-1][1] != a:
                out.append((start, a))
        return out

    def describe(self) -> str:
        parts = [f"{p}={it.describe() if isinstance(it, BitRamp) else it.name}"
                 for p, it in self.rules]
        d = self.default
        parts.append(
            f"default={d.describe() if isinstance(d, BitRamp) else d.name}")
        return ",".join(parts)


class BitBudgetController:
    """Resolves the per-entry wire bits every ``resolve_every`` steps.

    Deterministic baseline: the schedule's linear ramps, quantized to
    phase boundaries (``assignment_at``). With ``dcn_budget_bytes`` set
    (a quantized-DCN bytes/step budget) and per-entry ``group_sizes``
    known, each phase instead GREEDY WATER-FILLS bits from every ramp's
    LO toward its deterministic phase value: the next bit goes to the
    entry with the largest marginal quantization-MSE reduction per DCN
    byte (variance ~ 4^-bits, weighted by the observed per-bucket
    sigma^2 x group size — :meth:`observe` feeds the fused encode's
    stats output), while the assignment's cost stays under budget.
    Static quantized entries are a fixed cost subtracted from the
    budget first; identity (fp) entries don't ride the quantized wire.

    ``cost_fn(policy) -> dcn bytes/step`` optionally replaces the
    payload-only default (size x bits / 8) so the controller prices
    assignments with the SAME accounting the benchmarks report
    (``exchange.policy_link_stats`` — see launch/train.py)."""

    def __init__(self, schedule: BitSchedule, total_steps: int, *,
                 resolve_every: int = 50,
                 dcn_budget_bytes: Optional[float] = None,
                 group_sizes: Optional[Tuple[int, ...]] = None,
                 cost_fn: Optional[Callable[[QuantPolicy], float]] = None):
        if resolve_every < 1:
            raise ValueError(
                f"resolve_every must be >= 1, got {resolve_every}")
        self.schedule = schedule
        self.total_steps = int(total_steps)
        self.resolve_every = int(resolve_every)
        self.dcn_budget_bytes = dcn_budget_bytes
        self.group_sizes = tuple(group_sizes) if group_sizes else None
        self.cost_fn = cost_fn
        self.decisions: List[Dict[str, Any]] = []
        self._stats: Optional[Tuple[Dict[str, float], ...]] = None
        self._phase: Optional[int] = None
        self._cached: Optional[Tuple[Optional[int], ...]] = None

    # -- statistics feed ---------------------------------------------------
    def observe(self, stats) -> None:
        """Feed the latest per-entry statistics rows, aligned with
        ``schedule.items``: each row is a mapping (or indexable triple)
        with ``sigma_sq`` (mean per-bucket gradient variance),
        ``clip_frac`` and ``ef_norm_sq`` — exactly what
        ``PartitionedExchange.group_stats`` emits per group."""
        rows = []
        for r in stats:
            if isinstance(r, Mapping):
                rows.append({"sigma_sq": float(r.get("sigma_sq", 0.0)),
                             "clip_frac": float(r.get("clip_frac", 0.0)),
                             "ef_norm_sq": float(r.get("ef_norm_sq", 0.0))})
            else:
                vals = [float(v) for v in r]
                vals += [0.0] * (3 - len(vals))
                rows.append({"sigma_sq": vals[0], "clip_frac": vals[1],
                             "ef_norm_sq": vals[2]})
        if len(rows) != self.schedule.n_entries:
            raise ValueError(
                f"stats rows {len(rows)} != schedule entries "
                f"{self.schedule.n_entries}")
        self._stats = tuple(rows)

    # -- resolution --------------------------------------------------------
    def phase_start(self, step: int) -> int:
        return (max(int(step), 0) // self.resolve_every) * self.resolve_every

    def assignment_at(self, step: int) -> Tuple[Optional[int], ...]:
        """The bits tuple governing ``step`` (cached per phase; appends a
        decision record the first time each phase is resolved)."""
        start = self.phase_start(step)
        if self._phase == start and self._cached is not None:
            return self._cached
        a = self._solve(start)
        self._phase, self._cached = start, a
        self.decisions.append({
            "step": start,
            "bits": list(a),
            "est_dcn_bytes": self._assignment_bytes(a),
            "budget": self.dcn_budget_bytes,
            "stats_driven": self._stats is not None
            and self.dcn_budget_bytes is not None
            and self.group_sizes is not None,
        })
        return a

    def _assignment_bytes(self, assignment) -> Optional[float]:
        if self.group_sizes is None:
            return None
        if self.cost_fn is not None:
            return float(self.cost_fn(self.schedule.policy_at(assignment)))
        total = 0.0
        for item, bits, n in zip(self.schedule.items, assignment,
                                 self.group_sizes):
            if isinstance(item, BitRamp):
                total += n * bits / 8.0
            elif item.name != "fp":
                total += n * item.to_quantizer().wire_bits_per_element / 8.0
        return total

    def _solve(self, start: int) -> Tuple[Optional[int], ...]:
        det = self.schedule.assignment(start, self.total_steps)
        if self.dcn_budget_bytes is None or self.group_sizes is None:
            return det
        items = self.schedule.items
        # start every ramp at LO; static entries are fixed
        bits = [it.lo if isinstance(it, BitRamp) else None for it in items]
        sizes = self.group_sizes

        def weight(i):
            if self._stats is not None:
                s = self._stats[i]
                # importance of one more bit for entry i: observed bucket
                # variance x element count (EF pressure folded in — a
                # group whose residual keeps growing is under-quantized)
                return ((s["sigma_sq"] + s["ef_norm_sq"] / max(sizes[i], 1))
                        * sizes[i]) or float(sizes[i])
            return float(sizes[i])

        def cost():
            return self._assignment_bytes(
                tuple(b if b is not None else None for b in bits))

        blocked: set = set()
        while True:
            best, best_gain = None, 0.0
            for i, it in enumerate(items):
                if (not isinstance(it, BitRamp) or sizes[i] == 0
                        or i in blocked):
                    continue
                cap = det[i] if det[i] is not None else it.hi
                if bits[i] >= cap:
                    continue
                # MSE(b) ~ 4^-b: marginal gain per byte of the extra bit
                gain = weight(i) * (4.0 ** -bits[i] - 4.0 ** -(bits[i] + 1))
                gain /= max(sizes[i] / 8.0, 1e-9)
                if gain > best_gain:
                    best, best_gain = i, gain
            if best is None:
                break
            bits[best] += 1
            if cost() > self.dcn_budget_bytes:
                # this entry's next bit doesn't fit; a smaller group's might
                bits[best] -= 1
                blocked.add(best)
        return tuple(bits[i] if isinstance(items[i], BitRamp) else None
                     for i in range(len(items)))
