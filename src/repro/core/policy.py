"""QuantPolicy: declarative per-parameter-group quantization policy.

The paper's optimal-condition machinery picks optimal *levels* per bucket,
but which leaves get quantized at all is a modelling decision: TernGrad
leaves small/sensitive layers (biases, norms) in full precision, and
Adaptive Gradient Quantization adapts levels per tensor group. A
``QuantPolicy`` captures that as an ordered list of

    (path-pattern  ->  QuantConfig)

rules plus a default, resolved against each parameter leaf's path string
(the same strings ``model.param_paths`` / the gather hook see). The first
matching rule wins; unmatched leaves get the default.

Grammar (launcher ``--quant``, arch configs, JSON):

    POLICY  := SCHEME                      # uniform shorthand
             | RULE ("," RULE)*
    RULE    := PATTERN "=" SCHEME
             | "default" "=" SCHEME
    PATTERN := python regex, matched with re.search against the leaf path
    SCHEME  := any registered scheme name (repro.core.api.all_methods)

Examples:

    "orq-9"                                    # uniform (back-compat)
    "norm|bias=fp, embed=bingrad-b, default=orq-9"
    '{"norm|bias": "fp", "default": "orq-9"}'  # JSON form of the same
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Mapping, Tuple

from repro.core.api import QuantConfig

_GRAMMAR = ("policy grammar: 'pattern=scheme[,pattern=scheme...]"
            "[,default=scheme]' (regex patterns, first match wins) "
            "or a single scheme name for a uniform policy")


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One ordered rule: regex ``pattern`` (re.search) -> ``cfg``."""

    pattern: str
    cfg: QuantConfig

    def __post_init__(self):
        if not self.pattern.strip():
            # re.search("") matches every path — a stray '=' would
            # silently hijack the whole policy
            raise ValueError(f"empty policy pattern; {_GRAMMAR}")
        try:
            re.compile(self.pattern)
        except re.error as e:
            raise ValueError(
                f"bad policy pattern {self.pattern!r}: {e}; {_GRAMMAR}"
            ) from e

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered rules + default, resolvable against any model's param paths."""

    rules: Tuple[PolicyRule, ...] = ()
    default: QuantConfig = QuantConfig(name="fp")

    # -- constructors ------------------------------------------------------
    @classmethod
    def uniform(cls, cfg) -> "QuantPolicy":
        """Back-compat shorthand: every leaf gets ``cfg`` (a QuantConfig or
        a scheme name)."""
        if isinstance(cfg, str):
            cfg = QuantConfig(name=cfg)
        return cls(rules=(), default=cfg)

    @classmethod
    def parse(cls, spec: str, **defaults) -> "QuantPolicy":
        """Parse a policy string (see module grammar). ``defaults`` are
        extra QuantConfig fields (bucket_size, clip_c, ...) applied to
        every rule built from a bare scheme name."""
        spec = spec.strip()
        if spec.startswith("{"):
            try:
                d = json.loads(spec)
            except json.JSONDecodeError as e:
                raise ValueError(f"bad policy JSON {spec!r}: {e}") from e
            return cls.from_dict(d, **defaults)
        if "=" not in spec:
            return cls.uniform(_cfg(spec, defaults))
        rules, default = [], None
        for entry in _split_entries(spec):
            # split on the LAST '=': the scheme never contains one, so
            # regex patterns with lookarounds (e.g. ``norm(?=\d)``) work
            pattern, scheme = (s.strip() for s in entry.rsplit("=", 1))
            if pattern == "default":
                if default is not None:
                    raise ValueError(
                        f"duplicate 'default' entry in policy {spec!r}")
                default = _cfg(scheme, defaults)
            else:
                rules.append(PolicyRule(pattern, _cfg(scheme, defaults)))
        if default is None:
            default = _cfg("fp", defaults)
        return cls(rules=tuple(rules), default=default)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any], **defaults) -> "QuantPolicy":
        """Dict/JSON form: {pattern: scheme-or-config-dict, ...,
        'default': ...}. Insertion order is rule order."""
        rules, default = [], None
        for pattern, val in d.items():
            if isinstance(val, str):
                cfg = _cfg(val, defaults)
            elif isinstance(val, QuantConfig):
                cfg = val
            elif isinstance(val, Mapping):
                cfg = _cfg_from_dict(val, defaults)
            else:
                raise ValueError(
                    f"bad policy value {val!r} for pattern {pattern!r}: "
                    f"expected a scheme name, QuantConfig, or field dict; "
                    f"{_GRAMMAR}")
            if pattern == "default":
                default = cfg
            else:
                rules.append(PolicyRule(pattern, cfg))
        return cls(rules=tuple(rules),
                   default=default if default is not None
                   else _cfg("fp", defaults))

    @classmethod
    def coerce(cls, obj, **defaults) -> "QuantPolicy":
        """Anything-to-policy: QuantPolicy (as-is), QuantConfig (uniform),
        str (parse), Mapping (from_dict)."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, QuantConfig):
            return cls.uniform(obj)
        if isinstance(obj, str):
            return cls.parse(obj, **defaults)
        if isinstance(obj, Mapping):
            return cls.from_dict(obj, **defaults)
        raise TypeError(f"cannot build a QuantPolicy from {type(obj)!r}")

    # -- resolution --------------------------------------------------------
    @property
    def is_uniform(self) -> bool:
        return not self.rules

    def resolve(self, path: str) -> QuantConfig:
        """First matching rule's config, else the default."""
        for rule in self.rules:
            if rule.matches(path):
                return rule.cfg
        return self.default

    def unmatched_rules(self, paths) -> Tuple[str, ...]:
        """Patterns that match NONE of ``paths`` — a typo'd or misspelled
        pattern silently falls through to the default otherwise, so
        resolvers (PolicyLayout.from_tree) warn on these."""
        paths = list(paths)
        return tuple(r.pattern for r in self.rules
                     if not any(r.matches(p) for p in paths))

    def describe(self) -> str:
        parts = [f"{r.pattern}={r.cfg.name}" for r in self.rules]
        parts.append(f"default={self.default.name}")
        return ",".join(parts)


_SCHEME_TOKEN = re.compile(r"[A-Za-z0-9_\-]+")


def _split_entries(spec: str) -> list:
    """Split a policy string into 'pattern=scheme' entries. Commas and '='
    INSIDE a pattern (regex quantifiers like ``{1,2}``, lookarounds like
    ``(?=x)``) are kept: segments are merged until the text after the last
    '=' looks like a bare scheme token — only then is the entry complete."""
    entries, buf = [], ""
    for seg in spec.split(","):
        if not buf and not seg.strip():
            continue
        buf = f"{buf},{seg}" if buf else seg
        if "=" in buf and _SCHEME_TOKEN.fullmatch(
                buf.rsplit("=", 1)[1].strip()):
            entries.append(buf.strip())
            buf = ""
    if buf.strip():
        raise ValueError(
            f"bad policy entry {buf.strip()!r} (missing '=scheme'); "
            f"{_GRAMMAR}")
    return entries


def _cfg(scheme: str, defaults: Mapping[str, Any]) -> QuantConfig:
    cfg = QuantConfig(name=scheme.strip().lower().replace("_", "-"),
                      **defaults)
    try:
        cfg.to_quantizer()   # validate the name against the registry now
        # (make_quantizer's error already names the valid schemes)
    except ValueError as e:
        raise ValueError(
            f"bad scheme {scheme!r} in policy: {e}; {_GRAMMAR}") from e
    return cfg


def _cfg_from_dict(val: Mapping[str, Any],
                   defaults: Mapping[str, Any]) -> QuantConfig:
    kw = dict(defaults)
    kw.update(val)
    name = kw.pop("name", "fp")
    fields = {f.name for f in dataclasses.fields(QuantConfig)}
    bad = sorted(set(kw) - fields)
    if bad:
        # a plain ValueError so launchers surface it as a clean parse
        # error instead of a TypeError traceback
        raise ValueError(
            f"unknown QuantConfig field(s) {bad} in policy entry; valid "
            f"fields: {sorted(fields)}")
    return _cfg(name, kw)
