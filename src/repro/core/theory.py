"""Closed-form quantization-error quantities used to validate the theory.

* ``expected_mse`` — D = E(v − Q(v))² for unbiased random rounding (Eq. 9):
  for v in [b_{k-1}, b_k] the conditional variance is (v−b_{k-1})(b_k−v),
  so D = Σ_k ∫ (v−b_{k-1})(b_k−v) p(v) dv, evaluated exactly on the empirical
  distribution (no sampling noise — this is what Theorem 1 minimizes).
* ``deterministic_mse`` — E(v − Q(v))² for a deterministic rule (BinGrad-b /
  SignSGD), exact on the empirical distribution.
* ``empirical_bias`` — Monte-Carlo E[Q(v)] − v estimator used by the
  unbiasedness property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rounding as R
from repro.core.quantizers import Quantizer


def expected_mse(bkt: jnp.ndarray, mask: jnp.ndarray,
                 levels: jnp.ndarray) -> jnp.ndarray:
    """Exact E‖v − Q(v)‖² per bucket for random rounding at given levels.

    Values outside [levels[0], levels[-1]] contribute their squared clip
    distance plus the rounding variance of the clipped value (matches
    Eq. 14's partially-biased scheme; for ORQ the ends are min/max so no
    element clips).
    """
    v = bkt.astype(jnp.float32)
    k = R.find_interval(v, levels)
    lo = jnp.take_along_axis(levels, k, axis=-1)
    hi = jnp.take_along_axis(levels, k + 1, axis=-1)
    vc = jnp.clip(v, lo, hi)
    var = (vc - lo) * (hi - vc)            # rounding variance (Eq. 9 integrand)
    bias2 = (v - vc) ** 2                  # clipping error
    err = jnp.where(mask, var + bias2, 0.0)
    cnt = jnp.maximum(mask.sum(-1).astype(jnp.float32), 1.0)
    return err.sum(-1) / cnt


def deterministic_mse(bkt: jnp.ndarray, mask: jnp.ndarray,
                      levels: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Exact E‖v − Q(v)‖² per bucket for a deterministic assignment."""
    v = bkt.astype(jnp.float32)
    q = jnp.take_along_axis(levels, idx, axis=-1)
    err = jnp.where(mask, (v - q) ** 2, 0.0)
    cnt = jnp.maximum(mask.sum(-1).astype(jnp.float32), 1.0)
    return err.sum(-1) / cnt


def scheme_mse(qz: Quantizer, flat: jnp.ndarray) -> jnp.ndarray:
    """Exact per-tensor expected quantization MSE of a scheme (no sampling)."""
    from repro.core import buckets as B

    bkt, mask = B.to_buckets(flat.reshape(-1).astype(jnp.float32),
                             qz.bucket_size)
    if qz.clip_c is not None:
        from repro.core import clipping
        bkt_fit = clipping.sigma_clip(bkt, mask, qz.clip_c)
    else:
        bkt_fit = bkt
    lv = qz.fit(bkt, mask)  # fit applies clip internally
    if qz.method in ("bingrad_b", "signsgd"):
        idx = qz.assign(bkt, lv, jax.random.key(0))  # deterministic
        per_bucket = deterministic_mse(bkt_fit, mask, lv, idx)
        # plus clip bias if clipping enabled (error vs original values)
        if qz.clip_c is not None:
            per_bucket = deterministic_mse(bkt, mask, lv, idx)
    else:
        per_bucket = expected_mse(bkt_fit if qz.clip_c is None else bkt,
                                  mask, lv)
    cnt = mask.sum(-1).astype(jnp.float32)
    return (per_bucket * cnt).sum() / jnp.maximum(cnt.sum(), 1.0)


def empirical_bias(qz: Quantizer, flat: jnp.ndarray, key: jax.Array,
                   n_samples: int = 256) -> jnp.ndarray:
    """Monte-Carlo mean of Q(v) − v over repeated rounding draws."""
    keys = jax.random.split(key, n_samples)

    def one(k):
        return qz.qdq(flat, k)

    qs = jax.lax.map(one, keys)
    return qs.mean(axis=0) - flat
