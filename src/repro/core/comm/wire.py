"""Wire format for quantized gradients: level fit + rounding + uint32 packing.

One "wire unit" is a pair ``(words, levels)``:

    words   (nb, nw) uint32 — bit-packed level indices, ``nw`` words per
            bucket at ``qz.wire_bits_per_element`` bits per element;
    levels  (nb, s)  float32 — the per-bucket runtime level tables
            (the paper's level selection happens per bucket, so the tables
            ride the wire next to the payload).

Both collective phases (worker->server and server->worker) speak exactly
this format; the functions here are the single place the encode/decode
pipeline is defined, shared by ``collectives`` and ``exchange``.

Since PR 5 the default path is the FUSED one-pass kernel pipeline
(``kernels/fused_*``): ``encode`` lowers to exactly one ``pallas_call``
(σ-clip -> level search -> random rounding -> bit-pack in one VMEM-tiled
sweep — only the level FIT stays outside as cheap jnp, and for BinGrad-b
even the fit fuses), and ``decode``/``decode_mean``/``decode_each`` lower
to one ``pallas_call`` each (unpack + dequantize [+ average]). The PRNG
bits are threaded in from the same threefry stream as before, so the
fused path is bit-identical to the multi-pass one (``encode_multipass``
et al., kept below as the parity baseline) and to the pure-jnp reference
oracle that ``use_kernels=False`` — or the ``REPRO_USE_KERNELS=0`` env
override — selects. One caveat: the MEAN decode kernels (fused and
multi-pass alike) accumulate ``val/L`` per worker while the oracle sums
then scales, so kernel-vs-oracle equality there is exact only when the
worker count is a power of two (scaling by 2^-k never rounds) and
float-close otherwise; every other op is exact everywhere.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import Quantizer
from repro.kernels import ops

#: schemes that use unbiased random rounding (Eq. 7) on a fitted table
_RR_METHODS = ("orq", "terngrad", "qsgd", "linear", "minmax2", "bingrad_pb")


def bucket_len(chunk: int, d: int) -> int:
    """Effective bucket length for a chunk of ``chunk`` elements."""
    return min(d, max(chunk, 1))


# kept under the historical private name too (monolith-era callers/tests)
_bucket_len = bucket_len


def assign(qz: Quantizer, bkt, levels, key, use_kernels: bool, mask=None):
    """MULTI-PASS rounding dispatch (the PR-1..4 pipeline): random-rounding
    methods go through the Pallas quant_rr kernel. Kept as the building
    block of ``encode_multipass`` / the parity baseline; the default
    ``encode``/``qdq`` path fuses this stage into one kernel instead.

    ``mask`` is the real bucket-validity mask; the σ-clip must see it so
    padded ragged-tail positions feed the σ estimate exactly as in
    ``qz.fit`` (``None`` = all valid)."""
    from repro.core import clipping, rounding as R

    if qz.method in _RR_METHODS:
        if qz.clip_c is not None:
            if mask is None:
                mask = jnp.ones(bkt.shape, dtype=bool)
            bkt = clipping.sigma_clip(bkt, mask, qz.clip_c)
        bits = R.random_bits(key, bkt.shape)
        return ops.quant_rr(bkt, levels, bits, use_kernels=use_kernels)
    return qz.assign(bkt, levels, key, mask=mask)


_assign = assign


def _fused_mode(qz: Quantizer) -> str:
    """Static rounding mode of the fused stage for ``qz`` ('' = no fused
    path; fall back to the multi-pass composition)."""
    if qz.method in _RR_METHODS:
        return "rr"
    if qz.method == "bingrad_b":
        return "bin"
    if qz.method == "signsgd":
        return "sign"
    return ""


def encode_rbits(qz: Quantizer, key, shape):
    """The threefry uint32 stream :func:`encode` would draw for a ``shape``
    bucket layout (None for the deterministic schemes). The pipelined
    exchange generates the bits ONCE for the full canonical (nb, d_eff)
    layout and slices the bucket rows per chunk — ``jax.random.bits`` is
    counter-based over the row-major flattened shape, so bits drawn
    per-chunk-shape would differ and break bit-identity with the
    single-shot path."""
    from repro.core import rounding as R

    if _fused_mode(qz) != "rr":
        return None
    return R.random_bits(key, shape)


def encode(qz: Quantizer, bkt, mask, key, *, use_kernels: bool = True,
           rbits=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fit levels on masked buckets, round, and bit-pack — the fused path.

    bkt/mask are (nb, d_eff); returns ``(words, levels)`` wire units with
    masked-out slots forced to index 0 (they never reach the decoder's
    averaged output — callers slice them away). Everything after the
    level fit is ONE ``pallas_call`` (for BinGrad-b the fit fuses too);
    bit-identical to :func:`encode_multipass` given the same key.

    ``rbits`` optionally supplies the precomputed rounding stream (see
    :func:`encode_rbits`); the default draws it from ``key`` here. Every
    stage — fit, clip, round, pack — is independent per bucket row, so
    encoding a row-slice with the matching ``rbits`` slice reproduces the
    full encode's rows exactly (what the pipelined exchange relies on)."""
    mode = _fused_mode(qz)
    if mode == "bin":
        # b₀ search + conditional-mean levels + threshold + pack, one sweep
        return ops.encode_bingrad(bkt, mask, clip_c=qz.clip_c,
                                  lloyd_iters=qz.lloyd_iters,
                                  use_kernels=use_kernels)
    if not mode:
        return encode_multipass(qz, bkt, mask, key, use_kernels=use_kernels)
    levels = qz.fit(bkt, mask)                            # runtime levels
    if mode == "rr" and rbits is None:
        rbits = encode_rbits(qz, key, bkt.shape)
    words = ops.encode_fused(bkt, levels, rbits if mode == "rr" else None,
                             mask, bits=qz.wire_bits_per_element,
                             clip_c=qz.clip_c, mode=mode,
                             use_kernels=use_kernels)
    return words, levels


def encode_multipass(qz: Quantizer, bkt, mask, key, *,
                     use_kernels: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The PR-1..4 multi-pass encode (fit -> assign kernel -> masked
    select -> pack kernel, each materializing (nb, d) intermediates).
    Kept as the parity/regression baseline for the fused path."""
    levels = qz.fit(bkt, mask)                            # runtime levels
    idx = jnp.where(mask, assign(qz, bkt, levels, key, use_kernels,
                                 mask=mask), 0)
    words = ops.pack(idx, qz.wire_bits_per_element, use_kernels=use_kernels)
    return words, levels


def qdq(qz: Quantizer, bkt, mask, key, *,
        use_kernels: bool = True) -> jnp.ndarray:
    """Fused local quantize->dequantize on the wire layout: (nb, d_eff)
    values -> (nb, d_eff) f32, bit-identical to what :func:`encode` would
    put on the wire (same fit, same clip, same PRNG bits). The
    error-feedback residual hot path — one ``pallas_call``, no idx or
    pack/unpack round-trip (masked-out slots decode to level 0 exactly
    like the multi-pass path)."""
    from repro.core import rounding as R

    levels = qz.fit(bkt, mask)
    mode = _fused_mode(qz)
    if not mode:
        idx = jnp.where(mask, assign(qz, bkt, levels, key, use_kernels,
                                     mask=mask), 0)
        return Quantizer.decode(idx, levels)
    rbits = R.random_bits(key, bkt.shape) if mode == "rr" else None
    return ops.qdq_fused(bkt, levels, rbits, mask, clip_c=qz.clip_c,
                         mode=mode, use_kernels=use_kernels)


def decode(qz: Quantizer, words, levels, d_eff: int, *, average: bool = True,
           use_kernels: bool = True) -> jnp.ndarray:
    """Decode L stacked wire units in ONE ``pallas_call``: unpack +
    dequantize [+ average]. ``average=True`` is the 'server' side of
    phase 1 (-> (nb, d_eff) mean); ``average=False`` is phase 2's
    deterministic broadcast decode (-> (L, nb, d_eff))."""
    bits = qz.wire_bits_per_element
    if average:
        return ops.decode_fused_mean(words, levels, d_eff, bits=bits,
                                     use_kernels=use_kernels)
    return ops.decode_fused_each(words, levels, d_eff, bits=bits,
                                 use_kernels=use_kernels)


def decode_mean(qz: Quantizer, words, levels, d_eff: int, *,
                use_kernels: bool = True) -> jnp.ndarray:
    """Decode L stacked wire units and average: (L, nb, nw) u32 + (L, nb, s)
    -> (nb, d_eff) mean values. This is the 'server' side of phase 1."""
    return decode(qz, words, levels, d_eff, average=True,
                  use_kernels=use_kernels)


def decode_each(qz: Quantizer, words, levels, d_eff: int, *,
                use_kernels: bool = True) -> jnp.ndarray:
    """Decode L stacked wire units without averaging: -> (L, nb, d_eff).
    Phase 2's all-gather'ed broadcast is decoded this way (every worker
    reconstructs each server's re-quantized chunk deterministically)."""
    return decode(qz, words, levels, d_eff, average=False,
                  use_kernels=use_kernels)


def decode_mean_multipass(qz: Quantizer, words, levels, d_eff: int, *,
                          use_kernels: bool = True) -> jnp.ndarray:
    """The PR-1..4 multi-pass mean decode (vmapped unpack kernel writing
    the full (L, nb, d) idx tensor, then dequant_avg). Parity baseline."""
    bits = qz.wire_bits_per_element
    idx_all = jax.vmap(
        lambda w: ops.unpack(w, bits, d_eff, use_kernels=use_kernels)
    )(words)                                              # (L, nb, d_eff)
    return ops.dequant_avg(idx_all, levels, use_kernels=use_kernels)


def decode_each_multipass(qz: Quantizer, words, levels, d_eff: int, *,
                          use_kernels: bool = True) -> jnp.ndarray:
    """The PR-1..4 multi-pass per-worker decode. Parity baseline."""
    bits = qz.wire_bits_per_element
    idx_all = jax.vmap(
        lambda w: ops.unpack(w, bits, d_eff, use_kernels=use_kernels)
    )(words)                                              # (L, nb, d_eff)
    return jax.vmap(Quantizer.decode)(idx_all, levels)


def encode_stats(qz: Quantizer, flat: jnp.ndarray,
                 d_eff: int) -> jnp.ndarray:
    """(3,) f32 ``[sigma_sq, clip_frac, l2_sq]`` of a flat buffer under
    ``qz``'s bucket layout — the optional statistics output of the encode
    path (per-bucket sigma^2 count-weighted over the buffer, the fraction
    of elements ``qz.clip_c`` would clamp, the squared norm). This is the
    cheap feed the adaptive ``BitBudgetController`` re-solves per-group
    bits from; see ``ops.bucket_stats`` (reductions only, no extra
    ``pallas_call``)."""
    from repro.core import buckets

    bkt, mask = buckets.to_buckets(flat.astype(jnp.float32), d_eff)
    return ops.bucket_stats(bkt, mask, clip_c=qz.clip_c)


def wire_unit_bytes(qz: Quantizer, nb: int, d_eff: int) -> int:
    """Bytes on the wire for one (words, levels) unit of nb buckets."""
    from repro.core import encode as E

    words = E.packed_words(d_eff, qz.wire_bits_per_element)
    return 4 * nb * (words + qz.s)
