"""Wire format for quantized gradients: level fit + rounding + uint32 packing.

One "wire unit" is a pair ``(words, levels)``:

    words   (nb, nw) uint32 — bit-packed level indices, ``nw`` words per
            bucket at ``qz.wire_bits_per_element`` bits per element;
    levels  (nb, s)  float32 — the per-bucket runtime level tables
            (the paper's level selection happens per bucket, so the tables
            ride the wire next to the payload).

Both collective phases (worker->server and server->worker) speak exactly
this format; the functions here are the single place the encode/decode
pipeline is defined, shared by ``collectives`` and ``exchange``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import Quantizer
from repro.kernels import ops


def bucket_len(chunk: int, d: int) -> int:
    """Effective bucket length for a chunk of ``chunk`` elements."""
    return min(d, max(chunk, 1))


# kept under the historical private name too (monolith-era callers/tests)
_bucket_len = bucket_len


def assign(qz: Quantizer, bkt, levels, key, use_kernels: bool, mask=None):
    """Rounding dispatch: random-rounding methods go through the Pallas
    quant_rr kernel (VMEM-tiled; never materializes an (nb, d, s) tensor).

    ``mask`` is the real bucket-validity mask; the σ-clip must see it so
    padded ragged-tail positions feed the σ estimate exactly as in
    ``qz.fit`` (``None`` = all valid)."""
    from repro.core import clipping, rounding as R

    if qz.method in ("orq", "terngrad", "qsgd", "linear", "minmax2",
                     "bingrad_pb"):
        if qz.clip_c is not None:
            if mask is None:
                mask = jnp.ones(bkt.shape, dtype=bool)
            bkt = clipping.sigma_clip(bkt, mask, qz.clip_c)
        bits = R.random_bits(key, bkt.shape)
        return ops.quant_rr(bkt, levels, bits, use_kernels=use_kernels)
    return qz.assign(bkt, levels, key, mask=mask)


_assign = assign


def encode(qz: Quantizer, bkt, mask, key, *,
           use_kernels: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fit levels on masked buckets, round, and bit-pack.

    bkt/mask are (nb, d_eff); returns ``(words, levels)`` wire units with
    masked-out slots forced to index 0 (they never reach the decoder's
    averaged output — callers slice them away)."""
    levels = qz.fit(bkt, mask)                            # runtime levels
    idx = jnp.where(mask, assign(qz, bkt, levels, key, use_kernels,
                                 mask=mask), 0)
    words = ops.pack(idx, qz.wire_bits_per_element, use_kernels=use_kernels)
    return words, levels


def decode_mean(qz: Quantizer, words, levels, d_eff: int, *,
                use_kernels: bool = True) -> jnp.ndarray:
    """Decode L stacked wire units and average: (L, nb, nw) u32 + (L, nb, s)
    -> (nb, d_eff) mean values. This is the 'server' side of phase 1."""
    bits = qz.wire_bits_per_element
    idx_all = jax.vmap(
        lambda w: ops.unpack(w, bits, d_eff, use_kernels=use_kernels)
    )(words)                                              # (L, nb, d_eff)
    return ops.dequant_avg(idx_all, levels, use_kernels=use_kernels)


def decode_each(qz: Quantizer, words, levels, d_eff: int, *,
                use_kernels: bool = True) -> jnp.ndarray:
    """Decode L stacked wire units without averaging: -> (L, nb, d_eff).
    Phase 2's all-gather'ed broadcast is decoded this way (every worker
    reconstructs each server's re-quantized chunk deterministically)."""
    bits = qz.wire_bits_per_element
    idx_all = jax.vmap(
        lambda w: ops.unpack(w, bits, d_eff, use_kernels=use_kernels)
    )(words)                                              # (L, nb, d_eff)
    return jax.vmap(Quantizer.decode)(idx_all, levels)


def wire_unit_bytes(qz: Quantizer, nb: int, d_eff: int) -> int:
    """Bytes on the wire for one (words, levels) unit of nb buckets."""
    from repro.core import encode as E

    words = E.packed_words(d_eff, qz.wire_bits_per_element)
    return 4 * nb * (words + qz.s)
