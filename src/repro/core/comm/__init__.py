"""Quantized gradient communication, layered.

    wire           pack/unpack + level tables — the uint32 payload format
    collectives    phase-1/phase-2 shard_map primitives (Algorithm 2)
    gather         custom-VJP FSDP / replicated parameter gathers (per-leaf)
    exchange       fused flat-buffer engine (GradLayout + GradientExchange,
                   PolicyLayout + PartitionedExchange)
    fsdp_exchange  shard-aware fused ZeRO-3 engine (FsdpLayout +
                   FsdpExchange + the whole-tree custom-VJP gather)

This package replaces the former ``repro.core.comm`` monolith; every name
that module exported (including the historical private helpers some tests
reach for) is re-exported here so old call sites keep working unmodified.

``hierarchical`` adds the two-level (ICI/DCN) mode: axis splitting
(``split_dp_axes``), the full-precision intra-pod scatter/gather
primitives, and the per-link accounting (``link_stats`` /
``policy_link_stats``) that prices ICI vs DCN bytes separately.
"""
from repro.core.comm.collectives import (_names, _rs_mean_parts, axis_size,
                                         local_qdq_comm_layout,
                                         psum_mean_tree,
                                         quantized_all_reduce_mean,
                                         quantized_reduce_scatter_mean)
from repro.core.comm.exchange import (GradientExchange, GradLayout,
                                      GroupSegment, LeafSlot,
                                      PartitionedExchange, PolicyLayout,
                                      fused_stats, link_stats,
                                      observed_link_stats, per_leaf_stats,
                                      policy_link_stats, policy_stats)
from repro.core.comm.hierarchical import (HIERARCHIES, INTER_AXIS_NAMES,
                                          intra_all_gather, intra_chunk_len,
                                          intra_reduce_scatter_mean,
                                          resolve_hierarchy,
                                          shard_valid_mask, split_dp_axes)
from repro.core.comm.fsdp_exchange import (FsdpExchange, FsdpGroup,
                                           FsdpLayout, FsdpSlot,
                                           make_fused_tree_gather,
                                           reduce_scatter_mean_block)
from repro.core.comm.gather import make_fsdp_gather, make_replicated_gather
from repro.core.comm.wire import _assign, _bucket_len

__all__ = [
    "axis_size",
    "local_qdq_comm_layout",
    "psum_mean_tree",
    "quantized_all_reduce_mean",
    "quantized_reduce_scatter_mean",
    "make_fsdp_gather",
    "make_replicated_gather",
    "FsdpExchange",
    "FsdpGroup",
    "FsdpLayout",
    "FsdpSlot",
    "GradLayout",
    "GradientExchange",
    "GroupSegment",
    "LeafSlot",
    "PartitionedExchange",
    "PolicyLayout",
    "make_fused_tree_gather",
    "reduce_scatter_mean_block",
    "fused_stats",
    "per_leaf_stats",
    "policy_stats",
    "link_stats",
    "policy_link_stats",
    "observed_link_stats",
    "HIERARCHIES",
    "INTER_AXIS_NAMES",
    "resolve_hierarchy",
    "split_dp_axes",
    "intra_all_gather",
    "intra_chunk_len",
    "intra_reduce_scatter_mean",
    "shard_valid_mask",
]
