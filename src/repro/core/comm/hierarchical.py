"""Hierarchical two-level (ICI/DCN) quantized gradient exchange.

The paper's optimal quantization condition holds for ANY gradient
distribution — in particular for the *intra-pod-averaged* gradient. On a
multi-pod mesh (``("pod", "data")`` dp axes) the fast intra-pod ICI links
can therefore carry full-precision collectives while quantization is
reserved for the scarce inter-pod DCN hops, exactly where DQ-SGD argues
compression should adapt to the communication setting and where TernGrad
reports the bulk of its wall-clock wins:

    phase 0 (ICI, full precision)   ``intra_reduce_scatter_mean``: each
        worker ends with a 1/L_intra shard of the pod-local mean gradient —
        the only data that still needs to cross pods.
    phase 1+2 (DCN, quantized)      the ordinary Algorithm 2 exchange
        (``quantized_all_reduce_mean``) runs on the SHARD over the ``pod``
        axis only: levels are fitted to the intra-averaged shard, so the
        unbiasedness / optimal-condition guarantees apply unchanged to the
        axis that actually gets quantized.
    phase 3 (ICI, full precision)   ``intra_all_gather`` reassembles the
        full global-mean buffer inside each pod.

Quantized wire traffic on the DCN link shrinks by 1/L_intra (each pod
sends shards, not full gradients); the ICI links pay two f32 collectives
they can afford. On a single-pod mesh the split degenerates to
``(intra=(), inter=dp_axes)`` and the exchange is bit-identical to the
flat one — the degenerate path IS the flat path.

This module owns the axis-splitting policy and the full-precision intra
primitives; the quantized inter phases live in ``collectives.py`` and the
engines (``exchange.py``/``fsdp_exchange.py``) compose the two.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax import lax

from repro.core.comm.collectives import _names, axis_size

# dp axes that cross the slow inter-pod (DCN) boundary; everything else in
# the dp tuple is a fast intra-pod (ICI) axis. Matches the mesh layer's
# multi-pod convention (launch/mesh.py: ("pod", "data", "model")).
INTER_AXIS_NAMES: Tuple[str, ...] = ("pod",)

HIERARCHIES = ("flat", "two_level", "two_level_async", "auto")


def resolve_hierarchy(hierarchy: str, dp_axes, local_steps: int = 1) -> str:
    """'flat', 'two_level' or 'two_level_async' for a dp axis tuple; 'auto'
    picks two_level whenever the dp mesh has >= 2 axes (i.e. a pod axis to
    split off) — never the temporal variant, which changes training
    semantics and must be opted into explicitly.

    ``two_level_async`` with ``local_steps <= 1`` resolves to
    ``two_level``: an H=1 window syncs on every step, which IS the spatial
    hierarchy — routing it onto the literal two_level code path makes the
    flat≡H=1 bit-identity hold by construction, the same way a single-pod
    two_level IS flat.
    """
    if hierarchy not in HIERARCHIES:
        raise ValueError(
            f"hierarchy must be one of {HIERARCHIES}, got {hierarchy!r}")
    if hierarchy == "auto":
        return "two_level" if len(tuple(dp_axes)) >= 2 else "flat"
    if hierarchy == "two_level_async" and local_steps <= 1:
        return "two_level"
    return hierarchy


def split_dp_axes(dp_axes, hierarchy: str) -> Tuple[Tuple[str, ...],
                                                    Tuple[str, ...]]:
    """Split the ordered dp axis tuple into ``(intra_axes, inter_axes)``.

    flat: everything is quantized -> ``((), dp_axes)``.
    two_level: the slow :data:`INTER_AXIS_NAMES` axes carry the quantized
    exchange, the rest stay full precision. A mesh with no pod axis (or a
    pod-only dp mesh) degenerates to the flat split, which keeps two_level
    bit-identical to flat on single-pod meshes by construction.

    The inter axes must precede the intra axes in mesh order (they do for
    the canonical ``("pod", "data")`` tuple): the fused fsdp layout relies
    on the combined worker enumeration being inter-major.
    """
    dp = _names(dp_axes)
    if resolve_hierarchy(hierarchy, dp) == "flat":
        return (), dp
    inter = tuple(a for a in dp if a in INTER_AXIS_NAMES)
    intra = tuple(a for a in dp if a not in INTER_AXIS_NAMES)
    if not inter or not intra:
        return (), dp
    if dp != inter + intra:
        raise ValueError(
            f"inter axes {inter} must precede intra axes {intra} in the dp "
            f"tuple {dp}: the combined worker enumeration (and the fused "
            f"fsdp row layout) is inter-major")
    return intra, inter


# ---------------------------------------------------------------------------
# full-precision intra-pod primitives (inside shard_map over the dp axes)
# ---------------------------------------------------------------------------

def intra_chunk_len(n: int, n_intra: int) -> int:
    """Static per-worker shard length of an (n,) buffer scattered over
    ``n_intra`` intra workers (ceil division; the tail shard is padded)."""
    return -(-n // max(n_intra, 1))


def intra_reduce_scatter_mean(flat: jnp.ndarray, intra_names) -> jnp.ndarray:
    """(n,) local buffer -> (ceil(n/L_i),) shard of the intra-axis MEAN.
    Full precision (one psum_scatter on the fast ICI link)."""
    names = _names(intra_names)
    L = axis_size(names)
    n = flat.shape[0]
    chunk = intra_chunk_len(n, L)
    padded = jnp.pad(flat.astype(jnp.float32), (0, L * chunk - n))
    return lax.psum_scatter(padded.reshape(L, chunk), names,
                            scatter_dimension=0, tiled=False) / L


def intra_all_gather(shard: jnp.ndarray, intra_names, n: int) -> jnp.ndarray:
    """(chunk,) per-worker shard -> the reassembled (n,) buffer (one
    all_gather on the fast ICI link; inverse of the scatter above)."""
    names = _names(intra_names)
    full = lax.all_gather(shard, names, axis=0, tiled=False)
    return full.reshape(-1)[:n]


def shard_valid_mask(n: int, intra_names) -> jnp.ndarray:
    """(chunk,) bool: which positions of THIS worker's intra shard map to
    real elements of the original (n,) buffer (False = scatter padding).
    Threaded into the quantized inter exchange so ragged-tail padding can
    never skew a bucket's sigma fit."""
    names = _names(intra_names)
    L = axis_size(names)
    chunk = intra_chunk_len(n, L)
    d = lax.axis_index(names)
    return d * chunk + jnp.arange(chunk) < n
