"""Quantized collectives: the distributed half of Algorithm 2, TPU-native.

The paper's parameter-server exchange maps onto two collective phases inside
``shard_map`` (manual axes = the data-parallel mesh axes):

  phase 1 (worker -> server)  ``quantized_reduce_scatter_mean``:
      each worker fits levels on its *local* gradient (the paper's runtime
      level selection), quantizes, bit-packs, and ``all_to_all``s the uint32
      payload + f32 level tables. Every worker then decodes the L received
      copies of its own chunk and averages — it *is* the server for that
      chunk. Wire bytes shrink by ~32/bits vs an f32 reduce-scatter.

  phase 2 (server -> worker)  inside ``quantized_all_reduce_mean``:
      the averaged chunk is re-quantized (fresh levels) and ``all_gather``ed
      — the paper's §4 option (b) "quantize the averaged gradient that the
      server sends back". Decoding is deterministic, so all workers
      reconstruct identical full gradients and replicated parameters stay
      in sync. ``server_requant=False`` gathers the f32 chunk instead
      (exact broadcast, 32-bit downlink).

The wire format (fit + round + uint32 bit-pack) lives in
``repro.core.comm.wire``; this module owns the collective choreography.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.comm import wire
from repro.core.comm.wire import _bucket_len
from repro.core.quantizers import Quantizer
from repro.utils import compat


def _names(axis_names) -> Tuple[str, ...]:
    """Normalize ``axis_names`` to an ORDERED tuple.

    Axis order is semantically meaningful here: it fixes the worker
    enumeration every collective in both phases relies on, and it must
    agree with the mesh/PartitionSpec axis order. A ``set`` iterates in
    hash order, which varies with ``PYTHONHASHSEED`` — two processes of a
    multi-process run could then lower the same collective with different
    axis orderings — and any fixed normalization (e.g. sorting) could
    still disagree with the mesh order. So sets are rejected outright;
    pass the ordered tuple the mesh was built with.
    """
    if isinstance(axis_names, str):
        return (axis_names,)
    if isinstance(axis_names, (set, frozenset)):
        raise TypeError(
            "axis_names must be an ordered tuple (or a single name), not "
            f"a set: {sorted(axis_names)!r} — set iteration order is "
            "PYTHONHASHSEED-dependent and the collective axis order must "
            "match the mesh axis order")
    return tuple(axis_names)


def axis_size(axis_names) -> int:
    n = 1
    for a in _names(axis_names):
        n *= compat.axis_size(a)
    return n


# ---------------------------------------------------------------------------
# phase 1 core: quantized reduce-scatter over explicit (L, chunk) parts
# ---------------------------------------------------------------------------

def _chunk_spans(n_rows: int, k) -> list:
    """Split ``n_rows`` bucket rows into ``k`` contiguous [a, b) spans
    (clamped to [1, n_rows]; the first ``n_rows % k`` spans get the extra
    row). The pipeline schedule is STATIC — span boundaries are Python
    ints, so each chunk lowers to its own encode + collective ops and XLA's
    latency-hiding scheduler can overlap chunk k's transfer with chunk
    k+1's encode."""
    k = max(1, min(int(k), n_rows))
    base, rem = divmod(n_rows, k)
    spans, a = [], 0
    for i in range(k):
        b = a + base + (1 if i < rem else 0)
        spans.append((a, b))
        a = b
    return spans


def _rs_mean_parts(parts, valid, qz: Quantizer, key, names, use_kernels,
                   pipeline_chunks: int = 1):
    """parts (L, chunk) local contributions, one row per destination worker;
    valid (L, chunk) bool. Returns this worker's (chunk,) mean slice.

    ``key`` must already be folded per-worker (callers fold in the dp axis
    index OUTSIDE any nested manual region — axis_index of an outer-manual
    axis cannot lower inside a nested shard_map).

    ``pipeline_chunks > 1`` splits the nbc bucket rows into that many
    contiguous spans and runs fit→encode→all_to_all→decode once per span,
    double-buffered: span k's payload is in flight while span k+1 encodes.
    Bit-identical to the single-shot path — every encode/decode stage is
    independent per bucket row, and the random-rounding stream is drawn
    ONCE at the full (L·nbc, d_eff) layout and sliced per span (threefry
    bits are counter-based over the flattened shape, so drawing them at
    the span's own shape would change them)."""
    L, chunk = parts.shape
    d_eff = _bucket_len(chunk, qz.bucket_size)
    pad = -(-chunk // d_eff) * d_eff - chunk
    parts = jnp.pad(parts.astype(jnp.float32), ((0, 0), (0, pad)))
    valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nbc = parts.shape[1] // d_eff

    bkt = parts.reshape(L * nbc, d_eff)
    mask = valid.reshape(L * nbc, d_eff)
    spans = _chunk_spans(nbc, pipeline_chunks)
    if len(spans) == 1:
        words, levels = wire.encode(qz, bkt, mask, key,
                                    use_kernels=use_kernels)
        words = words.reshape(L, nbc, -1)
        levels = levels.reshape(L, nbc, -1)
        # the wire: uint32 payload + f32 level tables
        words = lax.all_to_all(words, names, split_axis=0, concat_axis=0)
        levels = lax.all_to_all(levels, names, split_axis=0, concat_axis=0)
        mean_bkt = wire.decode_mean(qz, words, levels, d_eff,
                                    use_kernels=use_kernels)
        return mean_bkt.reshape(-1)[:chunk]

    # pipelined: K per-span wire units, each its own pair of all_to_alls.
    rbits = wire.encode_rbits(qz, key, (L * nbc, d_eff))
    bkt = bkt.reshape(L, nbc, d_eff)
    mask = mask.reshape(L, nbc, d_eff)
    rbits = None if rbits is None else rbits.reshape(L, nbc, d_eff)
    means = []
    for a, b in spans:
        sz = b - a
        sw, sl = wire.encode(
            qz, bkt[:, a:b].reshape(L * sz, d_eff),
            mask[:, a:b].reshape(L * sz, d_eff), key,
            use_kernels=use_kernels,
            rbits=None if rbits is None
            else rbits[:, a:b].reshape(L * sz, d_eff))
        sw = sw.reshape(L, sz, -1)
        sl = sl.reshape(L, sz, -1)
        sw = lax.all_to_all(sw, names, split_axis=0, concat_axis=0)
        sl = lax.all_to_all(sl, names, split_axis=0, concat_axis=0)
        means.append(wire.decode_mean(qz, sw, sl, d_eff,
                                      use_kernels=use_kernels))
    mean_bkt = jnp.concatenate(means, axis=0)             # (nbc, d_eff)
    return mean_bkt.reshape(-1)[:chunk]


def _valid_parts(valid, n: int, L: int, chunk: int) -> jnp.ndarray:
    """(L, chunk) bool validity for an (n,) buffer split into L chunks.
    ``valid`` optionally overrides the default arange<n mask — the
    hierarchical exchange passes the GLOBAL validity of an intra-scattered
    shard so its padding can't skew level fits."""
    if valid is None:
        return (jnp.arange(L * chunk) < n).reshape(L, chunk)
    return jnp.pad(valid, (0, L * chunk - n)).reshape(L, chunk)


def quantized_reduce_scatter_mean(
    flat: jnp.ndarray,
    qz: Quantizer,
    key: jax.Array,
    axis_names,
    *,
    worker_id=None,
    use_kernels: bool = True,
    valid=None,
    pipeline_chunks: int = 1,
) -> jnp.ndarray:
    """Each worker holds a full local gradient ``flat`` (n,). Returns this
    worker's (chunk,) slice of the across-worker *mean*, chunk = ceil(n/L).
    FP scheme short-circuits to a plain psum_scatter.

    ``worker_id`` defaults to ``axis_index`` of the dp axes; custom-VJP
    backward callers must pass it explicitly (axis_index cannot lower from
    transposed/hoisted contexts). ``valid`` optionally marks which of the
    n positions are real data (default: all of them). ``pipeline_chunks``
    splits the exchange into that many bucket-row spans whose encodes
    overlap the previous span's transfer — bit-identical to the
    single-shot schedule (see ``_rs_mean_parts``)."""
    n = flat.shape[0]
    names = _names(axis_names)
    L = axis_size(names)
    chunk = -(-n // L)
    padded = jnp.pad(flat, (0, L * chunk - n))
    if qz.is_identity:
        return lax.psum_scatter(
            padded.reshape(L, chunk), names, scatter_dimension=0,
            tiled=False) / L
    valid = _valid_parts(valid, n, L, chunk)
    if worker_id is None:
        worker_id = lax.axis_index(names)
    key = jax.random.fold_in(key, worker_id)
    return _rs_mean_parts(padded.reshape(L, chunk), valid, qz, key, names,
                          use_kernels, pipeline_chunks=pipeline_chunks)


# ---------------------------------------------------------------------------
# phase 1 + 2: quantized all-reduce (mean), replicated-parameter mode
# ---------------------------------------------------------------------------

def local_qdq_comm_layout(
    flat: jnp.ndarray,
    qz: Quantizer,
    key: jax.Array,
    axis_names,
    *,
    worker_id=None,
    use_kernels: bool = True,
    valid=None,
) -> jnp.ndarray:
    """This worker's own dequantized gradient, bit-identical to what it
    contributed to ``quantized_reduce_scatter_mean`` (same chunk/bucket
    layout, same folded key, same ``valid`` mask). Used by error feedback:
    e ← g − Q⁻¹(Q(g)). Runs the fused ``wire.qdq`` kernel — one
    ``pallas_call``, no idx tensor or pack/unpack round-trip."""
    n = flat.shape[0]
    names = _names(axis_names)
    L = axis_size(names)
    chunk = -(-n // L)
    padded = jnp.pad(flat.astype(jnp.float32), (0, L * chunk - n))
    d_eff = _bucket_len(chunk, qz.bucket_size)
    pad2 = -(-chunk // d_eff) * d_eff - chunk
    parts = jnp.pad(padded.reshape(L, chunk), ((0, 0), (0, pad2)))
    valid = jnp.pad(_valid_parts(valid, n, L, chunk), ((0, 0), (0, pad2)))
    bkt = parts.reshape(-1, d_eff)
    mask = valid.reshape(-1, d_eff)
    if worker_id is None:
        worker_id = lax.axis_index(names)
    key = jax.random.fold_in(key, worker_id)
    vals = wire.qdq(qz, bkt, mask, key, use_kernels=use_kernels)
    return vals.reshape(L, -1)[:, :chunk].reshape(-1)[:n]


def quantized_all_reduce_mean(
    flat: jnp.ndarray,
    qz: Quantizer,
    key: jax.Array,
    axis_names,
    *,
    worker_id=None,
    server_requant: bool = True,
    use_kernels: bool = True,
    valid=None,
    pipeline_chunks: int = 1,
) -> jnp.ndarray:
    """Full Algorithm 2 exchange. Returns the (n,) mean gradient, identical
    on every worker (the phase-2 decode is deterministic). ``valid``
    optionally marks the real positions of ``flat`` (both phases fit their
    levels on valid data only). ``pipeline_chunks`` chunks BOTH phases —
    phase 2's re-quantize + all_gather pipelines over the same bucket-row
    spans as phase 1 — and stays bit-identical to the single-shot path."""
    n = flat.shape[0]
    names = _names(axis_names)
    L = axis_size(names)
    if qz.is_identity:
        return lax.pmean(flat, names)

    chunk = -(-n // L)
    mean_chunk = quantized_reduce_scatter_mean(
        flat, qz, key, names, worker_id=worker_id, use_kernels=use_kernels,
        valid=valid, pipeline_chunks=pipeline_chunks)

    if not server_requant:
        full = lax.all_gather(mean_chunk, names, axis=0, tiled=False)
        return full.reshape(-1)[:n].astype(flat.dtype)

    # phase 2: re-quantize the averaged chunk; broadcast payload + levels.
    me = lax.axis_index(names) if worker_id is None else worker_id
    d_eff = _bucket_len(chunk, qz.bucket_size)
    pad = -(-chunk // d_eff) * d_eff - chunk
    bkt = jnp.pad(mean_chunk, (0, pad)).reshape(-1, d_eff)
    if valid is None:
        pos = me * chunk + jnp.arange(chunk + pad)
        mask = (pos < n) & (jnp.arange(chunk + pad) < chunk)
    else:
        vchunk = lax.dynamic_slice(
            jnp.pad(valid, (0, L * chunk - n)), (me * chunk,), (chunk,))
        mask = jnp.pad(vchunk, (0, pad))
    mask = mask.reshape(-1, d_eff)
    key2 = jax.random.fold_in(jax.random.fold_in(key, 0x5EC0), me)
    spans = _chunk_spans(bkt.shape[0], pipeline_chunks)
    if len(spans) == 1:
        words, levels = wire.encode(qz, bkt, mask, key2,
                                    use_kernels=use_kernels)
        words = lax.all_gather(words, names, axis=0, tiled=False)
        levels_all = lax.all_gather(levels, names, axis=0, tiled=False)
        vals = wire.decode_each(qz, words, levels_all, d_eff,
                                use_kernels=use_kernels)  # (L, nbc, d_eff)
    else:
        # pipelined downlink: span k's gather flies while k+1 re-quantizes.
        rbits = wire.encode_rbits(qz, key2, bkt.shape)
        parts = []
        for a, b in spans:
            sw, sl = wire.encode(qz, bkt[a:b], mask[a:b], key2,
                                 use_kernels=use_kernels,
                                 rbits=None if rbits is None else rbits[a:b])
            sw = lax.all_gather(sw, names, axis=0, tiled=False)
            sl = lax.all_gather(sl, names, axis=0, tiled=False)
            parts.append(wire.decode_each(qz, sw, sl, d_eff,
                                          use_kernels=use_kernels))
        vals = jnp.concatenate(parts, axis=1)             # (L, nbc, d_eff)
    vals = vals.reshape(L, -1)[:, :chunk]
    return vals.reshape(-1)[:n].astype(flat.dtype)


def psum_mean_tree(tree, axis_names):
    """FP baseline: plain pmean over the dp axes for a whole pytree."""
    return jax.tree_util.tree_map(lambda x: lax.pmean(x, axis_names), tree)
