"""Fused flat-buffer gradient exchange: one collective for the whole pytree.

Issuing the Algorithm 2 exchange once per parameter leaf costs a 100+ leaf
model 100+ collective launches, 100+ ragged-bucket paddings, and 100+ tiny
level-table transfers per step. TernGrad and Adaptive Gradient Quantization
both flatten gradients into large contiguous buffers before quantizing for
exactly this reason. This module does the same for the paper's exchange:

    GradLayout          static flatten/unflatten plan for a gradient pytree
                        (per-leaf offsets/sizes/dtypes, computed once at
                        trace time from static shapes);
    GradientExchange    runs a SINGLE quantized all-reduce (optionally
                        size-capped chunks for memory control) over the
                        fused f32 buffer, plus the matching fused
                        ``local_qdq`` for error-feedback residuals and the
                        fused single-device qdq path.

O(1) collective launches per step instead of O(num_leaves); and because
bucket boundaries land on the fused buffer, many-tiny-leaf trees also
save wire bytes (small leaves share buckets instead of each paying its
own ragged tail and level table — for few-large-leaf trees the byte
counts are essentially equal and the win is the launch count).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import wire
from repro.core.comm.collectives import (local_qdq_comm_layout,
                                         quantized_all_reduce_mean)
from repro.core.quantizers import Quantizer
from repro.utils.pytree import tree_flatten_with_path_strs


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's span inside the fused buffer."""

    path: str
    shape: Tuple[int, ...]
    dtype: Any
    offset: int
    size: int


@dataclasses.dataclass(frozen=True)
class GradLayout:
    """Static flatten/unflatten plan: leaf order, spans, dtype restore.

    Built once from abstract (or concrete) leaves — everything here is
    trace-time static, so ``flatten``/``unflatten`` lower to pure
    reshape/concat/slice with no per-leaf collective work.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    size: int                    # total element count of the fused buffer

    @classmethod
    def from_tree(cls, tree) -> "GradLayout":
        pairs, treedef = tree_flatten_with_path_strs(tree)
        slots: List[LeafSlot] = []
        off = 0
        for path, leaf in pairs:
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            slots.append(LeafSlot(path=path, shape=tuple(leaf.shape),
                                  dtype=leaf.dtype, offset=off, size=size))
            off += size
        return cls(treedef=treedef, slots=tuple(slots), size=off)

    # -- buffer <-> tree ---------------------------------------------------
    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> (size,) contiguous f32 buffer (canonical leaf order)."""
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.slots), (len(leaves), len(self.slots))
        return jnp.concatenate(
            [x.astype(jnp.float32).reshape(-1) for x in leaves])

    def unflatten(self, buf: jnp.ndarray, *, restore_dtype: bool = True):
        """(size,) buffer -> pytree, restoring each leaf's shape (and dtype
        unless ``restore_dtype=False`` — error-feedback residuals stay f32)."""
        leaves = []
        for s in self.slots:
            leaf = jax.lax.dynamic_slice_in_dim(buf, s.offset, s.size)
            leaf = leaf.reshape(s.shape)
            leaves.append(leaf.astype(s.dtype) if restore_dtype else leaf)
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def leaf_slice(self, buf: jnp.ndarray, i: int) -> jnp.ndarray:
        """The i-th leaf's span of the fused buffer, in leaf shape (f32)."""
        s = self.slots[i]
        return buf[s.offset:s.offset + s.size].reshape(s.shape)

    # -- static accounting -------------------------------------------------
    def padded_size(self, n_workers: int, bucket_size: int) -> int:
        """Fused buffer size after worker-chunk + bucket alignment (what
        actually hits the wire for a given mesh)."""
        chunk = -(-self.size // max(n_workers, 1))
        d_eff = wire.bucket_len(chunk, bucket_size)
        chunk_p = -(-chunk // d_eff) * d_eff
        return n_workers * chunk_p


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradientExchange:
    """Fused Algorithm 2 exchange over a GradLayout's flat buffer.

    ``max_chunk_elems`` optionally caps the per-collective buffer size (a
    memory-control knob for very large models): the fused buffer is split
    into ceil(n / cap) contiguous spans, each exchanged independently with
    a per-span folded key. Launches stay O(n / cap), independent of leaf
    count. ``local_qdq_flat`` applies the identical span/key schedule, so
    error-feedback residuals remain bit-consistent with what was sent.
    """

    qz: Quantizer
    axis_names: Any
    server_requant: bool = True
    use_kernels: bool = True
    max_chunk_elems: Optional[int] = None

    def __post_init__(self):
        if self.max_chunk_elems is not None and self.max_chunk_elems <= 0:
            raise ValueError(
                f"max_chunk_elems must be positive, got "
                f"{self.max_chunk_elems}")

    # -- span schedule (static) -------------------------------------------
    def spans(self, n: int) -> List[Tuple[int, int]]:
        cap = self.max_chunk_elems
        if not cap or n <= cap:
            return [(0, n)]
        return [(a, min(a + cap, n)) for a in range(0, n, cap)]

    def _span_key(self, key: jax.Array, i: int) -> jax.Array:
        return jax.random.fold_in(key, i) if self.max_chunk_elems else key

    # -- distributed paths (inside shard_map over the dp axes) -------------
    def exchange_flat(self, flat: jnp.ndarray, key: jax.Array, *,
                      worker_id=None) -> jnp.ndarray:
        """(n,) local gradient buffer -> (n,) across-worker mean, identical
        on every worker. One quantized all-reduce per span."""
        outs = [
            quantized_all_reduce_mean(
                flat[a:b], self.qz, self._span_key(key, i), self.axis_names,
                worker_id=worker_id, server_requant=self.server_requant,
                use_kernels=self.use_kernels)
            for i, (a, b) in enumerate(self.spans(flat.shape[0]))
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def local_qdq_flat(self, flat: jnp.ndarray, key: jax.Array, *,
                       worker_id=None) -> jnp.ndarray:
        """This worker's own dequantized fused buffer, bit-identical to its
        phase-1 contribution (same spans, same chunk/bucket layout, same
        folded keys). Error feedback: e ← g − Q⁻¹(Q(g)) on the FUSED layout."""
        outs = [
            local_qdq_comm_layout(
                flat[a:b], self.qz, self._span_key(key, i), self.axis_names,
                worker_id=worker_id, use_kernels=self.use_kernels)
            for i, (a, b) in enumerate(self.spans(flat.shape[0]))
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def exchange(self, tree, key: jax.Array, *, layout: Optional[GradLayout]
                 = None, worker_id=None):
        """Pytree-level convenience: flatten -> exchange_flat -> unflatten."""
        layout = layout or GradLayout.from_tree(tree)
        mean = self.exchange_flat(layout.flatten(tree), key,
                                  worker_id=worker_id)
        return layout.unflatten(mean)

    # -- single-device path (no mesh axes) ---------------------------------
    def qdq_local_flat(self, flat: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """Fused single-machine Algorithm 2: quantize->dequantize the whole
        buffer locally (one bucketed pass instead of one per leaf)."""
        if self.qz.is_identity:
            return flat
        outs = [
            self.qz.qdq(flat[a:b], self._span_key(key, i))
            for i, (a, b) in enumerate(self.spans(flat.shape[0]))
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    # -- static cost accounting (benchmarks / tests) -----------------------
    def collective_launches(self, n: int) -> int:
        """Collective launches for one fused exchange of n elements:
        phase 1 = 2 all_to_all (payload + level tables); phase 2 =
        2 all_gather when re-quantizing, 1 f32 all_gather otherwise;
        fp = 1 psum."""
        per_span = 1 if self.qz.is_identity else (
            4 if self.server_requant else 3)
        return per_span * len(self.spans(n))

    def wire_bytes_per_worker(self, n: int, n_workers: int) -> float:
        """Bytes one worker transmits per exchange (uplink phase 1 +
        phase-2 broadcast of its own chunk), after chunk/bucket padding."""
        if self.qz.is_identity:
            return 4.0 * n
        total = 0.0
        for a, b in self.spans(n):
            m = b - a
            chunk = -(-m // max(n_workers, 1))
            d_eff = wire.bucket_len(chunk, self.qz.bucket_size)
            nbc = -(-chunk // d_eff)                 # buckets per chunk
            up = wire.wire_unit_bytes(self.qz, nbc * n_workers, d_eff)
            if self.server_requant:
                down = wire.wire_unit_bytes(self.qz, nbc, d_eff)
            else:
                down = 4.0 * chunk
            total += up + down
        return total


def per_leaf_stats(qz: Quantizer, sizes: Sequence[int], n_workers: int, *,
                   server_requant: bool = True) -> Tuple[int, float]:
    """(launches, wire bytes per worker) for the pre-fusion per-leaf
    exchange: every leaf pays its own collectives and its own ragged
    chunk/bucket padding."""
    eng = GradientExchange(qz, ("data",), server_requant=server_requant)
    launches = sum(eng.collective_launches(n) for n in sizes)
    bytes_ = sum(eng.wire_bytes_per_worker(n, n_workers) for n in sizes)
    return launches, bytes_


def fused_stats(qz: Quantizer, sizes: Sequence[int], n_workers: int, *,
                server_requant: bool = True,
                max_chunk_elems: Optional[int] = None) -> Tuple[int, float]:
    """(launches, wire bytes per worker) for the fused exchange of the same
    leaves through one flat buffer."""
    eng = GradientExchange(qz, ("data",), server_requant=server_requant,
                           max_chunk_elems=max_chunk_elems)
    n = int(sum(sizes))
    return eng.collective_launches(n), eng.wire_bytes_per_worker(n, n_workers)
