"""Fused flat-buffer gradient exchange: one collective for the whole pytree.

Issuing the Algorithm 2 exchange once per parameter leaf costs a 100+ leaf
model 100+ collective launches, 100+ ragged-bucket paddings, and 100+ tiny
level-table transfers per step. TernGrad and Adaptive Gradient Quantization
both flatten gradients into large contiguous buffers before quantizing for
exactly this reason. This module does the same for the paper's exchange:

    GradLayout          static flatten/unflatten plan for a gradient pytree
                        (per-leaf offsets/sizes/dtypes, computed once at
                        trace time from static shapes);
    GradientExchange    runs a SINGLE quantized all-reduce (optionally
                        size-capped chunks for memory control) over the
                        fused f32 buffer, plus the matching fused
                        ``local_qdq`` for error-feedback residuals and the
                        fused single-device qdq path.

O(1) collective launches per step instead of O(num_leaves); and because
bucket boundaries land on the fused buffer, many-tiny-leaf trees also
save wire bytes (small leaves share buckets instead of each paying its
own ragged tail and level table — for few-large-leaf trees the byte
counts are essentially equal and the win is the launch count).

The compute side of every exchange (encode/decode/error-feedback qdq)
goes through ``core/comm/wire.py``, which since PR 5 lowers to the FUSED
one-pass Pallas kernels by default — one ``pallas_call`` per
encode/decode sweep, no (nb, d) intermediates in HBM;
``use_kernels=False`` (or ``REPRO_USE_KERNELS=0``) selects the pure-jnp
reference oracle, bit-identically.

The PARTITIONED mode (``PolicyLayout`` + ``PartitionedExchange``) extends
this to per-parameter-group policies (``repro.core.QuantPolicy``): leaves
are grouped by their resolved quantizer config into contiguous segments,
each segment gets its own fused quantized all-reduce, wire accounting,
and error-feedback residual stream. Launches stay O(#policy groups),
never O(#leaves); a uniform policy degenerates to exactly one group whose
buffer, keys, and wire layout are bit-identical to the single-engine path.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax import lax

from repro.core.api import QuantConfig
from repro.core.comm import hierarchical, wire
from repro.core.comm.collectives import (_names, local_qdq_comm_layout,
                                         quantized_all_reduce_mean)
from repro.core.policy import QuantPolicy
from repro.core.quantizers import Quantizer
from repro.utils.pytree import tree_flatten_with_path_strs


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's span inside the fused buffer."""

    path: str
    shape: Tuple[int, ...]
    dtype: Any
    offset: int
    size: int


@dataclasses.dataclass(frozen=True)
class GradLayout:
    """Static flatten/unflatten plan: leaf order, spans, dtype restore.

    Built once from abstract (or concrete) leaves — everything here is
    trace-time static, so ``flatten``/``unflatten`` lower to pure
    reshape/concat/slice with no per-leaf collective work.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    size: int                    # total element count of the fused buffer

    @classmethod
    def from_tree(cls, tree) -> "GradLayout":
        pairs, treedef = tree_flatten_with_path_strs(tree)
        slots: List[LeafSlot] = []
        off = 0
        for path, leaf in pairs:
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            slots.append(LeafSlot(path=path, shape=tuple(leaf.shape),
                                  dtype=leaf.dtype, offset=off, size=size))
            off += size
        return cls(treedef=treedef, slots=tuple(slots), size=off)

    # -- buffer <-> tree ---------------------------------------------------
    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> (size,) contiguous f32 buffer (canonical leaf order)."""
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.slots), (len(leaves), len(self.slots))
        return jnp.concatenate(
            [x.astype(jnp.float32).reshape(-1) for x in leaves])

    def unflatten(self, buf: jnp.ndarray, *, restore_dtype: bool = True):
        """(size,) buffer -> pytree, restoring each leaf's shape (and dtype
        unless ``restore_dtype=False`` — error-feedback residuals stay f32).

        Offsets are trace-time constants, so static slicing keeps the
        jaxpr pure slice/reshape (like ``leaf_slice``)."""
        leaves = []
        for s in self.slots:
            leaf = buf[s.offset:s.offset + s.size].reshape(s.shape)
            leaves.append(leaf.astype(s.dtype) if restore_dtype else leaf)
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def leaf_slice(self, buf: jnp.ndarray, i: int) -> jnp.ndarray:
        """The i-th leaf's span of the fused buffer, in leaf shape (f32)."""
        s = self.slots[i]
        return buf[s.offset:s.offset + s.size].reshape(s.shape)

    # -- static accounting -------------------------------------------------
    def padded_size(self, n_workers: int, bucket_size: int) -> int:
        """Fused buffer size after worker-chunk + bucket alignment (what
        actually hits the wire for a given mesh)."""
        chunk = -(-self.size // max(n_workers, 1))
        d_eff = wire.bucket_len(chunk, bucket_size)
        chunk_p = -(-chunk // d_eff) * d_eff
        return n_workers * chunk_p


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradientExchange:
    """Fused Algorithm 2 exchange over a GradLayout's flat buffer.

    ``axis_names`` is the QUANTIZED (inter/DCN) axis tuple. When
    ``intra_axes`` is non-empty the exchange runs hierarchically (the
    two-level ICI/DCN mode, see ``core/comm/hierarchical.py``): a
    full-precision reduce-scatter-mean over the fast intra axes first,
    the quantized Algorithm 2 only on the resulting shard over
    ``axis_names``, and a final full-precision all-gather back over the
    intra axes. With ``intra_axes=()`` (the default) this is the flat
    exchange, bit-identical to the pre-hierarchy engine.

    ``max_chunk_elems`` optionally caps the per-collective buffer size (a
    memory-control knob for very large models): the (shard) buffer is
    split into ceil(n / cap) contiguous spans, each exchanged
    independently with a per-span folded key. Launches stay O(n / cap),
    independent of leaf count. ``local_qdq_flat``/``local_qdq_shard``
    apply the identical span/key schedule, so error-feedback residuals
    remain bit-consistent with what was sent.

    ``pipeline_chunks`` is the PIPELINED schedule (a latency knob, not a
    memory knob): each span's quantized all-reduce is split into that many
    bucket-row chunks whose encodes overlap the previous chunk's
    collective. Unlike ``max_chunk_elems`` spans (which fold a per-span
    key), the pipelined schedule is bit-identical to ``pipeline_chunks=1``
    — same levels, same rounding stream, same wire payload, just issued
    as K collectives instead of one — so error-feedback residuals need no
    schedule awareness at all.
    """

    qz: Quantizer
    axis_names: Any
    server_requant: bool = True
    use_kernels: bool = True
    max_chunk_elems: Optional[int] = None
    intra_axes: Tuple[str, ...] = ()
    pipeline_chunks: int = 1

    def __post_init__(self):
        if self.max_chunk_elems is not None and self.max_chunk_elems <= 0:
            raise ValueError(
                f"max_chunk_elems must be positive, got "
                f"{self.max_chunk_elems}")
        if self.pipeline_chunks < 1:
            raise ValueError(
                f"pipeline_chunks must be >= 1, got {self.pipeline_chunks}")
        if self.intra_axes:
            overlap = set(_names(self.intra_axes)) & set(
                _names(self.axis_names))
            if overlap:
                raise ValueError(
                    f"intra_axes and axis_names overlap: {sorted(overlap)}")

    # -- span schedule (static) -------------------------------------------
    def spans(self, n: int) -> List[Tuple[int, int]]:
        cap = self.max_chunk_elems
        if not cap or n <= cap:
            return [(0, n)]
        return [(a, min(a + cap, n)) for a in range(0, n, cap)]

    def _span_key(self, key: jax.Array, i: int) -> jax.Array:
        return jax.random.fold_in(key, i) if self.max_chunk_elems else key

    # -- hierarchical (two-level) helpers ----------------------------------
    def _intra_fold(self, key: jax.Array, intra_id=None) -> jax.Array:
        """Decorrelate the rounding stream across intra shards (each shard
        quantizes different data). ``intra_id`` must be passed from the
        primal context by custom-VJP callers; no fold in flat mode, so the
        degenerate two_level key schedule equals the flat one."""
        if not self.intra_axes:
            return key
        if intra_id is None:
            intra_id = lax.axis_index(_names(self.intra_axes))
        return jax.random.fold_in(key, intra_id)

    def intra_scatter(self, flat: jnp.ndarray):
        """(n,) buffer -> (shard, valid) after the full-precision intra
        reduce-scatter-mean; ``(flat, None)`` in flat mode."""
        if not self.intra_axes:
            return flat, None
        return (hierarchical.intra_reduce_scatter_mean(flat, self.intra_axes),
                hierarchical.shard_valid_mask(flat.shape[0], self.intra_axes))

    def intra_gather(self, shard: jnp.ndarray, n: int) -> jnp.ndarray:
        """Inverse of :meth:`intra_scatter` (full-precision all_gather)."""
        if not self.intra_axes:
            return shard
        return hierarchical.intra_all_gather(shard, self.intra_axes, n)

    # -- distributed paths (inside shard_map over the dp axes) -------------
    def exchange_shard(self, shard: jnp.ndarray, key: jax.Array, *,
                       valid=None, worker_id=None,
                       intra_id=None) -> jnp.ndarray:
        """Quantized Algorithm 2 all-reduce of an (already intra-averaged)
        shard over the quantized ``axis_names`` only. One quantized
        all-reduce per span; ``valid`` masks scatter padding out of the
        level fits."""
        key = self._intra_fold(key, intra_id)
        outs = [
            quantized_all_reduce_mean(
                shard[a:b], self.qz, self._span_key(key, i), self.axis_names,
                worker_id=worker_id, server_requant=self.server_requant,
                use_kernels=self.use_kernels,
                valid=None if valid is None else valid[a:b],
                pipeline_chunks=self.pipeline_chunks)
            for i, (a, b) in enumerate(self.spans(shard.shape[0]))
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def local_qdq_shard(self, shard: jnp.ndarray, key: jax.Array, *,
                        valid=None, worker_id=None,
                        intra_id=None) -> jnp.ndarray:
        """This worker's own dequantized shard, bit-identical to its
        :meth:`exchange_shard` phase-1 contribution (same spans, same
        folded keys, same mask). Error feedback in two-level mode lives on
        this shard — the quantized (inter) axis only."""
        key = self._intra_fold(key, intra_id)
        outs = [
            local_qdq_comm_layout(
                shard[a:b], self.qz, self._span_key(key, i), self.axis_names,
                worker_id=worker_id, use_kernels=self.use_kernels,
                valid=None if valid is None else valid[a:b])
            for i, (a, b) in enumerate(self.spans(shard.shape[0]))
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def exchange_flat(self, flat: jnp.ndarray, key: jax.Array, *,
                      worker_id=None, intra_id=None) -> jnp.ndarray:
        """(n,) local gradient buffer -> (n,) across-worker mean, identical
        on every worker. Flat mode: one quantized all-reduce per span.
        Two-level mode: fp intra scatter -> quantized shard exchange over
        the inter axes -> fp intra gather (``worker_id``/``intra_id`` are
        the INTER/INTRA axis indices for custom-VJP callers)."""
        if not self.intra_axes:
            return self.exchange_shard(flat, key, worker_id=worker_id)
        n = flat.shape[0]
        shard, valid = self.intra_scatter(flat)
        mean = self.exchange_shard(shard, key, valid=valid,
                                   worker_id=worker_id, intra_id=intra_id)
        return self.intra_gather(mean, n)

    def local_qdq_flat(self, flat: jnp.ndarray, key: jax.Array, *,
                       worker_id=None) -> jnp.ndarray:
        """This worker's own dequantized fused buffer, bit-identical to its
        phase-1 contribution (same spans, same chunk/bucket layout, same
        folded keys). Error feedback: e ← g − Q⁻¹(Q(g)) on the FUSED layout.
        Flat mode only — two-level error feedback lives on the intra shard
        (:meth:`local_qdq_shard`), not the full buffer."""
        if self.intra_axes:
            raise ValueError(
                "local_qdq_flat is the flat-mode residual; a two-level "
                "engine's residual lives on the intra shard — use "
                "intra_scatter + local_qdq_shard")
        return self.local_qdq_shard(flat, key, worker_id=worker_id)

    def exchange(self, tree, key: jax.Array, *, layout: Optional[GradLayout]
                 = None, worker_id=None):
        """Pytree-level convenience: flatten -> exchange_flat -> unflatten."""
        layout = layout or GradLayout.from_tree(tree)
        mean = self.exchange_flat(layout.flatten(tree), key,
                                  worker_id=worker_id)
        return layout.unflatten(mean)

    # -- single-device path (no mesh axes) ---------------------------------
    def qdq_local_flat(self, flat: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """Fused single-machine Algorithm 2: quantize->dequantize the whole
        buffer locally (one bucketed pass instead of one per leaf)."""
        if self.qz.is_identity:
            return flat
        outs = [
            self.qz.qdq(flat[a:b], self._span_key(key, i))
            for i, (a, b) in enumerate(self.spans(flat.shape[0]))
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    # -- static cost accounting (benchmarks / tests) -----------------------
    def _pipeline_k(self, m: int, n_workers: Optional[int]) -> int:
        """Effective pipeline chunk count for an m-element span: the
        schedule clamps K to the span's bucket-row count (needs the worker
        count to know the chunk layout; unknown mesh -> assume un-clamped)."""
        if self.pipeline_chunks <= 1:
            return 1
        if n_workers is None:
            return self.pipeline_chunks
        chunk = -(-m // max(n_workers, 1))
        d_eff = wire.bucket_len(chunk, self.qz.bucket_size)
        nbc = -(-chunk // d_eff)
        return max(1, min(self.pipeline_chunks, nbc))

    def collective_launches(self, n: int,
                            n_workers: Optional[int] = None) -> int:
        """Collective launches for one fused exchange of n elements, PER
        pipeline chunk: phase 1 = 2 all_to_all (payload + level tables) per
        chunk; phase 2 = 2 all_gather per chunk when re-quantizing, 1 f32
        all_gather (un-chunked) otherwise; fp = 1 psum. Pass ``n_workers``
        for the exact per-span chunk clamp."""
        if self.qz.is_identity:
            return len(self.spans(n))
        total = 0
        for a, b in self.spans(n):
            k = self._pipeline_k(b - a, n_workers)
            total += 4 * k if self.server_requant else 2 * k + 1
        return total

    # -- reduce-scatter accounting (the fsdp phase-1-only exchange) --------
    @staticmethod
    def rs_stats(qz: Quantizer, n: int, n_workers: int,
                 pipeline_chunks: int = 1) -> Tuple[int, float]:
        """(launches, wire bytes per worker) for ONE fused quantized
        reduce-scatter of ``n`` elements — phase-1 uplink only, no
        server->worker broadcast. The single source of the RS formula for
        ``policy_stats(sharded_paths=...)`` and ``FsdpExchange``.
        ``pipeline_chunks`` multiplies the launch count (2 all_to_all per
        chunk); bytes are schedule-invariant."""
        if qz.is_identity:
            return 1, 4.0 * n                    # one psum_scatter
        chunk = -(-n // max(n_workers, 1))
        d_eff = wire.bucket_len(chunk, qz.bucket_size)
        nbc = -(-chunk // d_eff)
        k = max(1, min(int(pipeline_chunks), nbc))
        return 2 * k, float(wire.wire_unit_bytes(qz, nbc * n_workers, d_eff))

    def wire_bytes_per_worker(self, n: int, n_workers: int) -> float:
        """Bytes one worker transmits per exchange (uplink phase 1 +
        phase-2 broadcast of its own chunk), after chunk/bucket padding."""
        if self.qz.is_identity:
            return 4.0 * n
        total = 0.0
        for a, b in self.spans(n):
            m = b - a
            chunk = -(-m // max(n_workers, 1))
            d_eff = wire.bucket_len(chunk, self.qz.bucket_size)
            nbc = -(-chunk // d_eff)                 # buckets per chunk
            up = wire.wire_unit_bytes(self.qz, nbc * n_workers, d_eff)
            if self.server_requant:
                down = wire.wire_unit_bytes(self.qz, nbc, d_eff)
            else:
                down = 4.0 * chunk
            total += up + down
        return total


# ---------------------------------------------------------------------------
# partitioned mode: per-policy-group segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupSegment:
    """One policy group's contiguous segment: which canonical leaves it
    owns and how large its fused buffer is. ``rule_id`` is the policy
    rule index the group was formed from (``by_rule`` layouts only;
    None for config-grouped layouts) — the bits-independent handle a
    ``BitSchedule`` phase specialization re-resolves configs through."""

    cfg: QuantConfig
    leaf_ids: Tuple[int, ...]    # canonical leaf order indices, ascending
    size: int                    # total element count of the group buffer
    rule_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PolicyLayout:
    """Static partition plan: canonical leaves grouped by resolved
    QuantConfig into contiguous per-group buffers.

    ``slots`` stay in canonical leaf order; each slot's ``offset`` is the
    leaf's span inside its GROUP buffer (``leaf_group[i]`` says which).
    A uniform policy yields exactly one group whose buffer layout equals
    ``GradLayout.from_tree`` bit for bit.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    groups: Tuple[GroupSegment, ...]
    leaf_group: Tuple[int, ...]          # leaf i -> index into groups

    @classmethod
    def from_tree(cls, tree, policy: QuantPolicy, *, paths=None,
                  by_rule: bool = False) -> "PolicyLayout":
        """``paths`` optionally overrides the leaf path strings (a pytree of
        strings aligned with ``tree`` — e.g. ``model.param_paths``); the
        default is the keystr paths of ``tree`` itself.

        ``by_rule=True`` groups leaves by the policy RULE INDEX that
        matched them instead of by resolved config. When every rule
        resolves to a distinct config the partition (and group order —
        both key on first leaf appearance) is identical to config
        grouping, but the rule partition is invariant under config
        re-materialization — a ``BitSchedule`` phase that collapses two
        ramps onto the same scheme keeps two groups, so EF-residual
        buffer shapes survive the phase boundary (``with_configs``)."""
        pairs, treedef = tree_flatten_with_path_strs(tree)
        if paths is not None:
            path_strs = list(jax.tree_util.tree_leaves(paths))
            assert len(path_strs) == len(pairs), \
                (len(path_strs), len(pairs))
        else:
            path_strs = [p for p, _ in pairs]
        dead = policy.unmatched_rules(path_strs)
        if dead:
            # a typo'd pattern would otherwise silently fall through to
            # the default scheme for every leaf it was meant to cover
            warnings.warn(
                f"policy rules matched no parameter leaf: {dead}; check "
                f"the patterns against the model's param paths",
                stacklevel=2)

        group_ix: Dict[Any, int] = {}
        g_cfg: List[QuantConfig] = []
        g_rule: List[Optional[int]] = []
        g_leaves: List[List[int]] = []
        g_off: List[int] = []
        slots: List[LeafSlot] = []
        leaf_group: List[int] = []
        for i, ((_, leaf), path) in enumerate(zip(pairs, path_strs)):
            cfg = policy.resolve(path)
            rid = policy.resolve_ix(path) if by_rule else None
            gkey = rid if by_rule else cfg
            gi = group_ix.setdefault(gkey, len(g_cfg))
            if gi == len(g_cfg):
                g_cfg.append(cfg)
                g_rule.append(rid)
                g_leaves.append([])
                g_off.append(0)
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            slots.append(LeafSlot(path=path, shape=tuple(leaf.shape),
                                  dtype=leaf.dtype, offset=g_off[gi],
                                  size=size))
            g_off[gi] += size
            g_leaves[gi].append(i)
            leaf_group.append(gi)
        groups = tuple(
            GroupSegment(cfg=c, leaf_ids=tuple(ls), size=off, rule_id=r)
            for c, r, ls, off in zip(g_cfg, g_rule, g_leaves, g_off))
        return cls(treedef=treedef, slots=tuple(slots), groups=groups,
                   leaf_group=tuple(leaf_group))

    def with_configs(self, policy: QuantPolicy) -> "PolicyLayout":
        """Specialize a ``by_rule`` skeleton to one phase's concrete
        configs: identical treedef/slots/offsets/group membership, only
        each group's ``cfg`` re-resolved through its ``rule_id``. The
        bits-independent part of the layout is reused, never rebuilt."""
        for g in self.groups:
            if g.rule_id is None:
                raise ValueError(
                    "with_configs needs a by_rule layout (group rule_ids "
                    "are unset — build with from_tree(by_rule=True))")
        groups = tuple(
            dataclasses.replace(g, cfg=policy.cfg_for_rule(g.rule_id))
            for g in self.groups)
        return dataclasses.replace(self, groups=groups)

    @property
    def size(self) -> int:
        return sum(g.size for g in self.groups)

    # -- buffers <-> tree --------------------------------------------------
    def flatten_groups(self, tree) -> Tuple[jnp.ndarray, ...]:
        """Pytree -> one (group.size,) contiguous f32 buffer per group
        (leaves in canonical order within each group)."""
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.slots), (len(leaves), len(self.slots))
        return tuple(
            jnp.concatenate([leaves[i].astype(jnp.float32).reshape(-1)
                             for i in g.leaf_ids])
            for g in self.groups)

    def unflatten_groups(self, bufs: Sequence[jnp.ndarray], *,
                         restore_dtype: bool = True):
        """Per-group buffers -> pytree (static slicing; dtype restore
        skipped for f32 error-feedback residuals)."""
        assert len(bufs) == len(self.groups), (len(bufs), len(self.groups))
        leaves = []
        for i, s in enumerate(self.slots):
            buf = bufs[self.leaf_group[i]]
            leaf = buf[s.offset:s.offset + s.size].reshape(s.shape)
            leaves.append(leaf.astype(s.dtype) if restore_dtype else leaf)
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


@dataclasses.dataclass(frozen=True)
class PartitionedExchange:
    """Per-policy-group fused Algorithm 2: one ``GradientExchange`` per
    group, each over that group's contiguous segment with its own wire
    accounting, key stream, and error-feedback residuals.

    Collective launches are O(#groups) — a uniform policy is exactly the
    single-engine fused exchange (same buffer, same unfolded key, same
    wire layout), which the regression tests pin down bit for bit.
    """

    layout: PolicyLayout
    engines: Tuple[GradientExchange, ...]     # aligned with layout.groups

    @classmethod
    def build(cls, policy: QuantPolicy, tree, axis_names, *, paths=None,
              use_kernels: bool = True,
              max_chunk_elems: Optional[int] = None,
              intra_axes: Tuple[str, ...] = (),
              pipeline_chunks: int = 1,
              by_rule: bool = False) -> "PartitionedExchange":
        """``axis_names`` is the QUANTIZED (inter) axis tuple; a non-empty
        ``intra_axes`` turns every group engine hierarchical (two-level
        ICI/DCN mode — see ``GradientExchange``); ``pipeline_chunks``
        pipelines every group's exchange (bit-identical schedule knob).
        ``by_rule=True`` groups by policy rule index (bit-schedule
        skeletons; see ``PolicyLayout.from_tree``)."""
        layout = PolicyLayout.from_tree(tree, policy, paths=paths,
                                        by_rule=by_rule)
        engines = tuple(
            GradientExchange(
                g.cfg.to_quantizer(), axis_names,
                server_requant=g.cfg.server_requant,
                use_kernels=use_kernels, max_chunk_elems=max_chunk_elems,
                intra_axes=tuple(intra_axes),
                pipeline_chunks=pipeline_chunks)
            for g in layout.groups)
        return cls(layout=layout, engines=engines)

    def specialize(self, policy: QuantPolicy) -> "PartitionedExchange":
        """One phase's engine from a ``by_rule`` skeleton: the layout's
        bits-independent part (treedef/slots/group membership) is reused
        as-is, only per-group quantizers are rebuilt from the phase's
        concrete configs. Group count, order, sizes, key folding — and
        therefore EF-residual shapes — are identical across phases."""
        layout = self.layout.with_configs(policy)
        engines = tuple(
            dataclasses.replace(
                eng, qz=g.cfg.to_quantizer(),
                server_requant=g.cfg.server_requant)
            for eng, g in zip(self.engines, layout.groups))
        return dataclasses.replace(self, layout=layout, engines=engines)

    @property
    def intra_axes(self) -> Tuple[str, ...]:
        return self.engines[0].intra_axes if self.engines else ()

    def _group_key(self, key: jax.Array, gi: int) -> jax.Array:
        # single group == the uniform fused exchange: key stays unfolded so
        # the stream is bit-identical to GradientExchange on GradLayout
        return key if len(self.engines) == 1 else jax.random.fold_in(key, gi)

    @property
    def is_identity(self) -> bool:
        return all(e.qz.is_identity for e in self.engines)

    # -- distributed paths -------------------------------------------------
    def exchange_parts(self, bufs: Sequence[jnp.ndarray], key: jax.Array, *,
                       worker_id=None) -> Tuple[jnp.ndarray, ...]:
        """Per-group local buffers -> per-group across-worker means."""
        return tuple(
            eng.exchange_flat(buf, self._group_key(key, gi),
                              worker_id=worker_id)
            for gi, (eng, buf) in enumerate(zip(self.engines, bufs)))

    def local_qdq_parts(self, bufs: Sequence[jnp.ndarray], key: jax.Array, *,
                        worker_id=None) -> Tuple[jnp.ndarray, ...]:
        """Per-group fused local quantize->dequantize, bit-consistent with
        ``exchange_parts`` (error feedback); identity groups pass through
        unchanged (zero residual)."""
        return tuple(
            buf if eng.qz.is_identity
            else eng.local_qdq_flat(buf, self._group_key(key, gi),
                                    worker_id=worker_id)
            for gi, (eng, buf) in enumerate(zip(self.engines, bufs)))

    def exchange(self, tree, key: jax.Array, *, worker_id=None):
        """Pytree-level convenience: group-flatten -> per-group exchange ->
        unflatten."""
        bufs = self.layout.flatten_groups(tree)
        return self.layout.unflatten_groups(
            self.exchange_parts(bufs, key, worker_id=worker_id))

    # -- two-level (hierarchical) shard-part paths -------------------------
    def intra_scatter_parts(self, bufs: Sequence[jnp.ndarray]):
        """Per-group fp intra reduce-scatter-mean: (shards, valids)."""
        pairs = [eng.intra_scatter(buf)
                 for eng, buf in zip(self.engines, bufs)]
        return tuple(p[0] for p in pairs), tuple(p[1] for p in pairs)

    def exchange_shard_parts(self, shards: Sequence[jnp.ndarray],
                             key: jax.Array, valids, *,
                             worker_id=None) -> Tuple[jnp.ndarray, ...]:
        """Per-group quantized shard exchange over the inter axes (the key
        schedule matches :meth:`exchange_parts` group folding)."""
        return tuple(
            eng.exchange_shard(s, self._group_key(key, gi), valid=v,
                               worker_id=worker_id)
            for gi, (eng, s, v) in enumerate(zip(self.engines, shards,
                                                 valids)))

    def local_qdq_shard_parts(self, shards: Sequence[jnp.ndarray],
                              key: jax.Array, valids, *,
                              worker_id=None) -> Tuple[jnp.ndarray, ...]:
        """Per-group fused local shard quantize->dequantize, bit-consistent with
        :meth:`exchange_shard_parts`; identity groups pass through
        unchanged (zero residual)."""
        return tuple(
            s if eng.qz.is_identity
            else eng.local_qdq_shard(s, self._group_key(key, gi), valid=v,
                                     worker_id=worker_id)
            for gi, (eng, s, v) in enumerate(zip(self.engines, shards,
                                                 valids)))

    def intra_gather_parts(self, shards: Sequence[jnp.ndarray]
                           ) -> Tuple[jnp.ndarray, ...]:
        """Per-group fp intra all-gather back to full group buffers."""
        return tuple(
            eng.intra_gather(s, g.size)
            for eng, s, g in zip(self.engines, shards, self.layout.groups))

    def ef_shard_sizes(self, n_intra: int) -> Tuple[Optional[int], ...]:
        """Per-group two-level error-feedback residual lengths (one intra
        shard per worker — the residual lives on the quantized inter axis
        only); None for identity groups (nothing to feed back)."""
        return tuple(
            None if eng.qz.is_identity
            else hierarchical.intra_chunk_len(g.size, n_intra)
            for eng, g in zip(self.engines, self.layout.groups))

    # -- single-device path ------------------------------------------------
    def qdq_local_parts(self, bufs: Sequence[jnp.ndarray],
                        key: jax.Array) -> Tuple[jnp.ndarray, ...]:
        return tuple(
            eng.qdq_local_flat(buf, self._group_key(key, gi))
            for gi, (eng, buf) in enumerate(zip(self.engines, bufs)))

    # -- runtime statistics (the BitBudgetController feed) -----------------
    def group_stats(self, bufs: Sequence[jnp.ndarray],
                    ef=None) -> jnp.ndarray:
        """(n_groups, 3) f32 rows ``[sigma_sq, clip_frac, ef_norm_sq]``
        from the SAME per-group buffers the encode consumes (pre-exchange;
        each group bucketed at its own bucket_size): the mean per-bucket
        gradient variance, the fraction of elements the sigma-clip would
        clamp, and the squared norm of the group's error-feedback
        residual (``ef`` is the group-aligned residual tuple; 0 without
        EF). Cheap reductions only — no extra pallas_call, XLA fuses them
        into the step. ``jax.lax.pmean`` over the dp axes yields the
        fleet view the ``BitBudgetController.observe`` feed expects."""
        rows = []
        for gi, (eng, buf) in enumerate(zip(self.engines, bufs)):
            d_eff = wire.bucket_len(buf.shape[0], eng.qz.bucket_size)
            st = wire.encode_stats(eng.qz, buf, d_eff)
            e = None if ef is None else ef[gi]
            ef_sq = (jnp.zeros((), jnp.float32) if e is None
                     else jnp.sum(jnp.square(e.astype(jnp.float32))))
            rows.append(jnp.stack([st[0], st[1], ef_sq]))
        return jnp.stack(rows)

    # -- static cost accounting --------------------------------------------
    def collective_launches(self) -> int:
        return sum(eng.collective_launches(g.size)
                   for eng, g in zip(self.engines, self.layout.groups))

    def wire_bytes_per_worker(self, n_workers: int) -> float:
        return sum(eng.wire_bytes_per_worker(g.size, n_workers)
                   for eng, g in zip(self.engines, self.layout.groups))


def policy_stats(policy: QuantPolicy, path_sizes, n_workers: int, *,
                 max_chunk_elems: Optional[int] = None,
                 sharded_paths=None
                 ) -> Tuple[int, float, Tuple[str, ...]]:
    """(launches, wire bytes per worker, group labels) for a policy over
    ``[(path, size), ...]`` leaves — static accounting without a tree
    (benchmarks).

    ``sharded_paths`` (a container of paths, e.g. the dp-divisible leaves
    of an fsdp ``ShardingPlan``) splits the accounting into SHARDED
    segments — exchanged by the fused quantized reduce-scatter, phase-1
    uplink only, labelled ``<scheme>/rs`` — and replicated segments that
    keep the full Algorithm 2 all-reduce cost. Sharded leaf sizes are
    rounded up to a multiple of ``n_workers`` (the layout requires exact
    divisibility; the rounding only guards accounting callers)."""
    sharded_paths = frozenset(sharded_paths or ())
    groups: Dict[Tuple[QuantConfig, bool], int] = {}
    for path, size in path_sizes:
        cfg = policy.resolve(path)
        key = (cfg, path in sharded_paths)
        groups[key] = groups.get(key, 0) + int(size)
    launches, bytes_, labels = 0, 0.0, []
    for (cfg, sharded), n in groups.items():
        qz = cfg.to_quantizer()
        if sharded:
            n = -(-n // n_workers) * n_workers
            l, b = GradientExchange.rs_stats(qz, n, n_workers)
            launches += l
            bytes_ += b
            labels.append(f"{cfg.name}/rs")
            continue
        eng = GradientExchange(
            qz, ("data",),
            server_requant=cfg.server_requant,
            max_chunk_elems=max_chunk_elems)
        launches += eng.collective_launches(n)
        bytes_ += eng.wire_bytes_per_worker(n, n_workers)
        labels.append(cfg.name)
    return launches, bytes_, tuple(labels)


def link_stats(qz: Quantizer, n: int, *, n_intra: int, n_inter: int,
               two_level: bool, server_requant: bool = True,
               sharded: bool = False,
               max_chunk_elems: Optional[int] = None,
               pipeline_chunks: int = 1,
               sync_every: int = 1) -> Dict[str, float]:
    """Per-LINK wire bytes one worker transmits for ONE exchange of ``n``
    elements on an (n_inter pods) x (n_intra chips/pod) dp mesh:

        ici_bytes    bytes on the fast intra-pod (ICI) links
        dcn_bytes    bytes crossing the slow inter-pod (DCN) boundary
        dcn_q_bytes  the quantized subset of dcn_bytes (the paper's wire)
        launches     collective launches (incl. the fp intra phases)

    Traffic model: all_to_all/all_gather traffic is uniformly addressed, so
    the fraction (n_inter-1)/n_inter of a flat collective's bytes crosses
    pods; ring reduce-scatter/all-gather over one axis sends
    (L-1)/L * payload per worker. ``sharded=True`` accounts the fsdp
    phase-1-only reduce-scatter (no downlink; the parameter all-gather
    belongs to the forward). Convert to seconds with the ``launch/mesh.py``
    bandwidth constants (ICI_BW / DCN_BW). ``pipeline_chunks`` leaves every
    byte count unchanged (the pipelined schedule moves the same payload)
    but multiplies the quantized launch counts — per-chunk wire units each
    pay their own collective launch.

    ``sync_every=H > 1`` prices the temporal ``two_level_async`` hierarchy
    PER STEP in steady state: the exchange above runs once every H steps
    (all its bytes and launches amortize /H — the quantized DCN spend
    drops exactly H-fold), while every step additionally pays one
    full-precision all-reduce of the full (n,) gradient over the fast
    intra links (ring: 2(L_i-1)/L_i * 4n bytes, one launch)."""
    if sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every}")
    L = n_intra * n_inter
    dcn_frac = (n_inter - 1) / n_inter if n_inter > 1 else 0.0
    if not two_level:
        if sharded:
            launches, total = GradientExchange.rs_stats(
                qz, n, L, pipeline_chunks=pipeline_chunks)
        else:
            eng = GradientExchange(qz, ("dp",),
                                   server_requant=server_requant,
                                   max_chunk_elems=max_chunk_elems,
                                   pipeline_chunks=pipeline_chunks)
            launches = eng.collective_launches(n, L)
            total = eng.wire_bytes_per_worker(n, L)
        dcn = total * dcn_frac
        st = {"ici_bytes": total - dcn, "dcn_bytes": dcn,
              "dcn_q_bytes": 0.0 if qz.is_identity else dcn,
              "launches": float(launches)}
        return _amortize_sync(st, n, n_intra, sync_every)
    # two-level: fp intra phases + quantized inter exchange of the shard
    shard = -(-n // n_intra)
    ici = 4.0 * n * (n_intra - 1) / n_intra        # intra reduce-scatter
    launches = 1
    if sharded:
        l_i, inter_total = GradientExchange.rs_stats(
            qz, shard, n_inter, pipeline_chunks=pipeline_chunks)
    else:
        eng = GradientExchange(qz, ("pod",), server_requant=server_requant,
                               max_chunk_elems=max_chunk_elems,
                               pipeline_chunks=pipeline_chunks)
        l_i = eng.collective_launches(shard, n_inter)
        inter_total = eng.wire_bytes_per_worker(shard, n_inter)
        ici += 4.0 * n * (n_intra - 1) / n_intra   # final intra all-gather
        launches += 1
    launches += l_i
    dcn = inter_total * dcn_frac
    st = {"ici_bytes": ici + inter_total - dcn, "dcn_bytes": dcn,
          "dcn_q_bytes": 0.0 if qz.is_identity else dcn,
          "launches": float(launches)}
    return _amortize_sync(st, n, n_intra, sync_every)


def _amortize_sync(st: Dict[str, float], n: int, n_intra: int,
                   sync_every: int) -> Dict[str, float]:
    """Amortize one exchange's link stats over an H-step inner window and
    add the per-step full-precision intra all-reduce every inner step
    pays (two_level_async steady state)."""
    if sync_every <= 1:
        return st
    st = {k: v / sync_every for k, v in st.items()}
    if n_intra > 1:
        st["ici_bytes"] += 8.0 * n * (n_intra - 1) / n_intra
        st["launches"] += 1.0
    return st


def policy_link_stats(policy: QuantPolicy, path_sizes, *, n_intra: int,
                      n_inter: int, two_level: bool, sharded_paths=None,
                      max_chunk_elems: Optional[int] = None,
                      pipeline_chunks: int = 1, sync_every: int = 1
                      ) -> Tuple[Dict[str, float], Tuple[str, ...]]:
    """Aggregate :func:`link_stats` over a policy's groups (the per-link
    sibling of :func:`policy_stats`): returns the summed per-link dict and
    the group labels. Sharded leaves (fsdp reduce-scatter, phase-1 only)
    are rounded up to a worker multiple like in :func:`policy_stats`.
    ``sync_every`` amortizes over an H-step two_level_async window (see
    :func:`link_stats`)."""
    L = n_intra * n_inter
    sharded_paths = frozenset(sharded_paths or ())
    groups: Dict[Tuple[QuantConfig, bool], int] = {}
    for path, size in path_sizes:
        key = (policy.resolve(path), path in sharded_paths)
        groups[key] = groups.get(key, 0) + int(size)
    total = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "dcn_q_bytes": 0.0,
             "launches": 0.0}
    labels = []
    for (cfg, sharded), n in groups.items():
        if sharded:
            n = -(-n // L) * L
        st = link_stats(cfg.to_quantizer(), n, n_intra=n_intra,
                        n_inter=n_inter, two_level=two_level,
                        server_requant=cfg.server_requant, sharded=sharded,
                        max_chunk_elems=max_chunk_elems,
                        pipeline_chunks=pipeline_chunks,
                        sync_every=sync_every)
        for k in total:
            total[k] += st[k]
        labels.append(f"{cfg.name}/rs" if sharded else cfg.name)
    return total, tuple(labels)


def observed_link_stats(ex: "PartitionedExchange", *, n_intra: int,
                        n_inter: int, stats=None, sync_every: int = 1
                        ) -> Tuple[Dict[str, float], Tuple[Dict[str, Any],
                                                           ...]]:
    """Per-link accounting priced from an engine AS BUILT — the observed
    sibling of :func:`policy_link_stats`, which re-derives groups from a
    policy + path sizes and can drift from what actually runs. Every
    group row carries its :func:`link_stats` dict plus label/size, and —
    when ``stats`` (the runtime ``group_stats`` output, host-fetched) is
    given — the observed ``sigma_sq``/``clip_frac``/``ef_norm_sq``. The
    ``BitBudgetController`` cost_fn and the benchmarks both price
    assignments through THIS function, so the controller's budget and
    the reported BENCH bytes cannot disagree (the shared accounting
    path). Returns ``(summed totals, per-group rows)``."""
    two_level = bool(ex.intra_axes)
    total = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "dcn_q_bytes": 0.0,
             "launches": 0.0}
    rows: List[Dict[str, Any]] = []
    for gi, (eng, g) in enumerate(zip(ex.engines, ex.layout.groups)):
        st = link_stats(eng.qz, g.size, n_intra=n_intra, n_inter=n_inter,
                        two_level=two_level,
                        server_requant=eng.server_requant,
                        max_chunk_elems=eng.max_chunk_elems,
                        pipeline_chunks=eng.pipeline_chunks,
                        sync_every=sync_every)
        row: Dict[str, Any] = {"label": g.cfg.name, "size": g.size,
                               "rule_id": g.rule_id, **st}
        if stats is not None:
            s = np.asarray(stats[gi], dtype=np.float64)
            row.update(sigma_sq=float(s[0]), clip_frac=float(s[1]),
                       ef_norm_sq=float(s[2]))
        rows.append(row)
        for k in total:
            total[k] += st[k]
    return total, tuple(rows)


def per_leaf_stats(qz: Quantizer, sizes: Sequence[int], n_workers: int, *,
                   server_requant: bool = True) -> Tuple[int, float]:
    """(launches, wire bytes per worker) for the pre-fusion per-leaf
    exchange: every leaf pays its own collectives and its own ragged
    chunk/bucket padding."""
    eng = GradientExchange(qz, ("data",), server_requant=server_requant)
    launches = sum(eng.collective_launches(n) for n in sizes)
    bytes_ = sum(eng.wire_bytes_per_worker(n, n_workers) for n in sizes)
    return launches, bytes_


def fused_stats(qz: Quantizer, sizes: Sequence[int], n_workers: int, *,
                server_requant: bool = True,
                max_chunk_elems: Optional[int] = None) -> Tuple[int, float]:
    """(launches, wire bytes per worker) for the fused exchange of the same
    leaves through one flat buffer."""
    eng = GradientExchange(qz, ("data",), server_requant=server_requant,
                           max_chunk_elems=max_chunk_elems)
    n = int(sum(sizes))
    return eng.collective_launches(n), eng.wire_bytes_per_worker(n, n_workers)
