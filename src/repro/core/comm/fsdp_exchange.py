"""Fused policy-aware FSDP (ZeRO-3) gradient exchange.

The per-leaf fsdp gather (``make_fsdp_gather``) issues one quantized
reduce-scatter per parameter leaf — a 100+ leaf model pays 100+ collective
launches, ragged-bucket paddings, and level-table transfers per step, and
there is nowhere to hang an error-feedback residual because each leaf's
exchange lives inside its own custom-VJP. This module is the shard-aware
sibling of ``PolicyLayout``/``PartitionedExchange`` (``exchange.py``):

    FsdpLayout     static partition plan: leaves grouped by resolved
                   QuantConfig into contiguous per-group flat buffers whose
                   element order respects each leaf's dp-shard coordinates —
                   worker w's reduce-scatter chunk is exactly the
                   concatenation of worker w's parameter-shard slices;
    FsdpExchange   one fused quantized reduce-scatter per SHARDED policy
                   group (phase 1 only: fsdp has no server->worker
                   broadcast, the next forward's parameter all-gather is
                   the downlink) plus one fused quantized all-reduce per
                   REPLICATED group (leaves with no dp-divisible dim), with
                   per-group wire accounting and error-feedback residuals;
    make_fused_tree_gather
                   the custom-VJP whole-tree gather the train step calls:
                   forward = one fused bf16 all-gather per sharded group
                   (the ZeRO-3 parameter broadcast), backward = the fused
                   exchange above. Error-feedback residuals ride the
                   cotangent of the residual-buffer input, so
                   ``value_and_grad(loss, argnums=(0, 1))`` returns
                   (sharded grads, new residuals) in one pass and the
                   residual stream persists in ``TrainState.ef``.

Buffer layout of one sharded group (L dp workers, leaves a, b):

        row 0: [ a.shard0 | b.shard0 ]      rows = all_to_all'd chunks;
        row 1: [ a.shard1 | b.shard1 ]      worker w keeps the mean of
        ...                                 row w == grads for exactly
        row L-1: [ a.shardL-1 | b.shardL-1 ]   its own param shards.

Collective launches are O(#policy groups), never O(#leaves). Tensor
parallelism: flattening a TP-sharded cotangent into a single dp buffer
would force XLA to replicate it over the ``model`` axis, so callers keep
the per-leaf gather (with its nested-manual trick) whenever
``n_model > 1`` — see ``train/step.py``.

Every quantized phase here (the reduce-scatter encode/decode and the
error-feedback ``local_qdq``) goes through ``collectives``/``wire`` and
therefore rides the FUSED one-pass Pallas kernels by default since PR 5
(one ``pallas_call`` per sweep; ``use_kernels=False`` /
``REPRO_USE_KERNELS=0`` select the bit-identical jnp oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.api import QuantConfig
from repro.core.comm import wire
from repro.core.comm.collectives import (_names, _rs_mean_parts, axis_size,
                                         local_qdq_comm_layout,
                                         quantized_reduce_scatter_mean)
from repro.core.comm.exchange import GradientExchange, link_stats
from repro.core.policy import QuantPolicy
from repro.core.quantizers import Quantizer
from repro.utils.pytree import tree_flatten_with_path_strs


def reduce_scatter_mean_block(g, qz: Quantizer, key, axis_names, *, dim: int,
                              use_kernels: bool = True,
                              param_dtype=jnp.float32,
                              pipeline_chunks: int = 1):
    """Quantized reduce-scatter of ONE full-size cotangent block along
    ``dim``: returns this worker's shard of the across-worker mean, in the
    stored-shard shape. The single-leaf primitive shared by the per-leaf
    fsdp gather backward (``make_fsdp_gather``) and by tests.

    ``key`` must already be folded per-worker (callers fold in the dp axis
    index in the primal context — see ``make_fsdp_gather``)."""
    names = _names(axis_names)
    L = axis_size(names)
    gm = jnp.moveaxis(g.astype(jnp.float32), dim, 0)
    lead, rest = gm.shape[0], gm.shape[1:]
    chunk = (lead // L) * int(np.prod(rest)) if rest else lead // L
    parts = gm.reshape(L, chunk)
    if qz.is_identity:
        mean_chunk = lax.psum_scatter(
            parts, names, scatter_dimension=0, tiled=False) / L
    else:
        valid = jnp.ones((L, chunk), dtype=bool)
        mean_chunk = _rs_mean_parts(parts, valid, qz, key, names,
                                    use_kernels,
                                    pipeline_chunks=pipeline_chunks)
    out = mean_chunk.reshape((lead // L,) + rest)
    return jnp.moveaxis(out, 0, dim).astype(param_dtype)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FsdpSlot:
    """One leaf's span inside its group buffer (FULL-leaf coordinates)."""

    path: str
    shape: Tuple[int, ...]       # full (unsharded) leaf shape
    dtype: Any
    dim: Optional[int]           # dp-shard dim in full coords; None = repl.
    offset: int                  # sharded: offset inside each worker ROW
                                 # (elements of one shard); replicated:
                                 # offset inside the full group buffer
    size: int                    # full element count


@dataclasses.dataclass(frozen=True)
class FsdpGroup:
    """One policy group's contiguous segment. ``rule_id`` is the policy
    rule index (``by_rule`` layouts only) a ``BitSchedule`` phase
    specialization re-resolves the config through."""

    cfg: QuantConfig
    sharded: bool                # True: reduce-scatter; False: all-reduce
    leaf_ids: Tuple[int, ...]    # canonical leaf order indices, ascending
    size: int                    # full element count of the group buffer
    rule_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class FsdpLayout:
    """Static shard-aware partition plan for a ZeRO-3 parameter tree.

    Leaves are grouped by ``(resolved QuantConfig, sharded?)``; sharded
    groups are laid out worker-major (row w = worker w's shard slices of
    every leaf, concatenated in canonical order), so a reduce-scatter of
    the flattened buffer hands each worker a chunk that unflattens
    directly onto its stored parameter shards.
    """

    treedef: Any
    slots: Tuple[FsdpSlot, ...]
    groups: Tuple[FsdpGroup, ...]
    leaf_group: Tuple[int, ...]          # leaf i -> index into groups
    n_shards: int                        # L, the dp worker count

    @classmethod
    def from_tree(cls, tree, policy: QuantPolicy, *, paths, shard_dims,
                  n_shards: int, by_rule: bool = False) -> "FsdpLayout":
        """``paths``: pytree of path strings aligned with ``tree``;
        ``shard_dims``: path -> dp-shard dim in FULL leaf coords (None =
        replicated); ``n_shards``: dp worker count. Every sharded leaf's
        ``shape[dim]`` must divide by ``n_shards`` (``plan_sharding``
        guarantees it). ``by_rule=True`` keys the grouping on
        ``(policy rule index, sharded)`` instead of ``(config,
        sharded)`` — the bits-invariant partition a ``BitSchedule``
        skeleton needs (see ``PolicyLayout.from_tree``)."""
        pairs, treedef = tree_flatten_with_path_strs(tree)
        path_strs = list(jax.tree_util.tree_leaves(paths))
        assert len(path_strs) == len(pairs), (len(path_strs), len(pairs))

        group_ix: Dict[Tuple[Any, bool], int] = {}
        g_cfg: List[Tuple[QuantConfig, bool, Optional[int]]] = []
        g_leaves: List[List[int]] = []
        g_off: List[int] = []
        slots: List[FsdpSlot] = []
        leaf_group: List[int] = []
        for i, ((_, leaf), path) in enumerate(zip(pairs, path_strs)):
            cfg = policy.resolve(path)
            rid = policy.resolve_ix(path) if by_rule else None
            dim = shard_dims.get(path)
            if dim is not None and (not leaf.shape
                                    or leaf.shape[dim] % n_shards):
                raise ValueError(
                    f"leaf {path!r} shape {leaf.shape} is not divisible "
                    f"by {n_shards} along dim {dim}")
            sharded = dim is not None
            gkey = (rid if by_rule else cfg, sharded)
            gi = group_ix.setdefault(gkey, len(g_cfg))
            if gi == len(g_cfg):
                g_cfg.append((cfg, sharded, rid))
                g_leaves.append([])
                g_off.append(0)
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            slots.append(FsdpSlot(path=path, shape=tuple(leaf.shape),
                                  dtype=leaf.dtype, dim=dim,
                                  offset=g_off[gi], size=size))
            # sharded rows advance by ONE shard's elements; replicated
            # buffers by the full leaf
            g_off[gi] += size // n_shards if sharded else size
            g_leaves[gi].append(i)
            leaf_group.append(gi)
        groups = tuple(
            FsdpGroup(cfg=c, sharded=sh, leaf_ids=tuple(ls),
                      size=off * (n_shards if sh else 1), rule_id=r)
            for (c, sh, r), ls, off in zip(g_cfg, g_leaves, g_off))
        return cls(treedef=treedef, slots=tuple(slots), groups=groups,
                   leaf_group=tuple(leaf_group), n_shards=n_shards)

    def with_configs(self, policy: QuantPolicy) -> "FsdpLayout":
        """Specialize a ``by_rule`` skeleton to one phase's configs
        (identical slots/offsets/group membership; see
        ``PolicyLayout.with_configs``)."""
        for g in self.groups:
            if g.rule_id is None:
                raise ValueError(
                    "with_configs needs a by_rule layout (group rule_ids "
                    "are unset — build with from_tree(by_rule=True))")
        groups = tuple(
            dataclasses.replace(g, cfg=policy.cfg_for_rule(g.rule_id))
            for g in self.groups)
        return dataclasses.replace(self, groups=groups)

    @property
    def size(self) -> int:
        return sum(g.size for g in self.groups)

    # -- forward: fused parameter all-gather -------------------------------
    def gather_full(self, tree, axis_names, *, compute_dtype=jnp.bfloat16):
        """Sharded-param pytree -> full-leaf pytree (``compute_dtype``),
        ONE all_gather per sharded group (the ZeRO-3 parameter broadcast;
        replicated leaves just cast). Runs inside shard_map over the dp
        axes."""
        names = _names(axis_names)
        L = self.n_shards
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.slots), (len(leaves), len(self.slots))
        full: List[Any] = [None] * len(leaves)
        for g in self.groups:
            if not g.sharded:
                for i in g.leaf_ids:
                    full[i] = leaves[i].astype(compute_dtype)
                continue
            row = jnp.concatenate([
                jnp.moveaxis(leaves[i].astype(compute_dtype),
                             self.slots[i].dim, 0).reshape(-1)
                for i in g.leaf_ids])
            rows = lax.all_gather(row, names, axis=0, tiled=False)  # (L, .)
            for i in g.leaf_ids:
                s = self.slots[i]
                shard = s.size // L
                rest = s.shape[:s.dim] + s.shape[s.dim + 1:]
                seg = rows[:, s.offset:s.offset + shard]
                seg = seg.reshape((s.shape[s.dim],) + rest)
                full[i] = jnp.moveaxis(seg, 0, s.dim)
        return jax.tree_util.tree_unflatten(self.treedef, full)

    # -- backward: buffers <-> trees ---------------------------------------
    def flatten_groups(self, tree) -> Tuple[jnp.ndarray, ...]:
        """Full-leaf cotangent pytree -> one (group.size,) f32 buffer per
        group. Sharded groups are worker-major (see class docstring)."""
        L = self.n_shards
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.slots), (len(leaves), len(self.slots))
        bufs = []
        for g in self.groups:
            if not g.sharded:
                bufs.append(jnp.concatenate(
                    [leaves[i].astype(jnp.float32).reshape(-1)
                     for i in g.leaf_ids]))
                continue
            rows = jnp.concatenate([
                jnp.moveaxis(leaves[i].astype(jnp.float32),
                             self.slots[i].dim, 0).reshape(L, -1)
                for i in g.leaf_ids], axis=1)
            bufs.append(rows.reshape(-1))
        return tuple(bufs)

    def unflatten_outputs(self, outs: Sequence[jnp.ndarray], *,
                          param_dtype=jnp.float32):
        """Per-group exchange outputs -> pytree aligned with the STORED
        (sharded) parameters: sharded groups receive their own
        (group.size / L,) mean chunk, replicated groups the full
        (group.size,) mean buffer."""
        assert len(outs) == len(self.groups), (len(outs), len(self.groups))
        L = self.n_shards
        leaves = []
        for i, s in enumerate(self.slots):
            out = outs[self.leaf_group[i]]
            if s.dim is None:
                leaf = out[s.offset:s.offset + s.size].reshape(s.shape)
            else:
                shard = s.size // L
                rest = s.shape[:s.dim] + s.shape[s.dim + 1:]
                seg = out[s.offset:s.offset + shard]
                seg = seg.reshape((s.shape[s.dim] // L,) + rest)
                leaf = jnp.moveaxis(seg, 0, s.dim)
            leaves.append(leaf.astype(param_dtype))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FsdpExchange:
    """Per-policy-group fused ZeRO-3 exchange over an ``FsdpLayout``.

    Sharded groups run ONE quantized reduce-scatter (phase 1 only — the
    next forward's fused parameter all-gather is the downlink); replicated
    groups run the full Algorithm 2 all-reduce via a ``GradientExchange``.
    ``exchange_bufs``/``residual_bufs`` share one key schedule so
    error-feedback residuals stay bit-consistent with what was sent.

    With ``intra_axes`` set (the two-level ICI/DCN mode, see
    ``core/comm/hierarchical.py``) every group's quantized phase runs over
    the inter (``pod``) axes only, on data already averaged in full
    precision over the fast intra axes:

      * sharded groups: the worker-major buffer ``(L_p, L_i, chunk)`` is
        fp-psum_scattered over the intra axes (each worker keeps the
        intra-mean rows destined for its data-column across pods), then
        quantized-reduce-scattered over ``pod`` — the DCN uplink shrinks
        by 1/L_i and each worker still ends with exactly its param-shard
        mean chunk;
      * replicated groups: fp intra scatter -> quantized Algorithm 2 over
        ``pod`` -> fp intra gather (``GradientExchange`` two-level mode).

    Error-feedback residuals then live on the intra SHARD — the quantized
    inter axis only — so ``ef_group_sizes`` shrinks by the same 1/L_i.
    """

    layout: FsdpLayout
    engines: Tuple[GradientExchange, ...]    # aligned with layout.groups;
                                             # sharded groups use only .qz
    dp_axes: Tuple[str, ...] = ("data",)     # FULL ordered dp tuple (the
                                             # parameter all-gather axes)
    intra_axes: Tuple[str, ...] = ()         # fast fp axes; () = flat
    n_intra: int = 1                         # static size of intra_axes
    use_kernels: bool = True
    pipeline_chunks: int = 1                 # bit-identical chunked schedule

    @classmethod
    def build(cls, policy: QuantPolicy, tree, axis_names, *, paths,
              shard_dims, n_shards: int, use_kernels: bool = True,
              max_chunk_elems: Optional[int] = None,
              intra_axes=(), n_intra: int = 1,
              pipeline_chunks: int = 1,
              by_rule: bool = False) -> "FsdpExchange":
        """``axis_names`` is the FULL ordered dp tuple; a non-empty
        ``intra_axes`` (with its static size ``n_intra``) switches on the
        two-level mode — the quantized collectives then run over the
        remaining (inter) axes only, which must precede the intra axes in
        ``axis_names`` (the worker-major rows are inter-major).
        ``max_chunk_elems`` caps replicated-group collectives only: a
        sharded group's buffer must reduce-scatter in one piece (its rows
        are the worker chunks). ``pipeline_chunks`` pipelines every
        group's quantized collective (bit-identical schedule knob, see
        ``GradientExchange``)."""
        dp = _names(axis_names)
        intra = tuple(intra_axes)
        inter = tuple(a for a in dp if a not in intra)
        if intra:
            if dp != inter + intra:
                raise ValueError(
                    f"inter axes {inter} must precede intra axes {intra} "
                    f"in the dp tuple {dp} (worker-major rows are "
                    f"inter-major)")
            if n_intra <= 1 or n_shards % n_intra:
                raise ValueError(
                    f"n_intra must be > 1 and divide n_shards="
                    f"{n_shards}, got {n_intra}")
        else:
            n_intra = 1
        layout = FsdpLayout.from_tree(tree, policy, paths=paths,
                                      shard_dims=shard_dims,
                                      n_shards=n_shards, by_rule=by_rule)
        engines = tuple(
            GradientExchange(
                g.cfg.to_quantizer(), inter,
                server_requant=g.cfg.server_requant,
                use_kernels=use_kernels,
                max_chunk_elems=None if g.sharded else max_chunk_elems,
                intra_axes=intra, pipeline_chunks=pipeline_chunks)
            for g in layout.groups)
        return cls(layout=layout, engines=engines, dp_axes=dp,
                   intra_axes=intra, n_intra=n_intra,
                   use_kernels=use_kernels, pipeline_chunks=pipeline_chunks)

    def specialize(self, policy: QuantPolicy) -> "FsdpExchange":
        """One phase's engine from a ``by_rule`` skeleton: reuse the
        bits-independent layout, rebuild only per-group quantizers from
        the phase's concrete configs. Group structure — and therefore
        ``ef_group_sizes`` shapes — is identical across phases (ramps
        never materialize to identity, so the None pattern is static
        too)."""
        layout = self.layout.with_configs(policy)
        engines = tuple(
            dataclasses.replace(
                eng, qz=g.cfg.to_quantizer(),
                server_requant=g.cfg.server_requant)
            for eng, g in zip(self.engines, layout.groups))
        return dataclasses.replace(self, layout=layout, engines=engines)

    @property
    def axis_names(self):
        return self.dp_axes

    @property
    def inter_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.dp_axes if a not in self.intra_axes)

    @property
    def n_inter(self) -> int:
        return self.layout.n_shards // self.n_intra

    @property
    def is_identity(self) -> bool:
        return all(e.qz.is_identity for e in self.engines)

    def _group_key(self, key: jax.Array, gi: int) -> jax.Array:
        # mirrors PartitionedExchange: a single group keeps the unfolded key
        return key if len(self.engines) == 1 else jax.random.fold_in(key, gi)

    def _split_wid(self, worker_id):
        """Combined dp worker id -> (inter_id, intra_id). The combined
        enumeration is inter-major (inter axes precede intra axes), so the
        split is arithmetic — no extra primal-context captures needed."""
        if not self.intra_axes:
            return worker_id, None
        return worker_id // self.n_intra, worker_id % self.n_intra

    # -- two-level sharded-group primitive ---------------------------------
    def _sharded_intra_scatter(self, buf: jnp.ndarray) -> jnp.ndarray:
        """(L_p*L_i*chunk,) worker-major group buffer -> this worker's
        (L_p*chunk,) fp intra-mean: the rows destined for its data-column
        across all pods (exactly what the quantized inter reduce-scatter
        consumes)."""
        L = self.layout.n_shards
        chunk = buf.shape[0] // L
        parts = buf.reshape(self.n_inter, self.n_intra, chunk)
        intra_mean = lax.psum_scatter(
            parts, _names(self.intra_axes), scatter_dimension=1,
            tiled=False) / self.n_intra
        return intra_mean.reshape(-1)

    # -- distributed paths (inside shard_map over the dp axes) -------------
    def exchange_with_residuals(
        self, bufs: Sequence[jnp.ndarray], key: jax.Array, worker_id,
        ef_bufs=None,
    ) -> Tuple[Tuple[jnp.ndarray, ...], Optional[Tuple[Any, ...]]]:
        """The one-pass backward exchange: per-group local cotangent
        buffers -> (per-group outputs, new EF residuals or None).

        ``worker_id`` is the COMBINED dp axis index captured in the primal
        context (axis_index cannot lower in transposed contexts).
        ``ef_bufs`` (group-aligned, None entries for identity groups) are
        added to each group's quantizer input — the raw buffer in flat
        mode, the intra-mean shard in two-level mode — and the matching
        residuals e = b − Q⁻¹(Q(b)) come back as the second result.
        Sharded groups get this worker's (size/L,) mean chunk, replicated
        groups the full (size,) mean."""
        want_ef = ef_bufs is not None
        if not want_ef:
            ef_bufs = (None,) * len(self.engines)
        wid_inter, wid_intra = self._split_wid(worker_id)
        outs: List[jnp.ndarray] = []
        res: List[Optional[jnp.ndarray]] = []
        for gi, (eng, g) in enumerate(zip(self.engines, self.layout.groups)):
            gk = self._group_key(key, gi)
            ef = ef_bufs[gi]
            if not self.intra_axes:
                b = bufs[gi] if ef is None else bufs[gi] + ef
                if g.sharded:
                    outs.append(quantized_reduce_scatter_mean(
                        b, eng.qz, gk, self.dp_axes,
                        worker_id=worker_id, use_kernels=self.use_kernels,
                        pipeline_chunks=self.pipeline_chunks))
                    if want_ef and not eng.qz.is_identity:
                        res.append(b - local_qdq_comm_layout(
                            b, eng.qz, gk, self.dp_axes,
                            worker_id=worker_id,
                            use_kernels=self.use_kernels))
                    else:
                        res.append(None)
                else:
                    outs.append(eng.exchange_flat(b, gk,
                                                  worker_id=worker_id))
                    if want_ef and not eng.qz.is_identity:
                        res.append(b - eng.local_qdq_flat(
                            b, gk, worker_id=worker_id))
                    else:
                        res.append(None)
                continue
            # two-level: quantize only on the inter (pod) axes
            if g.sharded:
                shard = self._sharded_intra_scatter(bufs[gi])
                b = shard if ef is None else shard + ef
                kk = eng._intra_fold(gk, wid_intra)
                outs.append(quantized_reduce_scatter_mean(
                    b, eng.qz, kk, eng.axis_names, worker_id=wid_inter,
                    use_kernels=self.use_kernels,
                    pipeline_chunks=self.pipeline_chunks))
                if want_ef and not eng.qz.is_identity:
                    res.append(b - local_qdq_comm_layout(
                        b, eng.qz, kk, eng.axis_names, worker_id=wid_inter,
                        use_kernels=self.use_kernels))
                else:
                    res.append(None)
            else:
                shard, valid = eng.intra_scatter(bufs[gi])
                b = shard if ef is None else shard + ef
                mean_shard = eng.exchange_shard(
                    b, gk, valid=valid, worker_id=wid_inter,
                    intra_id=wid_intra)
                outs.append(eng.intra_gather(mean_shard, g.size))
                if want_ef and not eng.qz.is_identity:
                    res.append(b - eng.local_qdq_shard(
                        b, gk, valid=valid, worker_id=wid_inter,
                        intra_id=wid_intra))
                else:
                    res.append(None)
        return tuple(outs), (tuple(res) if want_ef else None)

    def exchange_bufs(self, bufs: Sequence[jnp.ndarray], key: jax.Array,
                      worker_id) -> Tuple[jnp.ndarray, ...]:
        """Per-group local cotangent buffers -> per-group outputs (see
        :meth:`exchange_with_residuals`, which the train step's backward
        uses to also stream the EF residuals in the same pass)."""
        return self.exchange_with_residuals(bufs, key, worker_id)[0]

    def residual_bufs(self, bufs: Sequence[jnp.ndarray], key: jax.Array,
                      worker_id) -> Tuple[Optional[jnp.ndarray], ...]:
        """Error-feedback residuals e = b − Q⁻¹(Q(b)), bit-consistent with
        ``exchange_bufs`` (same spans, same folded keys); identity groups
        have no quantization error and carry no residual buffer (None —
        matching ``ef_group_sizes``). Two-level residuals live on the
        intra-mean shard (this standalone path re-runs the fp intra
        scatter; the train step uses the combined
        :meth:`exchange_with_residuals` instead)."""
        wid_inter, wid_intra = self._split_wid(worker_id)
        res = []
        for gi, (eng, g) in enumerate(zip(self.engines, self.layout.groups)):
            if eng.qz.is_identity:
                res.append(None)
                continue
            gk = self._group_key(key, gi)
            if not self.intra_axes:
                if g.sharded:
                    local = local_qdq_comm_layout(
                        bufs[gi], eng.qz, gk, self.dp_axes,
                        worker_id=worker_id, use_kernels=self.use_kernels)
                else:
                    local = eng.local_qdq_flat(bufs[gi], gk,
                                               worker_id=worker_id)
                res.append(bufs[gi] - local)
                continue
            if g.sharded:
                shard = self._sharded_intra_scatter(bufs[gi])
                kk = eng._intra_fold(gk, wid_intra)
                res.append(shard - local_qdq_comm_layout(
                    shard, eng.qz, kk, eng.axis_names, worker_id=wid_inter,
                    use_kernels=self.use_kernels))
            else:
                shard, valid = eng.intra_scatter(bufs[gi])
                res.append(shard - eng.local_qdq_shard(
                    shard, gk, valid=valid, worker_id=wid_inter,
                    intra_id=wid_intra))
        return tuple(res)

    def ef_group_sizes(self) -> Tuple[Optional[int], ...]:
        """Per-group residual-buffer element counts, group-aligned: the
        quantizer-input length for quantized groups (the FULL group size in
        flat mode; the 1/L_i intra shard in two-level mode — residuals
        live on the quantized inter axis only), None for identity groups
        (an exact exchange leaves nothing to feed back — no buffer is
        allocated)."""
        sizes = []
        for eng, g in zip(self.engines, self.layout.groups):
            if eng.qz.is_identity:
                sizes.append(None)
            elif not self.intra_axes:
                sizes.append(g.size)
            elif g.sharded:
                sizes.append(g.size // self.n_intra)
            else:
                sizes.append(-(-g.size // self.n_intra))
        return tuple(sizes)

    # -- runtime statistics (the BitBudgetController feed) -----------------
    def group_stats_stored(self, grads_tree, ef=None) -> jnp.ndarray:
        """(n_groups, 3) f32 rows ``[sigma_sq, clip_frac, ef_norm_sq]``
        from the STORED-shard gradient tree the exchange hands back
        (each worker's param-shard slice of the across-worker mean).
        Unlike the replicated ``PartitionedExchange.group_stats`` (exact,
        pre-exchange) this is a post-exchange approximation — the mean is
        already quantized — but the controller only consumes RELATIVE
        group magnitudes, which survive. ``jax.lax.pmean`` over the dp
        axes yields the fleet view."""
        leaves = jax.tree_util.tree_leaves(grads_tree)
        assert len(leaves) == len(self.layout.slots), \
            (len(leaves), len(self.layout.slots))
        rows = []
        for gi, (eng, g) in enumerate(zip(self.engines, self.layout.groups)):
            buf = jnp.concatenate([
                leaves[i].astype(jnp.float32).reshape(-1)
                for i in g.leaf_ids])
            d_eff = wire.bucket_len(buf.shape[0], eng.qz.bucket_size)
            st = wire.encode_stats(eng.qz, buf, d_eff)
            e = None if ef is None else ef[gi]
            ef_sq = (jnp.zeros((), jnp.float32) if e is None
                     else jnp.sum(jnp.square(e.astype(jnp.float32))))
            rows.append(jnp.stack([st[0], st[1], ef_sq]))
        return jnp.stack(rows)

    # -- static cost accounting (benchmarks / tests) -----------------------
    def quantized_group_count(self) -> int:
        return sum(1 for e in self.engines if not e.qz.is_identity)

    def _group_link_stats(self, eng: GradientExchange, g) -> dict:
        return link_stats(
            eng.qz, g.size, n_intra=self.n_intra, n_inter=self.n_inter,
            two_level=bool(self.intra_axes),
            server_requant=eng.server_requant, sharded=g.sharded,
            max_chunk_elems=eng.max_chunk_elems,
            pipeline_chunks=eng.pipeline_chunks)

    def collective_launches(self) -> int:
        """Backward launches for one step: sharded groups pay phase 1 only
        (``GradientExchange.rs_stats``: 2 all_to_all per pipeline chunk;
        fp = 1 psum_scatter), replicated groups the full Algorithm 2
        count; two-level adds the fp intra scatter (and, for replicated
        groups, gather)."""
        if self.intra_axes:
            return int(sum(self._group_link_stats(eng, g)["launches"]
                           for eng, g in zip(self.engines,
                                             self.layout.groups)))
        L = self.layout.n_shards
        return sum(
            GradientExchange.rs_stats(
                eng.qz, g.size, L,
                pipeline_chunks=eng.pipeline_chunks)[0] if g.sharded
            else eng.collective_launches(g.size, L)
            for eng, g in zip(self.engines, self.layout.groups))

    def wire_bytes_per_worker(self) -> float:
        """Gradient bytes one worker transmits per step (sharded groups:
        phase-1 uplink only; the parameter all-gather downlink is bf16
        and belongs to the forward). Two-level mode counts both links
        (fp ICI + quantized DCN); see ``link_bytes_per_worker`` for the
        split."""
        if self.intra_axes:
            lb = self.link_bytes_per_worker()
            return lb["ici_bytes"] + lb["dcn_bytes"]
        L = self.layout.n_shards
        return sum(
            GradientExchange.rs_stats(eng.qz, g.size, L)[1] if g.sharded
            else eng.wire_bytes_per_worker(g.size, L)
            for eng, g in zip(self.engines, self.layout.groups))

    def link_bytes_per_worker(self) -> dict:
        """Per-link accounting {ici_bytes, dcn_bytes, dcn_q_bytes,
        launches} summed over groups (``exchange.link_stats`` model)."""
        total = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "dcn_q_bytes": 0.0,
                 "launches": 0.0}
        for eng, g in zip(self.engines, self.layout.groups):
            st = self._group_link_stats(eng, g)
            for k in total:
                total[k] += st[k]
        return total


# ---------------------------------------------------------------------------
# the custom-VJP whole-tree gather
# ---------------------------------------------------------------------------

def make_fused_tree_gather(ex: FsdpExchange, *,
                           compute_dtype=jnp.bfloat16,
                           param_dtype=jnp.float32):
    """Returns ``gather(shard_params, ef_bufs, key) -> full_params``.

    fwd: one fused bf16 all-gather per sharded policy group (replicated
         leaves cast in place) — the whole-tree ZeRO-3 parameter broadcast.
    bwd: the fused policy-aware exchange — cotangents are flattened into
         per-group buffers, error-feedback residuals (if ``ef_bufs`` is not
         None) are added, each group runs its single quantized
         reduce-scatter (sharded) or all-reduce (replicated), and the
         result unflattens onto the STORED parameter shards. The NEW
         residual stream is returned as the cotangent of ``ef_bufs``, so

             value_and_grad(loss_fn, argnums=(0, 1))(params, ef)

         yields ``(sharded_grads, new_ef)`` in one backward pass; the
         train step persists ``new_ef`` in ``TrainState.ef``.

    Pass ``ef_bufs=None`` to disable error feedback (no residual compute,
    no residual cotangent)."""
    names = _names(ex.axis_names)

    @jax.custom_vjp
    def gather(shard_params, ef_bufs, key):
        del ef_bufs, key
        return ex.layout.gather_full(shard_params, names,
                                     compute_dtype=compute_dtype)

    def fwd(shard_params, ef_bufs, key):
        # capture the worker id in the PRIMAL context: axis_index cannot
        # lower from the transposed/hoisted backward context
        wid = lax.axis_index(names)
        return gather(shard_params, ef_bufs, key), (key, wid, ef_bufs)

    def bwd(res, g_full):
        key, wid, ef_bufs = res
        bufs = ex.layout.flatten_groups(g_full)
        # e_{t-1} compensates this step's send: b = g + e, added to each
        # group's quantizer input (the raw buffer in flat mode, the
        # intra-mean shard in two-level mode — identity groups carry no
        # residual buffer; see ef_group_sizes). One pass computes both the
        # exchange outputs and the new residual stream.
        outs, new_ef = ex.exchange_with_residuals(bufs, key, wid, ef_bufs)
        shard_ct = ex.layout.unflatten_outputs(outs, param_dtype=param_dtype)
        key_ct = np.zeros(key.shape, dtype=jax.dtypes.float0)
        return shard_ct, new_ef, key_ct

    gather.defvjp(fwd, bwd)
    return gather
