"""ZeRO-3 parameter gathers whose custom-VJP backward is the quantized
gradient exchange.

For ZeRO-3 training the exchange rides the FSDP parameter gather:
``make_fsdp_gather`` returns an all_gather whose custom-VJP backward is the
phase-1 quantized reduce-scatter — exactly where the data-parallel gradient
communication lives. ``make_replicated_gather`` is the identity-forward
variant for leaves that stay dp-replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.comm.collectives import _names, quantized_all_reduce_mean
from repro.core.comm.fsdp_exchange import reduce_scatter_mean_block
from repro.core.quantizers import Quantizer
from repro.utils import compat
from repro.utils.compat import shard_map


def make_fsdp_gather(
    qz: Quantizer,
    axis_names,
    *,
    dim: int,
    tp_dim: Optional[int] = None,
    tp_axis: str = "model",
    compute_dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    use_kernels: bool = True,
):
    """Returns gather(w_slice, key) -> full ``compute_dtype`` leaf.

    fwd: cast + all_gather along ``dim`` over the dp axes (the FSDP
         parameter broadcast; bf16 wire).
    bwd: the paper — quantized reduce-scatter of the full-size local
         gradient cotangent; the f32 slice matches the stored shard.

    When the leaf is also tensor-parallel (``tp_dim`` over the auto
    ``tp_axis``), the backward runs inside a NESTED manual shard_map over
    that axis: every device quantizes its own contiguous gradient shard and
    the all_to_all stays within the dp axes. Without this, XLA has to
    replicate the strided flatten of a TP-sharded cotangent — terabytes of
    involuntary all-gather on 100B-parameter models.
    """
    names = _names(axis_names)

    @jax.custom_vjp
    def gather(w, key):
        del key
        return lax.all_gather(w.astype(compute_dtype), names, axis=dim,
                              tiled=True)

    def fwd(w, key):
        # capture the worker id in the PRIMAL context: axis_index cannot
        # lower from the transposed/hoisted backward context
        wid = lax.axis_index(names)
        return gather(w, key), (key, wid)

    def _local_rs(g, key):
        """Quantized RS of one (possibly per-tp-shard) cotangent block —
        the shared single-leaf primitive from ``fsdp_exchange``."""
        return reduce_scatter_mean_block(g, qz, key, names, dim=dim,
                                         use_kernels=use_kernels,
                                         param_dtype=param_dtype)

    def bwd(res, g):
        key, wid = res
        key_w = jax.random.fold_in(key, wid)
        # Legacy JAX cannot nest a manual region over the tp axis inside
        # the dp-manual region; fall back to the direct path (XLA then
        # partitions the flatten itself — slower, still correct).
        if tp_dim is not None and compat.supports_nested_manual():
            spec = [None] * g.ndim
            spec[tp_dim] = tp_axis
            pspec = jax.sharding.PartitionSpec(*spec)

            # NOTE: the rounding bits are shared across tp shards (the
            # shards quantize disjoint data, so unbiasedness is unaffected)
            out = shard_map(
                _local_rs,
                in_specs=(pspec, jax.sharding.PartitionSpec()),
                out_specs=pspec, axis_names={tp_axis},
                check_vma=False)(g, key_w)
        else:
            out = _local_rs(g, key_w)
        key_ct = np.zeros(key.shape, dtype=jax.dtypes.float0)
        return out, key_ct

    gather.defvjp(fwd, bwd)
    return gather


def make_replicated_gather(
    qz: Quantizer,
    axis_names,
    *,
    compute_dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    server_requant: bool = True,
    use_kernels: bool = True,
):
    """Identity 'gather' for dp-replicated leaves whose backward runs the
    full Algorithm 2 quantized all-reduce (leaves too small / indivisible to
    FSDP-shard still need their gradients exchanged and must stay bit-
    identical across workers — the deterministic phase-2 decode guarantees
    that)."""
    names = _names(axis_names)

    @jax.custom_vjp
    def gather(w, key):
        del key
        return w.astype(compute_dtype)

    def fwd(w, key):
        wid = lax.axis_index(names)   # primal context (see make_fsdp_gather)
        return gather(w, key), (key, wid)

    def bwd(res, g):
        key, wid = res
        flat = g.astype(jnp.float32).reshape(-1)
        if qz.is_identity:
            mean = lax.pmean(flat, names)
        else:
            mean = quantized_all_reduce_mean(
                flat, qz, key, names, worker_id=wid,
                server_requant=server_requant, use_kernels=use_kernels)
        out = mean.reshape(g.shape).astype(param_dtype)
        key_ct = np.zeros(key.shape, dtype=jax.dtypes.float0)
        return out, key_ct

    gather.defvjp(fwd, bwd)
    return gather
