"""Quantizer objects: the paper's schemes and its baselines behind one API.

A ``Quantizer`` is a stateless, jit-safe recipe with three stages that mirror
Algorithm 2's per-worker step:

    fit(bkt, mask)          -> levels   (runtime level selection — the paper)
    assign(bkt, levels, key)-> idx      (rounding rule)
    decode(idx, levels)     -> values   (dequantization, also the server side)

plus ``quantize(flat, key)`` / ``dequantize(q)`` convenience wrappers over the
bucketed layout and ``qdq`` (quantize∘dequantize) used by single-machine
training and tests.

Schemes:
    fp          identity (no quantization)
    orq         ORQ-s, s = 2^K+1 (ours, unbiased, Theorem 1 / Alg. 1)
    bingrad_pb  BinGrad-pb (ours, partially biased, Eq. 14/15)
    bingrad_b   BinGrad-b  (ours, fully biased, Eq. 16/17)
    terngrad    TernGrad (3 levels ±max|v|)
    qsgd        QSGD-s (evenly spaced levels)
    linear      Linear-s (CDF quantiles)
    signsgd     scaled SignSGD (Eq. 13, deterministic sign)
    minmax2     unbiased 2-level {min,max} (Corollary 1.1 endpoints)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import buckets as B
from repro.core import clipping, encode, levels as L, rounding as R


class QuantizedTensor(NamedTuple):
    """Bucketed quantized payload for one flat tensor."""

    idx: jnp.ndarray      # (nb, d) int32 level indices (wire: bit-packed)
    levels: jnp.ndarray   # (nb, s) float32 level table  (wire: as-is)
    n: int                # original element count (static)


@dataclasses.dataclass(frozen=True)
class Quantizer:
    method: str = "orq"
    num_levels: int = 9            # s; must be 2^K+1 for orq
    bucket_size: int = 2048        # paper's d (512 for ImageNet runs)
    clip_c: Optional[float] = None  # TernGrad-style σ-clip factor (None = off)
    refine_iters: int = 0          # beyond-paper ORQ coordinate sweeps
    lloyd_iters: int = 0           # beyond-paper BinGrad-b fixed-point iters
    qsgd_norm: str = "linf"

    # ------------------------------------------------------------------
    @property
    def unbiased(self) -> bool:
        # bingrad_pb is "partially biased" (unbiased only inside [b₋₁, b₁];
        # the clipped tails carry bias — Eq. 14), so it is not listed here.
        return self.method in ("fp", "orq", "terngrad", "qsgd", "linear",
                               "minmax2")

    @property
    def s(self) -> int:
        if self.method in ("bingrad_pb", "bingrad_b", "signsgd", "minmax2"):
            return 2
        if self.method == "terngrad":
            return 3
        return self.num_levels

    @property
    def wire_bits_per_element(self) -> int:
        return encode.bits_for_levels(self.s)

    @property
    def is_identity(self) -> bool:
        return self.method == "fp"

    # ------------------------------------------------------------------
    def fit(self, bkt: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
        if self.clip_c is not None and self.method not in ("fp",):
            bkt = clipping.sigma_clip(bkt, mask, self.clip_c)
        m = self.method
        if m == "orq":
            K = (self.num_levels - 1).bit_length() - 1
            assert 2 ** K + 1 == self.num_levels, (
                f"ORQ needs s = 2^K + 1, got {self.num_levels}")
            return L.orq_levels(bkt, mask, K, refine_iters=self.refine_iters)
        if m == "bingrad_pb":
            b1 = L.bingrad_pb_b1(bkt, mask)
            return jnp.stack([-b1, b1], axis=-1)
        if m == "bingrad_b":
            return L.bingrad_b_levels(bkt, mask, lloyd_iters=self.lloyd_iters)
        if m == "terngrad":
            return L.terngrad_levels(bkt, mask)
        if m == "qsgd":
            return L.qsgd_levels(bkt, mask, self.num_levels, norm=self.qsgd_norm)
        if m == "linear":
            return L.linear_levels(bkt, mask, self.num_levels)
        if m == "signsgd":
            return L.signsgd_scale(bkt, mask)
        if m == "minmax2":
            return L.minmax_levels(bkt, mask)
        raise ValueError(f"unknown method {self.method!r}")

    def assign(
        self, bkt: jnp.ndarray, levels: jnp.ndarray, key: jax.Array,
        mask: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        if self.clip_c is not None:
            # clip so the rounding sees the same values the fit saw — the
            # σ estimate must exclude padded ragged-tail positions exactly
            # like ``fit`` does, so callers thread the real bucket mask
            # through (``None`` keeps the all-valid legacy behaviour)
            if mask is None:
                mask = jnp.ones(bkt.shape, dtype=bool)
            bkt = clipping.sigma_clip(bkt, mask, self.clip_c)
        m = self.method
        if m in ("orq", "terngrad", "qsgd", "linear", "minmax2", "bingrad_pb"):
            bits = R.random_bits(key, bkt.shape)
            return R.random_round(bkt, levels, bits)
        if m == "bingrad_b":
            b0 = 0.5 * (levels[:, :1] + levels[:, 1:2])  # Eq. (17): midpoint
            return R.threshold_round(bkt, b0)
        if m == "signsgd":
            return R.threshold_round(bkt, jnp.zeros((bkt.shape[0], 1)))
        raise ValueError(f"unknown method {self.method!r}")

    @staticmethod
    def decode(idx: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
        return R.dequantize(idx, levels)

    # ------------------------------------------------------------------
    def quantize(self, flat: jnp.ndarray, key: jax.Array) -> QuantizedTensor:
        bkt, mask = B.to_buckets(flat.reshape(-1), self.bucket_size)
        lv = self.fit(bkt, mask)
        idx = self.assign(bkt, lv, key, mask=mask)
        idx = jnp.where(mask, idx, 0)
        return QuantizedTensor(idx=idx, levels=lv, n=flat.size)

    def dequantize(self, q: QuantizedTensor) -> jnp.ndarray:
        return B.from_buckets(self.decode(q.idx, q.levels), q.n)

    def qdq(self, flat: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """quantize -> dequantize, shape-preserving (single-machine Alg. 2)."""
        if self.is_identity:
            return flat
        shape, dtype = flat.shape, flat.dtype
        out = self.dequantize(self.quantize(flat.reshape(-1), key))
        return out.reshape(shape).astype(dtype)

    # ------------------------------------------------------------------
    def encode_wire(self, q: QuantizedTensor) -> jnp.ndarray:
        return encode.pack(q.idx, self.wire_bits_per_element)

    def decode_wire(self, words: jnp.ndarray, levels: jnp.ndarray,
                    n: int) -> QuantizedTensor:
        d = self.bucket_size
        idx = encode.unpack(words, self.wire_bits_per_element, d)
        return QuantizedTensor(idx=idx, levels=levels, n=n)

    def wire_bytes(self, n_elems: int) -> float:
        """Packed wire bytes for a tensor of n_elems (payload + level tables)."""
        nb = B.num_buckets(n_elems, self.bucket_size)
        if self.is_identity:
            return 4.0 * n_elems
        words = encode.packed_words(self.bucket_size, self.wire_bits_per_element)
        return 4.0 * (nb * words + nb * self.s)
