"""Bucket-based gradient layout (paper §5: bucket size d, default 512/2048).

The whole (flattened) gradient is split into buckets of fixed length ``d``;
each bucket is quantized independently with its own levels. The final,
possibly ragged bucket is handled with an explicit validity mask so padding
never contaminates the fitted levels.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def num_buckets(n: int, d: int) -> int:
    return -(-n // d)


def to_buckets(flat: jnp.ndarray, d: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(n,) -> ((nb, d) values, (nb, d) bool mask). Padding value is 0 but masked."""
    assert flat.ndim == 1, f"to_buckets expects flat input, got {flat.shape}"
    n = flat.shape[0]
    nb = num_buckets(n, d)
    pad = nb * d - n
    vals = jnp.pad(flat, (0, pad))
    mask = jnp.arange(nb * d, dtype=jnp.int32) < n
    return vals.reshape(nb, d), mask.reshape(nb, d)


def from_buckets(bkt: jnp.ndarray, n: int) -> jnp.ndarray:
    """(nb, d) -> (n,) dropping padding."""
    return bkt.reshape(-1)[:n]
