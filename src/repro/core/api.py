"""Public quantization API: config + pluggable scheme registry.

``QuantConfig`` is what flows through launcher flags / arch configs;
``make_quantizer`` turns it into the stateless ``Quantizer`` recipe by
looking the scheme family up in a registry. Built-in names (paper §5
nomenclature):

    fp | orq-3 | orq-5 | orq-9 | orq-17 | bingrad-pb | bingrad-b |
    terngrad | qsgd-5 | qsgd-9 | linear-5 | linear-9 | signsgd | minmax2

New scheme families plug in through ``register_scheme`` (no core edits);
``all_methods()`` / ``ALL_METHODS`` are derived from the registry, never
hand-listed.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Optional, Tuple

from repro.core.quantizers import Quantizer

_NAME_RE = re.compile(r"^([a-z]+[a-z0-9]*?)(?:-(pb|b|\d+))?$")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    name: str = "fp"               # e.g. "orq-9"
    bucket_size: int = 2048
    clip_c: Optional[float] = None
    refine_iters: int = 0
    lloyd_iters: int = 0
    server_requant: bool = True    # Algorithm 2 option (b): quantize the
                                   # averaged gradient on the way back down

    def to_quantizer(self) -> Quantizer:
        return make_quantizer(
            self.name,
            bucket_size=self.bucket_size,
            clip_c=self.clip_c,
            refine_iters=self.refine_iters,
            lloyd_iters=self.lloyd_iters,
        )


# ---------------------------------------------------------------------------
# scheme registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One scheme family: ``base`` name, a builder mapping the optional
    ``-suffix`` (level count / variant tag) to a Quantizer, and the
    advertised variant names the registry derives ``all_methods()`` from."""

    base: str
    builder: Callable[..., Quantizer]   # builder(suffix, **kw) -> Quantizer
    variants: Tuple[str, ...]
    doc: str = ""


_REGISTRY: Dict[str, SchemeSpec] = {}


def register_scheme(base: str, builder: Callable[..., Quantizer], *,
                    variants: Tuple[str, ...] = (), doc: str = "") -> SchemeSpec:
    """Register (or replace) a scheme family. ``builder(suffix, **kw)``
    receives the parsed ``-suffix`` (``None`` when absent) plus the
    Quantizer keyword args; ``variants`` are the names advertised through
    ``all_methods()`` (defaults to just ``base``)."""
    if not _NAME_RE.match(base) or "-" in base:
        raise ValueError(f"bad scheme base name {base!r}")
    variants = tuple(variants) or (base,)
    for v in variants:
        m = _NAME_RE.match(v)
        if not m or m.group(1) != base:
            # every advertised variant must round-trip through
            # make_quantizer, or all_methods() would name unparseable
            # schemes in help text and error messages
            raise ValueError(
                f"variant {v!r} cannot be parsed back to scheme {base!r} "
                f"(allowed suffixes: -pb, -b, or -<digits>)")
    spec = SchemeSpec(base=base, builder=builder, variants=variants, doc=doc)
    _REGISTRY[base] = spec
    return spec


def unregister_scheme(base: str) -> None:
    _REGISTRY.pop(base, None)


def registered_schemes() -> Dict[str, SchemeSpec]:
    """Snapshot of the registry (base -> SchemeSpec), insertion-ordered."""
    return dict(_REGISTRY)


def all_methods() -> list:
    """Every advertised scheme name, derived from the registry."""
    return [v for spec in _REGISTRY.values() for v in spec.variants]


def make_quantizer(name: str, **kw) -> Quantizer:
    m = _NAME_RE.match(name.strip().lower().replace("_", "-"))
    if not m:
        raise ValueError(
            f"bad quantizer name {name!r}; valid schemes: "
            f"{', '.join(all_methods())}")
    base, suffix = m.group(1), m.group(2)
    spec = _REGISTRY.get(base)
    if spec is None:
        raise ValueError(
            f"unknown quantizer {name!r}; valid schemes: "
            f"{', '.join(all_methods())}")
    return spec.builder(suffix, **kw)


# -- built-in families -------------------------------------------------------

def _fixed(method: str):
    def build(suffix, **kw):
        if suffix is not None:
            raise ValueError(f"scheme {method!r} takes no -suffix")
        return Quantizer(method=method, **kw)
    return build


def _leveled(method: str, default_s: int):
    def build(suffix, **kw):
        return Quantizer(method=method,
                         num_levels=int(suffix) if suffix else default_s,
                         **kw)
    return build


def _bingrad(suffix, **kw):
    if suffix not in ("pb", "b"):
        raise ValueError("bingrad needs a -pb or -b suffix")
    return Quantizer(method=f"bingrad_{suffix}", **kw)


register_scheme("fp", _fixed("fp"), doc="identity (no quantization)")
register_scheme("orq", _leveled("orq", 9),
                variants=("orq-3", "orq-5", "orq-9", "orq-17"),
                doc="ORQ-s, s = 2^K+1 (Theorem 1 / Alg. 1)")
register_scheme("bingrad", _bingrad, variants=("bingrad-pb", "bingrad-b"),
                doc="BinGrad partially/fully biased (Eq. 14-17)")
register_scheme("terngrad", _fixed("terngrad"),
                doc="TernGrad (3 levels ±max|v|)")
register_scheme("qsgd", _leveled("qsgd", 9), variants=("qsgd-5", "qsgd-9"),
                doc="QSGD-s (evenly spaced levels)")
register_scheme("linear", _leveled("linear", 9),
                variants=("linear-5", "linear-9"),
                doc="Linear-s (CDF quantiles)")
register_scheme("signsgd", _fixed("signsgd"),
                doc="scaled SignSGD (Eq. 13)")
register_scheme("minmax2", _fixed("minmax2"),
                doc="unbiased 2-level {min,max} (Corollary 1.1)")


def __getattr__(name: str):
    # ALL_METHODS stays importable but is always derived from the registry
    if name == "ALL_METHODS":
        return all_methods()
    raise AttributeError(name)
