"""Public quantization API: config + registry.

``QuantConfig`` is what flows through launcher flags / arch configs;
``make_quantizer`` turns it into the stateless ``Quantizer`` recipe.
Names accepted (paper §5 nomenclature):

    fp | orq-3 | orq-5 | orq-9 | orq-17 | bingrad-pb | bingrad-b |
    terngrad | qsgd-5 | qsgd-9 | linear-5 | linear-9 | signsgd | minmax2
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core.quantizers import Quantizer

_NAME_RE = re.compile(r"^([a-z]+[a-z0-9]*?)(?:-(pb|b|\d+))?$")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    name: str = "fp"               # e.g. "orq-9"
    bucket_size: int = 2048
    clip_c: Optional[float] = None
    refine_iters: int = 0
    lloyd_iters: int = 0
    server_requant: bool = True    # Algorithm 2 option (b): quantize the
                                   # averaged gradient on the way back down

    def to_quantizer(self) -> Quantizer:
        return make_quantizer(
            self.name,
            bucket_size=self.bucket_size,
            clip_c=self.clip_c,
            refine_iters=self.refine_iters,
            lloyd_iters=self.lloyd_iters,
        )


def make_quantizer(name: str, **kw) -> Quantizer:
    m = _NAME_RE.match(name.strip().lower().replace("_", "-"))
    if not m:
        raise ValueError(f"bad quantizer name {name!r}")
    base, suffix = m.group(1), m.group(2)
    if base == "bingrad":
        method = f"bingrad_{suffix}"
        return Quantizer(method=method, **kw)
    if base in ("orq", "qsgd", "linear"):
        s = int(suffix) if suffix else {"orq": 9, "qsgd": 9, "linear": 9}[base]
        return Quantizer(method=base, num_levels=s, **kw)
    if base in ("fp", "terngrad", "signsgd", "minmax2"):
        return Quantizer(method=base, **kw)
    raise ValueError(f"unknown quantizer {name!r}")


ALL_METHODS = [
    "fp", "orq-3", "orq-5", "orq-9", "orq-17", "bingrad-pb", "bingrad-b",
    "terngrad", "qsgd-5", "qsgd-9", "linear-5", "linear-9", "signsgd",
    "minmax2",
]
