"""Wire encoding: bit-packing level indices into uint32 words.

s levels need ceil(log2(s)) bits per element. The paper reports
information-theoretic ratios (32/log2(s), e.g. x20.2 for 3 levels); the wire
format here packs whole bits (e.g. 2 bits for 3 levels). ``wire_bits`` returns
both accountings so benchmarks can report the paper's ratio alongside the
achievable packed one.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp


def bits_for_levels(s: int) -> int:
    return max(1, math.ceil(math.log2(s)))


def elems_per_word(bits: int) -> int:
    return 32 // bits


def packed_words(d: int, bits: int) -> int:
    epw = elems_per_word(bits)
    return -(-d // epw)


def pack(idx: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(nb, d) int32 indices in [0, 2^bits) -> (nb, nw) uint32 words."""
    nb, d = idx.shape
    epw = elems_per_word(bits)
    nw = packed_words(d, bits)
    padded = jnp.pad(idx.astype(jnp.uint32), ((0, 0), (0, nw * epw - d)))
    lanes = padded.reshape(nb, nw, epw)
    shifts = (jnp.arange(epw, dtype=jnp.uint32) * jnp.uint32(bits))[None, None, :]
    # disjoint bit ranges: addition == bitwise OR
    return (lanes << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack(words: jnp.ndarray, bits: int, d: int) -> jnp.ndarray:
    """(nb, nw) uint32 -> (nb, d) int32 indices."""
    nb, nw = words.shape
    epw = elems_per_word(bits)
    shifts = (jnp.arange(epw, dtype=jnp.uint32) * jnp.uint32(bits))[None, None, :]
    mask = jnp.uint32(2 ** bits - 1)
    lanes = (words[:, :, None] >> shifts) & mask
    return lanes.reshape(nb, nw * epw)[:, :d].astype(jnp.int32)


def wire_bits(n_elems: int, n_buckets: int, s: int) -> Tuple[float, float]:
    """(paper information-theoretic bits, packed wire bits) for a tensor,
    including the per-bucket level-table overhead (s float32 values)."""
    overhead = n_buckets * s * 32
    info = n_elems * math.log2(s) + overhead
    packed = packed_words(n_elems // max(n_buckets, 1) if n_buckets else n_elems,
                          bits_for_levels(s)) * n_buckets * 32 + overhead
    return info, float(packed)
