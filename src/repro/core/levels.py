"""Quantization-level solvers.

This file implements the paper's contribution:

* ``orq_levels``       — Algorithm 1: greedy recursive bisection solving the
  optimal unbiased random-rounding condition Eq. (11)/(12) on the *empirical*
  per-bucket gradient distribution, for s = 2^K + 1 levels. Endpoints are the
  bucket min/max (Corollary 1.1).
* ``bingrad_pb_b1``    — Eq. (15): optimal partially-biased binary level b₁
  (b₋₁ = −b₁ under the paper's zero-mean-symmetric assumption).
* ``bingrad_b_levels`` — Eq. (17): fully-biased binary levels; paper sets
  b₀ = mean(G) for ease of implementation, b±₁ = conditional means. Optional
  ``lloyd_iters`` iterates the Eq. (17) fixed point exactly (beyond-paper; the
  paper's conclusion flags the greedy solver as future work to improve).

Baseline level rules (paper §5 comparison set):

* ``terngrad_levels`` — {−max|v|, 0, +max|v|} (TernGrad).
* ``qsgd_levels``     — s levels evenly spaced over ±‖G‖ (paper §3.1: "evenly
  spaced from −‖G‖ to ‖G‖"; ‖·‖ = ℓ∞ per bucket by default — TernGrad's scale
  and the common practical QSGD choice; ``norm='l2'`` gives the literal QSGD
  scaling).
* ``linear_levels``   — s levels linearly dividing the empirical CDF
  (quantiles), the paper's "Linear-s" naive baseline.
* ``signsgd_scale``   — scaled SignSGD: ±‖G‖₁/dim (Eq. 13).

All solvers are vectorized over buckets: inputs are ``(nb, d)`` values with a
``(nb, d)`` validity mask; outputs are ascending ``(nb, s)`` level tables in
float32. Everything is jit-safe (static shapes, no data-dependent control
flow).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SortedBuckets(NamedTuple):
    """Sorted per-bucket values with prefix sums; the 'empirical p(v)'."""

    v: jnp.ndarray      # (nb, d) ascending; padding sorted to the end as +inf
    psum: jnp.ndarray   # (nb, d+1) prefix sums of valid values (pads count 0)
    cnt: jnp.ndarray    # (nb,) int32 number of valid values


def sort_buckets(bkt: jnp.ndarray, mask: jnp.ndarray) -> SortedBuckets:
    bkt = bkt.astype(jnp.float32)
    v = jnp.sort(jnp.where(mask, bkt, jnp.inf), axis=-1)
    finite = jnp.isfinite(v)
    vz = jnp.where(finite, v, 0.0)
    psum = jnp.concatenate(
        [jnp.zeros_like(vz[:, :1]), jnp.cumsum(vz, axis=-1)], axis=-1
    )
    cnt = mask.sum(axis=-1).astype(jnp.int32)
    return SortedBuckets(v=v, psum=psum, cnt=cnt)


def _count_lt(sb: SortedBuckets, x: jnp.ndarray) -> jnp.ndarray:
    """Per bucket: #(v < x). x: (nb,) -> (nb,) int32."""
    return (jnp.where(jnp.isfinite(sb.v), sb.v, jnp.inf) < x[:, None]).sum(
        axis=-1
    ).astype(jnp.int32)


def _count_le(sb: SortedBuckets, x: jnp.ndarray) -> jnp.ndarray:
    return (jnp.where(jnp.isfinite(sb.v), sb.v, jnp.inf) <= x[:, None]).sum(
        axis=-1
    ).astype(jnp.int32)


def _take(a: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Per-bucket gather: a (nb, m), idx (nb,) -> (nb,)."""
    return jnp.take_along_axis(a, idx[:, None], axis=-1)[:, 0]


def _bucket_min(sb: SortedBuckets) -> jnp.ndarray:
    v0 = sb.v[:, 0]
    return jnp.where(sb.cnt > 0, jnp.where(jnp.isfinite(v0), v0, 0.0), 0.0)


def _bucket_max(sb: SortedBuckets) -> jnp.ndarray:
    idx = jnp.maximum(sb.cnt - 1, 0)
    vm = _take(sb.v, idx)
    return jnp.where(sb.cnt > 0, jnp.where(jnp.isfinite(vm), vm, 0.0), 0.0)


# ---------------------------------------------------------------------------
# ORQ: optimal unbiased multi-level condition (Theorem 1, Eqs. 11/12, Alg. 1)
# ---------------------------------------------------------------------------

def solve_midpoint(
    sb: SortedBuckets, bl: jnp.ndarray, br: jnp.ndarray
) -> jnp.ndarray:
    """Solve Eq. (12) for b_k given neighbours (b_{k-1}, b_{k+1}) = (bl, br).

    Discrete optimal condition:
        |{b_k <= v <= br}|  =  Σ_{bl<=v<=br} (v - bl) / (br - bl).

    The LHS is a decreasing step function of b_k over the sorted bucket
    values, so the solution index is closed-form from prefix sums — no
    iterative search needed (this is the O(d) runtime cost the paper cites).
    """
    idx_l = _count_lt(sb, bl)                    # first index with v >= bl
    idx_r = _count_le(sb, br)                    # one past last index with v <= br
    cnt_in = (idx_r - idx_l).astype(jnp.float32)  # #values in [bl, br]
    sum_in = _take(sb.psum, idx_r) - _take(sb.psum, idx_l)
    width = br - bl
    safe_w = jnp.where(width > 0, width, 1.0)
    rhs = (sum_in - bl * cnt_in) / safe_w        # target count in [b_k, br]
    # count{v in [b, br]} = idx_r - j  where j = first index with v >= b.
    j = jnp.round(idx_r.astype(jnp.float32) - rhs).astype(jnp.int32)
    j = jnp.clip(j, idx_l, jnp.maximum(idx_r - 1, idx_l))
    b = _take(sb.v, jnp.clip(j, 0, sb.v.shape[-1] - 1))
    b = jnp.where(jnp.isfinite(b), b, 0.0)
    mid = 0.5 * (bl + br)
    # Degenerate interval (no data inside, or zero width): bisect.
    b = jnp.where((cnt_in > 0) & (width > 0), b, mid)
    return jnp.clip(b, jnp.minimum(bl, br), jnp.maximum(bl, br))


def orq_levels(
    bkt: jnp.ndarray,
    mask: jnp.ndarray,
    K: int,
    *,
    refine_iters: int = 0,
) -> jnp.ndarray:
    """Algorithm 1: greedy recursive level selection. Returns (nb, 2^K + 1).

    ``refine_iters`` > 0 adds coordinate-descent sweeps re-solving every
    interior level against its converged neighbours (beyond-paper refinement
    of the greedy recursion; see EXPERIMENTS.md §Perf for its effect).
    """
    assert K >= 1
    s = 2 ** K + 1
    sb = sort_buckets(bkt, mask)
    nb = bkt.shape[0]
    levels = jnp.zeros((nb, s), dtype=jnp.float32)
    levels = levels.at[:, 0].set(_bucket_min(sb))       # Corollary 1.1
    levels = levels.at[:, s - 1].set(_bucket_max(sb))   # Corollary 1.1
    step = s - 1
    while step > 1:  # static recursion depth K
        half = step // 2
        for lo in range(0, s - 1, step):
            hi = lo + step
            b = solve_midpoint(sb, levels[:, lo], levels[:, hi])
            levels = levels.at[:, lo + half].set(b)
        step = half
    for _ in range(refine_iters):
        for k in range(1, s - 1):
            b = solve_midpoint(sb, levels[:, k - 1], levels[:, k + 1])
            levels = levels.at[:, k].set(b)
    return levels


def optimality_residual(
    bkt: jnp.ndarray, mask: jnp.ndarray, levels: jnp.ndarray
) -> jnp.ndarray:
    """Eq. (8) residual at each interior level, normalized. ~0 at optimum.

    residual_k = b_{k-1}·P[b_{k-1},b_k] + b_{k+1}·P[b_k,b_{k+1}]
                 − E[v; b_{k-1} <= v <= b_{k+1}]       (per unit mass)
    Used by tests and benchmarks to check Theorem 1 holds at the solver's
    output (up to the discreteness of the empirical distribution).
    """
    sb = sort_buckets(bkt, mask)
    s = levels.shape[-1]
    res = []
    for k in range(1, s - 1):
        bl, bk, br = levels[:, k - 1], levels[:, k], levels[:, k + 1]
        i_l = _count_lt(sb, bl)
        i_k = _count_lt(sb, bk)
        i_r = _count_le(sb, br)
        n_lo = (i_k - i_l).astype(jnp.float32)
        n_hi = (i_r - i_k).astype(jnp.float32)
        sum_in = _take(sb.psum, i_r) - _take(sb.psum, i_l)
        total = jnp.maximum(n_lo + n_hi, 1.0)
        r = (bl * n_lo + br * n_hi - sum_in) / total
        scale = jnp.maximum(jnp.abs(br - bl), 1e-12)
        res.append(r / scale)
    return jnp.stack(res, axis=-1)  # (nb, s-2)


# ---------------------------------------------------------------------------
# BinGrad (binary quantization, §3.2)
# ---------------------------------------------------------------------------

def bingrad_pb_b1(bkt: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (15): b₁ with  b₁·∫₀^∞ p  =  ∫_{b₁}^∞ v·p(v)dv,  solved on the
    empirical distribution by minimizing |LHS − RHS| over candidate data
    values (paper §3.2). Returns (nb,) positive scale; levels are ±b₁.
    """
    sb = sort_buckets(bkt, mask)
    n = sb.v.shape[-1]
    total = _take(sb.psum, sb.cnt)
    cnt_pos = (
        jnp.where(jnp.isfinite(sb.v), sb.v, -jnp.inf) > 0
    ).sum(axis=-1).astype(jnp.float32)
    # suffix sum from index j: S[cnt] - S[j]
    suffix = total[:, None] - sb.psum[:, :n]
    vpos = jnp.where(jnp.isfinite(sb.v) & (sb.v > 0), sb.v, jnp.nan)
    f = jnp.abs(vpos * cnt_pos[:, None] - suffix)
    f = jnp.where(jnp.isnan(f), jnp.inf, f)
    j = jnp.argmin(f, axis=-1)
    b1 = _take(sb.v, j)
    b1 = jnp.where(jnp.isfinite(b1) & (cnt_pos > 0), b1, 0.0)
    # all-nonpositive bucket: fall back to mean |v| scale
    absmean = jnp.where(
        sb.cnt > 0,
        jnp.abs(jnp.where(mask, bkt, 0.0)).sum(-1) / jnp.maximum(sb.cnt, 1),
        0.0,
    )
    return jnp.where(b1 > 0, b1, absmean)


def bingrad_b_levels(
    bkt: jnp.ndarray, mask: jnp.ndarray, *, lloyd_iters: int = 0
) -> jnp.ndarray:
    """Eq. (17): fully-biased binary levels. Returns (nb, 2) = (b₋₁, b₁).

    Paper default: b₀ = mean(G); b₋₁/b₁ = conditional means below/above b₀.
    ``lloyd_iters`` > 0 iterates b₀ ← (b₋₁+b₁)/2 (the exact Eq. 17 fixed
    point, i.e. 1-D 2-means) — beyond-paper refinement.
    """
    bkt = bkt.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    cnt = jnp.maximum(m.sum(-1, keepdims=True), 1.0)
    b0 = (bkt * m).sum(-1, keepdims=True) / cnt

    def cond_means(b0):
        lo = m * (bkt < b0)
        hi = m * (bkt >= b0)
        cl = lo.sum(-1, keepdims=True)
        ch = hi.sum(-1, keepdims=True)
        bm = (bkt * lo).sum(-1, keepdims=True) / jnp.maximum(cl, 1.0)
        bp = (bkt * hi).sum(-1, keepdims=True) / jnp.maximum(ch, 1.0)
        # empty side: collapse to the other side's mean (degenerate bucket)
        bm = jnp.where(cl > 0, bm, bp)
        bp = jnp.where(ch > 0, bp, bm)
        return bm, bp

    bm, bp = cond_means(b0)
    for _ in range(lloyd_iters):
        b0 = 0.5 * (bm + bp)
        bm, bp = cond_means(b0)
    return jnp.concatenate([bm, bp], axis=-1)


# ---------------------------------------------------------------------------
# Baselines (§5 comparison set)
# ---------------------------------------------------------------------------

def terngrad_levels(bkt: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """TernGrad: {−max|v|, 0, +max|v|}. Returns (nb, 3)."""
    a = jnp.where(mask, jnp.abs(bkt.astype(jnp.float32)), 0.0)
    mx = a.max(axis=-1)
    return jnp.stack([-mx, jnp.zeros_like(mx), mx], axis=-1)


def qsgd_levels(
    bkt: jnp.ndarray, mask: jnp.ndarray, s: int, *, norm: str = "linf"
) -> jnp.ndarray:
    """QSGD-s: s levels evenly spaced over ±‖G‖ per bucket. Returns (nb, s)."""
    b = bkt.astype(jnp.float32)
    if norm == "linf":
        r = jnp.where(mask, jnp.abs(b), 0.0).max(axis=-1)
    elif norm == "l2":
        r = jnp.sqrt(jnp.where(mask, b * b, 0.0).sum(axis=-1))
    else:
        raise ValueError(f"unknown norm {norm!r}")
    ticks = jnp.linspace(-1.0, 1.0, s, dtype=jnp.float32)
    return r[:, None] * ticks[None, :]


def linear_levels(bkt: jnp.ndarray, mask: jnp.ndarray, s: int) -> jnp.ndarray:
    """Linear-s: levels linearly dividing the empirical CDF (quantiles)."""
    sb = sort_buckets(bkt, mask)
    q = jnp.linspace(0.0, 1.0, s, dtype=jnp.float32)
    idx = jnp.round(q[None, :] * (sb.cnt[:, None] - 1).astype(jnp.float32))
    idx = jnp.clip(idx.astype(jnp.int32), 0, sb.v.shape[-1] - 1)
    lv = jnp.take_along_axis(sb.v, idx, axis=-1)
    lv = jnp.where(jnp.isfinite(lv), lv, 0.0)
    # enforce ascending (ties collapse fine for rounding)
    return jnp.where(sb.cnt[:, None] > 0, lv, jnp.zeros_like(lv))


def signsgd_scale(bkt: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Scaled SignSGD (Eq. 13): ±‖G‖₁/dim. Returns (nb, 2) = (−m, m)."""
    a = jnp.where(mask, jnp.abs(bkt.astype(jnp.float32)), 0.0)
    cnt = jnp.maximum(mask.sum(-1).astype(jnp.float32), 1.0)
    mmean = a.sum(-1) / cnt
    return jnp.stack([-mmean, mmean], axis=-1)


def minmax_levels(bkt: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Unbiased binary endpoints {min, max} (Corollary 1.1 for s=2) — the
    outlier-fragile scheme BinGrad-pb improves on. Returns (nb, 2)."""
    sb = sort_buckets(bkt, mask)
    return jnp.stack([_bucket_min(sb), _bucket_max(sb)], axis=-1)
