"""Chameleon 34B [arXiv:2405.09818]: 48L, d=8192, 64H (GQA kv=8),
d_ff=22016, vocab 65536 — early fusion: VQ image tokens are ordinary ids in
the shared vocabulary (the VQ-VAE tokenizer is the stubbed frontend;
``input_specs`` supplies interleaved text+image token ids). qk-norm."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    supports_long_context=False,  # pure full attention
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    q_chunk=64,
    kv_chunk=64,
)
