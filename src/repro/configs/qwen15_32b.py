"""Qwen1.5 32B [hf:Qwen/Qwen1.5-0.5B family]: 64L, d=5120, 40H (MHA,
kv=40), d_ff=27392, vocab 152064, QKV bias."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    supports_long_context=False,  # pure full attention
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    q_chunk=64,
    kv_chunk=64,
)
