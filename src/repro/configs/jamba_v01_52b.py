"""Jamba v0.1 52B [arXiv:2403.19887]: 32L, d=4096, Mamba:attention 7:1
(attention at position 4 of each 8-layer block), MoE 16 experts top-2 every
other layer, 32H (GQA kv=8), d_ff=14336, vocab 65536."""
import dataclasses

from repro.configs.base import MambaParams, ModelConfig, MoEParams

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_every=2,
    moe=MoEParams(num_experts=16, top_k=2, d_ff_expert=14336),
    mamba=MambaParams(d_state=16, d_conv=4, expand=2),
    supports_long_context=True,   # SSM state is O(1); few attn layers
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    layer_pattern=("mamba", "attn"),
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    moe=MoEParams(num_experts=4, top_k=2, d_ff_expert=256),
    mamba=MambaParams(d_state=8, d_conv=4, expand=2),
    q_chunk=64,
    kv_chunk=64,
)
