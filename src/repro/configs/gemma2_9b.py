"""Gemma-2 9B [arXiv:2408.00118]: 42L, d=3584, 16H (GQA kv=8, head_dim
256), d_ff=14336, vocab 256000, alternating local(4096)/global attention,
attn logit softcap 50, final logit softcap 30, tied embeddings."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern=("attn_local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    mlp_act="gelu",
    supports_long_context=True,   # half the layers windowed; global-layer
                                  # KV sequence-sharded at 500k
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    window=32,
    q_chunk=64,
    kv_chunk=64,
)
