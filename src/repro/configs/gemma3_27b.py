"""Gemma-3 27B [hf:google/gemma-3-1b-pt family]: 62L, d=5376, 32H (GQA
kv=16), d_ff=21504, vocab 262144, 5:1 local:global interleave (window 1024),
qk-norm, tied embeddings, 128k-class context."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    layer_pattern=("attn_local",) * 5 + ("attn",),
    window=1024,
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    mlp_act="gelu",
    rope_theta=1e6,
    rope_theta_local=1e4,
    supports_long_context=True,   # 5/6 of layers are windowed; global-layer
                                  # KV is sequence-sharded at 500k
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,                 # one local + one global (pattern cycles)
    layer_pattern=("attn_local", "attn"),
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    window=32,
    q_chunk=64,
    kv_chunk=64,
)
