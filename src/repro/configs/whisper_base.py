"""Whisper base [arXiv:2212.04356]: enc-dec, 6+6L, d=512, 8H, d_ff=2048,
vocab 51865. The mel-spectrogram + conv frontend is a STUB — ``input_specs``
supplies precomputed (B, 1500, 512) frame embeddings (see DESIGN.md)."""
import dataclasses

from repro.configs.base import EncoderParams, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encoder=EncoderParams(num_layers=6, num_frames=1500),
    norm="ln",
    mlp_act="gelu",
    norm_eps=1e-5,
    supports_long_context=False,  # enc-dec ASR; 500k decode out of scope
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    encoder=EncoderParams(num_layers=2, num_frames=30),
    q_chunk=32,
    kv_chunk=32,
)
