"""RWKV-6 "Finch" 3B [arXiv:2404.05892]: 32L, d=2560, attention-free
(40 heads x 64), channel-mix d_ff=8960, vocab 65536, data-dependent decay."""
import dataclasses

from repro.configs.base import ModelConfig, RWKVParams

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern=("rwkv",),
    rwkv=RWKVParams(head_dim=64, lora_mix=32, lora_decay=64),
    supports_long_context=True,   # O(1) recurrent state
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    d_ff=256,
    vocab_size=512,
    rwkv=RWKVParams(head_dim=32, lora_mix=16, lora_decay=16),
)
