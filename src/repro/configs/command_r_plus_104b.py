"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01 family]: 64L,
d=12288, 96H (GQA kv=8), d_ff=33792, vocab 256000, no biases."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    supports_long_context=False,  # pure full attention
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    q_chunk=64,
    kv_chunk=64,
)
