"""~110M-parameter dense LM used by the end-to-end training example."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="lm-100m",
    arch_type="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    q_chunk=256,
    kv_chunk=256,
    supports_long_context=False,
)

SMOKE = dataclasses.replace(CONFIG, num_layers=2, d_model=128, num_heads=4,
                            num_kv_heads=4, head_dim=32, d_ff=256,
                            vocab_size=512)
