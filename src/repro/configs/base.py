"""Model configuration schema + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEParams:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAParams:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaParams:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class RWKVParams:
    head_dim: int = 64
    lora_mix: int = 32
    lora_decay: int = 64
    chunk: int = 32     # chunked-WKV span (see EXPERIMENTS.md §Perf it. 1)


@dataclasses.dataclass(frozen=True)
class EncoderParams:
    """Whisper-style encoder over a stubbed modality frontend: the conv/mel
    stack is replaced by precomputed frame embeddings in ``input_specs``."""
    num_layers: int
    num_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                      # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    # layer pattern, cycled across layers: attn | attn_local | mamba | rwkv
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None        # sliding window for attn_local
    moe_every: int = 0                  # 0 = dense; n = MoE on layers i%n==n-1
    moe: Optional[MoEParams] = None
    first_layer_dense_ff: int = 0       # deepseek: layer 0 keeps a dense FFN
    mla: Optional[MLAParams] = None
    mamba: Optional[MambaParams] = None
    rwkv: Optional[RWKVParams] = None
    encoder: Optional[EncoderParams] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 1e4
    rope_theta_local: float = 0.0       # gemma3: different theta for local
    tie_embeddings: bool = False
    embed_scale: bool = False           # gemma: scale embeds by sqrt(D)
    mlp_act: str = "silu"
    norm: str = "rms"                   # rms | ln
    norm_eps: float = 1e-6
    q_chunk: int = 512
    kv_chunk: int = 512
    remat: bool = True
    attn_probs_bf16: bool = False   # beyond-paper: bf16 attention probs
                                    # (halves PV-einsum read traffic)
    # which input shapes this arch supports for decode; long_500k needs a
    # sub-quadratic/windowed stack (see DESIGN.md §shape-skips)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def layer_is_moe(self, i: int) -> bool:
        if self.moe_every == 0 or self.moe is None:
            return False
        if i == 0 and self.first_layer_dense_ff:
            return False
        return i % self.moe_every == self.moe_every - 1

    def layer_ff(self, i: int) -> int:
        if i == 0 and self.first_layer_dense_ff:
            return self.first_layer_dense_ff
        return self.d_ff


_REGISTRY = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "whisper-base": "repro.configs.whisper_base",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "lm-100m": "repro.configs.lm_100m",
}

ASSIGNED_ARCHS = [
    "mixtral-8x22b", "gemma3-27b", "whisper-base", "jamba-v0.1-52b",
    "deepseek-v2-236b", "command-r-plus-104b", "qwen1.5-32b",
    "chameleon-34b", "gemma2-9b", "rwkv6-3b",
]


def list_archs():
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    """Full-size config for ``--arch <name>``."""
    mod = importlib.import_module(_REGISTRY[name])
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant (<= 2 layers, d_model <= 512, <= 4
    experts) for CPU smoke tests."""
    mod = importlib.import_module(_REGISTRY[name])
    return mod.SMOKE
