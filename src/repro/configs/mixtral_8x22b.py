"""Mixtral 8x22B [arXiv:2401.04088]: 56L, d=6144, 48H (GQA kv=8), 8 experts
top-2 (d_ff_expert=16384), vocab 32768, sliding-window attention."""
import dataclasses

from repro.configs.base import ModelConfig, MoEParams

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    layer_pattern=("attn_local",),
    window=4096,
    moe_every=1,
    moe=MoEParams(num_experts=8, top_k=2, d_ff_expert=16384),
    rope_theta=1e6,
    supports_long_context=True,   # SWA: ring cache stays at `window`
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    window=64,
    moe=MoEParams(num_experts=4, top_k=2, d_ff_expert=256),
    q_chunk=64,
    kv_chunk=64,
)
