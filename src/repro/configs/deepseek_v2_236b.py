"""DeepSeek-V2 236B [arXiv:2405.04434]: 60L, d=5120, 128H with MLA
(kv_lora=512, q_lora=1536, rope/nope head dims 64/128), 2 shared + 160
routed experts top-6 (d_ff_expert=1536), first layer dense (d_ff=12288),
vocab 102400."""
import dataclasses

from repro.configs.base import MLAParams, ModelConfig, MoEParams

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    mla=MLAParams(q_lora=1536, kv_lora=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe_every=1,
    moe=MoEParams(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2),
    first_layer_dense_ff=12288,
    supports_long_context=False,  # MLA is full attention over the cache
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=64,
    vocab_size=512,
    mla=MLAParams(q_lora=64, kv_lora=32, rope_head_dim=16,
                  nope_head_dim=32, v_head_dim=32),
    moe=MoEParams(num_experts=4, top_k=2, d_ff_expert=64, num_shared=1),
    first_layer_dense_ff=128,
    q_chunk=64,
    kv_chunk=64,
)
