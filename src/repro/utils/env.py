"""The central (and only) ``os.environ`` accessor for ``src/repro``.

Every runtime flag the package reads from the environment resolves
through here — the ``env-read`` lint rule in ``repro.analysis`` forbids
``os.environ``/``os.getenv`` anywhere else under ``src/repro``, so flag
semantics (accepted spellings, validation errors, trace-time resolution)
can't fork per call site.

Flags:

``REPRO_PALLAS_INTERPRET``
    Overrides the backend autodetection for Pallas interpret mode in
    either direction (default: interpret everywhere except on a real TPU
    backend). ``1``/``true``/``yes``/``on`` forces interpret mode — e.g.
    to debug kernel numerics ON a TPU — and ``0``/``false``/``no``/``off``
    forces compiled kernels.

``REPRO_USE_KERNELS``
    ``0`` forces the pure-jnp reference oracle for EVERY op regardless
    of the caller's ``use_kernels`` flag — the CI matrix runs the whole
    tier-1 suite this way to enforce kernel/ref parity. ``1``/unset
    keeps the caller's flag (kernels by default).

No jax import at module scope: :func:`force_host_device_count` must be
callable BEFORE jax first initializes (device counts lock on first use).
"""
from __future__ import annotations

import os
from typing import Optional

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def env_flag(name: str, *, context: str = "") -> Optional[bool]:
    """Validated tri-state boolean env flag: True / False / None (unset).

    Any other spelling raises — a typo'd flag silently falling back to a
    default is how parity legs end up not testing what they claim."""
    env = os.environ.get(name, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    if env:
        raise ValueError(
            f"{name}={env!r}: expected one of {_TRUE + _FALSE} "
            f"(or unset{' ' + context if context else ''})")
    return None


def pallas_interpret() -> bool:
    """``REPRO_PALLAS_INTERPRET``, defaulting to backend autodetection
    (interpret everywhere except a real TPU). Resolved at trace time."""
    flag = env_flag("REPRO_PALLAS_INTERPRET",
                    context="for backend autodetection")
    if flag is not None:
        return flag
    import jax  # deferred: keep this module importable pre-jax-init
    return jax.default_backend() != "tpu"


def kernels_enabled() -> bool:
    """``REPRO_USE_KERNELS``: ``0`` forces the pure-jnp reference oracle
    everywhere (the CI parity matrix leg); ``1``/unset keeps each
    caller's ``use_kernels`` flag."""
    flag = env_flag("REPRO_USE_KERNELS",
                    context="to keep the caller's flag")
    return True if flag is None else flag


def force_host_device_count(n: int, *, platform: str = "cpu") -> None:
    """Expose ``n`` fake host devices (and default to ``platform``).

    Must run before jax first initializes — jax locks the device count
    on first use. Prepends to any caller-provided ``XLA_FLAGS`` so an
    explicit outer setting still wins."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(n)} "
        + os.environ.get("XLA_FLAGS", ""))
    if platform:
        os.environ.setdefault("JAX_PLATFORMS", platform)
