from repro.utils.pytree import tree_bytes, tree_count, tree_map_with_path_str
from repro.utils.sharding import choose_fsdp_dim, leaf_fsdp_spec

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_map_with_path_str",
    "choose_fsdp_dim",
    "leaf_fsdp_spec",
]
