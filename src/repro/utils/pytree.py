"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements across all array leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all array leaves (uses leaf dtype)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_map_with_path_str(fn, tree):
    """tree_map where fn receives (path_string, leaf)."""

    def _fn(path, leaf):
        return fn(jax.tree_util.keystr(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def tree_flatten_with_path_strs(tree):
    """tree_flatten returning ([(path_string, leaf), ...], treedef) in the
    canonical leaf order (the order ``tree_leaves`` / ``tree_unflatten``
    use), so callers can build positional layouts keyed by path."""
    pairs, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return ([(jax.tree_util.keystr(p), leaf) for p, leaf in pairs], treedef)
