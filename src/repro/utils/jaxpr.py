"""Jaxpr introspection helpers.

``collective_axis_counts`` walks a (closed) jaxpr — recursing into pjit /
shard_map / scan / custom-vjp sub-jaxprs — and tallies collective
primitives BY AXIS NAME. The hierarchical-exchange tests and benchmarks
use it to prove the quantized all_to_all/all_gather run only over the
inter-pod axis while the intra-pod axis carries full-precision
reduce_scatter/all_gather: string-matching on jaxpr pretty-printing is
brittle across jax versions, the eqn walk is not.
"""
from __future__ import annotations

from collections import Counter
from typing import Tuple

COLLECTIVE_PRIMS = ("all_to_all", "all_gather", "psum_scatter",
                    "reduce_scatter", "psum", "pmean", "ppermute")


def _sub_jaxprs(v):
    if hasattr(v, "jaxpr") and hasattr(getattr(v, "jaxpr"), "eqns"):
        return [v.jaxpr]                      # ClosedJaxpr
    if hasattr(v, "eqns"):
        return [v]                            # raw Jaxpr
    if isinstance(v, (tuple, list)):
        out = []
        for u in v:
            out.extend(_sub_jaxprs(u))
        return out
    return []


def collective_axis_counts(closed) -> Counter:
    """Counter mapping ``(primitive_name, axis_names_tuple)`` -> count of
    eqns, over the whole jaxpr including nested sub-jaxprs. ``closed`` is
    what ``jax.make_jaxpr(fn)(*args)`` returns."""
    counts: Counter = Counter()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in COLLECTIVE_PRIMS:
                ax = eqn.params.get("axis_name",
                                    eqn.params.get("axes"))
                if isinstance(ax, (tuple, list)):
                    ax = tuple(ax)
                else:
                    ax = (ax,)
                counts[(eqn.primitive.name, ax)] += 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)

    walk(closed.jaxpr)
    return counts


def axis_collectives(counts: Counter, prim: str,
                     axes: Tuple[str, ...]) -> int:
    """Total count of ``prim`` eqns whose axis tuple is exactly ``axes``."""
    return sum(n for (p, ax), n in counts.items()
               if p == prim and ax == tuple(axes))


def sized_outvar_count(closed, min_elems: int, dtype=None) -> int:
    """Count eqn OUTPUT variables (including nested sub-jaxprs) holding at
    least ``min_elems`` elements, optionally restricted to ``dtype``.

    The pipelined-exchange tests pin "no extra full-buffer
    materialization" with this: splitting the exchange into K chunks must
    not introduce additional full-buffer-sized f32 intermediates beyond
    what the single-shot schedule already writes."""
    count = 0

    def walk(jaxpr):
        nonlocal count
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None or not getattr(aval, "shape", None):
                    continue
                if dtype is not None and aval.dtype != dtype:
                    continue
                size = 1
                for d in aval.shape:
                    size *= int(d)
                if size >= min_elems:
                    count += 1
            for p in eqn.params.values():
                for sub in _sub_jaxprs(p):
                    walk(sub)

    walk(closed.jaxpr)
    return count
