"""Jaxpr introspection helpers (compat shim).

``collective_axis_counts`` tallies collective primitives BY AXIS NAME
over a whole (closed) jaxpr; ``sized_outvar_count`` pins "no extra
full-buffer materialization". Both now live in
``repro.analysis.stats`` on top of the ONE shared sub-jaxpr traversal
(``repro.analysis.traversal``) that also backs ``launch/hlo_cost.py``
and the ``repro.analysis`` invariant rules — this module re-exports
them for the existing tests/benchmarks import path.
"""
from __future__ import annotations

from repro.analysis.stats import (COLLECTIVE_PRIMS, axis_collectives,
                                  collective_axis_counts, eqn_axes,
                                  sized_outvar_count)

__all__ = ["COLLECTIVE_PRIMS", "axis_collectives",
           "collective_axis_counts", "eqn_axes", "sized_outvar_count"]
