"""JAX version compatibility shims.

The codebase is written against the modern ``jax.shard_map`` API
(keyword-only ``mesh``/``axis_names``/``check_vma``). Older JAX releases
(<= 0.4.x) only ship ``jax.experimental.shard_map.shard_map`` with the
positional ``(f, mesh, in_specs, out_specs, check_rep, auto)`` signature —
and their partial-auto mode (some axes manual, some left to the SPMD
partitioner) is unusable in practice: closure constants, typed PRNG keys,
``axis_index``/``all_gather``/``all_to_all`` and any ``lax.scan`` in the
body all abort XLA's partitioner with manual-subgroup sharding errors.

So on legacy JAX :func:`shard_map` runs FULL-manual over every mesh axis
instead: axes not listed in ``axis_names`` become manual-but-unused, with
each device along them holding a replicated copy. That is semantically
equivalent for this repo — the model's ``shard()`` sharding hints already
degrade to no-ops on legacy JAX (``get_abstract_mesh`` does not exist), so
the auto axes never carried computation there anyway; they only do on
modern JAX, where the native partial-auto path is used.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax

_HAS_NATIVE = hasattr(jax, "shard_map")

# Old-style shard_map needs the concrete mesh even when nested inside
# another shard_map (the new API infers it from context). Our wrappers push
# the mesh here while tracing their body so nested compat calls can pick
# it up.
_MESH_STACK: List[Any] = []


def axis_size(name) -> int:
    """Static size of one named mesh axis (``lax.axis_size`` where it
    exists; the axis-env fallback on older releases)."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    from jax._src import core as _core

    if hasattr(_core, "get_axis_env"):
        return _core.get_axis_env().axis_size(name)
    return _core.axis_frame(name).size  # pragma: no cover - very old jax


def supports_nested_manual() -> bool:
    """Whether a shard_map over one axis can nest inside a manual region
    over other axes. The modern API handles it; on legacy the outer region
    is already full-manual over every axis (see module docstring), so
    there is nothing left to nest over."""
    return _HAS_NATIVE


def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` if available, else the experimental fallback.

    ``axis_names`` is the set of MANUAL axes (the modern convention). The
    legacy fallback promotes every mesh axis to manual (see module
    docstring for why partial-auto is not an option there).
    """
    if _HAS_NATIVE:
        kw = dict(in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            # the set conversion happens ONLY here, at the jax boundary,
            # where axis_names is genuinely membership-semantic (which
            # axes are manual). Everything order-sensitive — the
            # collectives in repro.core.comm — receives the caller's
            # ordered tuple, never this set.
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)

    from jax.experimental.shard_map import shard_map as _legacy

    mesh = mesh if mesh is not None else _current_mesh()
    if mesh is None:
        raise ValueError(
            "legacy shard_map needs an explicit mesh (no enclosing "
            "compat.shard_map context to inherit one from)")
    inner = _legacy(f, mesh, in_specs, out_specs, check_rep=check_vma)

    def wrapped(*args):
        _MESH_STACK.append(mesh)
        try:
            return inner(*args)
        finally:
            _MESH_STACK.pop()

    return wrapped


def _current_mesh() -> Optional[Any]:
    if _MESH_STACK:
        return _MESH_STACK[-1]
    try:  # pjit/legacy global-mesh context, if any
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        return mesh if mesh.axis_names else None
    except Exception:  # pragma: no cover - private-API drift
        return None
