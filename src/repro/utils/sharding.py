"""Sharding-spec helpers: choosing FSDP dims per parameter leaf.

Parameters are stored ZeRO-3 style: each leaf is sharded over the combined
data-parallel axes ``(pod, data)`` along one dimension (the "fsdp dim") and —
independently, handled by XLA auto-SPMD — over the ``model`` axis along a
tensor-parallel dim. This module picks the fsdp dim.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from jax.sharding import PartitionSpec as P

# Canonical data-parallel mesh axes, slow-to-fast (pod = inter-pod DCN,
# data = intra-pod ICI). Every dp-axis selection in the repo goes through
# dp_axis_names so the ordering can never drift between call sites.
DP_AXIS_ORDER: Tuple[str, ...] = ("pod", "data")


def dp_axis_names(mesh) -> Tuple[str, ...]:
    """The mesh's data-parallel axes as an ORDERED tuple (pod before data).

    This is THE selection the train step, the launchers, and the dry-run
    lowering all share: the hierarchical exchange splits this tuple into
    (inter, intra) halves, so a silent copy-paste drift between call sites
    would desynchronize the collective axis order across processes.
    """
    return tuple(a for a in DP_AXIS_ORDER if a in mesh.axis_names)


def choose_fsdp_dim(
    shape: Sequence[int],
    n_shards: int,
    *,
    skip_dims: Tuple[int, ...] = (),
    prefer_sizes: Tuple[int, ...] = (),
) -> Optional[int]:
    """Pick the dimension to shard ``n_shards``-ways, or None to replicate.

    Preference order: a dim whose size is in ``prefer_sizes`` (typically the
    d_model-sized dims, which exist on almost every leaf and are divisible by
    the 32-way dp sharding for all assigned architectures), then the largest
    divisible dim. Dims in ``skip_dims`` (e.g. a layer-stack leading dim) are
    never chosen.
    """
    candidates = [
        i
        for i, s in enumerate(shape)
        if i not in skip_dims and i - len(shape) not in skip_dims and s % n_shards == 0 and s > 0
    ]
    if not candidates:
        return None
    for i in candidates:
        if shape[i] in prefer_sizes:
            return i
    return max(candidates, key=lambda i: shape[i])


def spec_dp_dim(spec: P, dp_axes: Tuple[str, ...]) -> Optional[int]:
    """The dimension a PartitionSpec shards over the dp axes (in FULL leaf
    coordinates — stacked leading dims included), or None if the leaf is
    dp-replicated. This is the shard coordinate the fused fsdp exchange
    lays its group buffers out by."""
    dp = set(dp_axes)
    for i, ent in enumerate(spec):
        if ent is None:
            continue
        names = ent if isinstance(ent, (tuple, list)) else (ent,)
        if any(a in dp for a in names):
            return i
    return None


def leaf_fsdp_spec(
    shape: Sequence[int],
    n_shards: int,
    dp_axes: Tuple[str, ...],
    *,
    skip_dims: Tuple[int, ...] = (),
    prefer_sizes: Tuple[int, ...] = (),
) -> P:
    """PartitionSpec placing the combined dp axes on the chosen fsdp dim."""
    dim = choose_fsdp_dim(
        shape, n_shards, skip_dims=skip_dims, prefer_sizes=prefer_sizes
    )
    if dim is None:
        return P()
    spec = [None] * len(shape)
    spec[dim] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*spec)
