"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. The production target is TPU v5e, 256 chips per
pod as a (16, 16) (data, model) mesh; multi-pod adds a leading 2-way "pod"
axis (2 x 256 = 512 chips).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # per chip, B/s
ICI_BW = 50e9                   # per link, B/s


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Mesh over the actually-available devices (for real runs/tests)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
