"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. The production target is TPU v5e, 256 chips per
pod as a (16, 16) (data, model) mesh; multi-pod adds a leading 2-way "pod"
axis (2 x 256 = 512 chips).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # per chip, B/s
ICI_BW = 50e9                   # per link, B/s (fast intra-pod)
DCN_BW = 12.5e9                 # per host, B/s (100 Gbps inter-pod NIC —
                                # the slow link the two-level hierarchical
                                # exchange reserves quantization for)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _positive_int(name: str, value) -> int:
    if not isinstance(value, int) or isinstance(value, bool) or value < 1:
        raise ValueError(
            f"{name} must be a positive integer, got {value!r}")
    return value


def make_host_mesh(data: int | None = None, model: int = 1, *,
                   pods: int = 1):
    """Mesh over the actually-available devices (for real runs/tests).

    ``pods > 1`` adds a leading "pod" axis — the multi-pod topology the
    two-level hierarchical exchange splits into (inter=pod, intra=data).
    Every factor is validated up front so a bad launch dies with a clear
    message here instead of a downstream XLA shape failure.
    """
    n = len(jax.devices())
    model = _positive_int("model", model)
    pods = _positive_int("pods", pods)
    if n % (model * pods):
        raise ValueError(
            f"model*pods={model}*{pods} does not divide the device count "
            f"{n}; pick factors of {n}")
    if data is None:
        data = n // (model * pods)
    data = _positive_int("data", data)
    if pods * data * model != n:
        raise ValueError(
            f"mesh shape pods*data*model = {pods}*{data}*{model} = "
            f"{pods * data * model} must equal the device count {n}")
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
