"""Serving launcher: batched greedy decoding on the host mesh.

Two paths:

  dense (default)      ring-buffer bf16 cache via make_serve_step; prompt
                       prefill runs chunked through the cache-filling
                       prefill step (``--prefill-chunk N``) or token-by-
                       token through the decode path (``--prefill-chunk
                       0``, the reference loop).
  paged (--kv-quant)   the continuous-batching engine over the paged
                       quantized KV cache (``--kv-quant orq-9`` etc.;
                       ``--kv-quant bf16`` is the unquantized escape
                       hatch, greedy-identical to the dense path at equal
                       context).

Timing starts AFTER a warm-up step on a throwaway cache, and prefill /
decode throughput are reported separately. A sha256 digest of the
generated tokens is printed for scheme-equivalence smokes.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --batch 4 --prompt-len 16 --gen 32 --prefill-chunk 8
    PYTHONPATH=src python -m repro.launch.serve --smoke --kv-quant orq-9
"""
from __future__ import annotations

import argparse
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh
from repro.models import LM
from repro.serve import Engine, ServeConfig
from repro.serve.step import (make_chunked_prefill_step, make_serve_step,
                              plan_serve_sharding)


def _digest(toks: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(
        np.asarray(toks, np.int32)).tobytes()).hexdigest()


def _serve_dense(args, cfg, model, params, prompt):
    mesh = make_host_mesh()
    cache = model.init_cache(args.batch, args.max_len)
    acache = jax.eval_shape(lambda: cache)
    aparams = jax.eval_shape(lambda: params)
    plan = plan_serve_sharding(model, aparams, acache, mesh)
    step = make_serve_step(model, mesh, plan)

    if cfg.encoder:
        key = jax.random.key(args.seed + 2)
        enc = jax.random.normal(key, (args.batch, cfg.encoder.num_frames,
                                      cfg.d_model)) * 0.02
        cache = model.warm_cache(params, cache, enc.astype(jnp.bfloat16))

    chunk = args.prefill_chunk
    if chunk and not model.supports_chunked_prefill():
        print("note: arch has no chunked-prefill path (stateful/MLA "
              "layers); falling back to the token-by-token loop")
        chunk = 0
    if chunk:
        # chunked prefill writes at absolute slots (no ring wrap), so the
        # prompt must fit the smallest layer cache (window for attn_local)
        min_c = min((cfg.window if s.kind == "attn_local" else args.max_len)
                    for s in model.specs)
        if args.prompt_len > min_c:
            print(f"note: prompt {args.prompt_len} exceeds the smallest "
                  f"layer cache ({min_c}); falling back to the "
                  f"token-by-token loop")
            chunk = 0
    pstep = make_chunked_prefill_step(model, mesh, plan) if chunk else None

    # warm up (compile) on a THROWAWAY cache — the real cache is donated
    # through the step functions, so warm-up must not consume it
    warm = model.init_cache(args.batch, args.max_len)
    _, warm = step(params, warm, prompt[:, :1], jnp.int32(0))
    if pstep is not None:
        warm = model.init_cache(args.batch, args.max_len)
        _, warm = pstep(params, warm, prompt[:, :min(chunk, args.prompt_len)],
                        jnp.int32(0))
    del warm

    t0 = time.time()
    if pstep is not None:
        for off in range(0, args.prompt_len, chunk):
            logits, cache = pstep(params, cache,
                                  prompt[:, off:off + chunk],
                                  jnp.int32(off))
        logits = logits[:, -1:]
    else:
        for i in range(args.prompt_len):
            logits, cache = step(params, cache, prompt[:, i][:, None],
                                 jnp.int32(i))
    jax.block_until_ready(logits)
    t1 = time.time()
    out = [jnp.argmax(logits[:, -1], axis=-1)]
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, out[-1][:, None],
                             jnp.int32(args.prompt_len + i))
        out.append(jnp.argmax(logits[:, -1], axis=-1))
    jax.block_until_ready(out[-1])
    t2 = time.time()

    toks = np.asarray(jnp.stack(out, axis=1))
    pre_tok = args.batch * args.prompt_len
    dec_tok = args.batch * (args.gen - 1)
    print("generated:", toks[:, :16])
    print(f"prefill: {pre_tok} tokens in {t1-t0:.2f}s = "
          f"{pre_tok/max(t1-t0, 1e-9):.1f} tok/s "
          f"({'chunk ' + str(chunk) if chunk else 'decode loop'})")
    print(f"decode:  {dec_tok} tokens in {t2-t1:.2f}s = "
          f"{dec_tok/max(t2-t1, 1e-9):.1f} tok/s "
          f"(host CPU, batch {args.batch})")
    print("tokens sha256:", _digest(toks))
    return 0


def _serve_paged(args, cfg, model, params, prompt):
    page = args.page_size
    if args.max_len % page:
        raise SystemExit(f"--max-len {args.max_len} must be a multiple of "
                         f"--page-size {page}")
    scfg = ServeConfig(kv_quant=args.kv_quant, page_size=page,
                       max_batch=args.batch,
                       max_pages_per_seq=args.max_len // page,
                       prefill_chunk=args.prefill_chunk or 16)
    try:
        eng = Engine(model, params, scfg)
    except ValueError as e:
        raise SystemExit(f"--kv-quant: {e}")

    # warm-up request compiles the prefill/decode traces before timing
    eng.submit(prompt[0, :scfg.prefill_chunk + 1], max_new=2)
    eng.run()
    eng.prefill_time, eng.prefill_tokens = 0.0, 0
    eng.decode_times, eng.decode_tokens = [], 0

    rids = [eng.submit(prompt[b], max_new=args.gen)
            for b in range(args.batch)]
    res = eng.run()
    toks = np.stack([np.asarray(res[r].generated, np.int32) for r in rids])

    pre_s, dec_s = eng.prefill_time, sum(eng.decode_times)
    lat = np.asarray(eng.decode_times) * 1e3
    print("generated:", toks[:, :16])
    print(f"prefill: {eng.prefill_tokens} tokens in {pre_s:.2f}s = "
          f"{eng.prefill_tokens/max(pre_s, 1e-9):.1f} tok/s "
          f"(chunk {scfg.prefill_chunk})")
    print(f"decode:  {eng.decode_tokens} tokens in {dec_s:.2f}s = "
          f"{eng.decode_tokens/max(dec_s, 1e-9):.1f} tok/s "
          f"(kv={args.kv_quant}, batch {args.batch})")
    if len(lat):
        print(f"step latency p50 {np.percentile(lat, 50):.1f}ms "
              f"p99 {np.percentile(lat, 99):.1f}ms")
    print(f"cache bytes: {eng.cache_bytes()} "
          f"({eng.kvq.token_bytes()} per token-layer)")
    print("tokens sha256:", _digest(toks))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk size (0 = token-by-token loop)")
    ap.add_argument("--kv-quant", default="",
                    help="paged-engine KV scheme (e.g. orq-9, bingrad-b; "
                         "bf16 = unquantized pages; empty = dense path)")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = LM(cfg)
    params = jax.jit(model.init)(jax.random.key(args.seed))
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    key = jax.random.key(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    if args.kv_quant:
        return _serve_paged(args, cfg, model, params, np.asarray(prompt))
    return _serve_dense(args, cfg, model, params, prompt)


if __name__ == "__main__":
    raise SystemExit(main())
