"""Serving launcher: batched greedy decoding with a KV/state cache on the
host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, get_smoke_config, list_archs
from repro.launch.mesh import make_host_mesh
from repro.models import LM
from repro.serve.step import make_serve_step, plan_serve_sharding


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = LM(cfg)
    mesh = make_host_mesh()
    params = jax.jit(model.init)(jax.random.key(args.seed))
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    cache = model.init_cache(args.batch, args.max_len)
    acache = jax.eval_shape(lambda: cache)
    aparams = jax.eval_shape(lambda: params)
    plan = plan_serve_sharding(model, aparams, acache, mesh)
    step = make_serve_step(model, mesh, plan)

    key = jax.random.key(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    if cfg.encoder:
        enc = jax.random.normal(key, (args.batch, cfg.encoder.num_frames,
                                      cfg.d_model)) * 0.02
        cache = model.warm_cache(params, cache, enc.astype(jnp.bfloat16))

    # prefill via the decode path (host-scale models)
    tok = prompt[:, :1]
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, i][:, None],
                             jnp.int32(i))
    out = [jnp.argmax(logits[:, -1], axis=-1)]
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, out[-1][:, None],
                             jnp.int32(args.prompt_len + i))
        out.append(jnp.argmax(logits[:, -1], axis=-1))
    dt = time.time() - t0
    toks = jnp.stack(out, axis=1)
    print("generated:", toks[:, :16])
    total = args.batch * (args.prompt_len + args.gen - 1)
    print(f"{total} tokens in {dt:.2f}s = {total/dt:.1f} tok/s "
          f"(host CPU, batch {args.batch})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
