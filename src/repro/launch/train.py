"""Training launcher.

Real execution runs on the host's devices (``--mesh host``); the production
mesh is exercised via launch/dryrun.py. Examples:

    PYTHONPATH=src python -m repro.launch.train --arch lm-100m --smoke \
        --steps 100 --quant orq-9 --mode replicated --batch 8 --seq 128

    # mixed per-parameter-group policy: fp norms/biases, ORQ-9 elsewhere
    PYTHONPATH=src python -m repro.launch.train --arch lm-100m --smoke \
        --quant "norm|bias=fp,default=orq-9" --mode replicated

    # adaptive bit budget: per-group wire bits follow a schedule (and,
    # with --bit-budget, a bytes/step water-filling solve fed by the
    # fused encode's runtime statistics); see EXPERIMENTS.md
    PYTHONPATH=src python -m repro.launch.train --arch lm-100m --smoke \
        --bit-schedule "norm|bias=fp,default=orq@5..2" \
        --bit-budget 2e5 --resolve-every 25 --mode replicated
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import get_config, get_smoke_config, list_archs
from repro.core import (BitBudgetController, BitSchedule, QuantPolicy,
                        all_methods, comm)
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import LM
from repro.optim.schedule import step_decay
from repro.train import TrainConfig, make_train_step
from repro.train.step import (ScheduledTrainStep, init_state,
                              specialize_engines)


def _params_digest(params) -> str:
    """sha256 over the raw bytes of every parameter leaf (canonical tree
    order) — a bit-level run fingerprint."""
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(jax.device_get(leaf)).tobytes())
    return h.hexdigest()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    # help text and validation are derived from the scheme registry, so a
    # newly registered scheme is accepted (and advertised) automatically
    ap.add_argument(
        "--quant", default="fp", metavar="SCHEME|POLICY",
        help="quantization scheme or per-parameter-group policy string. "
             f"Schemes: {', '.join(all_methods())}. Policy grammar: "
             "'pattern=scheme[,pattern=scheme...][,default=scheme]' with "
             "regex patterns matched against parameter paths (first match "
             'wins), e.g. "norm|bias=fp,embed=bingrad-b,default=orq-9".')
    ap.add_argument(
        "--bit-schedule", default=None, metavar="SCHEDULE",
        help="adaptive bit schedule: the --quant policy grammar extended "
             "with bit-ramp tokens 'family@HI..LO', HI <= 5 (e.g. "
             "\"embed=orq@5..3,norm|bias=fp,default=orq@4..1\"); per-group "
             "wire bits follow the ramp over --steps, re-resolved every "
             "--resolve-every steps (recompile on phase boundary — bits "
             "are never traced). Mutually exclusive with --quant.")
    ap.add_argument(
        "--bit-budget", type=float, default=None, metavar="BYTES",
        help="quantized-DCN bytes/step budget: each phase water-fills "
             "bits from the ramps' LO toward the deterministic ramp "
             "value, largest marginal MSE-reduction per byte first, fed "
             "by the fused encode's runtime statistics (needs "
             "--bit-schedule)")
    ap.add_argument("--resolve-every", type=int, default=50,
                    help="bit-schedule phase length in steps")
    ap.add_argument("--bucket", type=int, default=2048)
    ap.add_argument("--clip-c", type=float, default=None)
    ap.add_argument("--mode", default="replicated",
                    choices=["replicated", "fsdp"])
    ap.add_argument("--hierarchy", default="auto",
                    choices=list(comm.HIERARCHIES),
                    help="two_level runs the quantized exchange only over "
                         "the slow inter-pod (DCN) axis after a full-"
                         "precision intra-pod mean; auto picks two_level "
                         "whenever the dp mesh has >= 2 axes; "
                         "two_level_async additionally runs --local-steps "
                         "inner steps synced only over the fast intra "
                         "axis between quantized outer syncs of the "
                         "parameter delta (DiLoCo-style; needs "
                         "--pods >= 2 and replicated mode)")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="two_level_async window H: inner steps per "
                         "quantized outer sync (H=1 is bit-identical to "
                         "two_level)")
    ap.add_argument("--outer-optimizer", default="nesterov",
                    choices=["nesterov", "sgd"],
                    help="outer optimizer applied to the window's "
                         "parameter delta at sync steps")
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--pods", type=int, default=1,
                    help="leading pod axis size of the host mesh (>1 "
                         "builds the multi-pod ('pod','data','model') "
                         "topology the two-level exchange splits)")
    ap.add_argument("--per-leaf-exchange", action="store_true",
                    help="legacy one-collective-per-leaf exchange "
                         "(default: fused flat-buffer engine)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="accumulate EF residuals (replicated mode and "
                         "fused fsdp; persisted in TrainState.ef)")
    ap.add_argument("--exchange-chunk", type=int, default=None,
                    help="cap fused-collective size (elements) for memory")
    ap.add_argument("--pipeline-chunks", type=int, default=1,
                    help="split each fused exchange into K bucket-row "
                         "chunks so chunk k's collective overlaps chunk "
                         "k+1's encode (bit-identical to K=1)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None,
                    help="save final PARAMS here (params-only snapshot)")
    ap.add_argument("--state-checkpoint", default=None,
                    help="save the FULL TrainState here (params + "
                         "optimizer + EF residuals + outer state — what "
                         "--resume restores bit-for-bit, including mid-"
                         "window two_level_async positions)")
    ap.add_argument("--checkpoint-at", type=int, default=None,
                    metavar="STEP",
                    help="write --state-checkpoint after this step instead "
                         "of at the end (the run continues): a later "
                         "--resume of it must reproduce the rest of THIS "
                         "run bit-for-bit — lr boundaries and data stream "
                         "key off the absolute step, so the comparison "
                         "run must use the same --steps")
    ap.add_argument("--resume", default=None, metavar="STATE_CKPT",
                    help="restore a --state-checkpoint and continue from "
                         "its step counter (strict load: the tree must "
                         "match the configured run exactly)")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)
    if args.checkpoint_at is not None and not args.state_checkpoint:
        ap.error("--checkpoint-at needs --state-checkpoint")

    schedule = None
    if args.bit_schedule is not None:
        if args.quant != "fp":
            ap.error("--bit-schedule and --quant are mutually exclusive "
                     "(the schedule IS the policy; put static entries in "
                     "the schedule string)")
        if args.bit_budget is not None and args.per_leaf_exchange:
            ap.error("--bit-budget needs the fused exchange (its "
                     "statistics feed) — drop --per-leaf-exchange")
        try:
            schedule = BitSchedule.parse(args.bit_schedule,
                                         bucket_size=args.bucket,
                                         clip_c=args.clip_c)
        except ValueError as e:
            ap.error(str(e))
    try:
        policy = (None if schedule is not None else
                  QuantPolicy.parse(args.quant, bucket_size=args.bucket,
                                    clip_c=args.clip_c))
    except ValueError as e:
        ap.error(str(e))

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = LM(cfg)
    try:
        mesh = make_host_mesh(model=args.model_parallel, pods=args.pods)
    except ValueError as e:
        ap.error(str(e))
    try:
        tcfg = TrainConfig(
            policy=policy,
            mode=args.mode,
            hierarchy=args.hierarchy,
            local_steps=args.local_steps,
            outer_optimizer=args.outer_optimizer,
            outer_lr=args.outer_lr,
            outer_momentum=args.outer_momentum,
            fused_exchange=not args.per_leaf_exchange,
            error_feedback=args.error_feedback,
            exchange_chunk_elems=args.exchange_chunk,
            pipeline_chunks=args.pipeline_chunks,
            # the water-filling solve is statistics-driven; the pure ramp
            # needs no feed, so skip the per-step stats fetch without it
            collect_stats=(schedule is not None
                           and args.bit_budget is not None))
    except ValueError as e:
        ap.error(str(e))
    lr_fn = step_decay(args.lr, [args.steps // 2, 3 * args.steps // 4])
    controller = None
    if schedule is not None:
        controller = BitBudgetController(
            schedule, total_steps=args.steps,
            resolve_every=args.resolve_every,
            dcn_budget_bytes=args.bit_budget)
        step_fn = ScheduledTrainStep(model, mesh, tcfg, controller, lr_fn)
        # price assignments with the SAME per-link accounting the
        # benchmarks report, from the engines AS BUILT (shared path)
        n_intra = max(1, step_fn.skeleton.n_intra)
        n_inter = max(1, step_fn.plan.n_dp // n_intra)
        # two_level_async amortizes the outer exchange over the H-step
        # window — the controller budgets the same per-step DCN spend the
        # benchmarks report
        sync_every = (args.local_steps if comm.resolve_hierarchy(
            args.hierarchy, step_fn.plan.dp_axes,
            args.local_steps) == "two_level_async" else 1)

        def cost_fn(phase_policy):
            eng = specialize_engines(step_fn.skeleton, phase_policy)
            total, _ = comm.observed_link_stats(
                eng.pex, n_intra=n_intra, n_inter=n_inter,
                sync_every=sync_every)
            return total["dcn_q_bytes"]

        controller.cost_fn = cost_fn
        init_tcfg = step_fn.init_config
    else:
        init_tcfg = tcfg
    state = init_state(model, mesh, init_tcfg, jax.random.key(args.seed))
    if schedule is None:
        step_fn, _ = make_train_step(model, mesh, tcfg, lr_fn)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=args.seed)

    start = 0
    if args.resume:
        # strict full-state load against the freshly built state's tree:
        # params + optimizer + EF residuals + outer anchor/momentum all
        # round-trip, so a mid-window two_level_async run reproduces its
        # next outer sync bit-for-bit
        state, _ = load_checkpoint(args.resume, like=state)
        start = int(state.step)
        print(f"resumed {args.resume} at step {start}")
    history = []
    t0 = time.time()
    for i in range(start, args.steps):
        batch = data.batch(i)
        state, metrics = step_fn(state, batch, jax.random.key(args.seed))
        if args.state_checkpoint and args.checkpoint_at == i + 1:
            save_checkpoint(args.state_checkpoint, state,
                            step=int(state.step))
            print(f"state checkpoint -> {args.state_checkpoint} "
                  f"at step {i + 1}")
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            row = {"step": i, "loss": loss,
                   "nll": float(metrics["nll"]),
                   "lr": float(metrics["lr"])}
            bits = ""
            if controller is not None:
                row["bits"] = list(step_fn.last_assignment)
                bits = " bits " + ",".join(
                    "fp" if b is None else str(b) for b in row["bits"])
            history.append(row)
            print(f"step {i:5d} loss {loss:.4f}{bits} "
                  f"({(time.time()-t0)/(i-start+1):.2f}s/step)")
    # bit-level fingerprint of the final parameters: two runs of an
    # exchange schedule that is supposed to be bit-identical (e.g.
    # --pipeline-chunks K vs 1) must print the same digest
    digest = _params_digest(state.params)
    print("params sha256", digest)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state.params,
                        step=int(state.step))
        print("checkpoint ->", args.checkpoint)
    if args.state_checkpoint and args.checkpoint_at is None:
        save_checkpoint(args.state_checkpoint, state, step=int(state.step))
        print("state checkpoint ->", args.state_checkpoint)
    if args.metrics_out:
        out = {"history": history, "params_sha256": digest}
        if controller is not None:
            out["bit_decisions"] = controller.decisions
        with open(args.metrics_out, "w") as f:
            json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
