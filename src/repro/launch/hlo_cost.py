"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies exactly
once (verified empirically: a 10-step scanned matmul reports 1 matmul of
flops), which undercounts layer-scanned transformer programs by ~num_layers.
This module parses the partitioned HLO text structurally and multiplies
per-computation costs by each while op's ``known_trip_count`` backend
config, giving:

  * flops            — from dot ops (2 * prod(out) * contracted size);
                       matmuls dominate every assigned architecture
  * hbm_bytes        — sum of operand + output buffer bytes of non-trivial
                       instructions (an upper bound on HBM traffic: perfect
                       fusion reuse is not modelled; fusion internals are
                       not double-counted because only fusion roots appear
                       at computation level)
  * collective bytes — per kind (all-gather / all-reduce / all-to-all /
                       reduce-scatter / collective-permute), per-device
                       shard shapes, with ring-factor 2(L-1)/L≈2 applied to
                       all-reduce

All shapes in the SPMD-partitioned module are per-device shards, so every
number is per device.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.pallas import _ARITH_PRIMS  # noqa: F401  (compat)
from repro.analysis.pallas import _block_elems  # noqa: F401  (compat)
from repro.analysis.pallas import (kernel_flops, pallas_call_stats,
                                   pallas_eqn_stats)

_kernel_flops = kernel_flops  # compat alias for pre-analysis callers

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1, "pred": 1,
                "c64": 8, "c128": 16, "u4": 1, "s4": 1}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "copy", "iota", "after-all", "partition-id",
                   "replica-id"}
_COLLECTIVES = {"all-gather": 1.0, "all-reduce": 2.0, "all-to-all": 1.0,
                "reduce-scatter": 1.0, "collective-permute": 1.0}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all array shapes in a type string."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


class Instr:
    __slots__ = ("name", "otype", "op", "rest", "line")

    def __init__(self, name, otype, op, rest, line):
        self.name, self.otype, self.op = name, otype, op
        self.rest, self.line = rest, line


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in hlo.splitlines():
        # tuple types with >5 elements carry /*index=N*/ comments whose '='
        # breaks instruction parsing — strip all comments first
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped
                                       or stripped.startswith("ENTRY")):
            m = _COMP_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = comps[current]
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[current].append(
                Instr(m.group(1), m.group(2), m.group(3), m.group(4), line))
    return comps


def _shape_table(instrs: List[Instr]) -> Dict[str, str]:
    return {i.name: i.otype for i in instrs}


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.otype)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    ops = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
    if not mc or not ops:
        return 2.0 * out_elems  # fallback
    lhs_type = shapes.get(ops[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for ci in mc.group(1).split(","):
        if ci:
            idx = int(ci)
            if idx < len(dims):
                k *= dims[idx]
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        self._memo: Dict[str, dict] = {}

    def cost(self, comp: str = "__entry__") -> dict:
        if comp in self._memo:
            return self._memo[comp]
        # cycle guard: mark in-progress
        self._memo[comp] = zero = {
            "flops": 0.0, "hbm_bytes": 0.0, "transcendentals": 0.0,
            "collectives": {k: 0.0 for k in _COLLECTIVES},
            "collective_counts": {k: 0 for k in _COLLECTIVES},
        }
        instrs = self.comps.get(comp, [])
        shapes = _shape_table(instrs)
        total = dict(zero)
        total["collectives"] = dict(zero["collectives"])
        total["collective_counts"] = dict(zero["collective_counts"])
        for ins in instrs:
            out_elems, out_bytes = _shape_elems_bytes(ins.otype)
            if ins.op == "dot":
                total["flops"] += _dot_flops(ins, shapes)
            elif ins.op in ("exponential", "tanh", "log", "rsqrt", "power",
                            "sine", "cosine"):
                total["transcendentals"] += out_elems
            if ins.op not in _SKIP_BYTES_OPS:
                opnames = _OPERAND_RE.findall(ins.rest)
                in_bytes = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                               for o in opnames[:8])
                total["hbm_bytes"] += out_bytes + in_bytes
            if ins.op in _COLLECTIVES:
                _, ob = _shape_elems_bytes(ins.otype)
                opnames = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
                ib = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                         for o in opnames)
                total["collectives"][ins.op] += (_COLLECTIVES[ins.op]
                                                 * max(ib, ob))
                total["collective_counts"][ins.op] += 1
            # descend into called computations
            called = _CALL_RE.findall(ins.line)
            for grp in _BRANCH_RE.findall(ins.line):
                called += [s.strip().lstrip("%") for s in grp.split(",")]
            trips = 1
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                trips = int(tm.group(1)) if tm else 1
            for sub in called:
                if sub not in self.comps:
                    continue
                mult = trips
                sc = self.cost(sub)
                total["flops"] += mult * sc["flops"]
                if ins.op != "fusion":
                    # fusion internals never touch HBM; the fusion call
                    # site's own in/out bytes were counted above
                    total["hbm_bytes"] += mult * sc["hbm_bytes"]
                total["transcendentals"] += mult * sc["transcendentals"]
                for k in _COLLECTIVES:
                    total["collectives"][k] += mult * sc["collectives"][k]
                    total["collective_counts"][k] += (
                        mult * sc["collective_counts"][k])
        self._memo[comp] = total
        return total


def analyze(hlo: str) -> dict:
    return HloCost(hlo).cost()


# ---------------------------------------------------------------------------
# Pallas-kernel cost extraction (jaxpr-based) moved to
# ``repro.analysis.pallas`` (shared with the ``vmem-tile-budget`` rule);
# ``kernel_flops`` / ``pallas_eqn_stats`` / ``pallas_call_stats`` are
# re-exported above for existing callers of this module.
# ---------------------------------------------------------------------------
