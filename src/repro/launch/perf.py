"""§Perf variant runner: re-lower a (arch, shape) with one change and diff
the roofline terms against the stored baseline.

    PYTHONPATH=src python -m repro.launch.perf --arch whisper-base \
        --shape train_4k --variant quant_fp

Variants (hypotheses are logged in EXPERIMENTS.md §Perf):
    base          the sweep configuration (orq-9, defaults)
    quant_fp      FP gradient exchange (pre-paper baseline)
    quant_bingrad 1-bit BinGrad-b exchange (most aggressive)
    quant_orq3    3-level ORQ (2-bit wire)
    probs_bf16    bf16 attention probabilities in the PV einsum
    chunks_1k     q/kv chunk 1024 (fewer scan steps, bigger tiles)
    chunks_256    q/kv chunk 256
    noremat       disable layer-group rematerialization
    capacity_1    MoE capacity factor 1.0 (drop more, compute less)
"""
# Must precede any jax import (see dryrun.py).
from repro.utils.env import force_host_device_count

force_host_device_count(512)

import argparse
import os
import dataclasses
import json
import sys

VARIANTS = {
    "base": {},
    "quant_fp": {"quant": "fp"},
    "quant_bingrad": {"quant": "bingrad-b"},
    "quant_orq3": {"quant": "orq-3"},
    "probs_bf16": {"cfg": {"attn_probs_bf16": True}},
    "chunks_1k": {"cfg": {"q_chunk": 1024, "kv_chunk": 1024}},
    "chunks_256": {"cfg": {"q_chunk": 256, "kv_chunk": 256}},
    "noremat": {"cfg": {"remat": False}},
    "capacity_1": {},  # MoE capacity factor 1.0 (filled in main)
    # the paper's own topology: replicated params, Algorithm 2 all-reduce
    "repl_fp": {"quant": "fp", "mode": "replicated"},
    "repl_orq9": {"quant": "orq-9", "mode": "replicated"},
    "repl_orq3": {"quant": "orq-3", "mode": "replicated"},
    "repl_bingrad": {"quant": "bingrad-b", "mode": "replicated"},
    # pure 256-way DP (no TP partial-sum traffic): the cleanest view of
    # the gradient wire
    "dp256_fp": {"quant": "fp", "mode": "replicated", "mesh": (256, 1)},
    "dp256_orq9": {"quant": "orq-9", "mode": "replicated",
                   "mesh": (256, 1)},
    "dp256_bingrad": {"quant": "bingrad-b", "mode": "replicated",
                      "mesh": (256, 1)},
}


def main(argv=None):
    from repro.launch.dryrun import lower_case

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    v = VARIANTS[args.variant]
    cfg_overrides = dict(v.get("cfg", {}))
    if args.variant == "capacity_1":
        from repro.configs.base import get_config
        moe = get_config(args.arch).moe
        cfg_overrides["moe"] = dataclasses.replace(moe, capacity_factor=1.0)

    res = lower_case(args.arch, args.shape, multi_pod=args.multi_pod,
                     quant=v.get("quant", "orq-9"),
                     mode=v.get("mode", "fsdp"),
                     cfg_overrides=cfg_overrides or None,
                     mesh_shape=v.get("mesh"))
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}_{args.shape}_{args.variant}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    if "roofline" in res:
        r = res["roofline"]
        print(f"[perf] {tag}: compute={r['compute_s']:.4f}s "
              f"memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s "
              f"peak={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
        coll = res["cost"]["collective_bytes_per_device"]
        print("       wire:", {k: f"{b/2**30:.2f}GiB"
                               for k, b in coll.items() if b})
    else:
        print(res)
    return 0


if __name__ == "__main__":
    sys.exit(main())
