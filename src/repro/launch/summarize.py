"""Render the §Roofline markdown table from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.summarize [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("mesh") != args.mesh:
            continue
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])
                             if r["shape"] in ORDER else 9))

    print(f"### Roofline — mesh {args.mesh} "
          f"(terms in seconds/step; per-device)\n")
    print("| arch | shape | compute | memory† | collective | bottleneck |"
          " useful | peak GiB |")
    print("|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_err = 0
    for r in rows:
        if "skipped" in r:
            n_skip += 1
            print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                  f"SKIP (full-attn @500k) | — | — |")
            continue
        if "error" in r:
            n_err += 1
            print(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        n_ok += 1
        rf = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} "
              f"| {rf['memory_s']:.2f} | {rf['collective_s']:.3f} "
              f"| {rf['bottleneck'].replace('_s', '')} "
              f"| {min(rf['useful_flops_ratio'], 9.99):.2f} "
              f"| {r['memory']['peak_bytes_per_device'] / 2**30:.1f} |")
    print(f"\nok={n_ok} skip={n_skip} error={n_err}")
    print("\n† memory term is the trip-aware HLO bytes UPPER BOUND "
          "(launch/hlo_cost.py); deltas are comparable, absolute MFU is "
          "not implied.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
