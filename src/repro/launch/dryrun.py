"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder devices and extract roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
        --shape train_4k [--multi-pod] [--quant orq-9] [--out experiments]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init). 512 placeholder host devices cover both the single-pod
# (16x16) and multi-pod (2x16x16) production meshes.
from repro.utils.env import force_host_device_count

force_host_device_count(512)

import argparse
import os
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.core import QuantPolicy
from repro.launch import hlo_cost
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.shapes import SHAPES, input_specs, sds, shape_applicable
from repro.models import LM
from repro.serve.step import make_prefill_step, make_serve_step, \
    plan_serve_sharding
from repro.train import TrainConfig, make_train_step
from repro.train.state import TrainState
from repro.utils.pytree import tree_count
from repro.utils.sharding import dp_axis_names



def model_flops(cfg, shape, n_tokens: int) -> float:
    """MODEL_FLOPS: 6·N_active·D(tokens) for training, 2·N_active for
    forward/decode, N_active excluding unrouted experts."""
    model = LM(cfg)
    aparams = jax.eval_shape(model.init, jax.random.key(0))
    total = tree_count(aparams)
    active = total
    if cfg.moe:
        m = cfg.moe
        # routed expert leaves: (E, D, Fe) x2 + (E, Fe, D)
        expert = 3 * m.num_experts * cfg.d_model * m.d_ff_expert
        n_moe = sum(1 for i in range(cfg.num_layers) if cfg.layer_is_moe(i))
        inactive = n_moe * expert * (m.num_experts - m.top_k) / m.num_experts
        active = total - inactive
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * n_tokens, total, active


def lower_case(arch: str, shape_name: str, *, multi_pod: bool,
               quant: str, mode: str = "fsdp", hierarchy: str = "auto",
               cfg_overrides=None, mesh_shape=None):
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": skip}
    if mesh_shape is not None:  # e.g. (256, 1): pure data parallelism
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    model = LM(cfg)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            # use_kernels=False: interpret-mode Pallas lowers to a
            # scan-over-grid that the SPMD partitioner replicates; the
            # jnp path is numerically identical (tested) and partitions
            # cleanly. On real TPU the kernels run as per-shard calls.
            tcfg = TrainConfig(policy=QuantPolicy.parse(quant), mode=mode,
                               hierarchy=hierarchy, use_kernels=False)
            step_fn, plan = make_train_step(model, mesh, tcfg)
            aparams = jax.eval_shape(model.init, jax.random.key(0))
            shardings = plan.shardings(mesh)
            p_sds = jax.tree_util.tree_map(
                lambda a, s: sds(a.shape, a.dtype, s), aparams, shardings)
            from jax.sharding import NamedSharding, PartitionSpec as P
            rep = NamedSharding(mesh, P())
            state_sds = TrainState(
                params=p_sds,
                opt=jax.tree_util.tree_map(
                    lambda a, s: sds(a.shape, a.dtype, s), aparams,
                    shardings),
                step=sds((), jnp.int32, rep))
            dp = dp_axis_names(mesh)
            dp_ent = dp if len(dp) > 1 else dp[0]
            batch = input_specs(cfg, shape)
            batch_sds = {
                k: sds(v.shape, v.dtype,
                       NamedSharding(mesh, P(*([dp_ent] + [None] *
                                               (len(v.shape) - 1)))))
                for k, v in batch.items()}
            key = jax.random.key(0)
            lowered = step_fn.lower(state_sds, batch_sds, key)
        else:
            aparams = jax.eval_shape(model.init, jax.random.key(0))
            aparams = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(
                    a.shape, jnp.bfloat16 if jnp.issubdtype(
                        a.dtype, jnp.floating) else a.dtype), aparams)
            if shape.kind == "prefill":
                plan = plan_serve_sharding(model, aparams, None, mesh)
                step = make_prefill_step(model, mesh, plan)
                psh = plan.param_shardings(mesh)
                p_sds = jax.tree_util.tree_map(
                    lambda a, s: sds(a.shape, a.dtype, s), aparams, psh)
                from jax.sharding import NamedSharding, PartitionSpec as P
                dp = dp_axis_names(mesh)
                dp_ent = dp if len(dp) > 1 else dp[0]
                batch = input_specs(cfg, shape)
                batch_sds = {
                    k: sds(v.shape, v.dtype,
                           NamedSharding(mesh, P(*([dp_ent] + [None] *
                                                   (len(v.shape) - 1)))))
                    for k, v in batch.items()}
                lowered = step.lower(p_sds, batch_sds)
            else:  # decode
                seq_sharded = shape.name == "long_500k"
                acache = jax.eval_shape(
                    lambda: model.init_cache(shape.global_batch,
                                             shape.seq_len))
                plan = plan_serve_sharding(model, aparams, acache, mesh,
                                           seq_sharded=seq_sharded)
                n_dp = int(np.prod([s for a, s in zip(
                    mesh.axis_names, mesh.devices.shape) if a != "model"]))
                batch_dp = shape.global_batch % max(n_dp, 1) == 0
                step = make_serve_step(model, mesh, plan,
                                       batch_dp=batch_dp)
                psh = plan.param_shardings(mesh)
                csh = plan.cache_shardings(mesh)
                p_sds = jax.tree_util.tree_map(
                    lambda a, s: sds(a.shape, a.dtype, s), aparams, psh)
                c_sds = jax.tree_util.tree_map(
                    lambda a, s: sds(a.shape, a.dtype, s), acache, csh)
                from jax.sharding import NamedSharding, PartitionSpec as P
                dp = dp_axis_names(mesh)
                dp_ent = (dp if len(dp) > 1 else dp[0]) if batch_dp else None
                tok_sds = sds((shape.global_batch, 1), jnp.int32,
                              NamedSharding(mesh, P(dp_ent)))
                lowered = step.lower(p_sds, c_sds, tok_sds, jnp.int32(0))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware structural costs (XLA's cost_analysis counts scan
    # bodies once — see launch/hlo_cost.py)
    tc = hlo_cost.analyze(hlo)
    coll = tc["collectives"]
    coll_counts = tc["collective_counts"]

    n_chips = int(np.prod(mesh.devices.shape))
    n_tokens = shape.global_batch * (shape.seq_len
                                     if shape.kind != "decode" else 1)
    mflops, n_total, n_active = model_flops(cfg, shape, n_tokens)

    flops = float(tc["flops"])
    bytes_acc = float(tc["hbm_bytes"])
    coll_total = float(sum(coll.values()))

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant": quant,
        "mode": mode if shape.kind == "train" else "serve",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # donated buffers (state/cache) are aliased in-place
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_per_device": bytes_acc,
            "collective_bytes_per_device": coll,
            "collective_counts": coll_counts,
            # raw XLA numbers (scan bodies counted once) for reference
            "xla_flops_raw": float(cost.get("flops", 0.0)),
            "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        },
        "params": {"total": n_total, "active": n_active},
        "model_flops_total": mflops,
        "roofline": {
            # terms in seconds (per spec: per-device quantities / per-chip
            # peak — the SPMD module is the per-device program)
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / ICI_BW,
            "useful_flops_ratio": (mflops / n_chips) / max(flops, 1.0),
        },
    }
    r = result["roofline"]
    r["bottleneck"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: r[k])
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="orq-9", metavar="SCHEME|POLICY",
                    help="scheme name or QuantPolicy string (see "
                         "repro.launch.train --help for the grammar)")
    ap.add_argument("--mode", default="fsdp")
    ap.add_argument("--hierarchy", default="auto",
                    choices=["flat", "two_level", "auto"],
                    help="gradient-exchange topology on multi-pod meshes "
                         "(two_level quantizes only the inter-pod DCN hop)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for this mesh")
    args = ap.parse_args(argv)

    cases = ([(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cases:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        try:
            res = lower_case(arch, shape, multi_pod=args.multi_pod,
                             quant=args.quant, mode=args.mode,
                             hierarchy=args.hierarchy)
        except Exception as e:  # noqa: BLE001
            failures += 1
            res = {"arch": arch, "shape": shape, "error": repr(e)[:2000]}
            print(f"[FAIL] {tag}: {e!r}", file=sys.stderr)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)
        if "skipped" in res:
            print(f"[skip] {tag}: {res['skipped']}")
        elif "error" not in res:
            r = res["roofline"]
            print(f"[ok] {tag}: compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"bottleneck={r['bottleneck']} "
                  f"peak_mem={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"(compile {res['compile_s']:.0f}s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
