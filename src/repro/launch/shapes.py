"""Assigned input shapes and their ShapeDtypeStruct stand-ins."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input of this shape
    (weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.encoder:
            batch["enc_embeds"] = sds(
                (B, cfg.encoder.num_frames, cfg.d_model), jnp.float32)
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": sds((B, 1), jnp.int32),
            "pos": sds((), jnp.int32)}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if runnable; otherwise the skip reason (recorded in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention stack: 500k decode skipped "
                "(see DESIGN.md §shape-skips)")
    return None
