"""Serve a small model with batched requests through the cached decode
path (greedy sampling), demonstrating ring-buffer SWA caches and the
recurrent-state caches on an attention-free arch.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-9b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import LM
from repro.serve.step import make_serve_step, plan_serve_sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = LM(cfg)
    mesh = make_host_mesh()
    params = jax.jit(model.init)(jax.random.key(0))
    cache = model.init_cache(args.batch, args.prompt_len + args.gen)
    plan = plan_serve_sharding(model, jax.eval_shape(lambda: params),
                               jax.eval_shape(lambda: cache), mesh)
    step = make_serve_step(model, mesh, plan)

    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    logits = None
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, i][:, None],
                             jnp.int32(i))
    t_prefill = time.time() - t0
    tokens = [jnp.argmax(logits[:, -1], -1)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, tokens[-1][:, None],
                             jnp.int32(args.prompt_len + i))
        tokens.append(jnp.argmax(logits[:, -1], -1))
    t_gen = time.time() - t0
    out = jnp.stack(tokens, 1)
    n = args.batch * (args.gen - 1)
    print(f"arch={args.arch} batch={args.batch}")
    print("sample:", out[0, :24])
    print(f"prefill {args.prompt_len} steps in {t_prefill:.2f}s; "
          f"decode {n} tokens in {t_gen:.2f}s = {n / t_gen:.1f} tok/s")


if __name__ == "__main__":
    main()
