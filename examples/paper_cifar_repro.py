"""Paper-faithful single-machine repro (CIFAR setting, §5.1): a small conv
net trained with SGD+momentum 0.9, weight decay 5e-4, comparing FP /
TernGrad / ORQ-3 / ORQ-9 / BinGrad-b gradients (quantize->dequantize each
step, bucket d=2048, no clipping — exactly the paper's CIFAR protocol).
CIFAR itself is not available offline; the pipeline substitutes a
class-conditional synthetic 32x32x3 stream (see repro.data.synthetic).

    PYTHONPATH=src python examples/paper_cifar_repro.py --steps 120
"""
import argparse
import zlib

import jax
import jax.numpy as jnp

from repro.core import make_quantizer
from repro.data import cifar_like_batches
from repro.models.resnet import ResNetConfig, init_resnet, resnet_loss
from repro.optim import sgd_momentum
from repro.optim.optimizers import apply_updates

METHODS = ["fp", "terngrad", "orq-3", "orq-9", "bingrad-b"]


def train(method: str, steps: int, seed: int = 0):
    cfg = ResNetConfig(num_classes=10, width=16, blocks_per_stage=1)
    params = init_resnet(jax.random.key(seed), cfg)
    opt = sgd_momentum(momentum=0.9, weight_decay=5e-4)
    opt_state = opt.init(params)
    qz = make_quantizer(method, bucket_size=2048)
    data = cifar_like_batches(batch_size=64, seed=seed)

    @jax.jit
    def step(params, opt_state, batch, key):
        loss, grads = jax.value_and_grad(resnet_loss)(params, batch, cfg)
        if not qz.is_identity:
            grads = jax.tree_util.tree_map_with_path(
                lambda p, g: qz.qdq(
                    g.reshape(-1),
                    jax.random.fold_in(key, zlib.crc32(
                        jax.tree_util.keystr(p).encode()) & 0x7FFFFFFF)
                ).reshape(g.shape),
                grads)
        upd, opt_state = opt.update(grads, opt_state, params,
                                    jnp.float32(0.05))
        return apply_updates(params, upd), opt_state, loss

    loss = None
    for i in range(steps):
        batch = next(data)
        params, opt_state, loss = step(params, opt_state, batch,
                                       jax.random.fold_in(
                                           jax.random.key(1), i))
    # final train accuracy on a fresh batch
    batch = next(data)
    from repro.models.resnet import resnet_logits
    acc = float((jnp.argmax(resnet_logits(params, batch["images"], cfg), -1)
                 == batch["labels"]).mean())
    return float(loss), acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    print(f"{'method':10s} {'final loss':>11s} {'accuracy':>9s}")
    for m in METHODS:
        loss, acc = train(m, args.steps)
        print(f"{m:10s} {loss:11.4f} {acc:9.3f}")


if __name__ == "__main__":
    main()
