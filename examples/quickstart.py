"""Quickstart: quantize a gradient with every scheme and compare errors.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ALL_METHODS, make_quantizer, theory


def main():
    # a heavy-tailed stand-in for a real gradient
    key = jax.random.key(0)
    grad = jax.random.laplace(key, (1 << 18,)) * 0.01

    print(f"{'method':12s} {'levels':>6s} {'bits':>5s} {'exact MSE':>12s} "
          f"{'wire x':>7s} {'unbiased':>8s}")
    fp_bytes = 4 * grad.size
    for name in ALL_METHODS:
        qz = make_quantizer(name, bucket_size=2048)
        if qz.is_identity:
            print(f"{name:12s} {'-':>6s} {'32':>5s} {0.0:12.3e} "
                  f"{1.0:7.1f} {'yes':>8s}")
            continue
        mse = float(theory.scheme_mse(qz, grad))
        ratio = fp_bytes / qz.wire_bytes(grad.size)
        print(f"{name:12s} {qz.s:6d} {qz.wire_bits_per_element:5d} "
              f"{mse:12.3e} {ratio:7.1f} "
              f"{'yes' if qz.unbiased else 'no':>8s}")

    # quantize -> wire -> dequantize round trip
    qz = make_quantizer("orq-9")
    q = qz.quantize(grad, jax.random.key(1))
    words = qz.encode_wire(q)
    back = qz.dequantize(qz.decode_wire(words, q.levels, q.n))
    print(f"\norq-9 roundtrip: wire {words.size * 4 / 2**10:.0f} KiB "
          f"(fp32 {fp_bytes / 2**10:.0f} KiB), "
          f"emp. MSE {float(jnp.mean((back - grad) ** 2)):.3e}")


if __name__ == "__main__":
    main()
