"""End-to-end driver: train the ~110M-parameter LM for a few hundred steps
with quantized data-parallel gradients (Algorithm 2), comparing against FP.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --quant orq-9
    PYTHONPATH=src python examples/train_lm.py \
        --quant "norm|bias=fp,default=orq-9"      # mixed per-group policy
"""
import argparse
import time

import jax

from repro.configs.base import get_config
from repro.core import QuantPolicy
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import LM
from repro.optim.schedule import warmup_cosine
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quant", default="orq-9")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.03)
    args = ap.parse_args()

    cfg = get_config("lm-100m")
    model = LM(cfg)
    mesh = make_host_mesh()
    tcfg = TrainConfig(policy=QuantPolicy.parse(args.quant, bucket_size=2048,
                                                clip_c=2.5),
                       mode="replicated")
    lr_fn = warmup_cosine(args.lr, args.steps // 10, args.steps)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, lr_fn)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=0)
    print(f"training lm-100m ({args.quant}) for {args.steps} steps ...")
    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, data.batch(i), jax.random.key(7))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.4f}  "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    print("done.")


if __name__ == "__main__":
    main()
