"""Shared benchmark helpers: real-gradient harvesting + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM


def harvest_gradient(arch: str = "lm-100m", seq: int = 64, batch: int = 4,
                     seed: int = 0):
    """One real backprop gradient (flattened per-leaf dict) from a reduced
    model — the distribution quantizers are judged on (paper Fig. 1 uses
    ResNet-110 gradients; ours come from the transformer substrate)."""
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(seed))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       batch_size=batch, seed=seed)
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(
        params, data.batch(0))
    flat = jnp.concatenate(
        [g.reshape(-1).astype(jnp.float32)
         for g in jax.tree_util.tree_leaves(grads)])
    return flat


def time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jit-compiled fns)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
