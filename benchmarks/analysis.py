"""Rule-coverage snapshot of the invariant auditor (``repro.analysis``).

Not a timing benchmark: the emitted quantity is COVERAGE — which rules
are registered, how many bundles the matrix audit traced, and that both
the audit and the seeded-violation selftest behave (zero findings on
main; every rule fires on its seed). The committed snapshot
(``benchmarks/ANALYSIS.json``) is the ratchet: a PR that unregisters a
rule, shrinks the traced matrix, or breaks a seed fails ``--check``
against the baseline even though the test suite may still be green.

    {"schema": 1, "jax": ..., "n_findings": 0, "n_bundles": ...,
     "selftest_ok": true,
     "rules": [{"rule": ..., "kind": ..., "severity": ...,
                "protects": ..., "findings": 0}, ...]}

Usage:
    PYTHONPATH=src:. python benchmarks/analysis.py \
        [--out benchmarks/ANALYSIS_NEW.json]
    PYTHONPATH=src:. python benchmarks/analysis.py --check NEW.json \
        --baseline benchmarks/ANALYSIS.json
    PYTHONPATH=src:. python benchmarks/analysis.py --update-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import List

SCHEMA = 1
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "ANALYSIS.json")


def _audit(*extra: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *extra],
        env=env, capture_output=True, text=True, timeout=1800)


def collect() -> dict:
    """Run the full matrix audit + selftest in subprocesses (they need
    their own 8-fake-device jax) and distill the coverage snapshot."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as fh:
        path = fh.name
    try:
        audit = _audit("--check", "--json", path)
        if audit.returncode != 0:
            raise RuntimeError(
                f"matrix audit failed:\n{audit.stdout}\n{audit.stderr}")
        with open(path) as fh:
            rep = json.load(fh)
    finally:
        os.unlink(path)
    selftest = _audit("--selftest")
    return {
        "schema": SCHEMA,
        "jax": rep["jax"],
        "n_findings": rep["n_findings"],
        "n_bundles": len(rep["bundles"]),
        "selftest_ok": selftest.returncode == 0,
        "rules": rep["rules"],
    }


def check(new: dict, base: dict) -> List[str]:
    fails: List[str] = []
    if new.get("schema") != SCHEMA:
        fails.append(f"schema {new.get('schema')} != {SCHEMA}")
        return fails
    if new.get("n_findings", -1) != 0:
        fails.append(f"matrix audit has {new.get('n_findings')} finding(s) "
                     f"(must be 0 on main)")
    if not new.get("selftest_ok"):
        fails.append("selftest failed: some rule no longer fires on its "
                     "seeded violation")
    new_rules = {r["rule"] for r in new.get("rules", [])}
    lost = {r["rule"] for r in base.get("rules", [])} - new_rules
    if lost:
        fails.append(f"rule(s) unregistered vs baseline: {sorted(lost)}")
    if new.get("n_bundles", 0) < base.get("n_bundles", 0):
        fails.append(f"traced matrix shrank: {new.get('n_bundles')} < "
                     f"baseline {base.get('n_bundles')}")
    return fails


def run(emit) -> None:
    """benchmarks.run hook: one CSV row per rule + a coverage summary."""
    snap = collect()
    for r in snap["rules"]:
        emit(f"analysis/{r['rule']},0.0,"
             f"kind={r['kind']};findings={r['findings']}")
    emit(f"analysis/coverage,0.0,bundles={snap['n_bundles']};"
         f"selftest_ok={snap['selftest_ok']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--check", metavar="NEW_JSON", default=None)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.check) as fh:
            new = json.load(fh)
        with open(args.baseline) as fh:
            base = json.load(fh)
        fails = check(new, base)
        for f in fails:
            print(f"[FAIL] {f}")
        if not fails:
            print(f"[ok] coverage gate: {new['n_bundles']} bundles, "
                  f"{len(new['rules'])} rules, 0 findings")
        return 1 if fails else 0

    snap = collect()
    out = BASELINE if args.update_baseline else (
        args.out or os.path.join(os.path.dirname(BASELINE),
                                 "ANALYSIS_NEW.json"))
    with open(out, "w") as fh:
        json.dump(snap, fh, indent=1)
        fh.write("\n")
    print(f"wrote {out}: {snap['n_bundles']} bundles, "
          f"{len(snap['rules'])} rules, findings={snap['n_findings']}, "
          f"selftest_ok={snap['selftest_ok']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
