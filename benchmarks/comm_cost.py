"""Paper Table 1 + the compression-ratio column of Tables 2/5: wire bytes
and transmission time per gradient exchange, for the paper's CNNs and for
the assigned architectures, per method. Both the information-theoretic
ratio the paper quotes (32/log2 s) and the achievable packed ratio are
reported; times at the paper's 10 Gbps and at one v5e ICI link.

Also reports the fused flat-buffer exchange vs the legacy per-leaf one:
collective launches and wire bytes per worker per step (the fused engine
issues O(1) collectives regardless of leaf count — see
``repro/core/comm/exchange.py``); and the partitioned per-policy-group
exchange (``QuantPolicy``): launches + wire bytes for the recommended
mixed recipe (fp norms/biases, quantized matmuls) vs uniform fp / orq-9.
The ``fsdp_*`` rows report the fused ZeRO-3 exchange
(``core/comm/fsdp_exchange.py``): one quantized reduce-scatter per policy
group vs the per-leaf gather backward, with the sharded/replicated split
taken from the train step's own ``plan_sharding_shapes``.

The ``hier_*`` rows price the hierarchical two-level (ICI/DCN) exchange
on the 2x16x16 multi-pod production mesh (dp = pod(2) x data(16)): per-link
bytes and transmission times for flat (quantize over the combined dp axes)
vs two_level (full-precision intra-pod mean, quantized Algorithm 2 over
the pod/DCN axis only) — the quantized DCN traffic shrinks by ~1/16.

Runnable standalone for CI smoke: ``PYTHONPATH=src:. python
benchmarks/comm_cost.py --dry`` (reduced architecture set, prints the same
CSV rows).
"""
from __future__ import annotations

import argparse
import math

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.configs.base import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.core import QuantPolicy, comm, make_quantizer
from repro.models import LM

MIXED_POLICY = "norm|bias=fp,default=orq-9"   # EXPERIMENTS.md recipe
#: adaptive bit schedule the sched_* rows price (EXPERIMENTS.md)
SCHED_SPEC = "embed=orq@5..2,norm|bias=fp,default=orq@4..1"

PAPER_MODELS = {"AlexNet": 61.1e6, "VGG-19": 143.7e6, "DenseNet-161": 28.7e6,
                "GoogLeNet": 13.0e6, "ResNet-50": 25.6e6}
METHODS = ["fp", "signsgd", "bingrad-b", "terngrad", "orq-3", "qsgd-5",
           "orq-5", "qsgd-9", "orq-9"]
WORKERS = 4     # the paper's ImageNet runs use 4 workers


def _leaf_traces(cfg):
    """(model, abstract shapes, [(gather-path, size), ...]) — ONE abstract
    init trace per arch, shared by every accounting row."""
    model = LM(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    paths = jax.tree_util.tree_leaves(model.param_paths(shapes))
    sizes = [int(np.prod(x.shape))
             for x in jax.tree_util.tree_leaves(shapes)]
    return model, shapes, list(zip(paths, sizes))


def fsdp_policy_rows(emit, model, shapes, path_sizes, tag: str):
    """Fused fsdp (ZeRO-3) exchange for the mixed recipe: O(#groups)
    launches + reduce-scatter wire bytes vs the per-leaf gather backward
    (one exchange per leaf). Sharded-vs-replicated split comes from the
    same planner the train step uses (``plan_sharding_shapes``)."""
    from repro.train.step import plan_sharding_shapes
    plan = plan_sharding_shapes(model, shapes, dp_axes=("data",),
                                axis_sizes={"data": WORKERS, "model": 1})
    sharded = {p for p, d in plan.full_shard_dims().items() if d is not None}
    policy = QuantPolicy.parse(MIXED_POLICY, bucket_size=512)
    launches, bytes_, labels = comm.policy_stats(
        policy, path_sizes, WORKERS, sharded_paths=sharded)
    # per-leaf fsdp: every leaf pays its own exchange (RS if sharded,
    # full Algorithm 2 all-reduce otherwise)
    pl_launches, pl_bytes = 0, 0.0
    for path, size in path_sizes:
        l, b, _ = comm.policy_stats(policy, [(path, size)], WORKERS,
                                    sharded_paths=sharded)
        pl_launches += l
        pl_bytes += b
    emit(csv_row(
        f"table1_comm/fsdp_{tag}", 0.0,
        f"policy={MIXED_POLICY.replace(',', ' ')};"
        f"leaves={len(path_sizes)};sharded_leaves={len(sharded)};"
        f"groups={len(labels)};launches_fused={launches};"
        f"launches_perleaf={pl_launches};"
        f"wire_fused={bytes_/2**20:.2f}MiB;"
        f"wire_perleaf={pl_bytes/2**20:.2f}MiB;"
        f"wire_saved_pct={100*(1-bytes_/pl_bytes):.1f}"))


def hierarchy_rows(emit, path_sizes, tag: str):
    """Flat vs two-level per-link cost on the 2x16x16 multi-pod mesh:
    ICI (fast intra-pod) vs DCN (slow inter-pod) bytes per worker per
    exchange, with times at the launch/mesh.py link bandwidths. The
    acceptance bar is >= 4x fewer QUANTIZED DCN bytes for two_level."""
    from repro.launch.mesh import DCN_BW, ICI_BW
    n_inter, n_intra = 2, 16          # pod x data of the 2x16x16 mesh
    n = sum(s for _, s in path_sizes)
    policy = QuantPolicy.parse(MIXED_POLICY, bucket_size=512)
    rows = {}
    for mode, two in [("flat", False), ("two_level", True)]:
        st = comm.link_stats(make_quantizer("orq-9", bucket_size=512), n,
                             n_intra=n_intra, n_inter=n_inter,
                             two_level=two)
        pst, _ = comm.policy_link_stats(policy, path_sizes,
                                        n_intra=n_intra, n_inter=n_inter,
                                        two_level=two)
        rows[mode] = st
        emit(csv_row(
            f"table1_comm/hier_{tag}_{mode}", 0.0,
            f"mesh=2x16x16;dp=pod2*data16;scheme=orq-9;"
            f"ici={st['ici_bytes']/2**20:.2f}MiB;"
            f"dcn={st['dcn_bytes']/2**20:.2f}MiB;"
            f"dcn_quant={st['dcn_q_bytes']/2**20:.2f}MiB;"
            f"t_ici={st['ici_bytes']/ICI_BW*1e3:.2f}ms;"
            f"t_dcn={st['dcn_bytes']/DCN_BW*1e3:.2f}ms;"
            f"launches={int(st['launches'])};"
            f"mixed_policy_dcn_quant={pst['dcn_q_bytes']/2**20:.2f}MiB"))
    ratio = (rows["flat"]["dcn_q_bytes"]
             / max(rows["two_level"]["dcn_q_bytes"], 1.0))
    emit(csv_row(
        f"table1_comm/hier_{tag}_dcn_saving", 0.0,
        f"dcn_quant_flat_over_two_level={ratio:.1f}x;"
        f"pass_4x={'yes' if ratio >= 4.0 else 'NO'}"))
    # temporal tier on top of the spatial one: two_level_async(H) pays the
    # quantized outer exchange once per H-step window, so the PER-STEP
    # quantized DCN bytes drop exactly H-fold (the inner fp intra
    # all-reduce it adds rides the fast ICI links only)
    for h in (4, 8):
        st = comm.link_stats(make_quantizer("orq-9", bucket_size=512), n,
                             n_intra=n_intra, n_inter=n_inter,
                             two_level=True, sync_every=h)
        pst, _ = comm.policy_link_stats(policy, path_sizes,
                                        n_intra=n_intra, n_inter=n_inter,
                                        two_level=True, sync_every=h)
        hratio = rows["two_level"]["dcn_q_bytes"] / max(st["dcn_q_bytes"],
                                                        1.0)
        emit(csv_row(
            f"table1_comm/hier_{tag}_async_h{h}", 0.0,
            f"mesh=2x16x16;dp=pod2*data16;scheme=orq-9;local_steps={h};"
            f"ici={st['ici_bytes']/2**20:.2f}MiB;"
            f"dcn={st['dcn_bytes']/2**20:.2f}MiB;"
            f"dcn_quant={st['dcn_q_bytes']/2**20:.2f}MiB;"
            f"t_ici={st['ici_bytes']/ICI_BW*1e3:.2f}ms;"
            f"t_dcn={st['dcn_bytes']/DCN_BW*1e3:.2f}ms;"
            f"launches={st['launches']:.2f};"
            f"mixed_policy_dcn_quant={pst['dcn_q_bytes']/2**20:.4f}MiB;"
            f"dcn_quant_two_level_over_async={hratio:.2f}x;"
            f"pass_hx={'yes' if 0.9 * h <= hratio <= 1.1 * h else 'NO'}"))


def schedule_rows(emit, path_sizes, tag: str):
    """Adaptive bit schedule on the 2x16x16 two-level mesh: one row per
    PHASE (each phase's materialized static policy priced through the
    same ``policy_link_stats`` path every other row uses — the shared
    accounting the ``BitBudgetController`` cost_fn goes through too) and
    one amortized bytes/step row vs the schedule's static HI/LO endpoint
    policies. The ramp's win is the amortized column: early steps pay
    near-HI bytes, late steps near-LO."""
    from repro.core.policy import BitSchedule
    n_inter, n_intra = 2, 16
    total_steps, resolve_every = 1000, 250
    sched = BitSchedule.parse(SCHED_SPEC, bucket_size=512)
    phases = sched.phases(total_steps, resolve_every)
    amortized = 0.0
    for i, (start, a) in enumerate(phases):
        end = phases[i + 1][0] if i + 1 < len(phases) else total_steps
        pst, _ = comm.policy_link_stats(
            sched.policy_at(a), path_sizes, n_intra=n_intra,
            n_inter=n_inter, two_level=True)
        amortized += pst["dcn_q_bytes"] * (end - start) / total_steps
        bits = ",".join("fp" if b is None else str(b) for b in a)
        emit(csv_row(
            f"table1_comm/sched_{tag}_phase{start}", 0.0,
            f"bits={bits};steps={start}..{end};"
            f"dcn_quant={pst['dcn_q_bytes']/2**20:.2f}MiB;"
            f"launches={int(pst['launches'])}"))
    ends = {}
    for name, a in [("hi", sched.ceil_assignment()),
                    ("lo", sched.floor_assignment())]:
        pst, _ = comm.policy_link_stats(
            sched.policy_at(a), path_sizes, n_intra=n_intra,
            n_inter=n_inter, two_level=True)
        ends[name] = pst["dcn_q_bytes"]
    emit(csv_row(
        f"table1_comm/sched_{tag}_amortized", 0.0,
        f"schedule={SCHED_SPEC.replace(',', ' ')};phases={len(phases)};"
        f"dcn_quant_per_step={amortized/2**20:.2f}MiB;"
        f"static_hi={ends['hi']/2**20:.2f}MiB;"
        f"static_lo={ends['lo']/2**20:.2f}MiB;"
        f"saved_vs_hi_pct={100*(1-amortized/max(ends['hi'],1.0)):.1f}"))


def policy_vs_uniform(emit, path_sizes, tag: str):
    """Partitioned per-group exchange for the mixed recipe vs uniform fp /
    orq-9: per-group launches and wire bytes per worker."""
    n = sum(s for _, s in path_sizes)
    policy = QuantPolicy.parse(MIXED_POLICY, bucket_size=512)
    launches, bytes_, labels = comm.policy_stats(policy, path_sizes, WORKERS)
    sizes = [s for _, s in path_sizes]
    _, fp_bytes = comm.fused_stats(make_quantizer("fp"), sizes, WORKERS)
    qz = make_quantizer("orq-9", bucket_size=512)
    u_launch, u_bytes = comm.fused_stats(qz, sizes, WORKERS)
    fp_frac = sum(s for p, s in path_sizes
                  if policy.resolve(p).name == "fp") / n
    emit(csv_row(
        f"table1_comm/policy_{tag}", 0.0,
        f"policy={MIXED_POLICY.replace(',', ' ')};"
        f"groups={len(labels)};launches={launches};"
        f"launches_uniform={u_launch};fp_leaf_frac={100*fp_frac:.2f}pct;"
        f"wire={bytes_/2**20:.2f}MiB;wire_uniform_orq9={u_bytes/2**20:.2f}MiB;"
        f"wire_fp={fp_bytes/2**20:.2f}MiB;"
        f"saved_vs_fp_pct={100*(1-bytes_/fp_bytes):.1f}"))


def fused_vs_per_leaf(emit, sizes, tag: str):
    """Collective launches + wire bytes: fused buffer vs one exchange per
    parameter leaf, for one model's leaf sizes."""
    for m in ["terngrad", "orq-9"]:
        qz = make_quantizer(m, bucket_size=512)
        pl_launch, pl_bytes = comm.per_leaf_stats(qz, sizes, WORKERS)
        f_launch, f_bytes = comm.fused_stats(qz, sizes, WORKERS)
        emit(csv_row(
            f"table1_comm/fused_{tag}_{m}", 0.0,
            f"leaves={len(sizes)};launches_perleaf={pl_launch};"
            f"launches_fused={f_launch};"
            f"wire_perleaf={pl_bytes/2**20:.2f}MiB;"
            f"wire_fused={f_bytes/2**20:.2f}MiB;"
            f"wire_saved_pct={100*(1-f_bytes/pl_bytes):.1f}"))


def run(emit, dry: bool = False):
    # Table 1 reproduction: FP comm time at 10 Gbps
    for name, n in PAPER_MODELS.items():
        ms = n * 32 / 10e9 * 1e3
        emit(csv_row(f"table1_comm/{name}_fp", 0.0,
                     f"params={n/1e6:.1f}M;time_10gbps={ms:.0f}ms"))
    # ratios per method (paper quotes info-theoretic)
    for m in METHODS:
        qz = make_quantizer(m, bucket_size=512)
        if qz.is_identity:
            continue
        info_ratio = 32 / math.log2(qz.s)
        n = 25.6e6
        packed = qz.wire_bytes(int(n))
        emit(csv_row(f"table1_comm/ratio_{m}", 0.0,
                     f"info_x{info_ratio:.1f};packed_x{n*4/packed:.1f}"))
    # fused vs per-leaf exchange cost + mixed-policy partitioned cost
    if dry:
        model, shapes, ps = _leaf_traces(get_smoke_config("lm-100m"))
        fused_vs_per_leaf(emit, [s for _, s in ps], "lm-100m-smoke")
        policy_vs_uniform(emit, ps, "lm-100m-smoke")
        fsdp_policy_rows(emit, model, shapes, ps, "lm-100m-smoke")
        hierarchy_rows(emit, ps, "lm-100m-smoke")
        schedule_rows(emit, ps, "lm-100m-smoke")
        return
    # assigned archs: fused-vs-per-leaf cost + one full exchange per method
    # (one abstract init trace per arch, reused for both)
    for arch in ASSIGNED_ARCHS:
        model, shapes, ps = _leaf_traces(get_config(arch))
        sizes = [s for _, s in ps]
        fused_vs_per_leaf(emit, sizes, arch)
        policy_vs_uniform(emit, ps, arch)
        fsdp_policy_rows(emit, model, shapes, ps, arch)
        hierarchy_rows(emit, ps, arch)
        schedule_rows(emit, ps, arch)
        n = sum(sizes)
        for m in ["fp", "terngrad", "orq-9"]:
            qz = make_quantizer(m, bucket_size=512)
            wire = qz.wire_bytes(n)
            t_ici = wire / 50e9
            emit(csv_row(f"table1_comm/{arch}_{m}", 0.0,
                         f"params={n/1e9:.1f}B;wire={wire/2**30:.2f}GiB;"
                         f"t_ici_link={t_ici:.2f}s"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry", action="store_true",
                    help="reduced arch set (CI smoke)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(lambda row: print(row, flush=True), dry=args.dry)


if __name__ == "__main__":
    main()
