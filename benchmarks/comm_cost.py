"""Paper Table 1 + the compression-ratio column of Tables 2/5: wire bytes
and transmission time per gradient exchange, for the paper's CNNs and for
the assigned architectures, per method. Both the information-theoretic
ratio the paper quotes (32/log2 s) and the achievable packed ratio are
reported; times at the paper's 10 Gbps and at one v5e ICI link."""
from __future__ import annotations

import math

from benchmarks.common import csv_row
from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.core import make_quantizer
from repro.models import LM
from repro.utils.pytree import tree_count
import jax

PAPER_MODELS = {"AlexNet": 61.1e6, "VGG-19": 143.7e6, "DenseNet-161": 28.7e6,
                "GoogLeNet": 13.0e6, "ResNet-50": 25.6e6}
METHODS = ["fp", "signsgd", "bingrad-b", "terngrad", "orq-3", "qsgd-5",
           "orq-5", "qsgd-9", "orq-9"]


def run(emit):
    # Table 1 reproduction: FP comm time at 10 Gbps
    for name, n in PAPER_MODELS.items():
        ms = n * 32 / 10e9 * 1e3
        emit(csv_row(f"table1_comm/{name}_fp", 0.0,
                     f"params={n/1e6:.1f}M;time_10gbps={ms:.0f}ms"))
    # ratios per method (paper quotes info-theoretic)
    for m in METHODS:
        qz = make_quantizer(m, bucket_size=512)
        if qz.is_identity:
            continue
        info_ratio = 32 / math.log2(qz.s)
        n = 25.6e6
        packed = qz.wire_bytes(int(n))
        emit(csv_row(f"table1_comm/ratio_{m}", 0.0,
                     f"info_x{info_ratio:.1f};packed_x{n*4/packed:.1f}"))
    # assigned archs: one full gradient exchange per method
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        n = tree_count(jax.eval_shape(LM(cfg).init, jax.random.key(0)))
        for m in ["fp", "terngrad", "orq-9"]:
            qz = make_quantizer(m, bucket_size=512)
            wire = qz.wire_bytes(n)
            t_ici = wire / 50e9
            emit(csv_row(f"table1_comm/{arch}_{m}", 0.0,
                         f"params={n/1e9:.1f}B;wire={wire/2**30:.2f}GiB;"
                         f"t_ici_link={t_ici:.2f}s"))
