"""Paper Table 2 / Fig. 2 proxy: single-machine training convergence under
each quantization scheme (the paper's CIFAR setting: quantize->dequantize
the gradient each step, SGD+momentum). Reports final loss; the paper's
ordering (FP <= ORQ-9 < QSGD-9, ORQ-5 < QSGD-5, BinGrad-b competitive) is
asserted with tolerance.

DYNAMIC vs STATIC (``--adaptive`` / ``main``): the adaptive bit budget's
convergence gate. One ``ScheduledTrainStep`` run under a DCN-bytes/step
budget set strictly BELOW the cheapest static comparator, against static
policies at fixed bit-widths — same model, data, seeds, EF and step
count. Every run's wire cost is priced through the SAME
``policy_link_stats`` accounting (per-step quantized-DCN bytes on a
reference 4-worker link x steps). Emits ``BENCH_convergence.json``; the
committed snapshot's gate (dynamic final loss <= best static final loss
at strictly fewer total DCN bytes) is asserted by
``tests/test_bit_schedule.py``.

    PYTHONPATH=src:. python benchmarks/convergence.py --adaptive \
        [--out BENCH_convergence.json] [--steps 120]

TEMPORAL HIERARCHY (``--hier``): the two_level_async H-sweep — REAL
launcher runs (two_level baseline + H in {1, 2, 4, 8}) on an
8-fake-device pod2*data4 mesh, each priced through the same
``policy_link_stats(sync_every=H)`` accounting; merges a "hier" section
(losses, param digests, bytes/step, gate) into the snapshot. Gate: the
H=1 digest EQUALS the two_level digest (bit-identity), per-step
quantized DCN bytes strictly decreasing and tracking 1/H, losses
finite.

``--check [JSON]`` validates the COMMITTED snapshot without retraining
(re-derives every priced figure through the live accounting, recomputes
both gates) — what the CI convergence-bench job and
``python -m benchmarks.run --check`` run.
"""
from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import csv_row, time_call
from repro.configs.base import get_smoke_config
from repro.core import QuantConfig, QuantPolicy, comm
from repro.core.policy import BitBudgetController, BitSchedule
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr, step_decay
from repro.train import TrainConfig, make_train_step
from repro.train.step import ScheduledTrainStep, init_state

METHODS = ["fp", "orq-9", "qsgd-9", "linear-9", "orq-5", "qsgd-5",
           "terngrad", "orq-3", "bingrad-b", "bingrad-pb", "signsgd"]
STEPS = 40

#: the dynamic-vs-static gate setting: one schedule, static comparators
#: at its fixed bit-widths, everything else identical
DYN_SCHEDULE = "norm|bias=fp,default=orq@5..1"
STATIC_POLICIES = {
    "orq-17": "norm|bias=fp,default=orq-17",
    "orq-9": "norm|bias=fp,default=orq-9",
    "orq-5": "norm|bias=fp,default=orq-5",
}
#: reference link the accounting prices every run on (4 workers, flat)
ACC_WORKERS = 4
BUCKET = 2048
ADAPT_STEPS = 120   # the gate horizon; losses are averaged over the tail
LOSS_TAIL = 5

#: temporal-hierarchy H-sweep (``--hier``): real launcher runs on an
#: 8-fake-device pod2*data4 mesh, priced on the same per-link accounting
HIER_POLICY = "norm|bias=fp,default=orq-9"
HIER_STEPS = 40
HIER_WINDOWS = [1, 2, 4, 8]
HIER_INTRA, HIER_INTER = 4, 2


def train_once(name: str, steps: int = STEPS, seed: int = 0):
    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    tcfg = TrainConfig(policy=QuantConfig(name=name, bucket_size=2048),
                       mode="replicated")
    state = init_state(model, mesh, tcfg, jax.random.key(seed))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                       seed=seed)
    batches = [data.batch(i) for i in range(steps)]
    loss = None
    import time
    t0 = time.time()
    for i, b in enumerate(batches):
        state, m = step_fn(state, b, jax.random.key(1))
        loss = float(m["loss"])
    return loss, (time.time() - t0) / steps * 1e6


def run(emit):
    final = {}
    for name in METHODS:
        loss, us = train_once(name)
        final[name] = loss
        emit(csv_row(f"table2_convergence/{name}", us,
                     f"final_loss={loss:.4f};steps={STEPS}"))
    # qualitative Table-2 ordering with tolerance (short-run noise)
    ok = (final["orq-9"] <= final["qsgd-9"] + 0.15
          and final["orq-5"] <= final["qsgd-5"] + 0.15
          and final["fp"] <= final["orq-9"] + 0.15
          and final["orq-9"] <= final["linear-9"] + 0.15)
    emit(csv_row("table2_convergence/claims", 0.0,
                 f"ordering={'PASS' if ok else 'SOFT-FAIL'};"
                 + ";".join(f"{k}={v:.3f}" for k, v in final.items())))

# ---------------------------------------------------------------- adaptive

def _setup(seed: int = 0):
    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                      seed=seed)
    return model, mesh, data


def _path_sizes(model):
    import numpy as np
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    paths = jax.tree_util.tree_leaves(model.param_paths(shapes))
    sizes = [int(np.prod(x.shape))
             for x in jax.tree_util.tree_leaves(shapes)]
    return list(zip(paths, sizes))


def _dcn_per_step(policy, path_sizes) -> float:
    """Quantized-DCN bytes one step of this policy costs on the reference
    4-worker flat link — the single pricing path every run (static AND
    dynamic, including the controller's own cost_fn) goes through."""
    st, _ = comm.policy_link_stats(policy, path_sizes, n_intra=1,
                                   n_inter=ACC_WORKERS, two_level=False)
    return st["dcn_q_bytes"]


def _gate_lr(steps: int):
    """Paper §5 step decay (x0.1 at 1/2 and 3/4), shared by every gate
    run: the ramp's late low-bit phases coincide with the decayed-lr
    regime, where their extra quantization noise is damped — the setting
    bit ramps are designed for."""
    return step_decay(0.05, [steps // 2, 3 * steps // 4])


def _train_static(spec: str, steps: int, seed: int = 0) -> float:
    model, mesh, data = _setup(seed)
    tcfg = TrainConfig(policy=QuantPolicy.parse(spec, bucket_size=BUCKET),
                       mode="replicated", error_feedback=True)
    state = init_state(model, mesh, tcfg, jax.random.key(seed))
    step_fn, _ = make_train_step(model, mesh, tcfg, _gate_lr(steps))
    tail = []
    for i in range(steps):
        state, m = step_fn(state, data.batch(i), jax.random.key(1))
        tail = (tail + [float(m["loss"])])[-LOSS_TAIL:]
    return sum(tail) / len(tail)


def _train_dynamic(steps: int, budget: float, seed: int = 0):
    """One ScheduledTrainStep run under ``budget`` DCN-bytes/step; returns
    (final loss, total priced DCN bytes, controller decisions)."""
    model, mesh, data = _setup(seed)
    sched = BitSchedule.parse(DYN_SCHEDULE, bucket_size=BUCKET)
    ctl = BitBudgetController(sched, steps,
                              resolve_every=max(1, steps // 4),
                              dcn_budget_bytes=budget)
    tcfg = TrainConfig(mode="replicated", error_feedback=True,
                       collect_stats=True)
    step_fn = ScheduledTrainStep(model, mesh, tcfg, ctl, _gate_lr(steps))
    ps = _path_sizes(model)
    priced = {}

    def cost_fn(policy):
        return _dcn_per_step(policy, ps)

    ctl.cost_fn = cost_fn
    state = init_state(model, mesh, step_fn.init_config,
                       jax.random.key(seed))
    tail, total = [], 0.0
    for i in range(steps):
        state, m = step_fn(state, data.batch(i), jax.random.key(1))
        tail = (tail + [float(m["loss"])])[-LOSS_TAIL:]
        a = step_fn.last_assignment
        if a not in priced:
            priced[a] = _dcn_per_step(sched.policy_at(a), ps)
        total += priced[a]
    return sum(tail) / len(tail), total, ctl.decisions


def adaptive_report(steps: int = ADAPT_STEPS,
                    budget_frac: float = 1.0) -> dict:
    """The BENCH_convergence.json payload: statics, the budgeted dynamic
    run, and the gate (dynamic loss <= best static at strictly fewer
    total DCN bytes). Losses are tail means (last ``LOSS_TAIL`` steps).

    With ``budget_frac=1.0`` the bytes half of the gate holds by
    construction: the water-filling solve keeps EVERY phase's priced
    bytes <= the best static's per-step spend (same pricing path, exact
    equality at the same bits), and the ramp's late low-bit phases are
    strictly cheaper — so the dynamic total is strictly below the best
    static's. The loss half is the empirical claim the snapshot
    certifies."""
    model, _, _ = _setup()
    ps = _path_sizes(model)
    statics = {}
    for name, spec in STATIC_POLICIES.items():
        per_step = _dcn_per_step(QuantPolicy.parse(spec,
                                                   bucket_size=BUCKET), ps)
        loss = _train_static(spec, steps)
        statics[name] = {"policy": spec, "final_loss": round(loss, 6),
                         "dcn_bytes_per_step": per_step,
                         "total_dcn_bytes": per_step * steps}
        print(f"  static {name:8s} loss={loss:.4f} "
              f"bytes/step={per_step/2**20:.3f}MiB")
    best = min(statics, key=lambda k: statics[k]["final_loss"])
    budget = budget_frac * statics[best]["dcn_bytes_per_step"]
    dyn_loss, dyn_bytes, decisions = _train_dynamic(steps, budget)
    print(f"  dynamic          loss={dyn_loss:.4f} "
          f"total={dyn_bytes/2**20:.3f}MiB "
          f"bits={[d['bits'] for d in decisions]}")
    gate = {
        "best_static": best,
        "dynamic_loss_le_best_static":
            dyn_loss <= statics[best]["final_loss"],
        "dynamic_bytes_lt_best_static":
            dyn_bytes < statics[best]["total_dcn_bytes"],
    }
    return {
        "schema": 1,
        "steps": steps,
        "schedule": DYN_SCHEDULE,
        "bucket_size": BUCKET,
        "accounting": {"n_intra": 1, "n_inter": ACC_WORKERS,
                       "two_level": False, "metric": "dcn_q_bytes"},
        "budget_frac_of_best_static": budget_frac,
        "dcn_budget_bytes_per_step": budget,
        "static": statics,
        "dynamic": {"final_loss": round(dyn_loss, 6),
                    "total_dcn_bytes": dyn_bytes,
                    "decisions": decisions},
        "gate": gate,
    }


# ------------------------------------------------------- temporal hierarchy

def _hier_dcn_per_step(h: int, path_sizes) -> float:
    """Quantized-DCN bytes/step of the outer exchange on the pod2*data4
    reference mesh, amortized over the H-step window — the same
    ``sync_every`` accounting the launcher's controller cost_fn and the
    comm_cost benchmark rows use."""
    policy = QuantPolicy.parse(HIER_POLICY, bucket_size=BUCKET)
    st, _ = comm.policy_link_stats(policy, path_sizes,
                                   n_intra=HIER_INTRA, n_inter=HIER_INTER,
                                   two_level=True, sync_every=h)
    return st["dcn_q_bytes"]


def _hier_launch(hierarchy: str, local_steps: int, steps: int) -> dict:
    """One REAL launcher run (subprocess: the mesh needs its own 8 fake
    devices); returns {"final_loss", "params_sha256"}."""
    import os
    import subprocess
    import sys
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "metrics.json")
        cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
               "lm-100m", "--smoke", "--steps", str(steps), "--batch", "8",
               "--seq", "16", "--mode", "replicated", "--pods", "2",
               "--quant", HIER_POLICY, "--error-feedback", "--hierarchy",
               hierarchy, "--local-steps", str(local_steps),
               "--log-every", "1", "--metrics-out", out]
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        subprocess.run(cmd, env=env, check=True, capture_output=True)
        with open(out) as f:
            m = json.load(f)
    tail = [r["loss"] for r in m["history"][-LOSS_TAIL:]]
    return {"final_loss": round(sum(tail) / len(tail), 6),
            "params_sha256": m["params_sha256"]}


def _hier_gate(hier: dict) -> dict:
    """Recompute the hier gate booleans from a report section's recorded
    numbers (shared by the sweep and by ``--check``)."""
    import math
    tl = hier["two_level"]
    runs = hier["async"]
    hs = sorted(int(h) for h in runs)
    bytes_by_h = [runs[str(h)]["dcn_bytes_per_step"] for h in hs]
    ratio_ok = all(
        0.95 * h <= tl["dcn_bytes_per_step"] / runs[str(h)]
        ["dcn_bytes_per_step"] <= 1.05 * h for h in hs)
    return {
        "h1_bit_identical_to_two_level":
            runs["1"]["params_sha256"] == tl["params_sha256"],
        "dcn_bytes_strictly_decreasing":
            all(a > b for a, b in zip(bytes_by_h, bytes_by_h[1:])),
        "dcn_ratio_tracks_h": ratio_ok,
        "losses_finite": all(
            math.isfinite(r["final_loss"])
            for r in [tl] + [runs[str(h)] for h in hs]),
    }


def hier_report(steps: int = HIER_STEPS) -> dict:
    """The H-sweep payload merged into BENCH_convergence.json under
    "hier": a two_level baseline plus two_level_async at H in
    ``HIER_WINDOWS``, all REAL launcher runs on the pod2*data4 mesh.
    The H=1 run must be BIT-identical to two_level (same params digest:
    the degenerate window resolves to the very same program)."""
    model, _, _ = _setup()
    ps = _path_sizes(model)
    base = _hier_launch("two_level", 1, steps)
    base["dcn_bytes_per_step"] = _hier_dcn_per_step(1, ps)
    print(f"  two_level    loss={base['final_loss']:.4f} "
          f"sha={base['params_sha256'][:12]}")
    runs = {}
    for h in HIER_WINDOWS:
        r = _hier_launch("two_level_async", h, steps)
        r["dcn_bytes_per_step"] = _hier_dcn_per_step(h, ps)
        runs[str(h)] = r
        print(f"  async H={h:<2d}   loss={r['final_loss']:.4f} "
              f"bytes/step={r['dcn_bytes_per_step']/2**20:.4f}MiB "
              f"sha={r['params_sha256'][:12]}")
    hier = {
        "steps": steps,
        "policy": HIER_POLICY,
        "mesh": "pod2*data4",
        "windows": HIER_WINDOWS,
        "accounting": {"n_intra": HIER_INTRA, "n_inter": HIER_INTER,
                       "two_level": True, "metric": "dcn_q_bytes"},
        "two_level": base,
        "async": runs,
    }
    hier["gate"] = _hier_gate(hier)
    return hier


def check_report(path: str) -> bool:
    """CI validator for the COMMITTED snapshot — no training: re-derives
    every priced bytes figure through the live accounting and recomputes
    both gates from the recorded numbers, so a drifted accounting model,
    a hand-edited snapshot, or a false gate boolean all fail."""
    with open(path) as f:
        d = json.load(f)
    failures = []

    def expect(cond, msg):
        if not cond:
            failures.append(msg)

    expect(d.get("schema") == 1, f"schema != 1: {d.get('schema')}")
    model, _, _ = _setup()
    ps = _path_sizes(model)
    best = d["gate"]["best_static"]
    expect(best in d["static"], f"best_static {best!r} not recorded")
    for name, s in d["static"].items():
        priced = _dcn_per_step(
            QuantPolicy.parse(s["policy"], bucket_size=d["bucket_size"]),
            ps)
        expect(abs(priced - s["dcn_bytes_per_step"]) <= 1e-6 * priced,
               f"static {name}: recorded bytes/step "
               f"{s['dcn_bytes_per_step']} != live accounting {priced}")
        expect(abs(s["total_dcn_bytes"]
                   - s["dcn_bytes_per_step"] * d["steps"])
               <= 1e-6 * s["total_dcn_bytes"],
               f"static {name}: total != per_step * steps")
    expect(d["dynamic"]["final_loss"] <= d["static"][best]["final_loss"],
           "adaptive gate: dynamic loss > best static")
    expect(d["dynamic"]["total_dcn_bytes"]
           < d["static"][best]["total_dcn_bytes"],
           "adaptive gate: dynamic bytes >= best static")
    expect(d["gate"]["dynamic_loss_le_best_static"] is True
           and d["gate"]["dynamic_bytes_lt_best_static"] is True,
           "adaptive gate booleans not all true")
    hier = d.get("hier")
    expect(hier is not None, "no 'hier' section (run --hier to add it)")
    if hier is not None:
        rows = [("two_level", 1, hier["two_level"])] + [
            (f"async h{h}", int(h), hier["async"][str(h)])
            for h in sorted(hier["async"], key=int)]
        for name, h, r in rows:
            priced = _hier_dcn_per_step(h, ps)
            expect(abs(priced - r["dcn_bytes_per_step"]) <= 1e-6 * priced,
                   f"hier {name}: recorded bytes/step "
                   f"{r['dcn_bytes_per_step']} != live accounting "
                   f"{priced}")
        gate = _hier_gate(hier)
        expect(gate == hier["gate"],
               f"hier gate drift: recorded {hier['gate']} recomputed "
               f"{gate}")
        for k, v in gate.items():
            expect(v is True, f"hier gate {k} is {v}")
    for msg in failures:
        print(f"[check] FAIL: {msg}")
    print(f"{path}: {'PASS' if not failures else 'FAIL'} "
          f"({len(failures)} finding(s))")
    return not failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--adaptive", action="store_true",
                    help="dynamic-vs-static bit budget gate -> JSON")
    ap.add_argument("--hier", action="store_true",
                    help="two_level_async H-sweep (REAL launcher runs); "
                         "merges a 'hier' section into --out")
    ap.add_argument("--check", nargs="?", const="", default=None,
                    metavar="JSON",
                    help="validate a committed snapshot (default: the "
                         "repo's benchmarks/BENCH_convergence.json) "
                         "WITHOUT retraining; exit 1 on any failure")
    ap.add_argument("--out", default="BENCH_convergence.json")
    ap.add_argument("--steps", type=int, default=ADAPT_STEPS)
    ap.add_argument("--hier-steps", type=int, default=HIER_STEPS)
    args = ap.parse_args(argv)
    if args.check is not None:
        import os
        path = args.check or os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            "BENCH_convergence.json")
        raise SystemExit(0 if check_report(path) else 1)
    if args.hier:
        import os
        report = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                report = json.load(f)
        report["hier"] = hier_report(steps=args.hier_steps)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        g = report["hier"]["gate"]
        ok = all(v is True for v in g.values())
        print(f"wrote {args.out}; hier gate "
              f"{'PASS' if ok else 'FAIL'} ({g})")
        raise SystemExit(0 if ok else 1)
    if args.adaptive:
        report = adaptive_report(steps=args.steps)
        import os
        if os.path.exists(args.out):
            with open(args.out) as f:
                prior = json.load(f)
            if "hier" in prior:         # keep the H-sweep section
                report["hier"] = prior["hier"]
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        g = report["gate"]
        ok = (g["dynamic_loss_le_best_static"]
              and g["dynamic_bytes_lt_best_static"])
        print(f"wrote {args.out}; gate "
              f"{'PASS' if ok else 'FAIL'} (best={g['best_static']})")
        raise SystemExit(0 if ok else 1)
    run(print)


if __name__ == "__main__":
    main()
