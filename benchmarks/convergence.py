"""Paper Table 2 / Fig. 2 proxy: single-machine training convergence under
each quantization scheme (the paper's CIFAR setting: quantize->dequantize
the gradient each step, SGD+momentum). Reports final loss; the paper's
ordering (FP <= ORQ-9 < QSGD-9, ORQ-5 < QSGD-5, BinGrad-b competitive) is
asserted with tolerance."""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_call
from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

METHODS = ["fp", "orq-9", "qsgd-9", "linear-9", "orq-5", "qsgd-5",
           "terngrad", "orq-3", "bingrad-b", "bingrad-pb", "signsgd"]
STEPS = 40


def train_once(name: str, steps: int = STEPS, seed: int = 0):
    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    tcfg = TrainConfig(quant=QuantConfig(name=name, bucket_size=2048),
                       mode="replicated")
    state = init_state(model, mesh, tcfg, jax.random.key(seed))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                       seed=seed)
    batches = [data.batch(i) for i in range(steps)]
    loss = None
    import time
    t0 = time.time()
    for i, b in enumerate(batches):
        state, m = step_fn(state, b, jax.random.key(1))
        loss = float(m["loss"])
    return loss, (time.time() - t0) / steps * 1e6


def run(emit):
    final = {}
    for name in METHODS:
        loss, us = train_once(name)
        final[name] = loss
        emit(csv_row(f"table2_convergence/{name}", us,
                     f"final_loss={loss:.4f};steps={STEPS}"))
    # qualitative Table-2 ordering with tolerance (short-run noise)
    ok = (final["orq-9"] <= final["qsgd-9"] + 0.15
          and final["orq-5"] <= final["qsgd-5"] + 0.15
          and final["fp"] <= final["orq-9"] + 0.15
          and final["orq-9"] <= final["linear-9"] + 0.15)
    emit(csv_row("table2_convergence/claims", 0.0,
                 f"ordering={'PASS' if ok else 'SOFT-FAIL'};"
                 + ";".join(f"{k}={v:.3f}" for k, v in final.items())))
