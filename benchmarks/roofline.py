"""§Roofline: read the dry-run artifacts and print the per-(arch x shape)
roofline table (compute/memory/collective terms, bottleneck, useful-flops
ratio). The dry-runs themselves are produced by launch/dryrun.py."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def run(emit):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit(csv_row("roofline/none", 0.0,
                     "no dry-run artifacts; run launch/dryrun.py --all"))
        return
    n_ok = n_skip = n_err = 0
    for f in files:
        with open(f) as fh:
            res = json.load(fh)
        tag = f"{res['arch']}/{res['shape']}/{res.get('mesh','?')}"
        if "skipped" in res:
            n_skip += 1
            emit(csv_row(f"roofline/{tag}", 0.0, "SKIP:" +
                         res["skipped"][:60]))
            continue
        if "error" in res:
            n_err += 1
            emit(csv_row(f"roofline/{tag}", 0.0, "ERROR"))
            continue
        n_ok += 1
        r = res["roofline"]
        emit(csv_row(
            f"roofline/{tag}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;"
            f"bottleneck={r['bottleneck'].replace('_s','')};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"mem_GiB={res['memory']['peak_bytes_per_device']/2**30:.1f}"))
    emit(csv_row("roofline/summary", 0.0,
                 f"ok={n_ok};skip={n_skip};error={n_err}"))
