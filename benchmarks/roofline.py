"""§Roofline: read the dry-run artifacts and print the per-(arch x shape)
roofline table (compute/memory/collective terms, bottleneck, useful-flops
ratio). The dry-runs themselves are produced by launch/dryrun.py.

Also emits per-pallas_call rows for the fused one-pass quantization
kernels (``roofline/pallas/...``): VMEM footprint per grid step,
arithmetic intensity, and whether the block sizing honours the kernels'
VMEM_TILE_BYTES budget. These come from the jaxpr (launch.hlo_cost.
pallas_call_stats) — the HLO text parser cannot see interpret-mode
pallas_calls — so the PR 5/6 tiling fix is checkable in-repo without a
TPU.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")

#: (tag, scheme kwargs) — one fused kernel family per wire format:
#: round-to-nearest-or-random (orq), sigma-clipped (terngrad), bingrad
_PALLAS_SCHEMES = [
    ("orq-9", dict(method="orq", num_levels=9)),
    ("terngrad", dict(method="terngrad", clip_c=2.5)),
    ("bingrad-b", dict(method="bingrad_b")),
]

#: (nb, d) shapes: small fits one grid step; large forces row_block to
#: split the grid so the VMEM cap is visibly load-bearing
_PALLAS_SHAPES = [(64, 512), (4096, 512)]


def _pallas_rows(emit):
    import jax
    import jax.numpy as jnp

    from repro.core.comm import wire
    from repro.core.quantizers import Quantizer
    from repro.kernels.fused_encode import VMEM_TILE_BYTES
    from repro.launch.hlo_cost import pallas_call_stats

    for label, kw in _PALLAS_SCHEMES:
        for nb, d in _PALLAS_SHAPES:
            qz = Quantizer(bucket_size=d, **kw)
            bkt = jnp.ones((nb, d), jnp.float32)
            mask = jnp.ones((nb, d), jnp.float32)
            key = jax.random.key(0)

            enc = jax.make_jaxpr(
                lambda b, m, k: wire.encode(qz, b, m, k, use_kernels=True)
            )(bkt, mask, key)
            words, levels = wire.encode(qz, bkt, mask, key, use_kernels=True)
            ws = jnp.stack([words] * 4)
            lvs = jnp.stack([levels] * 4)
            dec = jax.make_jaxpr(
                lambda w, l: wire.decode_mean(qz, w, l, d, use_kernels=True)
            )(ws, lvs)

            for op, closed in (("encode", enc), ("decode", dec)):
                for st in pallas_call_stats(closed):
                    fits = st["vmem_bytes"] <= VMEM_TILE_BYTES
                    emit(csv_row(
                        f"roofline/pallas/{op}/{label}/nb{nb}xd{d}"
                        f"/{st['kernel']}",
                        0.0,
                        f"grid={'x'.join(map(str, st['grid'])) or '1'};"
                        f"vmem_KiB={st['vmem_bytes'] / 1024:.0f};"
                        f"hbm_KiB={st['hbm_bytes'] / 1024:.0f};"
                        f"ai={st['arithmetic_intensity']:.2f}flop_per_B;"
                        f"fits_vmem_tile={'yes' if fits else 'NO'}"))


def run(emit):
    _pallas_rows(emit)
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit(csv_row("roofline/none", 0.0,
                     "no dry-run artifacts; run launch/dryrun.py --all"))
        return
    n_ok = n_skip = n_err = 0
    for f in files:
        with open(f) as fh:
            res = json.load(fh)
        tag = f"{res['arch']}/{res['shape']}/{res.get('mesh','?')}"
        if "skipped" in res:
            n_skip += 1
            emit(csv_row(f"roofline/{tag}", 0.0, "SKIP:" +
                         res["skipped"][:60]))
            continue
        if "error" in res:
            n_err += 1
            emit(csv_row(f"roofline/{tag}", 0.0, "ERROR"))
            continue
        n_ok += 1
        r = res["roofline"]
        emit(csv_row(
            f"roofline/{tag}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;"
            f"bottleneck={r['bottleneck'].replace('_s','')};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"mem_GiB={res['memory']['peak_bytes_per_device']/2**30:.1f}"))
    emit(csv_row("roofline/summary", 0.0,
                 f"ok={n_ok};skip={n_skip};error={n_err}"))
