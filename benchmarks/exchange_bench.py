"""Exchange benchmark: single-shot vs PIPELINED quantized all-reduce.

Times ``GradientExchange.exchange_flat`` (the full two-phase Algorithm 2
exchange) on an 8-fake-device host mesh across ``pipeline_chunks`` values
and emits ``BENCH_exchange.json`` in a stable schema CI can diff:

    {"schema": 1, "jax": ..., "n_devices": 8, "quick": ...,
     "summary": {"<scheme>": {"best_k": ..., "best_speedup": ...,
                              "wins": <#chunk counts at least as fast
                                       as single-shot>}},
     "entries": [{"key": "exchange/terngrad/n392708/k4",
                  "scheme": "terngrad", "n": ..., "pipeline_chunks": 4,
                  "step_us": ..., "speedup_vs_single_shot": ...}, ...]}

The pipelined schedule splits the flat buffer's bucket rows into K
chunks, each with its own encode -> all_to_all -> decode (and re-quantize
-> all_gather in phase 2), bit-identical to K=1 — so the gate here is
purely about STEP TIME. Like ``kernel_bench``, timings are min-of-iters
and the gated quantity is a ratio measured in the same process
(``speedup_vs_single_shot``), so runner speed cancels. The container is
CPU-only: there is no real compute/transfer overlap, but the chunked
schedule still pays its real dispatch/layout costs while working on
cache-sized pieces — the gate protects "pipelining does not cost step
time", the TPU overlap win comes on top.

Gate (``--check``): schema intact, no errors, every scheme must show
``wins >= 2`` (pipelined step-time <= single-shot, within a small noise
allowance, at two or more chunk counts), and the per-scheme best speedup
must not regress more than ``--tolerance`` vs the committed baseline.

Usage:
    PYTHONPATH=src:. python benchmarks/exchange_bench.py [--quick]
    PYTHONPATH=src:. python benchmarks/exchange_bench.py --check NEW.json \
        --baseline benchmarks/BENCH_exchange.json [--tolerance .25]
    PYTHONPATH=src:. python benchmarks/exchange_bench.py --quick \
        --update-baseline        # refresh the committed baseline
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

SCHEMA = 1
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_exchange.json")

#: noise allowance on "pipelined <= single-shot": a chunk count counts as
#: a win when step_us <= single_shot_us * (1 + WIN_SLACK)
WIN_SLACK = 0.05

QUICK = dict(schemes=("bingrad-b", "terngrad"), n=512 * 96 * 8 - 100,
             ks=(1, 2, 4, 8), iters=5, warmup=2)
FULL = dict(schemes=("bingrad-b", "terngrad", "orq-9"), n=512 * 96 * 8 - 100,
            ks=(1, 2, 4, 8), iters=8, warmup=3)

# the timing loop runs in a subprocess: the fake 8-device view must not
# leak into the caller (same rule as tests/ and benchmarks/distributed.py)
PROG = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import comm
from repro.core.api import QuantConfig
from repro.utils.compat import shard_map

cfg = json.loads({cfg_json!r})
mesh = jax.make_mesh((8,), ("dp",))
key = jax.random.key(7)
x = jax.random.normal(jax.random.key(1), (8, cfg["n"]), jnp.float32)

def time_min(fn):
    for _ in range(cfg["warmup"]):
        jax.block_until_ready(fn(x))
    best = float("inf")
    for _ in range(cfg["iters"]):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6

entries = []
for scheme in cfg["schemes"]:
    qz = QuantConfig(name=scheme, bucket_size=512).to_quantizer()
    for k in cfg["ks"]:
        eng = comm.GradientExchange(qz, ("dp",), pipeline_chunks=k)
        fn = jax.jit(shard_map(lambda v: eng.exchange_flat(v[0], key),
                               mesh=mesh, in_specs=P("dp"), out_specs=P(),
                               check_vma=False))
        entries.append({{"scheme": scheme, "n": cfg["n"],
                         "pipeline_chunks": k,
                         "step_us": round(time_min(fn), 1)}})
print("RESULT " + json.dumps(entries))
"""


def bench(quick: bool = True) -> dict:
    import jax

    cfg = QUICK if quick else FULL
    prog = PROG.format(cfg_json=json.dumps(cfg))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                         env=env, capture_output=True, text=True,
                         timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(
            f"exchange bench subprocess failed:\n{out.stdout}\n{out.stderr}")
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    raw = json.loads(line[len("RESULT "):])

    base = {e["scheme"]: e["step_us"] for e in raw
            if e["pipeline_chunks"] == 1}
    entries, summary = [], {}
    for e in raw:
        ss = base[e["scheme"]]
        sp = round(ss / e["step_us"], 4) if e["step_us"] else 0.0
        entries.append({
            "key": (f"exchange/{e['scheme']}/n{e['n']}"
                    f"/k{e['pipeline_chunks']}"),
            "scheme": e["scheme"], "n": e["n"],
            "pipeline_chunks": e["pipeline_chunks"],
            "step_us": e["step_us"], "speedup_vs_single_shot": sp,
        })
    for scheme in {e["scheme"] for e in entries}:
        es = [e for e in entries if e["scheme"] == scheme
              and e["pipeline_chunks"] > 1]
        best = max(es, key=lambda e: e["speedup_vs_single_shot"])
        wins = sum(e["step_us"] <= base[scheme] * (1.0 + WIN_SLACK)
                   for e in es)
        summary[scheme] = {"best_k": best["pipeline_chunks"],
                           "best_speedup": best["speedup_vs_single_shot"],
                           "wins": wins}
    return {"schema": SCHEMA, "jax": jax.__version__, "n_devices": 8,
            "quick": quick, "win_slack": WIN_SLACK,
            "summary": summary, "entries": entries}


def check(new: dict, baseline: dict, tolerance: float) -> list:
    """Regression gate. Returns failure strings (empty = pass).

    Hard checks: schema version; every scheme shows ``wins >= 2`` —
    pipelined step-time at-most single-shot (within WIN_SLACK) at two or
    more chunk counts. Timing check: per-scheme best pipelined speedup
    must stay within ``tolerance`` of the baseline's."""
    fails = []
    if new.get("schema") != SCHEMA:
        fails.append(f"schema mismatch: {new.get('schema')} != {SCHEMA}")
        return fails
    if not new.get("entries"):
        return ["no entries in run"]
    for scheme, s in new.get("summary", {}).items():
        if s["wins"] < 2:
            fails.append(
                f"{scheme}: pipelined step-time beat single-shot at only "
                f"{s['wins']} chunk count(s) (need >= 2)")
        b = baseline.get("summary", {}).get(scheme)
        if b and s["best_speedup"] < b["best_speedup"] * (1.0 - tolerance):
            fails.append(
                f"{scheme}: best pipelined speedup regressed "
                f"{b['best_speedup']:.3f} -> {s['best_speedup']:.3f} "
                f"(> {tolerance:.0%} drop)")
    return fails


def run(emit) -> None:
    """benchmarks.run hook: quick pass, CSV rows + JSON artifact."""
    from benchmarks.common import csv_row

    res = bench(quick=True)
    with open("BENCH_exchange.json", "w") as fh:
        json.dump(res, fh, indent=1, sort_keys=True)
    for e in res["entries"]:
        emit(csv_row(e["key"], e["step_us"],
                     f"x{e['speedup_vs_single_shot']:.2f}_vs_single_shot"))
    emit(csv_row("exchange/json", 0.0, "wrote BENCH_exchange.json"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_exchange.json")
    ap.add_argument("--check", metavar="RUN_JSON", default=None)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.check) as fh:
            new = json.load(fh)
        with open(args.baseline) as fh:
            base = json.load(fh)
        fails = check(new, base, args.tolerance)
        for f in fails:
            print(f"FAIL {f}")
        if fails:
            sys.exit(1)
        print(f"OK {len(new['entries'])} entries; pipelined wins >= 2 "
              f"chunk counts per scheme "
              f"({os.path.basename(args.baseline)})")
        return

    res = bench(quick=args.quick)
    out = args.baseline if args.update_baseline else args.out
    with open(out, "w") as fh:
        json.dump(res, fh, indent=1, sort_keys=True)
    print(f"wrote {out} ({len(res['entries'])} entries)")
    for e in res["entries"]:
        print(f"  {e['key']}: {e['step_us'] / 1e3:.1f}ms "
              f"x{e['speedup_vs_single_shot']:.2f}")
    for scheme, s in res["summary"].items():
        print(f"  {scheme}: best k={s['best_k']} "
              f"x{s['best_speedup']:.2f}, wins={s['wins']}")


if __name__ == "__main__":
    main()
