"""Paper Table 3: sensitivity to bucket size d (128..32768). The paper's
claim: ORQ-3 degrades more slowly than TernGrad as d grows. We measure the
exact expected quantization MSE on a real gradient per bucket size."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_row, harvest_gradient
from repro.core import make_quantizer, theory

SIZES = [128, 512, 1024, 2048, 4096, 8192, 16384, 32768]


def run(emit):
    g = harvest_gradient()
    scale = float(jnp.abs(g).std()) + 1e-12
    series = {}
    for method in ["terngrad", "orq-3"]:
        series[method] = []
        for d in SIZES:
            qz = make_quantizer(method, bucket_size=d)
            mse = float(theory.scheme_mse(qz, g)) / scale ** 2
            series[method].append(mse)
            emit(csv_row(f"table3_bucket/{method}_d{d}", 0.0,
                         f"nmse={mse:.4e}"))
    # relative degradation from smallest to largest bucket
    deg = {m: series[m][-1] / series[m][0] for m in series}
    ok = (series["orq-3"][-1] < series["terngrad"][-1]
          and all(a <= b for a, b in zip(series["orq-3"],
                                         series["terngrad"])))
    emit(csv_row("table3_bucket/claims", 0.0,
                 f"orq_degrade=x{deg['orq-3']:.2f};"
                 f"terngrad_degrade=x{deg['terngrad']:.2f};"
                 f"orq_always_better={'PASS' if ok else 'FAIL'}"))
