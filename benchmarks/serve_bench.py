"""Serve benchmark: paged quantized-KV engine vs the bf16 escape hatch.

Drives the continuous-batching engine (``repro.serve.Engine``) on the
smoke arch across KV schemes and emits ``BENCH_serve.json`` in a stable
schema CI can diff:

    {"schema": 1, "jax": ..., "quick": ...,
     "ratios": {"lm-100m": {"orq-5": 0.2005, ...},      # full-size dims
                "gemma2-9b": {...}},
     "summary": {"<scheme>": {"slowdown_vs_bf16": ...,
                              "cache_ratio_smoke": ...}},
     "entries": [{"key": "serve/orq-9/b3", "scheme": "orq-9",
                  "decode_tok_s": ..., "prefill_tok_s": ...,
                  "p50_ms": ..., "p99_ms": ..., "cache_bytes": ...,
                  "token_bytes": ..., "slowdown_vs_bf16": ...,
                  "drift_mean_abs": ..., "argmax_match": ...}, ...]}

``ratios`` is pure byte math at the REAL archs' KV dims (the smoke dims
are too small to amortize the per-token level table); ``cache_bytes`` is
the measured device footprint of the smoke pools. ``drift_mean_abs`` /
``argmax_match`` compare each request's first-token logits against the
bf16 engine on the identical workload (the logit-drift accuracy note in
EXPERIMENTS.md).

Like ``kernel_bench``/``exchange_bench``, the gated timing quantity is a
ratio measured in the same process (``slowdown_vs_bf16`` — quantized
decode step time over bf16 decode step time), so runner speed cancels.

Gate (``--check``): schema intact; the full-size cache-byte ratios for
orq-5 and bingrad-b stay <= 0.25 on every arch (the PR-7 compression
criterion — deterministic math, a hard floor no baseline refresh can
ratchet away); every scheme's ``slowdown_vs_bf16`` stays under the
absolute ``MAX_SLOWDOWN`` ceiling; and it must not regress more than
``--tolerance`` (default 1.0 — step timings on shared CPU runners
jitter ~2x) vs the committed baseline.

Usage:
    PYTHONPATH=src:. python benchmarks/serve_bench.py [--quick]
    PYTHONPATH=src:. python benchmarks/serve_bench.py --check NEW.json \
        --baseline benchmarks/BENCH_serve.json [--tolerance .25]
    PYTHONPATH=src:. python benchmarks/serve_bench.py --quick \
        --update-baseline        # refresh the committed baseline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA = 1
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_serve.json")

#: schemes whose full-size cache ratio is hard-gated at <= MAX_RATIO
GATED_SCHEMES = ("orq-5", "bingrad-b")
MAX_RATIO = 0.25

#: absolute ceiling on the quantized/bf16 decode-step ratio: losing the
#: fused kernel path costs an order of magnitude, so a hard ceiling
#: catches it regardless of how noisy the baseline machine was
MAX_SLOWDOWN = 8.0

#: full-size KV dims the ratio table is computed at: (kv_heads, head_dim)
RATIO_ARCHS = {"lm-100m": (12, 64), "gemma2-9b": (8, 256)}

QUICK = dict(schemes=("bf16", "orq-9", "orq-5", "bingrad-b"),
             batch=3, prompt_len=8, max_new=16, page_size=4,
             prefill_chunk=4, iters=3)
FULL = dict(schemes=("bf16", "orq-9", "orq-5", "bingrad-b"),
            batch=3, prompt_len=16, max_new=48, page_size=4,
            prefill_chunk=4, iters=5)


def _run_engine(cfg, scheme):
    """One engine workload; returns raw timings + first-token logits."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_smoke_config
    from repro.models import LM
    from repro.serve import Engine, ServeConfig

    model = LM(get_smoke_config("lm-100m"))
    params = jax.jit(model.init)(jax.random.key(0))
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    prompts = [np.asarray(jax.random.randint(
        jax.random.key(100 + i), (cfg["prompt_len"],), 0,
        model.cfg.vocab_size), np.int32) for i in range(cfg["batch"])]

    total = cfg["prompt_len"] + cfg["max_new"]
    scfg = ServeConfig(
        kv_quant=scheme, page_size=cfg["page_size"],
        max_batch=cfg["batch"],
        max_pages_per_seq=-(-total // cfg["page_size"]),
        prefill_chunk=cfg["prefill_chunk"], record_logits=True)
    eng = Engine(model, params, scfg)

    # warm-up request compiles both traces, then metrics reset
    eng.submit(prompts[0][:cfg["prefill_chunk"]], max_new=2)
    eng.run()

    # min-of-iters (like kernel_bench/exchange_bench): traces are warm
    # after the first pass, so extra iterations only pay the tokens.
    # Content-derived seeds make every iteration bit-identical.
    best = None
    for _ in range(cfg["iters"]):
        eng.prefill_time, eng.prefill_tokens = 0.0, 0
        eng.decode_times, eng.decode_tokens = [], 0
        rids = [eng.submit(p, max_new=cfg["max_new"]) for p in prompts]
        res = eng.run()
        it = dict(
            prefill_s=eng.prefill_time,
            prefill_tokens=eng.prefill_tokens,
            decode_times=list(eng.decode_times),
            decode_tokens=eng.decode_tokens,
            cache_bytes=eng.cache_bytes(),
            token_bytes=eng.kvq.token_bytes(),
            first_logits=[np.asarray(res[r].logits[0], np.float32)
                          for r in rids])
        if best is None or sum(it["decode_times"]) < sum(
                best["decode_times"]):
            best = it

    # the gated quantity: the bare jitted decode step, min-of-N on a
    # fixed state (the compute is independent of page-table content, so
    # the drained engine's trash-page state times the real step). The
    # host-loop numbers above keep scheduling overhead for the report;
    # this isolates device+kernel time from host jitter.
    import time

    table = jnp.asarray(eng.page_table)
    pos = jnp.zeros((scfg.max_batch,), jnp.int32)
    seeds = jnp.asarray(eng.seeds)
    toks = jnp.zeros((scfg.max_batch, 1), jnp.int32)
    pools = eng.pools
    lg, _, pools = eng._fwd(params, pools, table, pos, seeds, toks)
    jax.block_until_ready(lg)
    step_s = float("inf")
    for _ in range(20):
        t0 = time.perf_counter()
        lg, _, pools = eng._fwd(params, pools, table, pos, seeds, toks)
        jax.block_until_ready(lg)
        step_s = min(step_s, time.perf_counter() - t0)
    eng.pools = pools
    best["step_s"] = step_s
    return best


def _ratio_table():
    from repro.serve.kv_cache import KVQuantSpec, token_bytes_ratio

    table = {}
    for arch, (kv, hd) in RATIO_ARCHS.items():
        table[arch] = {
            s: round(token_bytes_ratio(KVQuantSpec(s, kv, hd)), 4)
            for s in ("orq-9", "orq-5", "bingrad-b")}
    return table


def bench(quick: bool = True) -> dict:
    import jax
    import numpy as np

    cfg = QUICK if quick else FULL
    raw = {s: _run_engine(cfg, s) for s in cfg["schemes"]}
    bf16 = raw["bf16"]
    # the gated ratio uses the isolated jitted-step min-of-N timing
    # (mean/percentiles of the host loop stay in the report)
    bf16_step = bf16["step_s"]

    entries, summary = [], {}
    for scheme in cfg["schemes"]:
        r = raw[scheme]
        dec_s = sum(r["decode_times"])
        step = r["step_s"]
        lat = np.asarray(r["decode_times"]) * 1e3
        drift = float(np.mean([np.abs(a - b).mean() for a, b in
                               zip(r["first_logits"],
                                   bf16["first_logits"])]))
        match = float(np.mean([a.argmax(-1) == b.argmax(-1) for a, b in
                               zip(r["first_logits"],
                                   bf16["first_logits"])]))
        slow = round(step / bf16_step, 4) if bf16_step else 0.0
        entries.append({
            "key": f"serve/{scheme}/b{cfg['batch']}",
            "scheme": scheme,
            "decode_tok_s": round(r["decode_tokens"] / max(dec_s, 1e-9),
                                  1),
            "prefill_tok_s": round(r["prefill_tokens"]
                                   / max(r["prefill_s"], 1e-9), 1),
            "p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p99_ms": round(float(np.percentile(lat, 99)), 3),
            "cache_bytes": r["cache_bytes"],
            "token_bytes": r["token_bytes"],
            "slowdown_vs_bf16": slow,
            "drift_mean_abs": round(drift, 5),
            "argmax_match": match,
        })
        summary[scheme] = {
            "slowdown_vs_bf16": slow,
            "cache_ratio_smoke": round(r["cache_bytes"]
                                       / bf16["cache_bytes"], 4)}
    return {"schema": SCHEMA, "jax": jax.__version__, "quick": quick,
            "workload": {k: v for k, v in cfg.items() if k != "schemes"},
            "ratios": _ratio_table(), "summary": summary,
            "entries": entries}


def check(new: dict, baseline: dict, tolerance: float) -> list:
    """Regression gate. Returns failure strings (empty = pass).

    Hard checks: schema version; full-size cache ratios for the gated
    schemes <= MAX_RATIO on every arch; every scheme's
    ``slowdown_vs_bf16`` stays under the absolute MAX_SLOWDOWN ceiling
    (losing the fused path costs far more). Timing check: per-scheme
    ``slowdown_vs_bf16`` must not grow more than ``tolerance`` over the
    committed baseline — interpret-mode step timings jitter ~2x run to
    run, so the default tolerance is wide; the ceiling is the backstop."""
    fails = []
    if new.get("schema") != SCHEMA:
        fails.append(f"schema mismatch: {new.get('schema')} != {SCHEMA}")
        return fails
    if not new.get("entries"):
        return ["no entries in run"]
    for arch, ratios in new.get("ratios", {}).items():
        for scheme in GATED_SCHEMES:
            r = ratios.get(scheme)
            if r is None or r > MAX_RATIO:
                fails.append(
                    f"{arch}/{scheme}: cache-bytes ratio {r} > "
                    f"{MAX_RATIO} of bf16 (compression criterion)")
    for scheme, s in new.get("summary", {}).items():
        if s["slowdown_vs_bf16"] > MAX_SLOWDOWN:
            fails.append(
                f"{scheme}: decode slowdown vs bf16 "
                f"{s['slowdown_vs_bf16']:.2f} > hard ceiling "
                f"{MAX_SLOWDOWN} (fused path lost?)")
        b = baseline.get("summary", {}).get(scheme)
        if (b and b.get("slowdown_vs_bf16")
                and s["slowdown_vs_bf16"]
                > b["slowdown_vs_bf16"] * (1.0 + tolerance)):
            fails.append(
                f"{scheme}: decode slowdown vs bf16 regressed "
                f"{b['slowdown_vs_bf16']:.3f} -> "
                f"{s['slowdown_vs_bf16']:.3f} (> {tolerance:.0%})")
    return fails


def run(emit) -> None:
    """benchmarks.run hook: quick pass, CSV rows + JSON artifact."""
    from benchmarks.common import csv_row

    res = bench(quick=True)
    with open("BENCH_serve.json", "w") as fh:
        json.dump(res, fh, indent=1, sort_keys=True)
    for e in res["entries"]:
        emit(csv_row(e["key"], e["p50_ms"] * 1e3,
                     f"{e['decode_tok_s']}tok_s"
                     f"_x{e['slowdown_vs_bf16']:.2f}_vs_bf16"))
    emit(csv_row("serve/json", 0.0, "wrote BENCH_serve.json"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--check", metavar="RUN_JSON", default=None)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=1.0)
    ap.add_argument("--update-baseline", action="store_true")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.check) as fh:
            new = json.load(fh)
        with open(args.baseline) as fh:
            base = json.load(fh)
        fails = check(new, base, args.tolerance)
        for f in fails:
            print(f"FAIL {f}")
        if fails:
            sys.exit(1)
        print(f"OK {len(new['entries'])} entries; gated ratios <= "
              f"{MAX_RATIO} ({os.path.basename(args.baseline)})")
        return

    res = bench(quick=args.quick)
    out = args.baseline if args.update_baseline else args.out
    with open(out, "w") as fh:
        json.dump(res, fh, indent=1, sort_keys=True)
    print(f"wrote {out} ({len(res['entries'])} entries)")
    for e in res["entries"]:
        print(f"  {e['key']}: {e['decode_tok_s']} tok/s decode, "
              f"p50 {e['p50_ms']}ms, x{e['slowdown_vs_bf16']:.2f} vs "
              f"bf16, {e['cache_bytes']} cache bytes")
    for arch, ratios in res["ratios"].items():
        print(f"  ratios[{arch}]: " + ", ".join(
            f"{s}={r:.3f}" for s, r in sorted(ratios.items())))


if __name__ == "__main__":
    main()
