"""Paper Table 4: effect of the TernGrad-style clipping factor c on ORQ.
Reports quantization MSE (vs unclipped FP gradient) for c in {1.7, 2.5, off}
at s in {3, 5, 9}, plus a short convergence run at c=2.5 vs off."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_row, harvest_gradient
from repro.core import make_quantizer, theory
from benchmarks.convergence import train_once


def run(emit):
    g = harvest_gradient()
    scale = float(jnp.abs(g).std()) + 1e-12
    for s in (3, 5, 9):
        base = None
        for c in (None, 1.7, 2.5):
            qz = make_quantizer(f"orq-{s}", bucket_size=512, clip_c=c)
            mse = float(theory.scheme_mse(qz, g)) / scale ** 2
            tag = "off" if c is None else f"c{c}"
            if c is None:
                base = mse
            emit(csv_row(f"table4_clipping/orq-{s}_{tag}", 0.0,
                         f"nmse={mse:.4e};delta_vs_off={mse-base:+.3e}"))
    # clipping trades tail error for interior resolution; on heavy-tailed
    # gradients it can HELP ORQ-3 (fewer levels wasted on outliers)
    emit(csv_row("table4_clipping/note", 0.0,
                 "clip shrinks level span; see EXPERIMENTS.md"))
