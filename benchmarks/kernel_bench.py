"""Kernel benchmark harness: fused vs multi-pass encode/decode timings.

Times ``wire.encode`` / ``wire.decode_mean`` per scheme x wire-bit-width x
bucket size on three paths — the fused one-pass kernels (PR 5), the
multi-pass kernels (PR 1-4), and the pure-jnp reference oracle — and
emits ``BENCH_kernels.json`` in a stable schema CI can diff:

    {"schema": 1, "jax": ..., "backend": ..., "quick": ...,
     "modes": ["interpret", ...],
     "summary": {"encode_speedup_geomean": ..., "decode_speedup_geomean": ...},
     "entries": [{"key": "encode/orq-9/d512/nb32/interpret",
                  "op": "encode", "scheme": "orq-9", "wire_bits": 4,
                  "bucket": 512, "nb": 32, "mode": "interpret",
                  "fused_us": ..., "multipass_us": ..., "ref_us": ...,
                  "speedup_vs_multipass": ..., "melems_per_s": ...,
                  "bit_identical": true}, ...]}

The CI regression gate (``--check``) is built to survive noisy shared
runners without going blind:

* timings are MIN-of-iters (a load-robust lower bound, the standard for
  microbenchmarks on contended machines);
* the gated quantity is ``speedup_vs_multipass`` — fused and multipass
  are measured in the SAME process on the SAME machine, so runner speed
  cancels;
* the 25% tolerance applies to the GEOMEAN of that ratio across all
  encode entries and all decode entries (one gate per op) — averaging
  ~5 schemes beats per-entry scheduler noise down far below the
  tolerance while still catching a fused pipeline that got slower
  relative to the work it replaces;
* ENCODE additionally carries a hard absolute floor (PR 6): its
  speedup_vs_multipass geomean must exceed 1.0 — the fused encode has to
  BEAT the multipass path, not just hold its baseline ratio. Decode
  stays regression-gated only.

It also fails hard if any entry lost bit-identity, errored, or the
schema changed. Per-entry raw microseconds are recorded for humans (and
gateable with ``--check-raw`` where the runner fleet is homogeneous).

Usage:
    PYTHONPATH=src:. python benchmarks/kernel_bench.py [--quick] \
        [--out BENCH_kernels.json] [--backend interpret|compiled|both]
    PYTHONPATH=src:. python benchmarks/kernel_bench.py --check NEW.json \
        --baseline benchmarks/BENCH_kernels_baseline.json [--tolerance .25]
    PYTHONPATH=src:. python benchmarks/kernel_bench.py --quick \
        --update-baseline        # refresh the committed baseline

``--quick`` is the CI/PR configuration: one bucket size, fewer buckets,
fewer timing iters. Interpret mode executes the kernel bodies in Python
(this container is CPU-only), so absolute times are NOT TPU times —
they track the op count and intermediate traffic of each pipeline, which
is exactly what the gate is protecting.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA = 1
DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_kernels_baseline.json")

# (scheme label, Quantizer kwargs) — one per wire bit-width 1..5;
# terngrad carries the paper's sigma-clip so the fused clip stage is timed
SCHEMES = [
    ("bingrad-b", dict(method="bingrad_b")),                    # 1 bit
    ("terngrad", dict(method="terngrad", clip_c=2.5)),          # 2 bits
    ("orq-5", dict(method="orq", num_levels=5)),                # 3 bits
    ("orq-9", dict(method="orq", num_levels=9)),                # 4 bits
    ("orq-17", dict(method="orq", num_levels=17)),              # 5 bits
]

QUICK = dict(buckets=(512,), nb=24, L=4, iters=5, warmup=2)
FULL = dict(buckets=(512, 2048), nb=128, L=4, iters=7, warmup=2)


def _time_min(fn, *args, iters: int, warmup: int) -> float:
    """MIN wall time per call in microseconds. The minimum is the
    load-robust estimator for microbenchmarks on shared machines: every
    source of contention only ever ADDS time, so the min converges on
    the true cost while the median still wanders with scheduler noise."""
    import time

    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _geomean(xs):
    import math

    xs = list(xs)
    if not xs:
        return None
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _entries(cfg, mode):
    """Build + time every (scheme, bucket) point for one backend mode."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.quantizers import Quantizer
    from repro.core.comm import wire

    use_compiled = mode == "compiled"
    env_prev = os.environ.get("REPRO_PALLAS_INTERPRET")
    os.environ["REPRO_PALLAS_INTERPRET"] = "0" if use_compiled else "1"
    out = []
    try:
        for label, kw in SCHEMES:
            for d in cfg["buckets"]:
                qz = Quantizer(bucket_size=d, **kw)
                nb, L = cfg["nb"], cfg["L"]
                bkt = jax.random.laplace(jax.random.key(7), (nb, d)) * 0.1
                # ragged tail: last bucket only one-third valid
                mask = (jnp.arange(nb * d).reshape(nb, d)
                        < (nb - 1) * d + d // 3)
                key = jax.random.key(3)

                enc_f = jax.jit(lambda b, m, k, q=qz: wire.encode(
                    q, b, m, k, use_kernels=True))
                enc_m = jax.jit(lambda b, m, k, q=qz: wire.encode_multipass(
                    q, b, m, k, use_kernels=True))
                enc_r = jax.jit(lambda b, m, k, q=qz: wire.encode(
                    q, b, m, k, use_kernels=False))
                try:
                    words, levels = jax.block_until_ready(
                        enc_f(bkt, mask, key))
                except Exception as e:  # noqa: BLE001 — backend can't lower
                    out.append({"key": f"encode/{label}/d{d}/nb{nb}/{mode}",
                                "mode": mode, "error": str(e)[:200]})
                    continue
                w_m, lv_m = enc_m(bkt, mask, key)
                w_r, lv_r = enc_r(bkt, mask, key)
                enc_ident = bool(
                    np.array_equal(words, w_m) and np.array_equal(words, w_r)
                    and np.array_equal(levels, lv_m)
                    and np.array_equal(levels, lv_r))

                t_kwargs = dict(iters=cfg["iters"], warmup=cfg["warmup"])
                fus = _time_min(enc_f, bkt, mask, key, **t_kwargs)
                mp = _time_min(enc_m, bkt, mask, key, **t_kwargs)
                rf = _time_min(enc_r, bkt, mask, key, **t_kwargs)
                out.append({
                    "key": f"encode/{label}/d{d}/nb{nb}/{mode}",
                    "op": "encode", "scheme": label,
                    "wire_bits": qz.wire_bits_per_element, "bucket": d,
                    "nb": nb, "mode": mode,
                    "fused_us": round(fus, 2), "multipass_us": round(mp, 2),
                    "ref_us": round(rf, 2),
                    "speedup_vs_multipass": round(mp / fus, 4),
                    "melems_per_s": round(nb * d / fus, 3),
                    "bit_identical": enc_ident,
                })

                ws = jnp.stack([words] * L)
                lvs = jnp.stack([levels] * L)
                dec_f = jax.jit(lambda w, l, q=qz: wire.decode_mean(
                    q, w, l, d, use_kernels=True))
                dec_m = jax.jit(lambda w, l, q=qz: wire.decode_mean_multipass(
                    q, w, l, d, use_kernels=True))
                dec_r = jax.jit(lambda w, l, q=qz: wire.decode_mean(
                    q, w, l, d, use_kernels=False))
                # fused == multipass exactly; the oracle scales AFTER the
                # worker sum, which is still exact when 1/L is a power of
                # two (multiplying by 2^-k never rounds) — L is 4 here —
                # and only float-close otherwise
                out_f = np.asarray(dec_f(ws, lvs))
                dec_ident = bool(np.array_equal(out_f,
                                                np.asarray(dec_m(ws, lvs))))
                out_r = np.asarray(dec_r(ws, lvs))
                if L & (L - 1) == 0:
                    dec_ident = dec_ident and bool(np.array_equal(out_f,
                                                                  out_r))
                else:
                    dec_ident = dec_ident and bool(np.allclose(
                        out_f, out_r, rtol=1e-6, atol=1e-7))
                fus = _time_min(dec_f, ws, lvs, **t_kwargs)
                mp = _time_min(dec_m, ws, lvs, **t_kwargs)
                rf = _time_min(dec_r, ws, lvs, **t_kwargs)
                out.append({
                    "key": f"decode/{label}/d{d}/nb{nb}/L{L}/{mode}",
                    "op": "decode", "scheme": label,
                    "wire_bits": qz.wire_bits_per_element, "bucket": d,
                    "nb": nb, "L": L, "mode": mode,
                    "fused_us": round(fus, 2), "multipass_us": round(mp, 2),
                    "ref_us": round(rf, 2),
                    "speedup_vs_multipass": round(mp / fus, 4),
                    "melems_per_s": round(L * nb * d / fus, 3),
                    "bit_identical": dec_ident,
                })
    finally:
        if env_prev is None:
            os.environ.pop("REPRO_PALLAS_INTERPRET", None)
        else:
            os.environ["REPRO_PALLAS_INTERPRET"] = env_prev
    return out


def bench(quick: bool = True, backend: str = "interpret") -> dict:
    import jax

    modes = [backend] if backend != "both" else ["interpret", "compiled"]
    cfg = QUICK if quick else FULL
    entries = []
    for mode in modes:
        entries.extend(_entries(cfg, mode))
    summary = {}
    for op in ("encode", "decode"):
        g = _geomean(e["speedup_vs_multipass"] for e in entries
                     if e.get("op") == op)
        if g is not None:
            summary[f"{op}_speedup_geomean"] = round(g, 4)
    return {
        "schema": SCHEMA,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "quick": quick,
        "modes": modes,
        "summary": summary,
        "entries": entries,
    }


def check(new: dict, baseline: dict, tolerance: float,
          raw: bool = False, encode_floor: float = 1.0) -> list:
    """Regression gate. Returns a list of failure strings (empty = pass).

    Hard (deterministic) checks: schema version, no errored entries,
    every entry bit-identical, and — since the PR 6 tiling fix — the
    encode ``speedup_vs_multipass`` geomean of the NEW run must clear
    ``encode_floor`` (> 1.0: the fused encode must actually beat the
    multipass path it replaced, not merely not regress). Timing check:
    the encode/decode GEOMEAN of ``speedup_vs_multipass`` must stay
    within ``tolerance`` of the baseline geomean — computed over the
    overlapping keys only, so a changed scheme matrix can't silently
    skew the comparison (decode stays regression-gated only)."""
    fails = []
    if new.get("schema") != SCHEMA:
        fails.append(f"schema mismatch: {new.get('schema')} != {SCHEMA}")
        return fails
    base_by_key = {e["key"]: e for e in baseline.get("entries", [])
                   if "error" not in e}
    overlap = {"encode": ([], []), "decode": ([], [])}
    for e in new.get("entries", []):
        if "error" in e:
            fails.append(f"{e['key']}: benchmark errored: {e['error']}")
            continue
        if not e.get("bit_identical", False):
            fails.append(f"{e['key']}: fused path lost bit-identity")
        b = base_by_key.get(e["key"])
        if b is None:
            continue                      # new point: no baseline yet
        news, olds = overlap[e["op"]]
        news.append(e["speedup_vs_multipass"])
        olds.append(b["speedup_vs_multipass"])
        if raw and e["fused_us"] > b["fused_us"] * (1.0 + tolerance):
            fails.append(
                f"{e['key']}: fused_us regressed {b['fused_us']:.1f} -> "
                f"{e['fused_us']:.1f}us (> {tolerance:.0%})")
    if not any(news for news, _ in overlap.values()):
        fails.append("no overlapping keys between run and baseline "
                     "(wrong baseline file or schema drift?)")
    enc = [e["speedup_vs_multipass"] for e in new.get("entries", [])
           if "error" not in e and e["op"] == "encode"]
    if enc and encode_floor is not None:
        g_enc = _geomean(enc)
        if g_enc <= encode_floor:
            fails.append(
                f"encode: speedup_vs_multipass geomean {g_enc:.3f} does "
                f"not clear the hard floor {encode_floor:.2f} over "
                f"{len(enc)} entries — the fused encode must beat the "
                f"multipass path")
    for op, (news, olds) in overlap.items():
        if not news:
            continue
        g_new, g_old = _geomean(news), _geomean(olds)
        if g_new < g_old * (1.0 - tolerance):
            fails.append(
                f"{op}: speedup_vs_multipass geomean regressed "
                f"{g_old:.3f} -> {g_new:.3f} over {len(news)} entries "
                f"(> {tolerance:.0%} drop)")
    return fails


def run(emit) -> None:
    """benchmarks.run hook: quick interpret-mode pass, CSV rows + JSON."""
    from benchmarks.common import csv_row

    res = bench(quick=True, backend="interpret")
    with open("BENCH_kernels.json", "w") as fh:
        json.dump(res, fh, indent=1, sort_keys=True)
    for e in res["entries"]:
        if "error" in e:
            emit(csv_row(f"kernels/{e['key']}", 0.0, "ERROR"))
            continue
        emit(csv_row(
            f"kernels/{e['key']}", e["fused_us"],
            f"x{e['speedup_vs_multipass']:.2f}_vs_multipass;"
            f"bits={e['wire_bits']};bit_identical={e['bit_identical']}"))
    emit(csv_row("kernels/json", 0.0, "wrote BENCH_kernels.json"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI/PR configuration: small shapes, few iters")
    ap.add_argument("--backend", default="interpret",
                    choices=("interpret", "compiled", "both"))
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--check", metavar="RUN_JSON", default=None,
                    help="gate RUN_JSON against --baseline instead of "
                         "benchmarking")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--encode-floor", type=float, default=1.0,
                    help="hard floor for the NEW run's encode "
                         "speedup_vs_multipass geomean (fused must beat "
                         "multipass); pass a negative value to disable")
    ap.add_argument("--check-raw", action="store_true",
                    help="also gate raw fused_us (homogeneous runners only)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the fresh run to --baseline")
    args = ap.parse_args(argv)

    if args.check:
        with open(args.check) as fh:
            new = json.load(fh)
        with open(args.baseline) as fh:
            base = json.load(fh)
        floor = None if args.encode_floor < 0 else args.encode_floor
        fails = check(new, base, args.tolerance, raw=args.check_raw,
                      encode_floor=floor)
        for f in fails:
            print(f"FAIL {f}")
        if fails:
            sys.exit(1)
        print(f"OK {len(new['entries'])} entries within "
              f"{args.tolerance:.0%} of baseline "
              f"({os.path.basename(args.baseline)})")
        return

    res = bench(quick=args.quick, backend=args.backend)
    with open(args.out, "w") as fh:
        json.dump(res, fh, indent=1, sort_keys=True)
    print(f"wrote {args.out} ({len(res['entries'])} entries)")
    for e in res["entries"]:
        if "error" in e:
            print(f"  {e['key']}: ERROR {e['error'][:80]}")
        else:
            print(f"  {e['key']}: fused {e['fused_us']:.1f}us "
                  f"multipass {e['multipass_us']:.1f}us "
                  f"ref {e['ref_us']:.1f}us "
                  f"x{e['speedup_vs_multipass']:.2f} "
                  f"bit_identical={e['bit_identical']}")
    if args.update_baseline:
        with open(args.baseline, "w") as fh:
            json.dump(res, fh, indent=1, sort_keys=True)
        print(f"updated baseline {args.baseline}")


if __name__ == "__main__":
    main()
