"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table3]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    ("fig1_quant_error", "benchmarks.quant_error"),
    ("table1_comm", "benchmarks.comm_cost"),
    ("table2_convergence", "benchmarks.convergence"),
    ("table3_bucket", "benchmarks.bucket_size"),
    ("table4_clipping", "benchmarks.clipping"),
    ("table5_distributed", "benchmarks.distributed"),
    ("roofline", "benchmarks.roofline"),
    ("kernels", "benchmarks.kernel_bench"),
    ("exchange", "benchmarks.exchange_bench"),
    ("serve", "benchmarks.serve_bench"),
    ("analysis", "benchmarks.analysis"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes of benchmarks to run")
    args = ap.parse_args(argv)
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")

    def emit(row: str) -> None:
        print(row, flush=True)

    failures = 0
    for tag, modname in MODULES:
        if only and not any(tag.startswith(o) for o in only):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(emit)
        except Exception:  # noqa: BLE001
            failures += 1
            emit(f"{tag}/ERROR,0.0,{traceback.format_exc(limit=1)!r}"
                 .replace("\n", " "))
    if failures:
        print(f"# {failures} benchmark module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
