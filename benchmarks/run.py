"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table3]
    PYTHONPATH=src python -m benchmarks.run --check

``--check`` runs every registered self-contained snapshot gate (a
module's ``--check`` mode validating its COMMITTED baseline without
re-benchmarking) and exits nonzero when any of them fails.

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    ("fig1_quant_error", "benchmarks.quant_error"),
    ("table1_comm", "benchmarks.comm_cost"),
    ("table2_convergence", "benchmarks.convergence"),
    ("table3_bucket", "benchmarks.bucket_size"),
    ("table4_clipping", "benchmarks.clipping"),
    ("table5_distributed", "benchmarks.distributed"),
    ("roofline", "benchmarks.roofline"),
    ("kernels", "benchmarks.kernel_bench"),
    ("exchange", "benchmarks.exchange_bench"),
    ("serve", "benchmarks.serve_bench"),
    ("analysis", "benchmarks.analysis"),
]

#: (tag, module, argv) snapshot gates ``--check`` runs: each module's
#: main() must validate its committed artifact with these args and exit
#: nonzero on failure (the RUN_JSON-style checks that need a fresh
#: benchmark run first don't belong here — CI drives those per-job)
CHECKS = [
    ("table2_convergence", "benchmarks.convergence", ["--check"]),
]


def _run_check(tag: str, modname: str, argv) -> bool:
    """True iff the module's check passed; a crash counts as a failure."""
    try:
        mod = __import__(modname, fromlist=["main"])
        code = mod.main(argv)
        return not code
    except SystemExit as e:  # argparse-style mains exit instead of return
        return not e.code
    except Exception:  # noqa: BLE001
        print(f"# {tag}/--check crashed:", file=sys.stderr)
        traceback.print_exc()
        return False


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes of benchmarks to run")
    ap.add_argument("--check", action="store_true",
                    help="run every registered snapshot gate instead of "
                         "benchmarking; exit 1 if any fails")
    args = ap.parse_args(argv)
    only = args.only.split(",") if args.only else None

    if args.check:
        checks = [(t, m, a) for t, m, a in CHECKS
                  if not only or any(t.startswith(o) for o in only)]
        failed = [t for t, m, a in checks if not _run_check(t, m, a)]
        for t in failed:
            print(f"# check FAILED: {t}", file=sys.stderr)
        print(f"# {len(checks) - len(failed)}/{len(checks)} checks passed")
        sys.exit(1 if failed else 0)

    print("name,us_per_call,derived")

    def emit(row: str) -> None:
        print(row, flush=True)

    failures = 0
    for tag, modname in MODULES:
        if only and not any(tag.startswith(o) for o in only):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(emit)
        except Exception:  # noqa: BLE001
            failures += 1
            emit(f"{tag}/ERROR,0.0,{traceback.format_exc(limit=1)!r}"
                 .replace("\n", " "))
    if failures:
        print(f"# {failures} benchmark module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
