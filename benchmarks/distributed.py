"""Paper Fig. 3 / Table 5 proxy: multi-worker distributed training with the
full Algorithm 2 exchange (worker-quantize -> all_to_all -> server-average
-> re-quantize -> broadcast). Runs in a subprocess with 4 fake devices (the
paper's ImageNet runs use 4 workers) and compares FP vs ORQ vs QSGD; also
reports traced collective counts for the fused-vs-per-leaf exchange in both
replicated and fsdp (ZeRO-3) modes, and — on a second (2, 4) pod x data
host mesh of 8 fake devices — the per-axis traced collective counts of the
hierarchical two-level exchange (quantized all_to_all/all_gather over
``pod`` only; full-precision reduce_scatter/all_gather over ``data``)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import csv_row

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROG = """
import jax, json
from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((4,), ("data",))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=16,
                   seed=11)
out = {}
for name in ["fp", "orq-9", "qsgd-9", "orq-3", "terngrad"]:
    tcfg = TrainConfig(policy=QuantConfig(name=name, bucket_size=2048,
                                         clip_c=2.5 if name != "fp" else None),
                       mode="replicated")
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    loss = None
    for i in range(30):
        state, m = step_fn(state, data.batch(i), jax.random.key(1))
        loss = float(m["loss"])
    out[name] = loss

# fused vs per-leaf: collective launches in the traced step + wire bytes
import numpy as np
from repro.core import comm, make_quantizer
counts = {}
for fused in (True, False):
    tcfg = TrainConfig(policy=QuantConfig(name="orq-9", bucket_size=2048),
                       mode="replicated", fused_exchange=fused)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    jx = str(jax.make_jaxpr(step_fn)(state, data.batch(0), jax.random.key(1)))
    counts["fused" if fused else "perleaf"] = (
        jx.count("all_to_all["), jx.count("all_gather["))
qz = make_quantizer("orq-9", bucket_size=2048)
sizes = [int(np.prod(x.shape))
         for x in jax.tree_util.tree_leaves(state.params)]
pl_launch, pl_bytes = comm.per_leaf_stats(qz, sizes, 4)
f_launch, f_bytes = comm.fused_stats(qz, sizes, 4)
out["_collectives"] = {"counts": counts, "leaves": len(sizes),
                       "launches": [pl_launch, f_launch],
                       "wire_bytes": [pl_bytes, f_bytes]}

# fsdp (ZeRO-3): fused per-group reduce-scatter vs per-leaf gather backward
fcounts = {}
for fused in (True, False):
    tcfg = TrainConfig(policy=QuantConfig(name="orq-9", bucket_size=2048),
                       mode="fsdp", fused_exchange=fused)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, plan = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    jx = str(jax.make_jaxpr(step_fn)(state, data.batch(0), jax.random.key(1)))
    fcounts["fused" if fused else "perleaf"] = (
        jx.count("all_to_all["), jx.count("all_gather["))
aparams = jax.eval_shape(model.init, jax.random.key(0))
fex = comm.FsdpExchange.build(
    tcfg.resolved_policy(), aparams, plan.dp_axes, paths=plan.paths,
    shard_dims=plan.full_shard_dims(), n_shards=plan.n_dp)
out["_fsdp"] = {"counts": fcounts, "groups": len(fex.layout.groups),
                "launches": fex.collective_launches(),
                "wire_bytes": fex.wire_bytes_per_worker()}
print("RESULT " + json.dumps(out))
"""


PROG_HIER = """
import jax, json
from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state
from repro.utils.jaxpr import collective_axis_counts

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8,
                   seed=0)
out = {}
for mode in ("replicated", "fsdp"):
    for hier in ("flat", "two_level"):
        tcfg = TrainConfig(policy="orq-9", mode=mode, hierarchy=hier)
        state = init_state(model, mesh, tcfg, jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        closed = jax.make_jaxpr(step_fn)(state, data.batch(0),
                                         jax.random.key(1))
        counts = collective_axis_counts(closed)
        out[f"{mode}/{hier}"] = {
            f"{p}@{'*'.join(map(str, ax))}": n
            for (p, ax), n in sorted(counts.items())
            if p in ("all_to_all", "all_gather", "reduce_scatter",
                     "psum_scatter")}
print("RESULT " + json.dumps(out))
"""


def _run_prog(prog: str, n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                       env=env, capture_output=True, text=True,
                       timeout=3600)
    assert r.returncode == 0, r.stdout + r.stderr
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line.split(" ", 1)[1])


def run(emit):
    res = _run_prog(PROG, 4)
    coll = res.pop("_collectives")
    fsdp = res.pop("_fsdp", None)
    for name, loss in res.items():
        emit(csv_row(f"table5_distributed/{name}", 0.0,
                     f"final_loss={loss:.4f};workers=4;clip=2.5"))
    (pl_l, f_l), (pl_b, f_b) = coll["launches"], coll["wire_bytes"]
    fused_a2a, fused_ag = coll["counts"]["fused"]
    pleaf_a2a, pleaf_ag = coll["counts"]["perleaf"]
    emit(csv_row(
        "table5_distributed/fused_vs_perleaf", 0.0,
        f"leaves={coll['leaves']};traced_a2a={fused_a2a}v{pleaf_a2a};"
        f"traced_ag={fused_ag}v{pleaf_ag};launches={f_l}v{pl_l};"
        f"wire={f_b/2**20:.2f}v{pl_b/2**20:.2f}MiB"))
    if fsdp:
        fa2a, fag = fsdp["counts"]["fused"]
        pa2a, pag = fsdp["counts"]["perleaf"]
        emit(csv_row(
            "table5_distributed/fsdp_fused_vs_perleaf", 0.0,
            f"groups={fsdp['groups']};traced_a2a={fa2a}v{pa2a};"
            f"traced_ag={fag}v{pag};launches={fsdp['launches']};"
            f"wire={fsdp['wire_bytes']/2**20:.2f}MiB"))
    ok = (res["orq-9"] <= res["qsgd-9"] + 0.15
          and res["orq-3"] <= res["terngrad"] + 0.15)
    emit(csv_row("table5_distributed/claims", 0.0,
                 f"ordering={'PASS' if ok else 'SOFT-FAIL'}"))

    # hierarchical two-level exchange: per-axis traced collective counts
    # on a (2, 4) pod x data host mesh (8 fake devices)
    hier = _run_prog(PROG_HIER, 8)
    for case, counts in hier.items():
        body = ";".join(f"{k}={v}" for k, v in sorted(counts.items()))
        emit(csv_row(
            f"table5_distributed/hier_{case.replace('/', '_')}", 0.0,
            f"mesh=2x4(pod*data);{body}"))
    two = hier["replicated/two_level"]
    quant_on_data = any("@" in k and "data" in k.split("@")[1]
                        for k in two if k.startswith("all_to_all"))
    emit(csv_row(
        "table5_distributed/hier_claims", 0.0,
        f"quantized_a2a_pod_only={'PASS' if not quant_on_data else 'FAIL'}"))
