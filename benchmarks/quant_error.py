"""Paper Fig. 1 + Fig. 2 (error rows): quantization MSE per method on a
REAL model gradient and on reference distributions, plus level-utilization
and shape-distortion statistics (the two criteria of §5.1.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, harvest_gradient, time_call
from repro.core import buckets as B
from repro.core import make_quantizer, theory

METHODS = ["terngrad", "orq-3", "qsgd-5", "linear-5", "orq-5", "qsgd-9",
           "linear-9", "orq-9", "bingrad-pb", "bingrad-b", "signsgd"]


def level_utilization(qz, g):
    """Fraction of levels carrying >1% of mass (criterion 1 of §5.1.2)."""
    q = qz.quantize(g, jax.random.key(0))
    s = qz.s
    counts = jnp.stack([(q.idx == k).sum() for k in range(s)])
    frac = counts / counts.sum()
    return float((frac > 0.01).mean())


def shape_distortion(qz, g):
    """W1-like distance between FP and dequantized histograms
    (criterion 2 of §5.1.2)."""
    out = qz.qdq(g, jax.random.key(1))
    qs = jnp.linspace(0.01, 0.99, 51)
    return float(jnp.mean(jnp.abs(jnp.quantile(g, qs)
                                  - jnp.quantile(out, qs))))


def run(emit):
    g = harvest_gradient()
    scale = float(jnp.abs(g).std()) + 1e-12
    rows = {}
    for name in METHODS:
        qz = make_quantizer(name, bucket_size=2048)
        mse = float(theory.scheme_mse(qz, g)) / scale ** 2
        util = level_utilization(qz, g[:1 << 16])
        dist = shape_distortion(qz, g[:1 << 16]) / scale
        us = time_call(jax.jit(lambda x, k, q=qz: q.qdq(x, k)),
                       g[:1 << 18], jax.random.key(0))
        rows[name] = mse
        emit(csv_row(f"fig1_quant_error/{name}", us,
                     f"nmse={mse:.4e};util={util:.2f};distort={dist:.3f}"))
    # the paper's headline orderings must hold on real gradients
    assert rows["orq-3"] < rows["terngrad"]
    assert rows["orq-5"] < rows["qsgd-5"] and rows["orq-5"] < rows["linear-5"]
    assert rows["orq-9"] < rows["qsgd-9"] and rows["orq-9"] < rows["linear-9"]
    assert rows["bingrad-b"] < rows["bingrad-pb"]
    emit(csv_row("fig1_quant_error/claims", 0.0, "paper_ordering=PASS"))
