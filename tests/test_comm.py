"""Multi-device tests for the quantized collectives.

These spawn subprocesses with XLA_FLAGS forcing 8 host devices, because the
main test process must keep the default single-device view (per the repo's
dry-run-only rule for fake device counts).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import make_quantizer
from repro.core import comm

mesh = jax.make_mesh((4, 2), ("data", "model"))
DP = ("data",)
L = 4

from repro.utils.compat import shard_map

def shmap(f, in_specs, out_specs):
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, axis_names={"data"}, check_vma=False))
"""


def test_fp_reduce_scatter_matches_psum():
    run_devices(COMMON + """
n = 1000
g = jax.random.normal(jax.random.key(0), (L, n))   # one grad per worker
qz = make_quantizer("fp")

def f(gl):
    gl = gl[0]
    out = comm.quantized_reduce_scatter_mean(gl, qz, jax.random.key(1), DP)
    return out[None]

out = shmap(f, (P("data", None),), P("data", None))(g)
chunk = -(-n // L)
want = np.pad(np.asarray(g.mean(0)), (0, L * chunk - n)).reshape(L, chunk)
np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6, atol=1e-7)
print("fp-rs OK")
""")


def test_quantized_reduce_scatter_matches_simulation():
    """The collective must equal a local simulation of Algorithm 2:
    each worker quantizes its grad with its own folded key; the mean of the
    dequantized copies is the result."""
    run_devices(COMMON + """
from repro.core import buckets as B
n = 5000
key = jax.random.key(7)
g = jax.random.laplace(jax.random.key(2), (L, n)) * 0.1

for name, d in [("orq-5", 512), ("terngrad", 2048), ("bingrad-b", 256),
                ("qsgd-9", 1024), ("signsgd", 512)]:
    qz = make_quantizer(name, bucket_size=d)

    # NOTE: the PRNG key must ride in_specs, not a closure — legacy
    # partial-auto shard_map mis-shards closed-over extended-dtype consts
    def f(gl, k):
        gl = gl[0]
        out = comm.quantized_reduce_scatter_mean(gl, qz, k, DP)
        return out[None]

    out = np.asarray(shmap(f, (P("data", None), P()),
                           P("data", None))(g, key))

    # local simulation (mirrors _rs_mean_parts exactly)
    chunk = -(-n // L)
    d_eff = min(d, chunk)
    chunk_p = -(-chunk // d_eff) * d_eff
    sims = []
    for w in range(L):
        kw = jax.random.fold_in(key, w)
        flat = jnp.pad(g[w], (0, L * chunk - n))
        parts = jnp.pad(flat.reshape(L, chunk), ((0,0),(0,chunk_p-chunk)))
        valid = jnp.pad((jnp.arange(L*chunk) < n).reshape(L, chunk),
                        ((0,0),(0,chunk_p-chunk)))
        bkt = parts.reshape(-1, d_eff); mask = valid.reshape(-1, d_eff)
        lv = qz.fit(bkt, mask)
        idx = jnp.where(mask, qz.assign(bkt, lv, kw), 0)
        sims.append(np.asarray(qz.decode(idx, lv).reshape(L, chunk_p)[:, :chunk]))
    want = np.stack(sims).mean(0)   # (L, chunk): mean of dequantized copies
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6,
                               err_msg=name)
    print(name, "rs-sim OK")
""")


def test_quantized_all_reduce_identical_and_unbiased():
    run_devices(COMMON + """
n = 4096
g = jax.random.laplace(jax.random.key(3), (L, n)) * 0.01
qz = make_quantizer("orq-9", bucket_size=512)

def f(gl, k):
    gl = gl[0]
    out = comm.quantized_all_reduce_mean(gl, qz, k, DP,
                                         server_requant=True)
    return out[None]

out = np.asarray(shmap(f, (P("data", None), P()),
                       P("data", None))(g, jax.random.key(5)))
# identical on every worker (deterministic decode)
for w in range(1, L):
    np.testing.assert_array_equal(out[0], out[w])
# close to the true mean (quantization noise only)
err = np.abs(out[0] - np.asarray(g.mean(0)))
assert err.mean() < 0.01, err.mean()
print("allreduce OK")

# server_requant=False must equal the rs result exactly
def f2(gl, k):
    gl = gl[0]
    out = comm.quantized_all_reduce_mean(gl, qz, k, DP,
                                         server_requant=False)
    return out[None]
out2 = np.asarray(shmap(f2, (P("data", None), P()),
                        P("data", None))(g, jax.random.key(5)))
for w in range(1, L):
    np.testing.assert_array_equal(out2[0], out2[w])
print("allreduce-norequant OK")
""")


def test_fsdp_gather_fwd_and_quantized_bwd():
    run_devices(COMMON + """
d0, d1 = 8, 6   # full leaf (8, 6), fsdp dim 0 -> local (2, 6)
w_full = jax.random.normal(jax.random.key(4), (d0, d1))
x = jax.random.normal(jax.random.key(5), (L, 4, d0))  # per-worker batch
qz = make_quantizer("orq-9", bucket_size=16)
gather = comm.make_fsdp_gather(qz, DP, dim=0, compute_dtype=jnp.float32)

def f(wl, xl, key):
    xl = xl[0]
    def loss(wl):
        wg = gather(wl, key)
        return ((xl @ wg) ** 2).sum()
    l, gr = jax.value_and_grad(loss)(wl)
    return lax.pmean(l, "data")[None], gr

step = shmap(f, (P("data", None), P("data", None, None), P()),
             (P("data"), P("data", None)))
loss, grads = step(w_full, x, jax.random.key(6))
# fwd correctness: loss equals unsharded computation (loss[0] = the pmean)
want_loss = sum(float(((x[i] @ w_full) ** 2).sum()) for i in range(L)) / L
np.testing.assert_allclose(float(loss[0]), want_loss, rtol=1e-5)

# bwd: grads ~ mean of per-worker grads, up to quantization noise
def one(i):
    return jax.grad(lambda w: ((x[i] @ w) ** 2).sum())(w_full)
gtrue = np.mean([np.asarray(one(i)) for i in range(L)], axis=0)
gq = np.asarray(grads)
assert gq.shape == (d0, d1)
rel = np.abs(gq - gtrue).mean() / (np.abs(gtrue).mean() + 1e-9)
assert rel < 0.2, rel           # 9-level quantization noise
# direction must agree strongly
cos = (gq * gtrue).sum() / (np.linalg.norm(gq) * np.linalg.norm(gtrue))
assert cos > 0.98, cos
print("fsdp-gather OK, cos =", cos)

# fp quantizer: gradient must be EXACT (pure psum_scatter path)
gfp = comm.make_fsdp_gather(make_quantizer("fp"), DP, dim=0,
                            compute_dtype=jnp.float32)
def ffp(wl, xl, key):
    xl = xl[0]
    def loss(wl):
        return ((xl @ gfp(wl, key)) ** 2).sum()
    return jax.grad(loss)(wl)
g2 = np.asarray(shmap(ffp, (P("data", None), P("data", None, None), P()),
                P("data", None))(w_full, x, jax.random.key(6)))
np.testing.assert_allclose(g2, gtrue, rtol=1e-4, atol=1e-5)
print("fsdp-gather-fp exact OK")
""")


def test_multi_axis_dp():
    """dp over BOTH mesh axes at once (the (pod, data) case)."""
    run_devices(COMMON + """
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
n = 2000
g = jax.random.laplace(jax.random.key(8), (8, n)) * 0.1
qz = make_quantizer("orq-5", bucket_size=256)

def f(gl):
    gl = gl[0]
    out = comm.quantized_all_reduce_mean(
        gl, qz, jax.random.key(9), ("pod", "data"))
    return out[None]

fn = jax.jit(shard_map(f, mesh=mesh2,
             in_specs=(P(("pod", "data"), None),),
             out_specs=P(("pod", "data"), None),
             axis_names={"pod", "data"}, check_vma=False))
out = np.asarray(fn(g))
for w in range(1, 8):
    np.testing.assert_array_equal(out[0], out[w])
# two quantization passes (worker->server, server->worker) of 5-level ORQ
err = np.abs(out[0] - np.asarray(g.mean(0))).mean()
assert err < 0.06, err
print("multi-axis OK")
""")
