"""σ-clip mask-consistency regressions (no hypothesis dependency — these
must run even without the optional test extras).

``Quantizer.assign`` used to rebuild an all-True mask for its σ-clip, so a
ragged bucket's zero padding deflated the σ estimate relative to ``fit``:
the rounding saw different clipped values than the levels were fitted on.
The real bucket mask is now threaded through both the quantizer and the
comm wire path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as B
from repro.core import clipping, make_quantizer
from repro.core.comm import wire

jax.config.update("jax_platform_name", "cpu")


def _ragged_outliers(n=40):
    """Heavy-tailed ragged data where σ-clipping is actually engaged, so
    the padded-vs-real σ estimates produce different indices."""
    base = jax.random.laplace(jax.random.key(3), (n,))
    return base.at[::5].mul(6.0)


@pytest.mark.parametrize("name", ["orq-5", "terngrad", "qsgd-5"])
def test_clip_ragged_bucket_fit_assign_consistent(name):
    """quantize on a ragged flat with clip_c set must equal
    clip-once-then-quantize-unclipped, level for level, index for index."""
    d = 64                           # one ragged bucket, 24 padded slots
    g = _ragged_outliers()
    qz = make_quantizer(name, bucket_size=d, clip_c=1.5)
    q = qz.quantize(g, jax.random.key(7))

    qz0 = make_quantizer(name, bucket_size=d)      # no clip
    bkt, mask = B.to_buckets(g, d)
    clipped = clipping.sigma_clip(bkt, mask, 1.5)
    lv = qz0.fit(clipped, mask)
    idx = jnp.where(mask, qz0.assign(clipped, lv, jax.random.key(7)), 0)
    np.testing.assert_array_equal(np.asarray(q.levels), np.asarray(lv))
    np.testing.assert_array_equal(np.asarray(q.idx), np.asarray(idx))

    # the discriminator: the legacy all-True-mask σ-clip (mask=None) gives
    # DIFFERENT indices on this data — i.e. this test fails pre-fix
    legacy = jnp.where(mask, qz.assign(bkt, lv, jax.random.key(7)), 0)
    assert int((np.asarray(legacy) != np.asarray(q.idx)).sum()) > 0


def test_clip_ragged_bucket_wire_path_consistent():
    """The comm wire path (wire.encode, used by both collective phases and
    the fused engines) threads the same mask through its σ-clip."""
    d = 64
    g = _ragged_outliers()
    qz = make_quantizer("orq-5", bucket_size=d, clip_c=1.5)
    bkt, mask = B.to_buckets(g, d)
    words, lv = wire.encode(qz, bkt, mask, jax.random.key(9),
                            use_kernels=False)

    qz0 = make_quantizer("orq-5", bucket_size=d)
    clipped = clipping.sigma_clip(bkt, mask, 1.5)
    words0, lv0 = wire.encode(qz0, clipped, mask, jax.random.key(9),
                              use_kernels=False)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv0))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(words0))


def test_clip_full_bucket_unchanged_by_fix():
    """Bucket-aligned flats (no padding) are unaffected by the mask
    threading: mask=None legacy behaviour == real-mask behaviour."""
    d = 64
    g = jax.random.laplace(jax.random.key(14), (2 * d,)) * 0.01
    qz = make_quantizer("orq-5", bucket_size=d, clip_c=2.0)
    bkt, mask = B.to_buckets(g, d)
    lv = qz.fit(bkt, mask)
    with_mask = qz.assign(bkt, lv, jax.random.key(3), mask=mask)
    legacy = qz.assign(bkt, lv, jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(with_mask), np.asarray(legacy))
