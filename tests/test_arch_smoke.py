"""Per-architecture smoke tests: reduced same-family variants (<=2 layers,
d_model<=512, <=4 experts) run one forward + one train step + one decode
step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models import LM

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.encoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.num_frames, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
class TestArchSmoke:
    def test_full_config_is_exact(self, arch):
        """The full config matches the assigned spec table."""
        cfg = get_config(arch)
        expect = {
            "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
            "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
            "whisper-base": (6, 512, 8, 8, 2048, 51865),
            "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
            "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
            "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
            "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
            "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
            "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
            "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
        }[arch]
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expect, (got, expect)

    def test_smoke_config_reduced(self, arch):
        cfg = get_smoke_config(arch)
        assert cfg.num_layers <= 2
        assert cfg.d_model <= 512
        if cfg.moe:
            assert cfg.moe.num_experts <= 4

    def test_forward_and_train_step(self, arch):
        cfg = get_smoke_config(arch)
        m = LM(cfg)
        params = m.init(jax.random.key(0))
        batch = make_batch(cfg, jax.random.key(1))

        @jax.jit
        def step(p, b):
            (loss, metrics), grads = jax.value_and_grad(
                m.loss, has_aux=True)(p, b)
            # one plain SGD step (optimizer substrate tested separately)
            new_p = jax.tree_util.tree_map(lambda w, g: w - 0.01 * g,
                                           p, grads)
            return loss, metrics, new_p

        loss, metrics, new_p = step(params, batch)
        assert np.isfinite(float(loss)), arch
        assert float(metrics["nll"]) > 0
        # params actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), params, new_p)
        assert max(jax.tree_util.tree_leaves(moved)) > 0
        # logits shape
        lg, aux = jax.jit(lambda p, b: m.logits(
            p, b["tokens"], enc_embeds=b.get("enc_embeds")))(params, batch)
        assert lg.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(lg).all()), arch

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        m = LM(cfg)
        params = m.init(jax.random.key(0))
        cache = m.init_cache(B, max_len=64)
        if cfg.encoder:
            enc = jax.random.normal(
                jax.random.key(2), (B, cfg.encoder.num_frames, cfg.d_model)
            ) * 0.02
            cache = jax.jit(m.warm_cache)(params, cache, enc)
        tok = jnp.zeros((B, 1), jnp.int32)
        lg, cache2 = jax.jit(m.decode_step)(params, cache, tok,
                                            jnp.int32(0))
        assert lg.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(lg).all()), arch
        # cache must change
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).max()),
            cache, cache2)
        assert max(jax.tree_util.tree_leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ["gemma2-9b", "mixtral-8x22b", "rwkv6-3b",
                                  "jamba-v0.1-52b"])
def test_decode_matches_forward(arch):
    """Sequential decode reproduces the training forward's logits.

    MoE configs get a drop-free capacity factor: the training forward drops
    over-capacity tokens (by design) while one-token decode never does, so
    exact equivalence only holds without drops."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = LM(cfg)
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, 12), 0,
                                cfg.vocab_size)
    lg_fwd, _ = jax.jit(lambda p, t: m.logits(p, t))(params, tokens)
    cache = m.init_cache(1, max_len=33)
    lg_last, _ = jax.jit(lambda p, c, t: m.prefill(p, c, t))(params, cache,
                                                             tokens)
    err = float(jnp.abs(lg_last[:, 0] - lg_fwd[:, -1]).max())
    scale = float(jnp.abs(lg_fwd[:, -1]).max()) + 1e-6
    assert err / scale < 0.05, (arch, err, scale)
