"""make_host_mesh validation (PR satellite) + the shared dp-axis helper.

The old ``data or n // model`` truthiness silently rewrote an explicit
``data=0``; an indivisible ``model`` surfaced as a downstream XLA shape
error. Both must now die here with a clear message. Runs on the default
single-device test view (every error path is reachable with n=1).
"""
import jax
import pytest

from repro.launch.mesh import make_host_mesh
from repro.utils.sharding import dp_axis_names

jax.config.update("jax_platform_name", "cpu")


class TestMakeHostMeshValidation:
    def test_default_ok(self):
        mesh = make_host_mesh()
        assert mesh.axis_names == ("data", "model")
        assert mesh.devices.size == len(jax.devices())

    def test_model_not_dividing_devices(self):
        n = len(jax.devices())
        with pytest.raises(ValueError, match="does not divide"):
            make_host_mesh(model=n + 1)

    def test_explicit_zero_data_rejected(self):
        # the old truthiness fallback silently replaced data=0
        with pytest.raises(ValueError, match="data must be a positive"):
            make_host_mesh(data=0)

    def test_bad_types_rejected(self):
        with pytest.raises(ValueError, match="model must be a positive"):
            make_host_mesh(model=0)
        with pytest.raises(ValueError, match="model must be a positive"):
            make_host_mesh(model=-2)
        with pytest.raises(ValueError, match="pods must be a positive"):
            make_host_mesh(pods=0)
        with pytest.raises(ValueError, match="data must be a positive"):
            make_host_mesh(data=2.0)  # type: ignore[arg-type]

    def test_product_mismatch(self):
        n = len(jax.devices())
        with pytest.raises(ValueError, match="must equal the device count"):
            make_host_mesh(data=n + 3)

    def test_pods_not_dividing(self):
        n = len(jax.devices())
        with pytest.raises(ValueError, match="does not divide"):
            make_host_mesh(pods=n + 1)


class TestDpAxisNames:
    """The deduped dp-axis selection (utils/sharding.dp_axis_names): the
    single source the train step, dryrun, and the hierarchy split share."""

    def test_orders_pod_before_data(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert dp_axis_names(mesh) == ("data",)
        mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        assert dp_axis_names(mesh3) == ("pod", "data")

    def test_no_dp_axes(self):
        mesh = jax.make_mesh((1,), ("model",))
        assert dp_axis_names(mesh) == ()

    def test_train_step_uses_it(self):
        from repro.train.step import _dp_axes
        mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        assert _dp_axes(mesh3) == dp_axis_names(mesh3)
