"""Per-kernel shape/dtype sweeps asserting against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def _inputs(seed, nb, d, s, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    v = (jax.random.laplace(k1, (nb, d)) * 0.1).astype(dtype)
    # ascending per-row level tables spanning the data
    base = jnp.sort(jax.random.uniform(k2, (nb, s), minval=-0.5, maxval=0.5),
                    axis=-1)
    bits = jax.random.bits(k3, (nb, d), dtype=jnp.uint32)
    return v, base, bits


class TestQuantRR:
    @pytest.mark.parametrize("s", [2, 3, 5, 9, 17])
    @pytest.mark.parametrize("nb,d", [(1, 128), (3, 256), (8, 2048), (17, 512)])
    def test_matches_ref(self, s, nb, d):
        v, lv, bits = _inputs(s * 100 + nb, nb, d, s)
        got = ops.quant_rr(v, lv, bits)
        want = ref.quant_rr_ref(v, lv, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        v, lv, bits = _inputs(7, 4, 256, 5, dtype)
        got = ops.quant_rr(v, lv, bits)
        want = ref.quant_rr_ref(v, lv, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_outside_range_values(self):
        v = jnp.array([[-10.0, 10.0, 0.0, 0.2] + [0.0] * 124])
        lv = jnp.array([[-1.0, 0.0, 1.0]])
        bits = jnp.zeros((1, 128), dtype=jnp.uint32)  # u=0 -> always round up
        got = np.asarray(ops.quant_rr(v, lv, bits))
        want = np.asarray(ref.quant_rr_ref(v, lv, bits))
        np.testing.assert_array_equal(got, want)
        assert got[0, 0] == 0      # below range -> bottom level
        assert got[0, 1] == 2      # above range -> top level

    def test_degenerate_equal_levels(self):
        v, _, bits = _inputs(9, 2, 128, 3)
        lv = jnp.zeros((2, 3))
        got = ops.quant_rr(v, lv, bits)
        want = ref.quant_rr_ref(v, lv, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestBinGradKernel:
    @pytest.mark.parametrize("nb,d", [(1, 128), (5, 512), (8, 2048)])
    def test_matches_ref(self, nb, d):
        v, _, _ = _inputs(nb, nb, d, 2)
        b0 = v.mean(axis=-1, keepdims=True)
        mask = jnp.ones((nb, d), dtype=bool)
        gi, gp = ops.bingrad_pass(v, b0, mask)
        wi, wp = ref.bingrad_pass_ref(v, b0, mask)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_allclose(np.asarray(gp), np.asarray(wp), rtol=1e-6)

    def test_masked(self):
        v, _, _ = _inputs(3, 2, 256, 2)
        mask = jnp.arange(256)[None, :] < jnp.array([[100], [256]])
        b0 = jnp.zeros((2, 1))
        gi, gp = ops.bingrad_pass(v, b0, mask)
        wi, wp = ref.bingrad_pass_ref(v, b0, mask)
        np.testing.assert_allclose(np.asarray(gp), np.asarray(wp), rtol=1e-6)
        assert float(gp[0, 1] + gp[0, 3]) == 100.0  # masked counts


class TestDequantAvg:
    @pytest.mark.parametrize("L", [1, 2, 4, 8])
    @pytest.mark.parametrize("s", [2, 3, 9])
    def test_matches_ref(self, L, s):
        nb, d = 5, 256
        key = jax.random.key(L * 10 + s)
        idx = jax.random.randint(key, (L, nb, d), 0, s)
        lv = jnp.sort(jax.random.normal(key, (L, nb, s)), axis=-1)
        got = ops.dequant_avg(idx, lv)
        want = ref.dequant_avg_ref(idx, lv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)

    def test_roundtrip_with_quant(self):
        """quantize with the kernel, decode with the kernel: unbiased-ish."""
        v, lv, bits = _inputs(11, 4, 2048, 9)
        idx = ops.quant_rr(v, lv, bits)
        out = ops.dequant_avg(idx[None], lv[None])
        # every decoded value is one of the two bracketing levels
        err = np.abs(np.asarray(out) - np.asarray(v))
        gaps = np.diff(np.asarray(lv), axis=-1).max()
        assert err.max() <= gaps + 0.5  # values outside level range clip


class TestBitpack:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4, 5])
    @pytest.mark.parametrize("nb,d", [(1, 64), (4, 517), (9, 2048)])
    def test_pack_unpack_roundtrip(self, bits, nb, d):
        idx = jax.random.randint(jax.random.key(bits), (nb, d), 0, 2 ** bits)
        words = ops.pack(idx, bits)
        assert words.dtype == jnp.uint32
        np.testing.assert_array_equal(
            np.asarray(ops.unpack(words, bits, d)), np.asarray(idx))

    @pytest.mark.parametrize("bits", [2, 4])
    def test_matches_ref(self, bits):
        idx = jax.random.randint(jax.random.key(99), (3, 300), 0, 2 ** bits)
        np.testing.assert_array_equal(
            np.asarray(ops.pack(idx, bits)),
            np.asarray(ref.pack_ref(idx, bits)))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10 ** 6),
    s=st.sampled_from([2, 3, 5, 9, 17]),
    nb=st.integers(1, 12),
    logd=st.integers(7, 12),
)
def test_quant_rr_property(seed, s, nb, logd):
    """Kernel == oracle for arbitrary shapes/levels, incl. ragged rows."""
    d = 2 ** logd
    v, lv, bits = _inputs(seed, nb, d, s)
    got = ops.quant_rr(v, lv, bits)
    want = ref.quant_rr_ref(v, lv, bits)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert got.min() >= 0 and got.max() <= s - 1
