"""Registry round-trip: every advertised method name must parse and report
a coherent wire accounting (no hypothesis dependency — this file must run
even without the optional test extras)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import ALL_METHODS, QuantConfig, make_quantizer

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", ALL_METHODS)
def test_all_methods_parse_and_wire_bits(name):
    qz = make_quantizer(name, bucket_size=512)
    assert qz.s >= 2 or qz.is_identity
    bits = qz.wire_bits_per_element
    assert 1 <= bits <= 5, (name, bits)          # s <= 17 -> <= 5 bits
    assert 2 ** bits >= qz.s, (name, bits, qz.s)  # indices must fit
    if not qz.is_identity:
        # packed wire must actually compress vs f32
        assert qz.wire_bytes(10_000) < 4.0 * 10_000, name


@pytest.mark.parametrize("name", ALL_METHODS)
def test_all_methods_qdq_roundtrip(name):
    g = (jax.random.laplace(jax.random.key(3), (4096,)) * 0.01
         ).astype(jnp.float32)
    qz = QuantConfig(name=name, bucket_size=512).to_quantizer()
    out = qz.qdq(g, jax.random.key(1))
    assert out.shape == g.shape and out.dtype == g.dtype
    assert bool(jnp.isfinite(out).all()), name


def test_all_methods_includes_full_registry():
    # names accepted by make_quantizer that the registry must advertise
    for name in ("minmax2", "orq-17"):
        assert name in ALL_METHODS
