"""Adaptive bit budget: schedule grammar + controller unit tests, frozen-
schedule bit-identity against the equivalent static policy (replicated
flat / two-level / fsdp, with EF, 8 fake devices), EF-residual carry
across a bits change, and the committed BENCH_convergence.json gate."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core import QuantConfig, QuantPolicy
from repro.core.policy import BitBudgetController, BitRamp, BitSchedule
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import ScheduledTrainStep, init_state

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body, n=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestGrammar:
    def test_ramp_parse_and_describe(self):
        s = BitSchedule.parse("embed=orq@5..3,norm|bias=fp,default=orq@4..1")
        assert s.n_entries == 3
        emb, nb, dflt = s.items
        assert isinstance(emb, BitRamp) and (emb.hi, emb.lo) == (5, 3)
        assert isinstance(nb, QuantConfig) and nb.name == "fp"
        assert isinstance(dflt, BitRamp) and (dflt.hi, dflt.lo) == (4, 1)
        assert not s.is_static
        assert BitSchedule.parse(s.describe()).describe() == s.describe()

    def test_constant_shorthand_is_static(self):
        s = BitSchedule.parse("default=orq@4")
        (r,) = s.items
        assert (r.hi, r.lo) == (4, 4) and s.is_static

    def test_hi_above_kernel_level_tile_rejected(self):
        # 6 bits -> s=33 levels overflows the fused kernels' 32-lane
        # level tile (LEVEL_PAD): must fail at parse, not inside pallas
        with pytest.raises(ValueError, match="<= 5"):
            BitSchedule.parse("default=orq@6..2")

    def test_inverted_and_zero_ramps_rejected(self):
        with pytest.raises(ValueError):
            BitSchedule.parse("default=orq@2..4")
        with pytest.raises(ValueError):
            BitSchedule.parse("default=orq@4..0")

    def test_assignment_and_materialization(self):
        s = BitSchedule.parse("norm=fp,default=orq@5..1", bucket_size=512)
        assert s.assignment(0, 100) == (None, 5)
        assert s.assignment(99, 100) == (None, 1)
        assert s.ceil_assignment() == (None, 5)
        assert s.floor_assignment() == (None, 1)
        hi = s.policy_at((None, 5))
        lo = s.policy_at((None, 1))
        assert hi.default.name == "orq-17" and hi.default.bucket_size == 512
        assert lo.default.name == "minmax2"   # b=1 maps to minmax2
        assert hi.rules[0].cfg.name == "fp"
        with pytest.raises(ValueError, match="length"):
            s.policy_at((None, 5, 4))

    def test_phases_dedupe(self):
        s = BitSchedule.parse("default=orq@4..3")
        ph = s.phases(100, 10)
        assert ph[0] == (0, (4,)) and ph[-1][1] == (3,)
        assert len(ph) == 2   # only distinct assignments survive


_NAME_BITS = {"minmax2": 1, "orq-3": 2, "orq-5": 3, "orq-9": 4, "orq-17": 5}


def _bit_cost(sizes):
    """cost_fn pricing a phase policy at bits-proportional bytes."""
    def fn(policy):
        cfgs = [r.cfg for r in policy.rules] + [policy.default]
        return sum(_NAME_BITS.get(c.name, 0) * n / 8.0
                   for c, n in zip(cfgs, sizes))
    return fn


class TestController:
    def test_deterministic_without_budget(self):
        s = BitSchedule.parse("norm=fp,default=orq@5..1")
        ctl = BitBudgetController(s, 100, resolve_every=25)
        assert ctl.assignment_at(0) == s.assignment(0, 100)
        assert ctl.assignment_at(10) == ctl.assignment_at(0)   # same phase
        assert ctl.assignment_at(99) == s.assignment(75, 100)  # phase start
        assert all(not d["stats_driven"] for d in ctl.decisions)

    def test_water_fill_respects_budget(self):
        s = BitSchedule.parse("embed=orq@5..1,default=orq@5..1")
        sizes = (1000, 1000)
        # budget only fits ~6 total bits across the two entries at phase 0
        ctl = BitBudgetController(s, 100, resolve_every=50,
                                  dcn_budget_bytes=6 * 1000 / 8.0,
                                  group_sizes=sizes,
                                  cost_fn=_bit_cost(sizes))
        a = ctl.assignment_at(0)
        assert sum(a) <= 6 and all(1 <= b <= 5 for b in a)
        est = ctl.decisions[0]["est_dcn_bytes"]
        assert est <= 6 * 1000 / 8.0

    def test_water_fill_follows_observed_variance(self):
        s = BitSchedule.parse("embed=orq@5..1,default=orq@5..1")
        sizes = (1000, 1000)
        ctl = BitBudgetController(s, 100, resolve_every=50,
                                  dcn_budget_bytes=5 * 1000 / 8.0,
                                  group_sizes=sizes,
                                  cost_fn=_bit_cost(sizes))
        ctl.observe([{"sigma_sq": 10.0, "clip_frac": 0.0, "ef_norm_sq": 0.0},
                     {"sigma_sq": 0.01, "clip_frac": 0.0, "ef_norm_sq": 0.0}])
        a = ctl.assignment_at(0)
        assert a[0] > a[1], a   # noisier entry wins the contested bits
        assert ctl.decisions[0]["stats_driven"]

    def test_blocked_entry_does_not_starve_smaller_one(self):
        # big entry's next bit never fits; the small entry must still fill
        s = BitSchedule.parse("embed=orq@5..1,default=orq@5..1")
        sizes = (100, 10000)
        ctl = BitBudgetController(s, 100, resolve_every=50,
                                  dcn_budget_bytes=(10000 + 5 * 100) / 8.0,
                                  group_sizes=sizes,
                                  cost_fn=_bit_cost(sizes))
        a = ctl.assignment_at(0)
        assert a[1] == 1 and a[0] == 5, a

    def test_observe_validates_length(self):
        s = BitSchedule.parse("norm=fp,default=orq@5..1")
        ctl = BitBudgetController(s, 100)
        with pytest.raises(ValueError):
            ctl.observe([{"sigma_sq": 1.0, "clip_frac": 0.0,
                          "ef_norm_sq": 0.0}])


def _setup(seed=0):
    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                       seed=seed)
    return model, mesh, data


def _run_scheduled(spec, steps, resolve_every, ef_reset_at=None):
    model, mesh, data = _setup()
    ctl = BitBudgetController(BitSchedule.parse(spec, bucket_size=512),
                              steps, resolve_every=resolve_every)
    tcfg = TrainConfig(mode="replicated", error_feedback=True)
    step_fn = ScheduledTrainStep(model, mesh, tcfg, ctl, constant_lr(0.05))
    state = init_state(model, mesh, step_fn.init_config, jax.random.key(0))
    seen = []
    for i in range(steps):
        if ef_reset_at is not None and i == ef_reset_at:
            state = state._replace(
                ef=jax.tree_util.tree_map(lambda x: x * 0.0, state.ef))
        state, m = step_fn(state, data.batch(i), jax.random.key(7))
        seen.append(step_fn.last_assignment)
    return state, seen, step_fn


class TestScheduledStep:
    def test_frozen_schedule_bit_identical_to_static(self):
        """A constant schedule compiles ONE engine and reproduces the
        static policy's params stream exactly (same PRNG, same kernels)."""
        model, mesh, data = _setup()
        steps = 6
        sstate = init_state(
            model, mesh,
            TrainConfig(policy=QuantPolicy.parse(
                "norm|bias=fp,default=orq-9", bucket_size=512),
                mode="replicated", error_feedback=True,
                group_by_rule=True),
            jax.random.key(0))
        step_fn, _ = make_train_step(
            model, mesh,
            TrainConfig(policy=QuantPolicy.parse(
                "norm|bias=fp,default=orq-9", bucket_size=512),
                mode="replicated", error_feedback=True,
                group_by_rule=True),
            constant_lr(0.05))
        for i in range(steps):
            sstate, _ = step_fn(sstate, data.batch(i), jax.random.key(7))
        dstate, seen, sched_fn = _run_scheduled(
            "norm|bias=fp,default=orq@4", steps, resolve_every=2)
        assert set(seen) == {(None, 4)}
        assert len(sched_fn._cache) == 1
        for a, b in zip(jax.tree_util.tree_leaves(sstate.params),
                        jax.tree_util.tree_leaves(dstate.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ef_carries_across_bits_change(self):
        """EF residuals survive a phase boundary at bits-invariant shapes
        — and actually matter: zeroing them at the boundary changes the
        params stream."""
        steps, boundary = 8, 4
        carried, seen, _ = _run_scheduled("norm|bias=fp,default=orq@4..2",
                                          steps, resolve_every=boundary)
        assert len(set(seen)) > 1, seen   # the bits really changed
        zeroed, _, _ = _run_scheduled("norm|bias=fp,default=orq@4..2",
                                      steps, resolve_every=boundary,
                                      ef_reset_at=boundary)
        c = jax.tree_util.tree_leaves(carried.params)
        z = jax.tree_util.tree_leaves(zeroed.params)
        assert all(np.isfinite(np.asarray(x)).all() for x in c)
        assert any(not np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(c, z))
        # residuals after the boundary are live, not silently zeroed
        assert any(float(np.abs(np.asarray(e)).max()) > 0
                   for e in jax.tree_util.tree_leaves(carried.ef))


def test_frozen_schedule_matches_static_multi_device():
    """Replicated flat (8), two-level (2x4) and fsdp (8): the frozen
    schedule's params after 3 EF steps equal the static policy's."""
    run_devices("""
import jax, numpy as np
from repro.configs.base import get_smoke_config
from repro.core import QuantPolicy
from repro.core.policy import BitBudgetController, BitSchedule
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import ScheduledTrainStep, init_state

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                   seed=3)
SPEC_S = "norm|bias=fp,default=orq-9"
SPEC_D = "norm|bias=fp,default=orq@4"
for mode, hier, shape, axes in [("replicated", "flat", (8,), ("data",)),
                                ("replicated", "two_level", (2, 4),
                                 ("pod", "data")),
                                ("fsdp", "flat", (8,), ("data",))]:
    mesh = jax.make_mesh(shape, axes)
    tcfg = TrainConfig(policy=QuantPolicy.parse(SPEC_S, bucket_size=512),
                       mode=mode, hierarchy=hier, error_feedback=True,
                       group_by_rule=True)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    for i in range(3):
        state, _ = step_fn(state, data.batch(i), jax.random.key(7))

    ctl = BitBudgetController(BitSchedule.parse(SPEC_D, bucket_size=512),
                              3, resolve_every=1)
    dcfg = TrainConfig(mode=mode, hierarchy=hier, error_feedback=True)
    sched_fn = ScheduledTrainStep(model, mesh, dcfg, ctl, constant_lr(0.05))
    dstate = init_state(model, mesh, sched_fn.init_config, jax.random.key(0))
    for i in range(3):
        dstate, _ = sched_fn(dstate, data.batch(i), jax.random.key(7))
    assert len(sched_fn._cache) == 1
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(dstate.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK", mode, hier)
""")


def test_bench_convergence_snapshot_gate():
    """The committed dynamic-vs-static snapshot certifies the ISSUE gate:
    dynamic loss <= best static at strictly fewer total DCN bytes."""
    path = os.path.join(ROOT, "benchmarks", "BENCH_convergence.json")
    with open(path) as f:
        d = json.load(f)
    assert d["schema"] == 1
    best = d["gate"]["best_static"]
    assert best in d["static"]
    assert d["gate"]["dynamic_loss_le_best_static"] is True
    assert d["gate"]["dynamic_bytes_lt_best_static"] is True
    # the booleans must be consistent with the recorded numbers
    assert (d["dynamic"]["final_loss"]
            <= d["static"][best]["final_loss"])
    assert (d["dynamic"]["total_dcn_bytes"]
            < d["static"][best]["total_dcn_bytes"])
    assert d["dynamic"]["decisions"], "controller recorded no decisions"
