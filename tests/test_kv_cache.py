"""Paged KV cache: byte math, allocator, append/gather, rbits stream.

The compression acceptance criterion lives here: at the real archs'
KV dims, orq-5 and bingrad-b pages cost <= 1/4 of bf16 at equal
batch x context (including the per-token level-table overhead).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.kv_cache import (KVQuantSpec, PageAllocator, TRASH_PAGE,
                                  append_rows, gather_context,
                                  init_kv_pools, pool_bytes,
                                  token_bytes_ratio, token_rbits)

jax.config.update("jax_platform_name", "cpu")


class TestKVQuantSpec:
    def test_bf16_token_bytes(self):
        # K + V, d = KV*hd elements each, 2 bytes per element
        assert KVQuantSpec("bf16", 4, 32).token_bytes() == 2 * 128 * 2

    def test_quantized_token_bytes(self):
        spec = KVQuantSpec("orq-9", 4, 32)     # d=128, 4 bits -> nw=16
        assert spec.bits == 4 and spec.nw == 16 and spec.s == 9
        assert spec.token_bytes() == 2 * (4 * 16 + 4 * 9)

    @pytest.mark.parametrize("kv,hd", [
        (12, 64),    # lm-100m
        (8, 256),    # gemma2-9b
    ])
    def test_compression_ratio_quarter_at_real_dims(self, kv, hd):
        """PR-7 acceptance: quantized cache bytes <= 1/4 of bf16 at equal
        batch x context for the gated schemes."""
        for scheme in ("orq-5", "bingrad-b"):
            r = token_bytes_ratio(KVQuantSpec(scheme, kv, hd))
            assert r <= 0.25, (scheme, kv, hd, r)
        # 1-bit pages are ~14x smaller even with the level tables
        assert token_bytes_ratio(KVQuantSpec("bingrad-b", kv, hd)) < 0.10

    def test_rejects_identity_scheme(self):
        with pytest.raises(ValueError, match="fused one-pass encode"):
            KVQuantSpec("fp", 4, 32).quantizer()


class TestPageAllocator:
    def test_trash_page_reserved(self):
        a = PageAllocator(5)
        got = a.alloc(4)
        assert got is not None and TRASH_PAGE not in got
        assert sorted(got) == [1, 2, 3, 4]

    def test_alloc_all_or_nothing(self):
        a = PageAllocator(4)
        assert a.alloc(4) is None          # only 3 allocatable
        assert a.num_free == 3
        got = a.alloc(2)
        assert a.num_free == 1
        a.free(got)
        assert a.num_free == 3

    def test_free_trash_page_raises(self):
        with pytest.raises(ValueError, match="trash page"):
            PageAllocator(4).free([TRASH_PAGE])

    def test_too_small_pool_raises(self):
        with pytest.raises(ValueError, match=">= 2 pages"):
            PageAllocator(1)


class TestPools:
    def _model(self):
        from repro.configs.base import get_smoke_config
        from repro.models import LM
        return LM(get_smoke_config("lm-100m"))

    def test_pool_shapes_and_bytes(self):
        model = self._model()
        kvq = KVQuantSpec("orq-9", model.cfg.num_kv_heads,
                          model.cfg.resolved_head_dim)
        pools = init_kv_pools(model, kvq, num_pages=9, page_size=4)
        leaves = jax.tree_util.tree_leaves(pools)
        reps = sum(g.repeats * len(g.unit) for g in model.groups)
        # kw/klv/vw/vlv per layer; leading axis carries the scan repeats
        assert all(x.shape[1:3] == (9, 4) for x in leaves)
        assert pool_bytes(pools) == sum(
            x.size * x.dtype.itemsize for x in leaves)
        # total pool bytes = layers * pages * page_size * token_bytes
        assert pool_bytes(pools) == reps * 9 * 4 * kvq.token_bytes()

    def test_bf16_pool_bytes(self):
        model = self._model()
        kvq = KVQuantSpec("bf16", model.cfg.num_kv_heads,
                          model.cfg.resolved_head_dim)
        pools = init_kv_pools(model, kvq, num_pages=5, page_size=4)
        reps = sum(g.repeats * len(g.unit) for g in model.groups)
        assert pool_bytes(pools) == reps * 5 * 4 * kvq.token_bytes()

    def test_append_then_gather_round_trip(self):
        """Tokens scattered at (page, slot) come back at context index ==
        absolute position when gathered through the page table."""
        S, nw, s = 4, 3, 2
        pool = {"kw": jnp.zeros((6, S, nw), jnp.uint32),
                "klv": jnp.zeros((6, S, s), jnp.float32)}
        # sequence owns pages [2, 5]; write tokens at abs positions 1, 5
        table = jnp.asarray([[2, 5]], jnp.int32)
        pos = np.asarray([1, 5])
        pages = jnp.asarray(table[0][pos // S])
        slots = jnp.asarray(pos % S)
        rows_w = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.uint32)
        rows_l = jnp.asarray([[.1, .2], [.3, .4]], jnp.float32)
        pool = append_rows(pool, pages, slots,
                           {"kw": rows_w, "klv": rows_l})
        ctx = gather_context(pool, table)
        assert ctx["kw"].shape == (1, 2 * S, nw)
        np.testing.assert_array_equal(np.asarray(ctx["kw"][0, 1]),
                                      np.asarray(rows_w[0]))
        np.testing.assert_array_equal(np.asarray(ctx["kw"][0, 5]),
                                      np.asarray(rows_w[1]))
        np.testing.assert_array_equal(np.asarray(ctx["klv"][0, 5]),
                                      np.asarray(rows_l[1]))
        # untouched slots stay zero
        assert int(jnp.abs(ctx["kw"][0, 0]).sum()) == 0


class TestTokenRbits:
    def test_keyed_on_seed_pos_salt_rep_only(self):
        """The stream depends on (seed, position, salt, rep) — NOT on the
        row's place in the batch (mixed-vs-alone determinism)."""
        d = 16
        seeds = jnp.asarray([7, 7, 9], jnp.int32)
        pos = jnp.asarray([3, 4, 3], jnp.int32)
        r = token_rbits(seeds, pos, salt=11, rep=jnp.int32(0), d=d)
        assert r.shape == (3, d)
        # same (seed, pos) alone == in a batch, any slot
        alone = token_rbits(seeds[1:2], pos[1:2], salt=11,
                            rep=jnp.int32(0), d=d)
        np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(alone[0]))
        # varying any key component changes the bits
        assert not np.array_equal(np.asarray(r[0]), np.asarray(r[1]))
        assert not np.array_equal(np.asarray(r[0]), np.asarray(r[2]))
        r_salt = token_rbits(seeds[:1], pos[:1], salt=12,
                             rep=jnp.int32(0), d=d)
        r_rep = token_rbits(seeds[:1], pos[:1], salt=11,
                            rep=jnp.int32(1), d=d)
        assert not np.array_equal(np.asarray(r[0]), np.asarray(r_salt[0]))
        assert not np.array_equal(np.asarray(r[0]), np.asarray(r_rep[0]))
