"""End-to-end training integration: single-device Algorithm 2 loop (loss
decreases under quantization) and multi-device fsdp/replicated equivalence
(subprocess with 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body, n=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _train_single(quant_name: str, steps: int = 30):
    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    tcfg = TrainConfig(policy=QuantConfig(name=quant_name, bucket_size=512),
                       mode="replicated")
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                       seed=3)
    losses = []
    for i in range(steps):
        state, metrics = step_fn(state, data.batch(i), jax.random.key(42))
        losses.append(float(metrics["loss"]))
    return losses


class TestSingleMachine:
    """Paper's single-machine mode: grads quantize->dequantize every step."""

    def test_fp_loss_decreases(self):
        losses = _train_single("fp")
        assert losses[-1] < losses[0] - 0.5, losses[::10]

    @pytest.mark.parametrize("name", ["orq-9", "bingrad-b", "terngrad"])
    def test_quantized_loss_decreases(self, name):
        losses = _train_single(name)
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0] - 0.3, (name, losses[::10])


def test_fsdp_mode_multi_device():
    """fsdp mode on a 4x2 (data, model) mesh: runs, loss decreases, and the
    fp-quantizer fsdp step matches the replicated fp step numerically."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                   seed=3)

def run(mode, quant):
    tcfg = TrainConfig(policy=QuantConfig(name=quant, bucket_size=512),
                       mode=mode)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, plan = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    losses = []
    for i in range(8):
        state, m = step_fn(state, data.batch(i), jax.random.key(42))
        losses.append(float(m["loss"]))
    return losses, state

l_fsdp_fp, s1 = run("fsdp", "fp")
l_repl_fp, s2 = run("replicated", "fp")
print("fsdp fp:", l_fsdp_fp)
print("repl fp:", l_repl_fp)
# same math up to bf16 gather noise and reduction order
np.testing.assert_allclose(l_fsdp_fp, l_repl_fp, rtol=0.05)
assert l_fsdp_fp[-1] < l_fsdp_fp[0]

l_q, _ = run("fsdp", "orq-5")
print("fsdp orq-5:", l_q)
assert np.isfinite(l_q).all()
assert l_q[-1] < l_q[0]
print("OK")
""")


def test_whisper_train_multi_device():
    """Enc-dec arch trains under fsdp mode (exercises encoder gathers)."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

cfg = get_smoke_config("whisper-base")
model = LM(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
tcfg = TrainConfig(policy=QuantConfig(name="orq-5", bucket_size=256),
                   mode="fsdp")
state = init_state(model, mesh, tcfg, jax.random.key(0))
step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
key = jax.random.key(1)
batch = {
    "tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
    "enc_embeds": jax.random.normal(key, (8, cfg.encoder.num_frames,
                                          cfg.d_model)) * 0.02,
}
for i in range(3):
    state, m = step_fn(state, batch, jax.random.key(2))
    assert np.isfinite(float(m["loss"])), m
print("whisper OK", float(m["loss"]))
""")


def test_moe_arch_multi_device():
    """MoE + hybrid archs train under fsdp with quantized comm."""
    run_devices("""
import jax, numpy as np
from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

mesh = jax.make_mesh((4, 2), ("data", "model"))
for arch in ["mixtral-8x22b", "jamba-v0.1-52b", "rwkv6-3b"]:
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    tcfg = TrainConfig(policy=QuantConfig(name="terngrad", bucket_size=256),
                       mode="fsdp")
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.02))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 16), 0,
                                          cfg.vocab_size)}
    state, m = step_fn(state, batch, jax.random.key(2))
    assert np.isfinite(float(m["loss"])), arch
    print(arch, "OK", float(m["loss"]))
""")
