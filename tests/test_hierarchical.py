"""Hierarchical two-level (ICI/DCN) exchange suite (PR tentpole).

Covers: the axis-split policy; single-pod bit-identity (two_level on a
mesh without a pod axis IS the flat exchange, traced and trained);
mean/variance parity with flat on a (2, 4) pod x data mesh for
orq-9/terngrad and fp exactness; EF residual shapes pinned to the
quantized inter axis (1/L_intra of the flat buffers); per-axis traced
collective counts (quantized all_to_all/all_gather over ``pod`` only);
and the per-link (ICI vs DCN) static accounting.

Multi-device cases run in subprocesses with XLA_FLAGS forcing 8 host
devices (the main test process must keep the default single-device view,
per the repo's dry-run-only rule for fake device counts).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.core import comm, make_quantizer

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestAxisSplit:
    def test_two_level_splits_pod_off(self):
        assert comm.split_dp_axes(("pod", "data"), "two_level") == \
            (("data",), ("pod",))

    def test_single_pod_degenerates_to_flat(self):
        assert comm.split_dp_axes(("data",), "two_level") == ((), ("data",))
        assert comm.split_dp_axes(("pod",), "two_level") == ((), ("pod",))

    def test_flat_and_auto(self):
        assert comm.split_dp_axes(("pod", "data"), "flat") == \
            ((), ("pod", "data"))
        assert comm.split_dp_axes(("pod", "data"), "auto") == \
            (("data",), ("pod",))
        assert comm.split_dp_axes(("data",), "auto") == ((), ("data",))

    def test_bad_hierarchy_rejected(self):
        with pytest.raises(ValueError, match="hierarchy"):
            comm.split_dp_axes(("data",), "pyramidal")

    def test_inter_must_precede_intra(self):
        # worker-major rows are inter-major; a data-before-pod dp tuple
        # would silently mis-slice the fsdp layout
        with pytest.raises(ValueError, match="precede"):
            comm.split_dp_axes(("data", "pod"), "two_level")

    def test_intra_chunk_len(self):
        assert comm.intra_chunk_len(999, 4) == 250
        assert comm.intra_chunk_len(1000, 4) == 250
        assert comm.intra_chunk_len(7, 1) == 7


class TestEngineStatics:
    def test_overlapping_axes_rejected(self):
        with pytest.raises(ValueError, match="overlap"):
            comm.GradientExchange(make_quantizer("orq-9"), ("pod", "data"),
                                  intra_axes=("data",))

    def test_local_qdq_flat_guarded_on_two_level(self):
        import jax.numpy as jnp
        eng = comm.GradientExchange(make_quantizer("orq-9"), ("pod",),
                                    intra_axes=("data",))
        with pytest.raises(ValueError, match="intra shard"):
            eng.local_qdq_flat(jnp.zeros(8), jax.random.key(0))

    def test_link_stats_dcn_saving(self):
        """On a 2x16 (pod x data) dp mesh the two-level exchange must cut
        quantized DCN bytes by >= 4x (it actually lands near 1/L_intra)."""
        qz = make_quantizer("orq-9", bucket_size=512)
        n = 10_000_000
        flat = comm.link_stats(qz, n, n_intra=16, n_inter=2,
                               two_level=False)
        two = comm.link_stats(qz, n, n_intra=16, n_inter=2, two_level=True)
        assert flat["dcn_q_bytes"] > 0
        assert flat["dcn_q_bytes"] / two["dcn_q_bytes"] >= 4.0
        # ICI picks up the fp scatter/gather instead; nothing quantized
        # rides the intra link in two-level mode
        assert two["ici_bytes"] > flat["ici_bytes"]

    def test_link_stats_single_pod_has_no_dcn(self):
        qz = make_quantizer("orq-9", bucket_size=512)
        st = comm.link_stats(qz, 10_000, n_intra=1, n_inter=8,
                             two_level=False)
        # n_inter=8 all off-"pod": everything is DCN by the model
        assert st["dcn_q_bytes"] > 0
        st = comm.link_stats(qz, 10_000, n_intra=8, n_inter=1,
                             two_level=False)
        assert st["dcn_q_bytes"] == 0.0

    def test_policy_link_stats_sharded_and_labels(self):
        from repro.core import QuantPolicy
        policy = QuantPolicy.parse("bias=fp,default=orq-9", bucket_size=512)
        ps = [("w1", 4096), ("w2", 2048), ("bias", 64)]
        st, labels = comm.policy_link_stats(
            policy, ps, n_intra=4, n_inter=2, two_level=True,
            sharded_paths={"w1", "w2"})
        assert sorted(labels) == ["fp", "orq-9/rs"]
        assert st["dcn_q_bytes"] > 0
        flat_st, _ = comm.policy_link_stats(
            policy, ps, n_intra=4, n_inter=2, two_level=False,
            sharded_paths={"w1", "w2"})
        assert st["dcn_q_bytes"] < flat_st["dcn_q_bytes"]

    def test_fsdp_ef_sizes_shrink_by_n_intra(self):
        """Two-level EF residuals live on the intra shard — the quantized
        inter axis only: per-worker buffers shrink by 1/n_intra."""
        import jax.numpy as jnp
        from repro.core import QuantPolicy
        tree = {"b": jnp.zeros((40,)), "w": jnp.zeros((16, 56))}
        policy = QuantPolicy.parse("b=fp,default=orq-9", bucket_size=64)
        kw = dict(paths={"b": "b", "w": "w"},
                  shard_dims={"b": None, "w": 0}, n_shards=8)
        flat = comm.FsdpExchange.build(policy, tree, ("pod", "data"), **kw)
        two = comm.FsdpExchange.build(policy, tree, ("pod", "data"),
                                      intra_axes=("data",), n_intra=4, **kw)
        assert flat.ef_group_sizes() == (None, 16 * 56)
        assert two.ef_group_sizes() == (None, 16 * 56 // 4)
        assert two.inter_axes == ("pod",) and two.n_inter == 2

    def test_fsdp_build_validation(self):
        import jax.numpy as jnp
        from repro.core import QuantPolicy
        tree = {"w": jnp.zeros((16, 56))}
        kw = dict(paths={"w": "w"}, shard_dims={"w": 0}, n_shards=8)
        with pytest.raises(ValueError, match="precede"):
            comm.FsdpExchange.build(QuantPolicy.uniform("orq-9"), tree,
                                    ("data", "pod"), intra_axes=("data",),
                                    n_intra=4, **kw)
        with pytest.raises(ValueError, match="n_intra"):
            comm.FsdpExchange.build(QuantPolicy.uniform("orq-9"), tree,
                                    ("pod", "data"), intra_axes=("data",),
                                    n_intra=3, **kw)


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core import QuantPolicy, comm, make_quantizer
from repro.utils.compat import shard_map
from repro.utils.jaxpr import axis_collectives, collective_axis_counts
"""


def test_single_pod_two_level_bit_identical_to_flat():
    """Acceptance: on a single-pod mesh (no pod axis) hierarchy='two_level'
    must be BIT-IDENTICAL to 'flat' — same traced program, same losses,
    same params after multiple steps, replicated and fsdp."""
    run_devices(COMMON + """
from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((8,), ("data",))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                   seed=3)

for mode in ("replicated", "fsdp"):
    out = {}
    for hier in ("flat", "two_level"):
        tcfg = TrainConfig(policy="orq-9", mode=mode, hierarchy=hier,
                           error_feedback=(mode == "replicated"))
        state = init_state(model, mesh, tcfg, jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        losses = []
        for i in range(3):
            state, m = step_fn(state, data.batch(i), jax.random.key(42))
            losses.append(float(m["loss"]))
        out[hier] = (losses, state)
    lf, sf = out["flat"]
    lt, st = out["two_level"]
    assert lf == lt, (mode, lf, lt)
    for a, b in zip(jax.tree_util.tree_leaves((sf.params, sf.opt, sf.ef)),
                    jax.tree_util.tree_leaves((st.params, st.opt, st.ef))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(mode, "SINGLE-POD-BITEXACT OK")
""")


def test_two_level_exchange_parity_2x4():
    """Exchange-level parity on a (2, 4) pod x data mesh: fp is exact for
    both topologies, orq-9/terngrad stay within quantization variance of
    the true mean (and of each other), and every worker decodes identical
    results."""
    run_devices(COMMON + """
mesh = jax.make_mesh((2, 4), ("pod", "data"))
DP = ("pod", "data")
L = 8
x = jax.random.laplace(jax.random.key(0), (L, 999)) * 0.1
true_mean = np.asarray(x.mean(0))

for name, tol in [("fp", 2e-7), ("orq-9", 0.02), ("terngrad", 0.09)]:
    qz = make_quantizer(name, bucket_size=64)
    flat_eng = comm.GradientExchange(qz, DP)
    two_eng = comm.GradientExchange(qz, ("pod",), intra_axes=("data",))

    def f(xw):
        g = xw[0]
        return (flat_eng.exchange_flat(g, jax.random.key(5))[None],
                two_eng.exchange_flat(g, jax.random.key(5))[None])

    fn = jax.jit(shard_map(f, mesh=mesh,
                           in_specs=(P(("pod", "data"), None),),
                           out_specs=(P(("pod", "data"), None),) * 2,
                           axis_names=("pod", "data"), check_vma=False))
    flat_out, two_out = map(np.asarray, fn(x))
    for w in range(1, L):
        np.testing.assert_array_equal(two_out[0], two_out[w])
        np.testing.assert_array_equal(flat_out[0], flat_out[w])
    ef_ = np.abs(flat_out[0] - true_mean).mean()
    et = np.abs(two_out[0] - true_mean).mean()
    assert ef_ < tol and et < tol, (name, ef_, et)
    assert np.abs(flat_out[0] - two_out[0]).mean() < 2 * tol
    print(name, "PARITY OK", ef_, et)
""")


def test_two_level_ef_residuals_shard_shaped_and_consistent():
    """EF residuals in two-level mode are intra SHARDS: bit-consistent
    with the quantized inter exchange (mean over pods of the local decode
    == the server_requant=False exchange), and the train state's tuple
    buffers have exactly the 1/L_intra shard length per worker."""
    run_devices(COMMON + """
mesh = jax.make_mesh((2, 4), ("pod", "data"))
L, L_I = 8, 4
n = 999
x = jax.random.laplace(jax.random.key(1), (L, n)) * 0.1
qz = make_quantizer("orq-5", bucket_size=64)
eng = comm.GradientExchange(qz, ("pod",), intra_axes=("data",),
                            server_requant=False)
chunk = comm.intra_chunk_len(n, L_I)

def f(xw):
    g = xw[0]
    key = jax.random.key(7)
    shard, valid = eng.intra_scatter(g)
    local = eng.local_qdq_shard(shard, key, valid=valid)
    mean = eng.exchange_shard(shard, key, valid=valid)
    resid = shard - local
    return local[None], mean[None], resid[None], shard[None]

spec = P(("pod", "data"), None)
local, mean, resid, shard = map(np.asarray, jax.jit(shard_map(
    f, mesh=mesh, in_specs=(spec,), out_specs=(spec,) * 4,
    axis_names=("pod", "data"), check_vma=False))(x))
assert local.shape == (L, chunk)      # residuals live on the intra shard
# worker (p, d) holds shard column d; mean over pods p of local decodes
# must equal the quantized inter mean (server_requant=False is exact
# phase-2), per data column
li = local.reshape(2, 4, chunk)
mi = mean.reshape(2, 4, chunk)
np.testing.assert_allclose(li.mean(0), mi[0], rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(resid, shard - local, rtol=1e-6, atol=1e-7)
assert np.abs(resid).max() > 0
print("EF-SHARD OK")

# train-state level: per-group tuple buffers of n_dp * ceil(size/L_i)
from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state, plan_sharding

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8,
                   seed=0)
tcfg = TrainConfig(policy="norm|bias=fp,default=orq-9", mode="replicated",
                   hierarchy="two_level", error_feedback=True)
state = init_state(model, mesh, tcfg, jax.random.key(0))
aparams = jax.eval_shape(model.init, jax.random.key(0))
plan = plan_sharding(model, aparams, mesh)
pex = comm.PartitionedExchange.build(
    tcfg.resolved_policy(), aparams, ("pod",), paths=plan.paths,
    intra_axes=("data",))
want = pex.ef_shard_sizes(L_I)
assert isinstance(state.ef, tuple) and len(state.ef) == len(want)
for e, w in zip(state.ef, want):
    if w is None:
        assert e is None
    else:
        assert e.shape == (L * w,), (e.shape, w)
step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
for i in range(2):
    state, _ = step_fn(state, data.batch(i), jax.random.key(42))
assert any(e is not None and float(np.abs(np.asarray(e)).max()) > 0
           for e in state.ef)
print("EF-STATE OK")
""")


@pytest.mark.slow
def test_two_level_traced_collectives_pod_only():
    """Acceptance: on a (2, 4) pod x data mesh the two-level train step's
    jaxpr runs quantized all_to_all/all_gather ONLY over the pod axis; the
    data axis carries one fp reduce_scatter (+ one fp all_gather in
    replicated mode), counted by walking the jaxpr eqns."""
    run_devices(COMMON + """
from repro.analysis import TraceBundle, run_checks
from repro.analysis.audit import expected_train_collectives
from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import exchange_engines, init_state

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8,
                   seed=0)

def bundle(mode, hier):
    tcfg = TrainConfig(policy="orq-9", mode=mode, hierarchy=hier)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    closed = jax.make_jaxpr(step_fn)(state, data.batch(0),
                                     jax.random.key(1))
    meta = expected_train_collectives(
        exchange_engines(model, mesh, tcfg), mesh, tcfg.pipeline_chunks)
    return TraceBundle(label=f"{mode}/{hier}", kind="train_step",
                       closed=closed, meta=meta), meta

# the engine-derived budgets must SAY what the paper claims before the
# rule checks the trace against them: quantized a2a/ag on pod only, the
# fp scatter/gather bracket on data only, 1 combined-axis fsdp broadcast
b2, m2 = bundle("replicated", "two_level")
exp = m2["expected_collectives"]
assert exp[("all_to_all", ("pod",))] == 2, exp
assert exp[("all_gather", ("pod",))] == 2, exp
assert exp[("reduce_scatter", ("data",))] == 1, exp
assert exp[("all_gather", ("data",))] == 1, exp
assert m2["exclusive_prims"]["all_to_all"] == [("pod",)], m2

bf, mf = bundle("replicated", "flat")
assert mf["expected_collectives"][("all_to_all", ("pod", "data"))] == 2, mf

bs, ms = bundle("fsdp", "two_level")
exp = ms["expected_collectives"]
assert exp[("all_to_all", ("pod",))] == 2, exp
assert exp[("all_gather", ("pod", "data"))] == 1, exp
assert exp[("reduce_scatter", ("data",))] == 1, exp
assert ms["exclusive_prims"]["all_to_all"] == [("pod",)], ms

# ... and the traces must match them exactly (the same collective-budget
# rule the CI matrix audit runs)
fs = run_checks([b2, bf, bs], rules=["collective-budget"])
assert not fs, [str(f) for f in fs]
print("JAXPR-POD-ONLY OK")
""")


def test_fsdp_two_level_consistent_with_flat():
    """fsdp on the (2, 4) mesh: two_level and flat start from the same
    forward (step-1 loss identical), both train finitely, and final
    params agree within quantization variance."""
    run_devices(COMMON + """
from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                   seed=3)

def run(hier, ef=False):
    tcfg = TrainConfig(policy="orq-9", mode="fsdp", hierarchy=hier,
                       error_feedback=ef)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    losses = []
    for i in range(3):
        state, m = step_fn(state, data.batch(i), jax.random.key(42))
        losses.append(float(m["loss"]))
    return losses, state

lf, sf = run("flat")
lt, st = run("two_level")
assert lf[0] == lt[0], (lf, lt)          # identical fused forward
assert np.isfinite(lf).all() and np.isfinite(lt).all()
da = np.concatenate([np.asarray(x).ravel() for x in
                     jax.tree_util.tree_leaves(sf.params)])
db = np.concatenate([np.asarray(x).ravel() for x in
                     jax.tree_util.tree_leaves(st.params)])
assert np.abs(da - db).mean() < 0.05 * np.abs(da).mean()

# EF residuals: group-aligned, shard-sized (1/L_i of the flat buffers)
le, se = run("two_level", ef=True)
assert np.isfinite(le).all()
lfe, sfe = run("flat", ef=True)
for e2, e1 in zip(se.ef, sfe.ef):
    if e1 is None:
        assert e2 is None
    else:
        assert e2.shape[0] * 4 == e1.shape[0], (e2.shape, e1.shape)
        assert float(np.abs(np.asarray(e2)).max()) > 0
print("FSDP-TWO-LEVEL OK")
""")
