"""Checkpoint crash-safety and strict-restore suite (PR satellite).

``save_checkpoint`` must be atomic: a crash at ANY point mid-save leaves
the previously committed checkpoint loadable (temp files are the only
litter). ``load_checkpoint`` must raise real ``ValueError``s — not
``assert`` (stripped under ``python -O``), not a silent dtype cast — on
shape mismatches, dtype mismatches, and missing/extra keys.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint

jax.config.update("jax_platform_name", "cpu")


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": (jnp.ones((4,), jnp.int32), {"c": jnp.zeros(())})}


def _like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


class TestAtomicSave:
    def test_crash_mid_npz_write_keeps_previous(self, tmp_path,
                                                monkeypatch):
        """Kill the save while the npz temp file is being written: the
        committed v1 checkpoint must still load, bit for bit."""
        path = str(tmp_path / "ck.npz")
        tree1 = _tree()
        save_checkpoint(path, tree1, step=1)

        real_savez = np.savez_compressed

        def dying_savez(f, **kw):
            f.write(b"PK\x03\x04 truncated garbage")
            raise RuntimeError("simulated crash mid-write")

        monkeypatch.setattr(np, "savez_compressed", dying_savez)
        tree2 = jax.tree_util.tree_map(lambda x: x + 100, tree1)
        with pytest.raises(RuntimeError, match="simulated crash"):
            save_checkpoint(path, tree2, step=2)
        monkeypatch.setattr(np, "savez_compressed", real_savez)

        back, step = load_checkpoint(path, _like(tree1))
        assert step == 1
        for want, got in zip(jax.tree_util.tree_leaves(tree1),
                             jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
        # the only residue is the temp file, which the next save replaces
        leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
        assert leftovers == ["ck.npz.tmp"], leftovers
        save_checkpoint(path, tree2, step=2)
        assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
        _, step = load_checkpoint(path, _like(tree1))
        assert step == 2

    def test_crash_between_npz_and_manifest_still_consistent(self, tmp_path,
                                                             monkeypatch):
        """A crash after the npz commit but before the external manifest
        replace must still load CONSISTENTLY (the manifest is embedded in
        the npz — the npz replace is the atomic commit point)."""
        path = str(tmp_path / "ck.npz")
        tree1 = _tree()
        save_checkpoint(path, tree1, step=1)

        real_replace = os.replace

        def replace_npz_only(src, dst):
            if dst.endswith(".manifest.json"):
                raise RuntimeError("simulated crash before manifest commit")
            return real_replace(src, dst)

        monkeypatch.setattr(os, "replace", replace_npz_only)
        tree2 = jax.tree_util.tree_map(lambda x: x + 100, tree1)
        with pytest.raises(RuntimeError, match="simulated crash"):
            save_checkpoint(path, tree2, step=2)
        monkeypatch.setattr(os, "replace", real_replace)

        back, step = load_checkpoint(path, _like(tree1))
        # the committed npz carries its own manifest: new data + new step,
        # never a stale-manifest/new-data mix
        assert step == 2
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree1["a"]) + 100)

    def test_external_manifest_fallback(self, tmp_path):
        """Checkpoints written without the embedded manifest (older
        format) still load via the external .manifest.json."""
        path = str(tmp_path / "old.npz")
        tree = _tree()
        flat = {jax.tree_util.keystr(p): np.asarray(l)
                for p, l in jax.tree_util.tree_flatten_with_path(tree)[0]}
        order = sorted(flat)
        np.savez_compressed(path, **{f"arr_{i}": flat[k]
                                     for i, k in enumerate(order)})
        with open(path + ".manifest.json", "w") as f:
            json.dump({"keys": order, "step": 5}, f)
        back, step = load_checkpoint(path, _like(tree))
        assert step == 5
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))


class TestStrictRestore:
    def test_shape_mismatch_raises_valueerror(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        save_checkpoint(path, _tree(), step=0)
        bad = _tree()
        bad["a"] = jnp.zeros((3, 2))
        with pytest.raises(ValueError, match=r"'a'.*\(2, 3\).*\(3, 2\)"):
            load_checkpoint(path, bad)

    def test_shape_check_survives_python_O(self, tmp_path):
        """The old guard was an ``assert`` — gone under ``python -O``.
        Run the mismatch load in an optimized subprocess and require the
        ValueError."""
        import subprocess
        import sys
        path = str(tmp_path / "ck.npz")
        save_checkpoint(path, {"a": jnp.zeros((4,))}, step=0)
        prog = (
            "import jax.numpy as jnp, pytest\n"
            "from repro.checkpoint import load_checkpoint\n"
            "try:\n"
            f"    load_checkpoint({path!r}, {{'a': jnp.zeros((5,))}})\n"
            "except ValueError:\n"
            "    print('RAISED')\n"
        )
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"),
                   JAX_PLATFORMS="cpu")
        out = subprocess.run([sys.executable, "-O", "-c", prog], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "RAISED" in out.stdout

    def test_dtype_mismatch_raises_not_casts(self, tmp_path):
        """Restoring an f32 checkpoint into a bf16 leaf used to truncate
        silently — it must now refuse."""
        path = str(tmp_path / "ck.npz")
        save_checkpoint(path, {"w": jnp.ones((8,), jnp.float32)}, step=0)
        with pytest.raises(ValueError, match="float32.*bfloat16"):
            load_checkpoint(path, {"w": jnp.zeros((8,), jnp.bfloat16)})
        with pytest.raises(ValueError, match="dtype"):
            load_checkpoint(path, {"w": jnp.zeros((8,), jnp.int32)})

    def test_missing_and_extra_keys_raise_with_names(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        save_checkpoint(path, {"a": jnp.zeros(3), "b": jnp.zeros(2)},
                        step=0)
        with pytest.raises(ValueError, match="missing keys.*'c'"):
            load_checkpoint(path, {"a": jnp.zeros(3), "b": jnp.zeros(2),
                                   "c": jnp.zeros(1)})
        with pytest.raises(ValueError, match="extra keys.*'b'"):
            load_checkpoint(path, {"a": jnp.zeros(3)})

    def test_exact_roundtrip_still_works(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        tree = _tree()
        save_checkpoint(path, tree, step=3)
        back, step = load_checkpoint(path, _like(tree))
        assert step == 3
        for want, got in zip(jax.tree_util.tree_leaves(tree),
                             jax.tree_util.tree_leaves(back)):
            assert got.dtype == want.dtype
            np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
