"""Unit + property tests for the optimal-condition level solvers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import buckets as B
from repro.core import levels as L

jax.config.update("jax_platform_name", "cpu")


def _bkt(x):
    x = jnp.asarray(x, dtype=jnp.float32).reshape(1, -1)
    return x, jnp.ones_like(x, dtype=bool)


class TestSortedBuckets:
    def test_prefix_sums_and_count(self):
        bkt, mask = _bkt([3.0, 1.0, 2.0])
        sb = L.sort_buckets(bkt, mask)
        np.testing.assert_allclose(np.asarray(sb.v[0]), [1, 2, 3])
        np.testing.assert_allclose(np.asarray(sb.psum[0]), [0, 1, 3, 6])
        assert int(sb.cnt[0]) == 3

    def test_masked_padding_ignored(self):
        bkt = jnp.array([[5.0, -1.0, 99.0, 99.0]])
        mask = jnp.array([[True, True, False, False]])
        sb = L.sort_buckets(bkt, mask)
        assert int(sb.cnt[0]) == 2
        np.testing.assert_allclose(np.asarray(sb.psum[0, 2]), 4.0)


class TestORQ:
    def test_endpoints_are_min_max(self):
        g = jax.random.normal(jax.random.key(0), (1, 512))
        mask = jnp.ones_like(g, dtype=bool)
        lv = L.orq_levels(g, mask, K=2)
        assert np.isclose(float(lv[0, 0]), float(g.min()))
        assert np.isclose(float(lv[0, -1]), float(g.max()))

    def test_levels_ascending(self):
        g = jax.random.laplace(jax.random.key(1), (4, 1024))
        mask = jnp.ones_like(g, dtype=bool)
        for K in (1, 2, 3, 4):
            lv = L.orq_levels(g, mask, K=K)
            d = np.diff(np.asarray(lv), axis=-1)
            assert (d >= -1e-6).all(), f"K={K} not ascending"

    def test_uniform_distribution_gives_even_spacing(self):
        # Remark 1.1: for uniform p, optimal b_k = midpoint of neighbours.
        g = jnp.linspace(-1.0, 1.0, 4097).reshape(1, -1)
        mask = jnp.ones_like(g, dtype=bool)
        lv = np.asarray(L.orq_levels(g, mask, K=2))[0]
        np.testing.assert_allclose(lv, np.linspace(-1, 1, 5), atol=2e-3)

    def test_optimality_residual_small(self):
        g = jax.random.laplace(jax.random.key(2), (8, 2048)) * 0.02
        mask = jnp.ones_like(g, dtype=bool)
        lv = L.orq_levels(g, mask, K=3)
        res = L.optimality_residual(g, mask, lv)
        assert float(jnp.abs(res).max()) < 0.08

    def test_refine_reduces_mse(self):
        from repro.core import theory
        from repro.core.quantizers import Quantizer

        g = jax.random.laplace(jax.random.key(3), (32768,)) * 0.02
        base = theory.scheme_mse(Quantizer("orq", 9), g)
        ref = theory.scheme_mse(Quantizer("orq", 9, refine_iters=3), g)
        assert float(ref) <= float(base) * 1.0001

    def test_degenerate_constant_bucket(self):
        g = jnp.full((1, 256), 0.5)
        mask = jnp.ones_like(g, dtype=bool)
        lv = L.orq_levels(g, mask, K=2)
        assert np.isfinite(np.asarray(lv)).all()
        np.testing.assert_allclose(np.asarray(lv[0, 0]), 0.5)
        np.testing.assert_allclose(np.asarray(lv[0, -1]), 0.5)

    def test_all_masked_bucket(self):
        g = jnp.ones((1, 64))
        mask = jnp.zeros_like(g, dtype=bool)
        lv = L.orq_levels(g, mask, K=1)
        assert np.isfinite(np.asarray(lv)).all()


class TestBinGrad:
    def test_pb_solves_eq15(self):
        # On the empirical distribution, b1*cnt_pos ≈ Σ_{v>=b1} v at solution.
        g = jnp.abs(jax.random.normal(jax.random.key(4), (1, 4096)))
        g = jnp.concatenate([g, -g], axis=-1)  # symmetric
        mask = jnp.ones_like(g, dtype=bool)
        b1 = float(L.bingrad_pb_b1(g, mask)[0])
        v = np.asarray(g[0])
        lhs = b1 * (v > 0).sum()
        rhs = v[v >= b1].sum()
        assert abs(lhs - rhs) / abs(rhs) < 0.01

    def test_b_levels_are_conditional_means(self):
        g = jax.random.normal(jax.random.key(5), (1, 2048))
        mask = jnp.ones_like(g, dtype=bool)
        lv = np.asarray(L.bingrad_b_levels(g, mask))[0]
        v = np.asarray(g[0])
        b0 = v.mean()
        np.testing.assert_allclose(lv[0], v[v < b0].mean(), rtol=1e-5)
        np.testing.assert_allclose(lv[1], v[v >= b0].mean(), rtol=1e-5)

    def test_lloyd_iters_reduce_mse(self):
        from repro.core import theory
        from repro.core.quantizers import Quantizer

        g = jax.random.laplace(jax.random.key(6), (16384,))
        base = theory.scheme_mse(Quantizer("bingrad_b"), g)
        ll = theory.scheme_mse(Quantizer("bingrad_b", lloyd_iters=5), g)
        assert float(ll) <= float(base) * 1.0001


class TestBaselines:
    def test_terngrad_levels(self):
        g = jnp.array([[0.5, -2.0, 1.0]])
        mask = jnp.ones_like(g, dtype=bool)
        lv = np.asarray(L.terngrad_levels(g, mask))[0]
        np.testing.assert_allclose(lv, [-2.0, 0.0, 2.0])

    def test_qsgd_evenly_spaced(self):
        g = jax.random.normal(jax.random.key(7), (2, 512))
        mask = jnp.ones_like(g, dtype=bool)
        lv = np.asarray(L.qsgd_levels(g, mask, 5))
        gaps = np.diff(lv, axis=-1)
        np.testing.assert_allclose(gaps, np.broadcast_to(gaps[:, :1], gaps.shape),
                                   rtol=1e-5)

    def test_linear_levels_are_quantiles(self):
        g = jnp.arange(101, dtype=jnp.float32).reshape(1, -1)
        mask = jnp.ones_like(g, dtype=bool)
        lv = np.asarray(L.linear_levels(g, mask, 5))[0]
        np.testing.assert_allclose(lv, [0, 25, 50, 75, 100])

    def test_signsgd_scale_is_l1_mean(self):
        g = jnp.array([[1.0, -3.0, 2.0, -2.0]])
        mask = jnp.ones_like(g, dtype=bool)
        lv = np.asarray(L.signsgd_scale(g, mask))[0]
        np.testing.assert_allclose(lv, [-2.0, 2.0])


@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=8,
                  max_size=256),
    K=st.integers(1, 3),
)
def test_orq_levels_property(data, K):
    """Property: levels finite, ascending, within [min, max] of data."""
    g = jnp.asarray(data, dtype=jnp.float32).reshape(1, -1)
    mask = jnp.ones_like(g, dtype=bool)
    lv = np.asarray(L.orq_levels(g, mask, K=K))[0]
    assert np.isfinite(lv).all()
    assert (np.diff(lv) >= -1e-4 * (1 + np.abs(lv[:-1]))).all()
    assert lv[0] >= np.min(data) - 1e-4
    assert lv[-1] <= np.max(data) + 1e-4


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2 ** 20),
    dist=st.sampled_from(["normal", "laplace", "uniform", "bimodal"]),
)
def test_orq_beats_even_spacing_property(seed, dist):
    """Theorem 1's point: optimal levels give <= MSE vs evenly spaced levels
    with the same span, for ANY distribution."""
    from repro.core import theory

    key = jax.random.key(seed)
    if dist == "normal":
        g = jax.random.normal(key, (1, 2048))
    elif dist == "laplace":
        g = jax.random.laplace(key, (1, 2048))
    elif dist == "uniform":
        g = jax.random.uniform(key, (1, 2048), minval=-1, maxval=1)
    else:
        k1, k2 = jax.random.split(key)
        g = jnp.concatenate(
            [jax.random.normal(k1, (1, 1024)) - 3,
             jax.random.normal(k2, (1, 1024)) + 3], axis=-1)
    mask = jnp.ones_like(g, dtype=bool)
    lv_orq = L.orq_levels(g, mask, K=2, refine_iters=2)
    lv_even = L.qsgd_levels(g, mask, 5)
    mse_orq = float(theory.expected_mse(g, mask, lv_orq).mean())
    mse_even = float(theory.expected_mse(g, mask, lv_even).mean())
    assert mse_orq <= mse_even * 1.02
