"""Fused quantized-KV serving kernels: parity, jaxpr pins, overrides.

Contracts pinned here (PR-7 acceptance):

* ``append_kv`` (one-pass quantize of new K/V rows to wire format) and
  ``decode_attend`` (fused dequant-attention over a packed context) are
  BIT-identical between the Pallas kernel path and the pure-jnp oracle,
  for schemes covering every wire width 1..5 bits plus BinGrad-b, across
  ragged page fills.
* Each lowers to exactly ONE ``pallas_call``; ``REPRO_USE_KERNELS=0``
  forces the oracle (zero pallas calls), read at trace time.
* ``append_kv``'s K/V stacking is a pure batching trick: each row's bits
  equal a standalone ``wire.encode`` of that tensor alone.
* ``decode_attend`` numerics match an independent numpy unpack ->
  level-decode -> masked-softmax GQA attention oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounding as R
from repro.core.api import make_quantizer
from repro.core.comm import wire
from repro.kernels import ops
from repro.kernels.fused_kv import append_kv, decode_attend

jax.config.update("jax_platform_name", "cpu")

KV, HD = 2, 8
D = KV * HD           # one bucket per token spans all KV heads

#: scheme -> expected wire bits; covers widths 1..5 plus BinGrad-b
SCHEMES = {
    "signsgd": 1,
    "bingrad-b": 1,
    "orq-3": 2,
    "orq-5": 3,
    "orq-9": 4,
    "orq-17": 5,
}


def _qz(name):
    return make_quantizer(name, bucket_size=D)


def _rbits(qz, rows, seed=3):
    if wire._fused_mode(qz) != "rr":
        return None
    return R.random_bits(jax.random.key(seed), (rows, D))


def _context(name, B, C, seed=0):
    """Quantize B*C random tokens' K/V rows and shape them as per-sequence
    (B, C, ...) paged-context views."""
    qz = _qz(name)
    kk = jax.random.split(jax.random.key(seed), 2)
    k_rows = jax.random.normal(kk[0], (B * C, D)) * 0.3
    v_rows = jax.random.normal(kk[1], (B * C, D)) * 0.3
    parts = append_kv(qz, k_rows, v_rows, _rbits(qz, 2 * B * C))
    return qz, tuple(p.reshape(B, C, -1) for p in parts)


def _fill_mask(fills, T, C):
    """Ragged page fills: sequence b attends to its first fills[b] slots."""
    B = len(fills)
    m = jnp.arange(C)[None, None, :] < jnp.asarray(fills)[:, None, None]
    return jnp.broadcast_to(m, (B, T, C))


class TestAppendParity:
    @pytest.mark.parametrize("name,bits", sorted(SCHEMES.items()))
    @pytest.mark.parametrize("rows", [1, 7, 16])   # ragged + exact fills
    def test_kernel_vs_oracle_bit_identical(self, name, bits, rows):
        qz = _qz(name)
        assert qz.wire_bits_per_element == bits
        kk = jax.random.split(jax.random.key(5), 2)
        k_rows = jax.random.laplace(kk[0], (rows, D)) * 0.2
        v_rows = jax.random.laplace(kk[1], (rows, D)) * 0.2
        rb = _rbits(qz, 2 * rows)
        got = append_kv(qz, k_rows, v_rows, rb, use_kernels=True)
        want = append_kv(qz, k_rows, v_rows, rb, use_kernels=False)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("name", sorted(SCHEMES))
    def test_stacking_matches_standalone_encode(self, name):
        """K/V stacking is pure batching: every encode stage is
        independent per bucket row, so each tensor's bits equal a
        standalone wire.encode of that tensor alone."""
        qz = _qz(name)
        rows = 6
        kk = jax.random.split(jax.random.key(8), 2)
        k_rows = jax.random.normal(kk[0], (rows, D))
        v_rows = jax.random.normal(kk[1], (rows, D))
        rb = _rbits(qz, 2 * rows)
        kw, klv, vw, vlv = append_kv(qz, k_rows, v_rows, rb)
        ones = jnp.ones((rows, D), dtype=bool)
        rk = None if rb is None else rb[:rows]
        rv = None if rb is None else rb[rows:]
        kw2, klv2 = wire.encode(qz, k_rows, ones, None, rbits=rk)
        vw2, vlv2 = wire.encode(qz, v_rows, ones, None, rbits=rv)
        for g, w in zip((kw, klv, vw, vlv), (kw2, klv2, vw2, vlv2)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_rejects_schemes_without_fused_encode(self):
        with pytest.raises(ValueError, match="fused one-pass encode"):
            append_kv(make_quantizer("fp", bucket_size=D),
                      jnp.zeros((2, D)), jnp.zeros((2, D)), None)


class TestAttendParity:
    @pytest.mark.parametrize("name", sorted(SCHEMES))
    @pytest.mark.parametrize("T", [1, 3])          # decode + prefill chunk
    def test_kernel_vs_oracle_bit_identical(self, name, T):
        B, C, H = 3, 12, 4
        qz, (kw, klv, vw, vlv) = _context(name, B, C)
        q = jax.random.normal(jax.random.key(7), (B, T, H, HD),
                              jnp.float32)
        mask = _fill_mask([5, 12, 1], T, C)        # ragged page fills
        kw_ = dict(bits=qz.wire_bits_per_element, kv_heads=KV,
                   scale=HD ** -0.5)
        got = ops.decode_attend(q, kw, klv, vw, vlv, mask,
                                use_kernels=True, **kw_)
        want = ops.decode_attend(q, kw, klv, vw, vlv, mask,
                                 use_kernels=False, **kw_)
        assert got.shape == (B, T, H, HD)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_softcap_kernel_vs_oracle(self):
        B, T, C, H = 2, 2, 8, 4
        qz, (kw, klv, vw, vlv) = _context("orq-9", B, C)
        q = jax.random.normal(jax.random.key(9), (B, T, H, HD))
        mask = _fill_mask([8, 3], T, C)
        kw_ = dict(bits=qz.wire_bits_per_element, kv_heads=KV,
                   scale=HD ** -0.5, softcap=4.0)
        got = ops.decode_attend(q, kw, klv, vw, vlv, mask,
                                use_kernels=True, **kw_)
        want = ops.decode_attend(q, kw, klv, vw, vlv, mask,
                                 use_kernels=False, **kw_)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_independent_numpy_oracle(self):
        """Unpack words + level-decode + masked-softmax GQA attention in
        plain numpy — independent of ref.kv_attend_block."""
        name, B, T, C, H = "orq-9", 2, 1, 8, 4
        qz, (kw, klv, vw, vlv) = _context(name, B, C)
        bits = qz.wire_bits_per_element
        q = jax.random.normal(jax.random.key(11), (B, T, H, HD),
                              jnp.float32)
        fills = [6, 8]
        mask = _fill_mask(fills, T, C)
        got = np.asarray(ops.decode_attend(
            q, kw, klv, vw, vlv, mask, bits=bits, kv_heads=KV,
            scale=HD ** -0.5))

        epw = 32 // bits
        m = (1 << bits) - 1

        def dec(w, lv):
            w = np.asarray(w)
            idx = np.stack([(w >> (bits * j)) & m for j in range(epw)],
                           axis=-1)
            idx = idx.reshape(B, C, -1)[:, :, :D].astype(np.int64)
            vals = np.take_along_axis(np.asarray(lv, np.float32), idx,
                                      axis=-1)
            return vals.reshape(B, C, KV, HD)

        k = dec(kw, klv)
        v = dec(vw, vlv)
        g = H // KV
        qg = np.asarray(q, np.float32).reshape(B, T, KV, g, HD)
        sc = np.einsum("btkgh,bckh->bkgtc", qg, k,
                       dtype=np.float32) * (HD ** -0.5)
        mb = np.asarray(mask)[:, 0][:, None, None, None, :]  # (B,1,1,1,C)
        sc = np.where(mb, sc, -2.0e38)
        p = np.exp(sc - sc.max(-1, keepdims=True))
        p = p / p.sum(-1, keepdims=True)
        want = np.einsum("bkgtc,bckh->btkgh", p, v,
                         dtype=np.float32).reshape(B, T, H, HD)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestJaxprPins:
    """PR-7 acceptance: one pallas_call per hot path; the oracle leg
    (REPRO_USE_KERNELS=0) lowers to zero."""

    @pytest.fixture(autouse=True)
    def _kernels_on(self, monkeypatch):
        # these assertions are about the KERNEL lowering; pin the env so
        # the CI reference-oracle leg (REPRO_USE_KERNELS=0) doesn't turn
        # them vacuous/false
        monkeypatch.setenv("REPRO_USE_KERNELS", "1")

    @staticmethod
    def _expect_pallas(closed, want: int) -> None:
        # the same rule the CI matrix audit runs (python -m repro.analysis)
        from repro.analysis import TraceBundle, run_checks

        fs = run_checks(
            [TraceBundle(label="pin", kind="serve_fwd", closed=closed,
                         meta={"expect_pallas_calls": want})],
            rules=["one-pallas-call"])
        assert not fs, [str(f) for f in fs]

    @pytest.mark.parametrize("name", sorted(SCHEMES))
    def test_append_single_pallas_call(self, name):
        qz = _qz(name)
        rb = _rbits(qz, 8)
        closed = jax.make_jaxpr(
            lambda k, v: append_kv(qz, k, v, rb))(
                jnp.zeros((4, D)), jnp.zeros((4, D)))
        self._expect_pallas(closed, 1)

    def test_attend_single_pallas_call(self):
        B, T, C, H = 2, 1, 8, 4
        qz, (kw, klv, vw, vlv) = _context("orq-9", B, C)
        mask = _fill_mask([8, 4], T, C)
        closed = jax.make_jaxpr(
            lambda q: decode_attend(q, kw, klv, vw, vlv, mask,
                                    bits=qz.wire_bits_per_element,
                                    kv_heads=KV, scale=0.25))(
                jnp.zeros((B, T, H, HD)))
        self._expect_pallas(closed, 1)

    def test_env_override_forces_oracle(self, monkeypatch):
        monkeypatch.setenv("REPRO_USE_KERNELS", "0")
        B, T, C, H = 2, 1, 8, 4
        qz, (kw, klv, vw, vlv) = _context("orq-9", B, C)
        mask = _fill_mask([8, 4], T, C)
        closed = jax.make_jaxpr(
            lambda q: ops.decode_attend(q, kw, klv, vw, vlv, mask,
                                        bits=qz.wire_bits_per_element,
                                        kv_heads=KV, scale=0.25))(
                jnp.zeros((B, T, H, HD)))
        self._expect_pallas(closed, 0)
