"""Pipelined quantized exchange (``pipeline_chunks``): bit-identity with
the single-shot schedule for every scheme variant on ragged buffers —
gradients AND error-feedback residuals — across the replicated, FSDP, and
two-level hierarchical paths; jaxpr pinning of the K-chunk collective
schedule (2K all_to_all + 2K all_gather per quantized exchange, no extra
full-buffer materialization); and the static launch/byte accounting.

Multi-device cases run in subprocesses with XLA_FLAGS forcing 8 host
devices (same harness as test_fused_exchange.py); the accounting tests
run in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.core import comm
from repro.core.api import QuantConfig
from repro.core.comm.collectives import _chunk_spans

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# the static chunk schedule
# ---------------------------------------------------------------------------

class TestChunkSpans:
    @pytest.mark.parametrize("nbc,k", [(1, 1), (1, 8), (5, 2), (6, 3),
                                       (7, 3), (24, 8), (24, 5), (3, 100)])
    def test_partition_properties(self, nbc, k):
        spans = _chunk_spans(nbc, k)
        assert len(spans) == min(max(k, 1), nbc)
        assert spans[0][0] == 0 and spans[-1][1] == nbc
        for (a, b), (c, _) in zip(spans, spans[1:]):
            assert b == c and b > a
        sizes = [b - a for a, b in spans]
        assert max(sizes) - min(sizes) <= 1       # balanced

    def test_k_one_is_single_span(self):
        assert _chunk_spans(17, 1) == [(0, 17)]

    def test_engine_rejects_bad_k(self):
        with pytest.raises(ValueError, match="pipeline_chunks"):
            comm.GradientExchange(
                QuantConfig(name="orq-9").to_quantizer(), ("dp",),
                pipeline_chunks=0)


# ---------------------------------------------------------------------------
# static accounting: launches scale with K, bytes don't
# ---------------------------------------------------------------------------

class TestPipelineAccounting:
    def test_launches_per_chunk_and_bytes_invariant(self):
        qz = QuantConfig(name="orq-9", bucket_size=512).to_quantizer()
        n, L = 512 * 24, 8
        base = comm.GradientExchange(qz, ("dp",))
        piped = comm.GradientExchange(qz, ("dp",), pipeline_chunks=3)
        assert base.collective_launches(n, L) == 4
        assert piped.collective_launches(n, L) == 12    # 2K a2a + 2K ag
        assert (piped.wire_bytes_per_worker(n, L)
                == base.wire_bytes_per_worker(n, L))

    def test_launches_clamped_to_bucket_rows(self):
        qz = QuantConfig(name="orq-9", bucket_size=512).to_quantizer()
        n, L = 512 * 8, 8          # one bucket row per worker chunk
        eng = comm.GradientExchange(qz, ("dp",), pipeline_chunks=16)
        assert eng.collective_launches(n, L) == 4       # K clamps to 1

    def test_no_requant_keeps_single_fp_gather(self):
        qz = QuantConfig(name="orq-9", bucket_size=512).to_quantizer()
        n, L = 512 * 24, 8
        eng = comm.GradientExchange(qz, ("dp",), server_requant=False,
                                    pipeline_chunks=3)
        assert eng.collective_launches(n, L) == 2 * 3 + 1

    def test_rs_stats_pipeline(self):
        qz = QuantConfig(name="orq-9", bucket_size=512).to_quantizer()
        n, L = 512 * 24, 8
        l1, b1 = comm.GradientExchange.rs_stats(qz, n, L)
        lk, bk = comm.GradientExchange.rs_stats(qz, n, L, pipeline_chunks=3)
        assert (l1, lk) == (2, 6) and b1 == bk

    def test_link_stats_pipeline_chunks(self):
        qz = QuantConfig(name="orq-9", bucket_size=512).to_quantizer()
        n = 512 * 64
        for two_level in (False, True):
            st1 = comm.link_stats(qz, n, n_intra=4, n_inter=2,
                                  two_level=two_level)
            stk = comm.link_stats(qz, n, n_intra=4, n_inter=2,
                                  two_level=two_level, pipeline_chunks=4)
            for k in ("ici_bytes", "dcn_bytes", "dcn_q_bytes"):
                assert st1[k] == stk[k], (two_level, k)
            assert stk["launches"] == st1["launches"] + 3 * 4, two_level

    def test_policy_link_stats_pipeline_chunks(self):
        from repro.core import QuantPolicy
        policy = QuantPolicy.parse("bias=fp,default=orq-9", bucket_size=512)
        sizes = [("w", 512 * 64), ("bias", 4096)]
        st1, _ = comm.policy_link_stats(policy, sizes, n_intra=4, n_inter=2,
                                        two_level=False)
        stk, _ = comm.policy_link_stats(policy, sizes, n_intra=4, n_inter=2,
                                        two_level=False, pipeline_chunks=4)
        assert stk["dcn_bytes"] == st1["dcn_bytes"]
        assert stk["launches"] > st1["launches"]


# ---------------------------------------------------------------------------
# bit-identity: pipelined == single-shot, grads AND EF residuals
# ---------------------------------------------------------------------------

def test_pipelined_bit_identity_replicated_all_schemes():
    """Replicated flat exchange on a ragged buffer: every registered scheme
    variant produces a bit-identical mean gradient and EF residual under
    pipeline_chunks in {2, 3, 8} vs the single-shot schedule."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import all_methods, comm
from repro.core.api import QuantConfig
from repro.utils.compat import shard_map

mesh = jax.make_mesh((8,), ("dp",))
n = 512 * 11 + 333                             # ragged: partial tail bucket
key = jax.random.key(7)
flats = jax.random.normal(jax.random.key(1), (8, n), jnp.float32)

for name in all_methods():
    cfg = QuantConfig(name=name, bucket_size=512)
    outs = {}
    for k in (1, 2, 3, 8):
        eng = comm.GradientExchange(cfg.to_quantizer(), ("dp",),
                                    pipeline_chunks=k)
        fn = jax.jit(shard_map(lambda x: eng.exchange_flat(x[0], key),
                               mesh=mesh, in_specs=P("dp"), out_specs=P(),
                               check_vma=False))
        g = np.asarray(fn(flats))
        if eng.qz.is_identity:
            r = None
        else:
            qfn = jax.jit(shard_map(
                lambda x: (x[0] - eng.local_qdq_flat(x[0], key))[None],
                mesh=mesh, in_specs=P("dp"), out_specs=P("dp"),
                check_vma=False))
            r = np.asarray(qfn(flats))
        outs[k] = (g, r)
    for k in (2, 3, 8):
        assert np.array_equal(outs[1][0], outs[k][0]), (name, k, "grads")
        if outs[1][1] is not None:
            assert np.array_equal(outs[1][1], outs[k][1]), (name, k, "ef")
    print(name, "OK")
print("PIPELINED-REPLICATED OK")
""")


def test_pipelined_bit_identity_two_level_all_schemes():
    """Two-level (ICI/DCN) path on a 2x4 ('pod','data') mesh: pipelined
    inter-pod exchange and the shard-level EF residual stay bit-identical
    to single-shot for every scheme."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import all_methods, comm
from repro.core.api import QuantConfig
from repro.utils.compat import shard_map

mesh = jax.make_mesh((2, 4), ("pod", "data"))
n = 512 * 7 + 123
key = jax.random.key(7)
flats = jax.random.normal(jax.random.key(1), (2, 4, n), jnp.float32)

for name in all_methods():
    cfg = QuantConfig(name=name, bucket_size=512)
    outs = {}
    for k in (1, 3):
        eng = comm.GradientExchange(cfg.to_quantizer(), ("pod",),
                                    intra_axes=("data",),
                                    pipeline_chunks=k)
        def f(x):
            flat = x[0, 0]
            mean = eng.exchange_flat(flat, key)
            if eng.qz.is_identity:
                return mean, jnp.zeros((1, 1))
            shard, valid = eng.intra_scatter(flat)
            res = shard - eng.local_qdq_shard(shard, key, valid=valid)
            return mean, res[None]
        fn = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P("pod", "data"),
            out_specs=(P(), P(("pod", "data"))), check_vma=False))
        g, r = fn(flats)
        outs[k] = (np.asarray(g), np.asarray(r))
    assert np.array_equal(outs[1][0], outs[3][0]), (name, "grads")
    assert np.array_equal(outs[1][1], outs[3][1]), (name, "ef")
    print(name, "OK")
print("PIPELINED-TWO-LEVEL OK")
""")


def test_pipelined_bit_identity_fsdp_all_schemes():
    """Fused FSDP exchange (sharded reduce-scatter group + replicated
    group per scheme): pipelined outputs and residual_bufs bit-identical
    to single-shot for every scheme."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core import QuantPolicy, all_methods, comm
from repro.utils.compat import shard_map

mesh = jax.make_mesh((8,), ("data",))
L = 8
gw = jax.random.laplace(jax.random.key(0), (L, 16, 72)) * 0.1
gb = jax.random.laplace(jax.random.key(1), (L, 40)) * 0.1
tree = {"b": jnp.zeros((40,)), "w": jnp.zeros((16, 72))}

for name in all_methods():
    policy = QuantPolicy.parse(f"default={name}", bucket_size=64)
    outs = {}
    for k in (1, 4):
        ex = comm.FsdpExchange.build(policy, tree, ("data",),
                                     paths={"b": "b", "w": "w"},
                                     shard_dims={"b": None, "w": 0},
                                     n_shards=L, pipeline_chunks=k)
        def f(gw_all, gb_all):
            g = {"b": gb_all[0], "w": gw_all[0]}
            wid = lax.axis_index(("data",))
            bufs = ex.layout.flatten_groups(g)
            o, res = ex.exchange_with_residuals(bufs, jax.random.key(7),
                                                wid, ef_bufs=(None,) *
                                                len(ex.engines))
            res = [jnp.zeros((1,)) if r is None else r for r in res]
            return ([lax.all_gather(x, "data")[None] for x in o],
                    [lax.all_gather(r, "data")[None] for r in res])
        ng = len(ex.layout.groups)
        fn = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("data", None, None), P("data", None)),
            out_specs=([P("data", None)] * ng, [P("data", None)] * ng),
            check_vma=False))
        o, res = fn(gw, gb)
        outs[k] = ([np.asarray(x) for x in o], [np.asarray(r) for r in res])
    for a, b in zip(outs[1][0], outs[4][0]):
        assert np.array_equal(a, b), (name, "grads")
    for a, b in zip(outs[1][1], outs[4][1]):
        assert np.array_equal(a, b), (name, "ef")
    print(name, "OK")
print("PIPELINED-FSDP OK")
""")


# ---------------------------------------------------------------------------
# jaxpr pinning: K collectives per phase, no extra full-buffer arrays
# ---------------------------------------------------------------------------

def test_jaxpr_pins_chunked_collectives_and_no_materialization():
    """The 2K-a2a/2K-ag budget and the no-extra-f32-buffers bound are
    enforced through the SAME rules the CI matrix audit runs
    (collective-budget / no-materialization in repro.analysis)."""
    run_devices("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.analysis import TraceBundle, run_checks, stats
from repro.core import comm
from repro.core.api import QuantConfig
from repro.utils.compat import shard_map

mesh = jax.make_mesh((8,), ("dp",))
n = 512 * 96 - 100      # 12 bucket rows per worker chunk (ragged tail)
key = jax.random.key(7)
x = jnp.zeros((8, n), jnp.float32)

def make(k):
    eng = comm.GradientExchange(
        QuantConfig(name="orq-9", bucket_size=512).to_quantizer(), ("dp",),
        pipeline_chunks=k)
    return jax.make_jaxpr(shard_map(
        lambda v: eng.exchange_flat(v[0], key), mesh=mesh,
        in_specs=P("dp"), out_specs=P(), check_vma=False))(x)

c1 = make(1)
# chunking must not add full-buffer-sized f32 intermediates: the K-chunk
# jaxpr holds no more >= n-element f32 arrays than the single-shot one
m1 = stats.sized_outvar_count(c1, n, dtype=jnp.float32)

def bundle(k, closed, baseline=None):
    # phase 1: 2 all_to_all per chunk; phase 2: 2 all_gather per chunk
    meta = {
        "expected_collectives": {("all_to_all", ("dp",)): 2 * k,
                                 ("all_gather", ("dp",)): 2 * k},
        "exclusive_prims": {"all_to_all": [("dp",)]},
    }
    if baseline is not None:
        meta["materialization"] = {"min_elems": n, "dtype": "float32",
                                   "max_count": baseline}
    return TraceBundle(label=f"pipelined/k{k}", kind="exchange",
                       closed=closed, meta=meta)

fs = run_checks([bundle(1, c1), bundle(3, make(3), baseline=m1)],
                rules=["collective-budget", "no-materialization"])
assert not fs, [str(f) for f in fs]
print("JAXPR-PIN OK", m1)
""")


class TestExchangeBenchGate:
    """The exchange_bench --check gate: schema, pipelined-wins floor,
    best-speedup regression (pure logic — no timing)."""

    def _mk(self, speedups, base_us=1000.0):
        import benchmarks.exchange_bench as xb

        entries = [{"key": "exchange/s/n100/k1", "scheme": "s", "n": 100,
                    "pipeline_chunks": 1, "step_us": base_us,
                    "speedup_vs_single_shot": 1.0}]
        wins = 0
        for i, sp in enumerate(speedups):
            us = base_us / sp
            wins += us <= base_us * (1.0 + xb.WIN_SLACK)
            entries.append({
                "key": f"exchange/s/n100/k{2 ** (i + 1)}", "scheme": "s",
                "n": 100, "pipeline_chunks": 2 ** (i + 1), "step_us": us,
                "speedup_vs_single_shot": sp})
        return {"schema": xb.SCHEMA, "jax": "x", "n_devices": 8,
                "quick": True, "win_slack": xb.WIN_SLACK,
                "summary": {"s": {"best_k": 2, "best_speedup": max(speedups),
                                  "wins": wins}},
                "entries": entries}

    def test_pass_when_pipelined_wins(self):
        import benchmarks.exchange_bench as xb

        run = self._mk([1.3, 1.6, 1.2])
        assert xb.check(run, run, 0.25) == []

    def test_fails_when_pipelining_costs_step_time(self):
        import benchmarks.exchange_bench as xb

        run = self._mk([0.7, 0.8, 1.4])       # only one chunk count wins
        fails = xb.check(run, self._mk([1.3, 1.6, 1.2]), 0.25)
        assert any("only 1 chunk count" in f for f in fails), fails

    def test_fails_on_best_speedup_regression(self):
        import benchmarks.exchange_bench as xb

        base = self._mk([1.5, 2.0, 1.5])
        new = self._mk([1.1, 1.2, 1.1])       # 2.0 -> 1.2 is a 40% drop
        fails = xb.check(new, base, 0.25)
        assert any("regressed" in f for f in fails), fails

    def test_fails_on_schema_change(self):
        import benchmarks.exchange_bench as xb

        run = self._mk([1.3, 1.6, 1.2])
        bad = dict(run, schema=999)
        assert any("schema" in f for f in xb.check(bad, run, 0.25))

    def test_committed_baseline_parses_and_gates_itself(self):
        import json

        import benchmarks.exchange_bench as xb

        assert os.path.exists(xb.DEFAULT_BASELINE), (
            "committed exchange baseline missing")
        with open(xb.DEFAULT_BASELINE) as fh:
            base = json.load(fh)
        assert base["schema"] == xb.SCHEMA
        assert base["entries"]
        # the acceptance criterion: pipelined at-least-matches single-shot
        # at >= 2 chunk counts per scheme, in the committed baseline
        assert all(s["wins"] >= 2 for s in base["summary"].values())
        assert xb.check(base, base, 0.25) == []
