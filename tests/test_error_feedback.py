"""Beyond-paper error feedback: residuals accumulate the per-step
quantization error and are re-injected (Karimireddy et al. line, cited by
the paper as a complementary technique). Most valuable for the biased
1-bit schemes."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core import QuantConfig
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(quant, ef, steps=30, seed=0):
    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    tcfg = TrainConfig(policy=QuantConfig(name=quant, bucket_size=512),
                       mode="replicated", error_feedback=ef)
    state = init_state(model, mesh, tcfg, jax.random.key(seed))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                       seed=3)
    loss = None
    for i in range(steps):
        state, m = step_fn(state, data.batch(i), jax.random.key(42))
        loss = float(m["loss"])
    return loss, state


class TestErrorFeedback:
    def test_residual_state_updates(self):
        loss, state = _train("bingrad-b", ef=True, steps=3)
        assert state.ef is not None
        norms = [float(jnp.abs(e).max())
                 for e in jax.tree_util.tree_leaves(state.ef)]
        assert max(norms) > 0  # residuals are being accumulated
        assert np.isfinite(loss)

    def test_ef_helps_biased_scheme(self):
        """EF compensates BinGrad-b's bias: final loss should improve
        (or at least not regress beyond noise)."""
        plain, _ = _train("signsgd", ef=False)
        with_ef, _ = _train("signsgd", ef=True)
        assert with_ef < plain + 0.05, (plain, with_ef)

    def test_ef_disabled_state_is_none(self):
        _, state = _train("bingrad-b", ef=False, steps=1)
        assert state.ef is None

    def test_multiworker_ef_residual_matches_local_qdq(self):
        """Distributed EF: the residual must equal g - localdecode(Q(g)),
        bit-consistent with the collective's own quantization."""
        body = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import make_quantizer, comm
        from repro.utils.compat import shard_map
        mesh = jax.make_mesh((4,), ("data",))
        qz = make_quantizer("orq-5", bucket_size=128)
        n, L = 1000, 4
        g = jax.random.laplace(jax.random.key(0), (L, n)) * 0.1
        key = jax.random.key(7)

        def f(gl):
            gl = gl[0]
            local = comm.local_qdq_comm_layout(gl, qz, key, ("data",))
            mean = comm.quantized_reduce_scatter_mean(gl, qz, key, ("data",))
            return local[None], jax.lax.all_gather(mean, "data")[None]

        fn = jax.jit(shard_map(f, mesh=mesh,
                     in_specs=(P("data", None),),
                     out_specs=(P("data", None), P("data", None, None)),
                     axis_names={"data"}, check_vma=False))
        local, gathered = fn(g)
        # mean of the workers' local dequantized copies == collective mean
        chunk = -(-n // L)
        want = np.asarray(local).mean(0)
        got = np.asarray(gathered)[0].reshape(-1)[:n]
        np.testing.assert_allclose(got, want[:n], rtol=1e-5, atol=1e-6)
        print("EF-LAYOUT OK")
        """
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        env["JAX_PLATFORMS"] = "cpu"
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                             env=env, capture_output=True, text=True,
                             timeout=600)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "EF-LAYOUT OK" in out.stdout
