"""Substrate tests: data pipeline, optimizers, schedules, checkpointing,
HLO cost parser."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


class TestData:
    def test_deterministic(self):
        from repro.data import SyntheticLM
        d = SyntheticLM(vocab_size=128, seq_len=16, batch_size=4, seed=5)
        a, b = d.batch(3), d.batch(3)
        assert bool((a["tokens"] == b["tokens"]).all())
        c = d.batch(4)
        assert not bool((a["tokens"] == c["tokens"]).all())

    def test_learnable_structure(self):
        """The copy channel makes token t correlate with t-8."""
        from repro.data import SyntheticLM
        d = SyntheticLM(vocab_size=512, seq_len=128, batch_size=16, seed=1)
        t = np.asarray(d.batch(0)["tokens"])
        match = (t[:, 8:] == t[:, :-8]).mean()
        assert match > 0.15, match  # ~copy_prob, >> 1/512 chance

    def test_range(self):
        from repro.data import SyntheticLM
        d = SyntheticLM(vocab_size=100, seq_len=32, batch_size=4)
        t = np.asarray(d.batch(0)["tokens"])
        assert t.min() >= 0 and t.max() < 100


class TestOptim:
    def test_sgd_momentum_matches_reference(self):
        from repro.optim import sgd_momentum
        from repro.optim.optimizers import apply_updates
        opt = sgd_momentum(momentum=0.9, weight_decay=0.0)
        p = {"w": jnp.array([1.0, 2.0])}
        g = {"w": jnp.array([0.1, -0.2])}
        st_ = opt.init(p)
        up, st_ = opt.update(g, st_, p, 0.1)
        np.testing.assert_allclose(np.asarray(up["w"]),
                                   [-0.01, 0.02], rtol=1e-6)
        up2, st_ = opt.update(g, st_, p, 0.1)
        # m2 = 0.9*m1 + g
        np.testing.assert_allclose(np.asarray(up2["w"]),
                                   [-0.019, 0.038], rtol=1e-6)

    def test_weight_decay(self):
        from repro.optim import sgd_momentum
        opt = sgd_momentum(momentum=0.0, weight_decay=0.1)
        p = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([0.0])}
        up, _ = opt.update(g, opt.init(p), p, 1.0)
        np.testing.assert_allclose(np.asarray(up["w"]), [-0.1], rtol=1e-6)

    def test_adamw_step(self):
        from repro.optim import adamw
        opt = adamw()
        p = {"w": jnp.ones((4,))}
        g = {"w": jnp.full((4,), 0.5)}
        s = opt.init(p)
        up, s = opt.update(g, s, p, 1e-2)
        # first step: update ~= -lr * sign(g)
        np.testing.assert_allclose(np.asarray(up["w"]),
                                   -1e-2 * np.ones(4), rtol=1e-3)

    def test_schedules(self):
        from repro.optim import step_decay, warmup_cosine
        sd = step_decay(0.1, [10, 20])
        assert float(sd(5)) == pytest.approx(0.1)
        assert float(sd(15)) == pytest.approx(0.01)
        assert float(sd(25)) == pytest.approx(0.001)
        wc = warmup_cosine(1.0, 10, 100)
        assert float(wc(0)) < float(wc(9)) <= 1.0
        assert float(wc(100)) == pytest.approx(0.1, rel=0.05)


class TestCheckpoint:
    def test_roundtrip(self):
        from repro.checkpoint import load_checkpoint, save_checkpoint
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": (jnp.ones((4,), jnp.int32), {"c": jnp.zeros(())})}
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            save_checkpoint(path, tree, step=7)
            like = jax.tree_util.tree_map(jnp.zeros_like, tree)
            back, step = load_checkpoint(path, like)
            assert step == 7
            for x, y in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(back)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_model_params_roundtrip(self):
        from repro.checkpoint import load_checkpoint, save_checkpoint
        from repro.configs.base import get_smoke_config
        from repro.models import LM
        model = LM(get_smoke_config("gemma2-9b"))
        params = model.init(jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "m.npz")
            save_checkpoint(path, params, step=1)
            back, _ = load_checkpoint(
                path, jax.tree_util.tree_map(jnp.zeros_like, params))
        la, lb = map(jax.tree_util.tree_leaves, (params, back))
        assert all(bool((a == b).all()) for a, b in zip(la, lb))


class TestHloCost:
    def test_scan_trip_multiplication(self):
        from repro.launch.hlo_cost import analyze

        def scanned(x, ws):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jnp.ones((128, 128))
        ws = jnp.ones((7, 128, 128))
        txt = jax.jit(scanned).lower(x, ws).compile().as_text()
        c = analyze(txt)
        assert c["flops"] == pytest.approx(2 * 128 ** 3 * 7, rel=0.01)

    def test_nested_scan(self):
        from repro.launch.hlo_cost import analyze

        def f(x, ws):
            def outer(c, w):
                def inner(c2, _):
                    return c2 @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            y, _ = jax.lax.scan(outer, x, ws)
            return y

        x = jnp.ones((64, 64))
        ws = jnp.ones((5, 64, 64))
        txt = jax.jit(f).lower(x, ws).compile().as_text()
        c = analyze(txt)
        assert c["flops"] == pytest.approx(2 * 64 ** 3 * 15, rel=0.01)

    def test_comment_stripping(self):
        """Tuple types with >5 elements carry /*index=N*/ comments."""
        from repro.launch.hlo_cost import parse_computations

        def f(a, b, c, d, e, g):
            def body(carry, _):
                a, b, c, d, e, g = carry
                return (a @ b, b, c, d, e, g), None
            out, _ = jax.lax.scan(body, (a, b, c, d, e, g), None, length=2)
            return out[0]

        args = [jnp.ones((32, 32))] * 6
        txt = jax.jit(f).lower(*args).compile().as_text()
        comps = parse_computations(txt)
        dots = sum(1 for instrs in comps.values()
                   for i in instrs if i.op == "dot")
        assert dots >= 1
