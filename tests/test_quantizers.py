"""Quantizer-level behaviour: unbiasedness, MSE ordering (the paper's core
claims), bucketing, clipping, wire accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ALL_METHODS, make_quantizer, theory
from repro.core import buckets as B

jax.config.update("jax_platform_name", "cpu")


def grad_proxy(seed=0, n=20000, scale=0.01):
    return jax.random.laplace(jax.random.key(seed), (n,)) * scale


class TestRoundTrip:
    @pytest.mark.parametrize("name", ALL_METHODS)
    def test_qdq_shape_dtype_finite(self, name):
        g = grad_proxy().astype(jnp.float32)
        qz = make_quantizer(name)
        out = qz.qdq(g, jax.random.key(1))
        assert out.shape == g.shape and out.dtype == g.dtype
        assert bool(jnp.isfinite(out).all())

    @pytest.mark.parametrize("name", ["orq-9", "bingrad-b", "terngrad"])
    def test_bf16_input(self, name):
        g = grad_proxy().astype(jnp.bfloat16)
        out = make_quantizer(name).qdq(g, jax.random.key(1))
        assert out.dtype == jnp.bfloat16

    def test_fp_is_identity(self):
        g = grad_proxy()
        assert bool((make_quantizer("fp").qdq(g, jax.random.key(0)) == g).all())

    @pytest.mark.parametrize("n", [1, 5, 2047, 2048, 2049, 10000])
    def test_ragged_sizes(self, n):
        g = grad_proxy(n=n)
        out = make_quantizer("orq-5").qdq(g, jax.random.key(2))
        assert out.shape == (n,)
        assert bool(jnp.isfinite(out).all())

    def test_values_are_levels(self):
        g = grad_proxy(n=4096)
        qz = make_quantizer("orq-5", bucket_size=2048)
        q = qz.quantize(g, jax.random.key(3))
        vals = np.asarray(qz.decode(q.idx, q.levels))
        lv = np.asarray(q.levels)
        for b in range(vals.shape[0]):
            assert np.isin(vals[b], lv[b]).all()


class TestUnbiasedness:
    """Assumption 1 / the paper's unbiased-vs-biased split."""

    @pytest.mark.parametrize("name", ["orq-3", "orq-9", "qsgd-5", "linear-5",
                                      "terngrad", "minmax2"])
    def test_unbiased_schemes(self, name):
        qz = make_quantizer(name, bucket_size=512)
        assert qz.unbiased
        g = grad_proxy(seed=3, n=2048, scale=1.0)
        bias = theory.empirical_bias(qz, g, jax.random.key(4), n_samples=400)
        # mean bias shrinks as 1/sqrt(samples); elementwise spread ~ quant step
        assert abs(float(bias.mean())) < 2e-2
        if name != "minmax2":  # minmax2's quant step is the whole range
            assert float(jnp.abs(bias).mean()) < 0.2

    @pytest.mark.parametrize("name", ["bingrad-pb", "bingrad-b", "signsgd"])
    def test_biased_schemes_declared(self, name):
        assert not make_quantizer(name).unbiased

    def test_bingrad_pb_unbiased_interior(self):
        """Eq. 14: elements strictly inside (b_{-1}, b_1) are unbiased."""
        qz = make_quantizer("bingrad-pb", bucket_size=2048)
        g = grad_proxy(seed=5, n=2048, scale=1.0)
        bkt, mask = B.to_buckets(g, 2048)
        lv = qz.fit(bkt, mask)
        b1 = float(lv[0, 1])
        bias = theory.empirical_bias(qz, g, jax.random.key(6), n_samples=600)
        interior = np.abs(np.asarray(g)) < 0.8 * b1
        assert np.abs(np.asarray(bias))[interior].mean() < 0.06


class TestPaperOrdering:
    """Table 2 / Fig. 2 qualitative claims on quantization error."""

    @pytest.mark.parametrize("dist", ["normal", "laplace", "student_t"])
    def test_orq_beats_counterparts(self, dist):
        key = jax.random.key(7)
        if dist == "normal":
            g = jax.random.normal(key, (30000,))
        elif dist == "laplace":
            g = jax.random.laplace(key, (30000,))
        else:
            g = jax.random.t(key, 3.0, (30000,))
        mse = {n: float(theory.scheme_mse(make_quantizer(n), g))
               for n in ["orq-3", "orq-5", "orq-9", "qsgd-5", "qsgd-9",
                          "linear-5", "linear-9", "terngrad"]}
        assert mse["orq-5"] < mse["qsgd-5"]
        assert mse["orq-5"] < mse["linear-5"]
        assert mse["orq-9"] < mse["qsgd-9"]
        assert mse["orq-9"] < mse["linear-9"]
        assert mse["orq-3"] < mse["terngrad"]
        # more levels => lower error
        assert mse["orq-9"] < mse["orq-5"] < mse["orq-3"]

    def test_bingrad_b_beats_pb_mse(self):
        g = grad_proxy(seed=8, n=30000)
        b = float(theory.scheme_mse(make_quantizer("bingrad-b"), g))
        pb = float(theory.scheme_mse(make_quantizer("bingrad-pb"), g))
        assert b < pb

    def test_bingrad_beats_minmax_endpoints(self):
        """§3.2: {min,max} levels are outlier-fragile; BinGrad fixes that."""
        g = grad_proxy(seed=9, n=30000)
        mm = float(theory.scheme_mse(make_quantizer("minmax2"), g))
        pb = float(theory.scheme_mse(make_quantizer("bingrad-pb"), g))
        assert pb < mm


class TestClipping:
    def test_clip_reduces_range(self):
        g = grad_proxy(seed=10, n=8192, scale=1.0)
        qz = make_quantizer("terngrad", clip_c=2.5)
        q = qz.quantize(g, jax.random.key(0))
        assert float(jnp.abs(q.levels).max()) < float(jnp.abs(g).max())

    def test_clip_changes_levels_not_shape(self):
        g = grad_proxy(seed=11)
        a = make_quantizer("orq-5").qdq(g, jax.random.key(0))
        b = make_quantizer("orq-5", clip_c=2.5).qdq(g, jax.random.key(0))
        assert a.shape == b.shape
        assert not bool(jnp.allclose(a, b))
    # ragged-bucket σ-clip regression tests live in tests/test_clipping.py
    # (they must run even without the optional hypothesis extra)


class TestWire:
    def test_wire_bytes_compression(self):
        n = 1 << 20
        fp = make_quantizer("fp").wire_bytes(n)
        tern = make_quantizer("terngrad").wire_bytes(n)
        orq9 = make_quantizer("orq-9").wire_bytes(n)
        bin2 = make_quantizer("bingrad-b").wire_bytes(n)
        assert fp / bin2 > 25          # ~x32 minus level-table overhead
        assert fp / tern > 14          # 2-bit packed (paper's x20.2 is entropy)
        assert 7 < fp / orq9 < 10.7    # 4-bit packed for 9 levels

    def test_pack_unpack_roundtrip(self):
        from repro.core import encode
        for s in (2, 3, 5, 9, 17):
            bits = encode.bits_for_levels(s)
            idx = jax.random.randint(jax.random.key(s), (4, 517), 0, s)
            words = encode.pack(idx, bits)
            assert words.dtype == jnp.uint32
            back = encode.unpack(words, bits, 517)
            assert bool((back == idx).all())


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10 ** 6),
    name=st.sampled_from(["orq-5", "qsgd-5", "linear-9", "terngrad",
                          "bingrad-pb", "bingrad-b", "signsgd"]),
    bucket=st.sampled_from([128, 512, 2048]),
    n=st.integers(2, 6000),
)
def test_quantizer_invariants_property(seed, name, bucket, n):
    """Invariants for any scheme: output finite, within [min, max] of the
    (possibly clipped) input range, deterministic given the same key."""
    g = jax.random.laplace(jax.random.key(seed), (n,))
    qz = make_quantizer(name, bucket_size=bucket)
    out1 = qz.qdq(g, jax.random.key(seed + 1))
    out2 = qz.qdq(g, jax.random.key(seed + 1))
    assert bool((out1 == out2).all())
    assert bool(jnp.isfinite(out1).all())
    # all schemes' levels live within ±max|g| (qsgd/linear levels can exceed
    # the one-sided data range, but never the symmetric max-abs envelope)
    amax = float(jnp.abs(g).max())
    assert float(jnp.abs(out1).max()) <= amax + 1e-4
