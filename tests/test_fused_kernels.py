"""Fused one-pass kernel pipeline: parity, jaxpr shape, env overrides.

The contracts pinned here (deterministic — no hypothesis; the property
sweep lives in test_fused_property.py):

* ``wire.encode``/``wire.qdq``/``wire.decode*`` on the fused path are
  BIT-identical to the PR-1..4 multi-pass pipeline and to the pure-jnp
  reference oracle, for every scheme, on ragged buffers, given the same
  PRNG key.
* The fused path lowers to exactly ONE ``pallas_call`` per encode/decode
  (the acceptance criterion of PR 5); the multi-pass path keeps >= 2.
* ``REPRO_USE_KERNELS=0`` forces the reference oracle everywhere and is
  read at TRACE time (the CI reference-oracle matrix leg relies on it).
* The kernel_bench regression gate parses the stable schema and fails on
  speedup regressions / bit-identity loss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import wire
from repro.core.quantizers import Quantizer
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.key(11)

SCHEMES = {
    "orq-9": dict(method="orq", num_levels=9),
    "orq-17": dict(method="orq", num_levels=17),
    "orq-5-clip": dict(method="orq", num_levels=5, clip_c=2.5),
    "terngrad-clip": dict(method="terngrad", clip_c=2.5),
    "qsgd-9": dict(method="qsgd", num_levels=9),
    "linear-5": dict(method="linear", num_levels=5),
    "minmax2": dict(method="minmax2"),
    "bingrad-pb": dict(method="bingrad_pb"),
    "bingrad-b": dict(method="bingrad_b"),
    "bingrad-b-lloyd-clip": dict(method="bingrad_b", clip_c=2.5,
                                 lloyd_iters=2),
    "signsgd": dict(method="signsgd"),
}


def _qz(name, d=64):
    return Quantizer(bucket_size=d, **SCHEMES[name])


def _buffers(nb, d, valid=None, seed=1):
    bkt = jax.random.laplace(jax.random.key(seed), (nb, d)) * 0.1
    n = nb * d if valid is None else valid
    mask = jnp.arange(nb * d).reshape(nb, d) < n
    return bkt, mask


def _pallas_calls(closed) -> int:
    from repro.analysis import stats

    return stats.pallas_call_count(closed)


def _expect_pallas(closed, want: int) -> None:
    """Pin the launch count through the SAME rule the CI matrix audit
    runs (``python -m repro.analysis --check``)."""
    from repro.analysis import TraceBundle, run_checks

    fs = run_checks(
        [TraceBundle(label="pin", kind="wire_op", closed=closed,
                     meta={"expect_pallas_calls": want})],
        rules=["one-pallas-call"])
    assert not fs, [str(f) for f in fs]


class TestEncodeParity:
    @pytest.mark.parametrize("name", sorted(SCHEMES))
    @pytest.mark.parametrize("nb,d,valid", [
        (5, 37, 172),      # ragged width, ragged tail, non-multiple rows
        (8, 64, 8 * 64),   # exact tile fit, fully valid
        (1, 129, 100),     # single odd-width bucket
    ])
    def test_fused_vs_multipass_vs_ref(self, name, nb, d, valid):
        qz = _qz(name, d)
        bkt, mask = _buffers(nb, d, valid)
        w_f, lv_f = wire.encode(qz, bkt, mask, KEY, use_kernels=True)
        w_m, lv_m = wire.encode_multipass(qz, bkt, mask, KEY,
                                          use_kernels=True)
        w_r, lv_r = wire.encode(qz, bkt, mask, KEY, use_kernels=False)
        np.testing.assert_array_equal(np.asarray(w_f), np.asarray(w_m))
        np.testing.assert_array_equal(np.asarray(w_f), np.asarray(w_r))
        np.testing.assert_array_equal(np.asarray(lv_f), np.asarray(lv_m))
        np.testing.assert_array_equal(np.asarray(lv_f), np.asarray(lv_r))

    def test_prng_bits_threaded_not_refreshed(self):
        """Same key -> identical words; different key -> different rounding
        (the fused path must consume the SAME threefry stream)."""
        qz = _qz("orq-9")
        bkt, mask = _buffers(6, 64)
        w1, _ = wire.encode(qz, bkt, mask, jax.random.key(0))
        w2, _ = wire.encode(qz, bkt, mask, jax.random.key(0))
        w3, _ = wire.encode(qz, bkt, mask, jax.random.key(1))
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
        assert not np.array_equal(np.asarray(w1), np.asarray(w3))


class TestDecodeParity:
    @pytest.mark.parametrize("name", ["orq-9", "terngrad-clip", "bingrad-b",
                                      "orq-17"])
    @pytest.mark.parametrize("L", [1, 3, 4])
    def test_mean_and_each(self, name, L):
        nb, d = 5, 37
        qz = _qz(name, d)
        bkt, mask = _buffers(nb, d, 172)
        units = [wire.encode(qz, bkt, mask, jax.random.key(i))
                 for i in range(L)]
        ws = jnp.stack([u[0] for u in units])
        lvs = jnp.stack([u[1] for u in units])
        m_f = wire.decode_mean(qz, ws, lvs, d, use_kernels=True)
        m_m = wire.decode_mean_multipass(qz, ws, lvs, d, use_kernels=True)
        np.testing.assert_array_equal(np.asarray(m_f), np.asarray(m_m))
        m_r = wire.decode_mean(qz, ws, lvs, d, use_kernels=False)
        np.testing.assert_allclose(np.asarray(m_f), np.asarray(m_r),
                                   rtol=1e-6, atol=1e-7)
        e_f = wire.decode_each(qz, ws, lvs, d, use_kernels=True)
        e_m = wire.decode_each_multipass(qz, ws, lvs, d, use_kernels=True)
        np.testing.assert_array_equal(np.asarray(e_f), np.asarray(e_m))
        e_r = wire.decode_each(qz, ws, lvs, d, use_kernels=False)
        np.testing.assert_array_equal(np.asarray(e_f), np.asarray(e_r))

    def test_decode_average_flag(self):
        qz = _qz("orq-9", 64)
        bkt, mask = _buffers(4, 64)
        w, lv = wire.encode(qz, bkt, mask, KEY)
        ws, lvs = w[None], lv[None]
        np.testing.assert_array_equal(
            np.asarray(wire.decode(qz, ws, lvs, 64, average=True)),
            np.asarray(wire.decode_mean(qz, ws, lvs, 64)))
        np.testing.assert_array_equal(
            np.asarray(wire.decode(qz, ws, lvs, 64, average=False)),
            np.asarray(wire.decode_each(qz, ws, lvs, 64)))


class TestQdqParity:
    """wire.qdq is the error-feedback hot path: must equal the legacy
    fit -> assign -> masked select -> decode composition bit for bit."""

    @pytest.mark.parametrize("name", sorted(SCHEMES))
    def test_fused_vs_legacy_vs_ref(self, name):
        nb, d = 5, 37
        qz = _qz(name, d)
        bkt, mask = _buffers(nb, d, 172)
        got = wire.qdq(qz, bkt, mask, KEY, use_kernels=True)
        lv = qz.fit(bkt, mask)
        idx = jnp.where(mask, wire.assign(qz, bkt, lv, KEY, True, mask=mask),
                        0)
        want = Quantizer.decode(idx, lv)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        ref = wire.qdq(qz, bkt, mask, KEY, use_kernels=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


class TestJaxprOnePallasCall:
    """PR-5 acceptance: the fused path lowers to exactly ONE pallas_call
    per encode/decode; the multi-pass path kept >= 2; the reference
    oracle has none."""

    @pytest.fixture(autouse=True)
    def _kernels_on(self, monkeypatch):
        # these assertions are about the KERNEL lowering; pin the env so
        # the CI reference-oracle leg (REPRO_USE_KERNELS=0) doesn't turn
        # them vacuous/false
        monkeypatch.setenv("REPRO_USE_KERNELS", "1")

    def _encode_jaxpr(self, qz, use_kernels):
        bkt, mask = _buffers(5, 37)
        return jax.make_jaxpr(
            lambda b, m, k: wire.encode(qz, b, m, k,
                                        use_kernels=use_kernels))(
            bkt, mask, KEY)

    @pytest.mark.parametrize("name", ["orq-9", "terngrad-clip", "bingrad-b",
                                      "signsgd"])
    def test_encode_single_pallas_call(self, name):
        _expect_pallas(self._encode_jaxpr(_qz(name, 37), True), 1)

    def test_encode_ref_has_none(self):
        _expect_pallas(self._encode_jaxpr(_qz("orq-9", 37), False), 0)

    def test_encode_multipass_has_more(self):
        qz = _qz("orq-9", 37)
        bkt, mask = _buffers(5, 37)
        closed = jax.make_jaxpr(
            lambda b, m, k: wire.encode_multipass(qz, b, m, k))(
            bkt, mask, KEY)
        assert _pallas_calls(closed) >= 2

    @pytest.mark.parametrize("average", [True, False])
    def test_decode_single_pallas_call(self, average):
        qz = _qz("orq-9", 37)
        ws = jnp.zeros((3, 5, 10), jnp.uint32)
        lvs = jnp.zeros((3, 5, 9))
        closed = jax.make_jaxpr(
            lambda w, l: wire.decode(qz, w, l, 37, average=average))(
            ws, lvs)
        _expect_pallas(closed, 1)

    def test_qdq_single_pallas_call(self):
        qz = _qz("orq-9", 37)
        bkt, mask = _buffers(5, 37)
        closed = jax.make_jaxpr(
            lambda b, m, k: wire.qdq(qz, b, m, k))(bkt, mask, KEY)
        _expect_pallas(closed, 1)


class TestUseKernelsEnv:
    """REPRO_USE_KERNELS forces the reference oracle globally and is read
    at trace time (documented next to REPRO_PALLAS_INTERPRET)."""

    def test_enabled_values(self, monkeypatch):
        monkeypatch.setenv("REPRO_USE_KERNELS", "0")
        assert ops.kernels_enabled() is False
        monkeypatch.setenv("REPRO_USE_KERNELS", "off")
        assert ops.kernels_enabled() is False
        monkeypatch.setenv("REPRO_USE_KERNELS", "1")
        assert ops.kernels_enabled() is True
        monkeypatch.setenv("REPRO_USE_KERNELS", "bogus")
        with pytest.raises(ValueError, match="REPRO_USE_KERNELS"):
            ops.kernels_enabled()
        monkeypatch.delenv("REPRO_USE_KERNELS", raising=False)
        assert ops.kernels_enabled() is True

    def test_env_read_at_trace_time(self, monkeypatch):
        """Flipping the env between two FRESH traces flips the lowering —
        the override must NOT be baked in at import time. (A fresh
        closure per trace: jax caches traces on function identity, which
        is exactly why the docs say to set the env before the first jit
        of a step function.)"""
        qz = _qz("orq-9", 37)
        bkt, mask = _buffers(5, 37)

        def trace():
            fn = lambda b, m, k: wire.encode(  # noqa: E731 — fresh each time
                qz, b, m, k, use_kernels=True)
            return _pallas_calls(jax.make_jaxpr(fn)(bkt, mask, KEY))

        monkeypatch.setenv("REPRO_USE_KERNELS", "0")
        assert trace() == 0
        monkeypatch.setenv("REPRO_USE_KERNELS", "1")
        assert trace() == 1
        monkeypatch.setenv("REPRO_USE_KERNELS", "bogus")
        with pytest.raises(ValueError, match="REPRO_USE_KERNELS"):
            trace()

    def test_forced_oracle_matches_kernel_numerics(self, monkeypatch):
        qz = _qz("orq-9", 64)
        bkt, mask = _buffers(6, 64)
        want_w, want_lv = wire.encode(qz, bkt, mask, KEY)
        monkeypatch.setenv("REPRO_USE_KERNELS", "0")
        got_w, got_lv = wire.encode(qz, bkt, mask, KEY)
        np.testing.assert_array_equal(np.asarray(want_w), np.asarray(got_w))
        np.testing.assert_array_equal(np.asarray(want_lv),
                                      np.asarray(got_lv))


class TestOpsJit:
    """Satellite: the ops wrappers dispatch to jit'd implementations —
    repeat calls with the same static shapes must not re-trace."""

    def test_ref_wrappers_are_jitted(self):
        idx = jnp.zeros((2, 64), jnp.int32)
        a = ops.pack(idx, 2, use_kernels=False)
        traces0 = ops._ref_pack._cache_size()
        for _ in range(3):
            ops.pack(idx, 2, use_kernels=False)
        assert ops._ref_pack._cache_size() == traces0
        np.testing.assert_array_equal(
            np.asarray(a),
            np.asarray(ops.pack(idx, 2, use_kernels=True)))

    def test_fused_wrappers_are_jitted(self, monkeypatch):
        monkeypatch.setenv("REPRO_USE_KERNELS", "1")
        v = jnp.zeros((2, 64))
        lv = jnp.tile(jnp.linspace(-1, 1, 9), (2, 1))
        rb = jnp.zeros((2, 64), jnp.uint32)
        m = jnp.ones((2, 64), bool)
        ops.encode_fused(v, lv, rb, m, bits=4)
        from repro.kernels import fused_encode
        n0 = fused_encode.encode_fused._cache_size()
        for _ in range(3):
            ops.encode_fused(v, lv, rb, m, bits=4)
        assert fused_encode.encode_fused._cache_size() == n0


class TestBenchGate:
    """The kernel_bench --check gate: schema, bit-identity, geomean
    regression detection (pure logic — no timing)."""

    def _mk(self, speedups, op="encode", bit_identical=True):
        return {
            "schema": 1, "quick": True, "modes": ["interpret"],
            "summary": {},
            "entries": [
                {"key": f"{op}/s{i}/d512/interpret", "op": op,
                 "scheme": f"s{i}", "wire_bits": 4, "bucket": 512,
                 "nb": 24, "mode": "interpret", "fused_us": 100.0,
                 "multipass_us": 100.0 * r, "ref_us": 120.0,
                 "speedup_vs_multipass": r, "melems_per_s": 1.0,
                 "bit_identical": bit_identical}
                for i, r in enumerate(speedups)],
        }

    def _bench(self):
        import benchmarks.kernel_bench as kb
        return kb

    def test_pass_within_tolerance(self):
        kb = self._bench()
        base = self._mk([2.0, 2.0, 2.0])
        new = self._mk([1.8, 1.9, 2.1])       # geomean well within 25%
        assert kb.check(new, base, 0.25) == []

    def test_fails_on_geomean_regression(self):
        kb = self._bench()
        base = self._mk([2.0, 2.0, 2.0])
        new = self._mk([1.2, 1.3, 1.2])       # ~38% drop
        fails = kb.check(new, base, 0.25)
        assert any("geomean regressed" in f for f in fails)

    def test_fails_on_bit_identity_loss(self):
        kb = self._bench()
        base = self._mk([2.0])
        new = self._mk([2.0], bit_identical=False)
        fails = kb.check(new, base, 0.25)
        assert any("bit-identity" in f for f in fails), fails

    def test_fails_on_schema_change(self):
        kb = self._bench()
        base = self._mk([2.0])
        new = self._mk([2.0])
        new["schema"] = 999
        assert any("schema" in f for f in kb.check(new, base, 0.25))

    def test_fails_on_disjoint_keys(self):
        kb = self._bench()
        base = self._mk([2.0])
        new = self._mk([2.0])
        new["entries"][0]["key"] = "encode/other/d1/interpret"
        assert any("no overlapping" in f for f in kb.check(new, base, 0.25))

    def test_committed_baseline_parses_and_matches_schema(self):
        import json
        import os
        kb = self._bench()
        path = kb.DEFAULT_BASELINE
        assert os.path.exists(path), "committed baseline JSON missing"
        with open(path) as fh:
            base = json.load(fh)
        assert base["schema"] == kb.SCHEMA
        assert base["entries"], "baseline has no entries"
        assert all(e.get("bit_identical") for e in base["entries"])
        # the gate passes a run against itself
        assert kb.check(base, base, 0.25) == []


class TestPallasCallStats:
    """Jaxpr-based per-pallas_call VMEM/AI extraction (launch.hlo_cost):
    the HLO text parser cannot see interpret-mode pallas_calls, so the
    roofline report reads the jaxpr grid mapping instead. These pin that
    the fused kernels' row_block sizing actually holds per-grid-step
    residency under VMEM_TILE_BYTES while HBM traffic scales with the
    problem."""

    @pytest.fixture(autouse=True)
    def _kernels_on(self, monkeypatch):
        # the stats are about the KERNEL lowering; pin the env so the CI
        # reference-oracle leg (REPRO_USE_KERNELS=0) doesn't void them
        monkeypatch.setenv("REPRO_USE_KERNELS", "1")

    def _encode_jaxpr(self, nb, d=512):
        qz = Quantizer(bucket_size=d, method="orq", num_levels=9)
        bkt = jnp.ones((nb, d), jnp.float32)
        mask = jnp.ones((nb, d), jnp.float32)
        return jax.make_jaxpr(
            lambda b, m, k: wire.encode(qz, b, m, k, use_kernels=True)
        )(bkt, mask, KEY)

    def test_fused_encode_within_vmem_tile(self):
        from repro.kernels.fused_encode import VMEM_TILE_BYTES
        from repro.launch.hlo_cost import pallas_call_stats

        stats = pallas_call_stats(self._encode_jaxpr(nb=4096))
        enc = [s for s in stats if "encode" in s["kernel"]]
        assert enc, f"no encode pallas_call found in {stats}"
        for s in enc:
            # the tiling fix: per-grid-step residency obeys the VMEM cap
            # even though the full problem is ~27 MiB
            assert s["vmem_bytes"] <= VMEM_TILE_BYTES
            assert s["hbm_bytes"] > VMEM_TILE_BYTES
            assert s["grid_steps"] > 1
            assert s["arithmetic_intensity"] > 0

    def test_small_problem_single_grid_step(self):
        from repro.launch.hlo_cost import pallas_call_stats

        stats = pallas_call_stats(self._encode_jaxpr(nb=8))
        enc = [s for s in stats if "encode" in s["kernel"]]
        assert enc and all(s["grid_steps"] == 1 for s in enc)

    def test_vmem_scales_with_row_block_not_problem(self):
        from repro.launch.hlo_cost import pallas_call_stats

        small = pallas_call_stats(self._encode_jaxpr(nb=4096))
        large = pallas_call_stats(self._encode_jaxpr(nb=8192))
        vs = max(s["vmem_bytes"] for s in small if "encode" in s["kernel"])
        vl = max(s["vmem_bytes"] for s in large if "encode" in s["kernel"])
        # doubling the rows grows HBM traffic, not the per-step footprint
        assert vl <= vs * 1.5

    def test_decode_mean_stats_present(self):
        from repro.launch.hlo_cost import pallas_call_stats

        qz = Quantizer(bucket_size=512, method="orq", num_levels=9)
        bkt = jnp.ones((32, 512), jnp.float32)
        mask = jnp.ones((32, 512), jnp.float32)
        words, levels = wire.encode(qz, bkt, mask, KEY, use_kernels=True)
        ws = jnp.stack([words] * 4)
        lvs = jnp.stack([levels] * 4)
        closed = jax.make_jaxpr(
            lambda w, l: wire.decode_mean(qz, w, l, 512, use_kernels=True)
        )(ws, lvs)
        stats = pallas_call_stats(closed)
        assert any("decode" in s["kernel"] for s in stats)
        assert all(s["vmem_bytes"] > 0 and s["hbm_bytes"] >= s["vmem_bytes"]
                   for s in stats)
