"""Property-based round-trip suite for the fused one-pass kernels.

For every wire bit-width (1-5), arbitrary bucket shapes, ragged-tail
masks, and PRNG keys: the fused ``encode``/``decode``/``qdq`` must be
bit-identical to the PR-1..4 multi-pass pipeline AND to the pure-jnp
reference oracle — including the per-bucket level tables that ride the
wire. (Decode-mean kernel-vs-ref is the one comparison that is only
allclose: the kernel accumulates ``val/L`` per worker while the oracle
sums then scales; fused-vs-multipass stays exact on both settings.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.comm import wire  # noqa: E402
from repro.core.quantizers import Quantizer  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

# one scheme per wire bit-width 1..5 (+ clip/lloyd variants mixed in)
WIDTH_SCHEMES = [
    dict(method="bingrad_b"),                               # 1 bit
    dict(method="bingrad_b", clip_c=2.5, lloyd_iters=1),    # 1 bit
    dict(method="signsgd"),                                 # 1 bit
    dict(method="minmax2"),                                 # 1 bit
    dict(method="terngrad"),                                # 2 bits
    dict(method="terngrad", clip_c=2.5),                    # 2 bits
    dict(method="orq", num_levels=5),                       # 3 bits
    dict(method="linear", num_levels=5),                    # 3 bits
    dict(method="orq", num_levels=9),                       # 4 bits
    dict(method="qsgd", num_levels=9),                      # 4 bits
    dict(method="orq", num_levels=17),                      # 5 bits
    dict(method="orq", num_levels=17, clip_c=1.7),          # 5 bits
]


def _case(seed, scheme_i, nb, d, frac):
    qz = Quantizer(bucket_size=d, **WIDTH_SCHEMES[scheme_i])
    bkt = jax.random.laplace(jax.random.key(seed), (nb, d)) * 0.1
    valid = max(1, int(nb * d * frac))          # ragged tail, >= 1 element
    mask = jnp.arange(nb * d).reshape(nb, d) < valid
    return qz, bkt, mask, jax.random.key(seed + 1)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 10 ** 6),
    scheme_i=st.integers(0, len(WIDTH_SCHEMES) - 1),
    nb=st.integers(1, 11),
    d=st.sampled_from([17, 64, 96, 128, 257]),
    frac=st.floats(0.05, 1.0),
)
def test_encode_roundtrip_bit_identical(seed, scheme_i, nb, d, frac):
    """fused == multi-pass == reference oracle, words AND level tables."""
    qz, bkt, mask, key = _case(seed, scheme_i, nb, d, frac)
    w_f, lv_f = wire.encode(qz, bkt, mask, key, use_kernels=True)
    w_m, lv_m = wire.encode_multipass(qz, bkt, mask, key, use_kernels=True)
    w_r, lv_r = wire.encode(qz, bkt, mask, key, use_kernels=False)
    assert (np.asarray(w_f) == np.asarray(w_m)).all()
    assert (np.asarray(w_f) == np.asarray(w_r)).all()
    assert (np.asarray(lv_f) == np.asarray(lv_m)).all()
    assert (np.asarray(lv_f) == np.asarray(lv_r)).all()

    # decode round-trip: words survive unpack exactly on both paths
    ws, lvs = w_f[None], lv_f[None]
    e_f = wire.decode_each(qz, ws, lvs, d, use_kernels=True)
    e_m = wire.decode_each_multipass(qz, ws, lvs, d, use_kernels=True)
    e_r = wire.decode_each(qz, ws, lvs, d, use_kernels=False)
    assert (np.asarray(e_f) == np.asarray(e_m)).all()
    assert (np.asarray(e_f) == np.asarray(e_r)).all()


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10 ** 6),
    scheme_i=st.integers(0, len(WIDTH_SCHEMES) - 1),
    nb=st.integers(1, 9),
    d=st.sampled_from([33, 64, 128]),
    L=st.integers(1, 5),
    frac=st.floats(0.05, 1.0),
)
def test_decode_mean_bit_identical_to_multipass(seed, scheme_i, nb, d, L,
                                                frac):
    """Per-worker wire units with DIFFERENT keys/levels; the fused mean
    decode must equal the multi-pass kernels exactly and the oracle to
    float tolerance."""
    qz, bkt, mask, _ = _case(seed, scheme_i, nb, d, frac)
    units = [wire.encode(qz, bkt, mask, jax.random.key(seed + i))
             for i in range(L)]
    ws = jnp.stack([u[0] for u in units])
    lvs = jnp.stack([u[1] for u in units])
    m_f = wire.decode_mean(qz, ws, lvs, d, use_kernels=True)
    m_m = wire.decode_mean_multipass(qz, ws, lvs, d, use_kernels=True)
    assert (np.asarray(m_f) == np.asarray(m_m)).all()
    m_r = wire.decode_mean(qz, ws, lvs, d, use_kernels=False)
    np.testing.assert_allclose(np.asarray(m_f), np.asarray(m_r),
                               rtol=1e-6, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10 ** 6),
    scheme_i=st.integers(0, len(WIDTH_SCHEMES) - 1),
    nb=st.integers(1, 9),
    d=st.sampled_from([33, 64, 128]),
    frac=st.floats(0.05, 1.0),
)
def test_qdq_bit_identical(seed, scheme_i, nb, d, frac):
    """The fused error-feedback qdq == legacy fit/assign/decode == oracle."""
    qz, bkt, mask, key = _case(seed, scheme_i, nb, d, frac)
    got = wire.qdq(qz, bkt, mask, key, use_kernels=True)
    lv = qz.fit(bkt, mask)
    idx = jnp.where(mask, wire.assign(qz, bkt, lv, key, True, mask=mask), 0)
    want = Quantizer.decode(idx, lv)
    assert (np.asarray(got) == np.asarray(want)).all()
    ref = wire.qdq(qz, bkt, mask, key, use_kernels=False)
    assert (np.asarray(got) == np.asarray(ref)).all()
