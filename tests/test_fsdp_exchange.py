"""fsdp-vs-replicated parity suite for the fused ZeRO-3 exchange
(``core/comm/fsdp_exchange.py``) and the PR's satellite fixes.

Covers: shard-aware layout round-trips; fused-fsdp grads bit-identical to
per-leaf-fsdp for a uniform fp policy on an 8-device mesh;
variance-consistency for orq-9/terngrad; EF residuals bit-consistent
across a checkpoint save/restore; the jaxpr O(#policy-groups) collective
guarantee; train-state donation on every jit path; ordered collective
axis names; and the ``REPRO_PALLAS_INTERPRET`` escape hatch.

Multi-device cases run in subprocesses with XLA_FLAGS forcing 8 host
devices (the main test process must keep the default single-device view,
per the repo's dry-run-only rule for fake device counts).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, comm

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _toy_layout(n_shards=4):
    """{"b": (40,) replicated-fp, "w": (16, 56) sharded-orq-9} layout."""
    tree = {"b": jnp.zeros((40,)), "w": jnp.zeros((16, 56))}
    policy = QuantPolicy.parse("b=fp,default=orq-9", bucket_size=64)
    return comm.FsdpLayout.from_tree(
        tree, policy, paths={"b": "b", "w": "w"},
        shard_dims={"b": None, "w": 0}, n_shards=n_shards), tree


class TestFsdpLayout:
    def test_grouping_and_sizes(self):
        layout, _ = _toy_layout()
        # canonical dict order: "b" (fp, replicated) then "w" (orq, sharded)
        assert [(g.cfg.name, g.sharded, g.size) for g in layout.groups] == \
            [("fp", False, 40), ("orq-9", True, 16 * 56)]
        assert layout.size == 40 + 16 * 56
        assert layout.leaf_group == (0, 1)

    def test_indivisible_leaf_rejected(self):
        tree = {"w": jnp.zeros((10, 3))}
        with pytest.raises(ValueError, match="not divisible"):
            comm.FsdpLayout.from_tree(
                tree, QuantPolicy.uniform("orq-9"), paths={"w": "w"},
                shard_dims={"w": 0}, n_shards=4)

    def test_flatten_rows_are_worker_shards(self):
        """Row w of a sharded group buffer == worker w's shard slices."""
        layout, _ = _toy_layout(n_shards=4)
        w = jax.random.normal(jax.random.key(0), (16, 56))
        b = jax.random.normal(jax.random.key(1), (40,))
        bufs = layout.flatten_groups({"b": b, "w": w})
        np.testing.assert_array_equal(np.asarray(bufs[0]), np.asarray(b))
        rows = np.asarray(bufs[1]).reshape(4, -1)
        for wk in range(4):
            np.testing.assert_array_equal(
                rows[wk], np.asarray(w)[wk * 4:(wk + 1) * 4].reshape(-1))

    def test_unflatten_outputs_inverts_shard_rows(self):
        """unflatten_outputs(row w) must hand worker w exactly its stored
        param-shard slices (the reduce-scatter output contract)."""
        layout, _ = _toy_layout(n_shards=4)
        w = jax.random.normal(jax.random.key(2), (16, 56))
        b = jax.random.normal(jax.random.key(3), (40,))
        bufs = layout.flatten_groups({"b": b, "w": w})
        rows = np.asarray(bufs[1]).reshape(4, -1)
        for wk in range(4):
            out = layout.unflatten_outputs([bufs[0], jnp.asarray(rows[wk])])
            np.testing.assert_array_equal(np.asarray(out["b"]),
                                          np.asarray(b, np.float32))
            np.testing.assert_array_equal(
                np.asarray(out["w"]),
                np.asarray(w, np.float32)[wk * 4:(wk + 1) * 4])

    def test_moveaxis_dim_round_trip(self):
        """A leaf sharded along a non-leading dim round-trips through the
        worker-major layout."""
        tree = {"w": jnp.zeros((3, 8, 5))}
        layout = comm.FsdpLayout.from_tree(
            tree, QuantPolicy.uniform("fp"), paths={"w": "w"},
            shard_dims={"w": 1}, n_shards=4)
        w = jax.random.normal(jax.random.key(4), (3, 8, 5))
        buf = layout.flatten_groups({"w": w})[0]
        rows = np.asarray(buf).reshape(4, -1)
        for wk in range(4):
            out = layout.unflatten_outputs([jnp.asarray(rows[wk])])
            np.testing.assert_array_equal(
                np.asarray(out["w"]),
                np.asarray(w, np.float32)[:, wk * 2:(wk + 1) * 2])


class TestStatics:
    def test_policy_stats_sharded_segments(self):
        policy = QuantPolicy.parse("bias=fp,default=orq-9", bucket_size=512)
        ps = [("w1", 4096), ("w2", 2048), ("bias", 64)]
        l_repl, b_repl, lab_repl = comm.policy_stats(policy, ps, 4)
        l_rs, b_rs, lab_rs = comm.policy_stats(
            policy, ps, 4, sharded_paths={"w1", "w2"})
        assert sorted(lab_repl) == ["fp", "orq-9"]
        assert sorted(lab_rs) == ["fp", "orq-9/rs"]
        # replicated: orq-9 all-reduce (2 a2a + 2 ag) + fp psum
        assert l_repl == 4 + 1
        # sharded: orq-9 reduce-scatter is phase-1 only (2 a2a) + fp psum
        assert l_rs == 2 + 1
        assert b_rs < b_repl          # no re-quantized downlink

    def test_fsdp_exchange_accounting(self):
        layout, tree = _toy_layout(n_shards=4)
        ex = comm.FsdpExchange.build(
            QuantPolicy.parse("b=fp,default=orq-9", bucket_size=64),
            tree, ("data",), paths={"b": "b", "w": "w"},
            shard_dims={"b": None, "w": 0}, n_shards=4)
        assert ex.quantized_group_count() == 1
        # fp replicated group: 1 pmean; orq-9 sharded group: 2 all_to_all
        assert ex.collective_launches() == 1 + 2
        assert ex.wire_bytes_per_worker() > 0
        assert not ex.is_identity

    def test_names_ordered_and_rejects_sets(self):
        from repro.core.comm.collectives import _names
        assert _names("data") == ("data",)
        assert _names(("pod", "data")) == ("pod", "data")   # order kept
        assert _names(["pod", "data"]) == ("pod", "data")
        # sets iterate in PYTHONHASHSEED order AND any fixed normalization
        # could disagree with the mesh order -> rejected outright
        with pytest.raises(TypeError, match="ordered tuple"):
            _names({"pod", "data"})
        with pytest.raises(TypeError, match="ordered tuple"):
            _names(frozenset({"data"}))


class TestPallasInterpretOverride:
    def test_env_forces_both_ways(self, monkeypatch):
        from repro.kernels import ops
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert ops._interpret() is True
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "off")
        assert ops._interpret() is False
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "bogus")
        with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
            ops._interpret()
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        assert ops._interpret() is (jax.default_backend() != "tpu")

    def test_forced_interpret_matches_default_numerics(self, monkeypatch):
        from repro.core import make_quantizer
        qz = make_quantizer("orq-5", bucket_size=128)
        flat = jax.random.laplace(jax.random.key(0), (512,)) * 0.1
        want = np.asarray(qz.qdq(flat, jax.random.key(1)))
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "true")
        got = np.asarray(qz.qdq(flat, jax.random.key(1)))
        np.testing.assert_array_equal(want, got)


class TestDonation:
    """Satellite: BOTH jit paths donate the train state (the replicated
    path used to keep the old params+opt alive, doubling peak memory)."""

    def _one_step(self, mesh_axes, mode):
        from repro.configs.base import get_smoke_config
        from repro.core import QuantConfig
        from repro.data import SyntheticLM
        from repro.models import LM
        from repro.optim.schedule import constant_lr
        from repro.train import TrainConfig, make_train_step
        from repro.train.step import init_state

        cfg = get_smoke_config("lm-100m")
        model = LM(cfg)
        mesh = jax.make_mesh((1,) * len(mesh_axes), mesh_axes)
        tcfg = TrainConfig(policy=QuantConfig(name="orq-9", bucket_size=512),
                           mode=mode)
        state = init_state(model, mesh, tcfg, jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           batch_size=2, seed=0)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            new_state, _ = step_fn(state, data.batch(0), jax.random.key(1))
            jax.block_until_ready(new_state)
        donation_warns = [w for w in caught
                          if "donat" in str(w.message).lower()]
        assert not donation_warns, [str(w.message) for w in donation_warns]
        return state

    @pytest.mark.slow
    def test_replicated_shard_map_path_donates(self):
        state = self._one_step(("data",), "replicated")
        assert all(x.is_deleted()
                   for x in jax.tree_util.tree_leaves(state.params))

    @pytest.mark.slow
    def test_single_device_path_donates(self):
        # a model-only mesh has no dp axes -> the plain-jit path
        state = self._one_step(("model",), "replicated")
        assert all(x.is_deleted()
                   for x in jax.tree_util.tree_leaves(state.params))

    @pytest.mark.slow
    def test_fsdp_fused_path_donates(self):
        state = self._one_step(("data",), "fsdp")
        assert all(x.is_deleted()
                   for x in jax.tree_util.tree_leaves(state.params))


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.core import QuantPolicy, comm
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state
from repro.utils.compat import shard_map

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((8,), ("data",))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8,
                   seed=3)

def run(policy, fused, ef=False, steps=2):
    tcfg = TrainConfig(policy=policy, mode="fsdp", fused_exchange=fused,
                       error_feedback=ef)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    losses = []
    for i in range(steps):
        state, m = step_fn(state, data.batch(i), jax.random.key(42))
        losses.append(float(m["loss"]))
    return losses, state
"""


def test_fp_fused_fsdp_bitexact_vs_per_leaf():
    """Acceptance: for a uniform fp policy the fused whole-tree exchange
    must be BIT-IDENTICAL to the per-leaf fsdp fallback — same losses,
    same params, same optimizer state, after multiple steps (8 workers)."""
    run_devices(COMMON + """
lf, sf = run("fp", True, steps=3)
lp, sp = run("fp", False, steps=3)
assert lf == lp, (lf, lp)
for a, b in zip(jax.tree_util.tree_leaves((sf.params, sf.opt)),
                jax.tree_util.tree_leaves((sp.params, sp.opt))):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# a fully-fp policy has no quantization error: EF allocates NO buffers
_, se = run("fp", True, ef=True, steps=1)
assert se.ef is None, se.ef
print("FP-FSDP-BITEXACT OK")
""")


def test_quantized_fused_fsdp_consistent_with_per_leaf():
    """orq-9 / terngrad: fused and per-leaf fsdp share the forward bit for
    bit (step-1 loss identical) and stay within quantization variance of
    each other afterwards; training remains finite."""
    run_devices(COMMON + """
for name in ["orq-9", "terngrad"]:
    lf, sf = run(name, True, steps=3)
    lp, sp = run(name, False, steps=3)
    # step 0 is pre-exchange: the fused forward must match exactly
    assert lf[0] == lp[0], (name, lf, lp)
    np.testing.assert_allclose(lf, lp, rtol=0.05)
    assert np.isfinite(lf).all() and np.isfinite(lp).all()
    # updated params agree to within quantization noise, and the fused
    # update is a real update (params moved)
    da = np.concatenate([np.asarray(x).ravel() for x in
                         jax.tree_util.tree_leaves(sf.params)])
    db = np.concatenate([np.asarray(x).ravel() for x in
                         jax.tree_util.tree_leaves(sp.params)])
    denom = np.abs(da).mean()
    assert np.abs(da - db).mean() < 0.05 * denom, name
    print(name, "FSDP-CONSISTENT OK")
""")


def test_fsdp_exchange_variance_and_residuals():
    """Exchange-level checks on a toy sharded tree (8 workers): the fused
    per-group reduce-scatter sits within quantization variance of the true
    mean, the fp group is exact, and residual_bufs is bit-consistent with
    the collective (mean over workers of the local decode == the RS mean,
    zero residual for fp)."""
    run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core import QuantPolicy, comm
from repro.utils.compat import shard_map

mesh = jax.make_mesh((8,), ("data",))
L = 8
gw = jax.random.laplace(jax.random.key(0), (L, 16, 56)) * 0.1
gb = jax.random.laplace(jax.random.key(1), (L, 40)) * 0.1

tree = {"b": jnp.zeros((40,)), "w": jnp.zeros((16, 56))}
policy = QuantPolicy.parse("b=fp,default=orq-9", bucket_size=64)
ex = comm.FsdpExchange.build(policy, tree, ("data",),
                             paths={"b": "b", "w": "w"},
                             shard_dims={"b": None, "w": 0}, n_shards=L)
assert [g.cfg.name for g in ex.layout.groups] == ["fp", "orq-9"]

def f(gw_all, gb_all):
    g = {"b": gb_all[0], "w": gw_all[0]}          # this worker's grads
    wid = lax.axis_index(("data",))
    bufs = ex.layout.flatten_groups(g)
    outs = ex.exchange_bufs(bufs, jax.random.key(7), wid)
    res = ex.residual_bufs(bufs, jax.random.key(7), wid)
    shard_grads = ex.layout.unflatten_outputs(outs)
    # gather everything for host-side checks
    return (jax.tree_util.tree_map(
                lambda x: lax.all_gather(x, "data")[None], shard_grads),
            lax.all_gather(res[1], "data")[None],
            lax.all_gather(bufs[1], "data")[None])

fn = jax.jit(shard_map(
    f, mesh=mesh,
    in_specs=(P("data", None, None), P("data", None)),
    out_specs=({"b": P("data", None, None), "w": P("data", None, None, None)},
               P("data", None, None), P("data", None, None)),
    axis_names=("data",), check_vma=False))
shard_grads, res_w, bufs_w = fn(gw, gb)

true_w = np.asarray(gw.mean(0))
true_b = np.asarray(gb.mean(0))
# fp replicated group: exact mean, identical on every worker
got_b = np.asarray(shard_grads["b"])[0]
for wk in range(L):
    np.testing.assert_allclose(got_b[wk], true_b, rtol=1e-5, atol=1e-6)
# orq-9 sharded group: worker w's output is ITS shard of the mean,
# within quantization variance
got_w = np.asarray(shard_grads["w"])[0]
for wk in range(L):
    err = np.abs(got_w[wk] - true_w[wk * 2:(wk + 1) * 2])
    assert err.mean() < 0.05, (wk, err.mean())
# residual bit-consistency: buffer - residual == local decode, and the
# across-worker mean of local decodes == the collective RS mean
res_w, bufs_w = np.asarray(res_w)[0], np.asarray(bufs_w)[0]
local = (bufs_w - res_w).reshape(L, L, -1)     # per worker: (L rows)
mean_rows = local.mean(0)                       # mean over workers
for wk in range(L):
    np.testing.assert_allclose(
        mean_rows[wk], got_w[wk].reshape(-1), rtol=1e-5, atol=1e-6)
assert np.abs(res_w).max() > 0
print("FSDP-EXCHANGE-VARIANCE OK")
""")


def test_whisper_fused_fsdp_mixed_groups():
    """Enc-dec arch under the fused fsdp exchange on a pure-dp mesh of 6
    workers: whisper's d_model=64 leaves have no 6-divisible dim and land
    in replicated groups while the 30-frame pos_embed shards — both group
    kinds inside one layout on a real model. The forward must match the
    per-leaf fallback bit for bit (step-1 loss) and training stays
    finite."""
    run_devices("""
import jax, numpy as np
from repro.configs.base import get_smoke_config
from repro.core import comm
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import init_state, plan_sharding

cfg = get_smoke_config("whisper-base")
model = LM(cfg)
mesh = jax.make_mesh((6,), ("data",))
key = jax.random.key(1)
batch = {
    "tokens": jax.random.randint(key, (6, 16), 0, cfg.vocab_size),
    "enc_embeds": jax.random.normal(key, (6, cfg.encoder.num_frames,
                                          cfg.d_model)) * 0.02,
}

def run(fused):
    tcfg = TrainConfig(policy="orq-5", mode="fsdp", fused_exchange=fused)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    losses = []
    for i in range(2):
        state, m = step_fn(state, batch, jax.random.key(2))
        losses.append(float(m["loss"]))
    return losses

aparams = jax.eval_shape(model.init, jax.random.key(0))
plan = plan_sharding(model, aparams, mesh)
tcfg = TrainConfig(policy="orq-5", mode="fsdp")
fex = comm.FsdpExchange.build(
    tcfg.resolved_policy(), aparams, plan.dp_axes, paths=plan.paths,
    shard_dims=plan.full_shard_dims(), n_shards=plan.n_dp)
kinds = {g.sharded for g in fex.layout.groups}
assert kinds == {True, False}, fex.layout.groups  # both group kinds

lf = run(True)
lp = run(False)
assert lf[0] == lp[0], (lf, lp)   # identical forward
assert np.isfinite(lf).all() and np.isfinite(lp).all()
print("WHISPER-FUSED-FSDP OK", lf)
""", n_devices=6)


def test_fsdp_ef_residuals_checkpoint_roundtrip():
    """EF residuals persist in TrainState.ef, accumulate (nonzero for the
    quantized group, zero for fp), and are bit-consistent across a
    checkpoint save/restore: continuing from the restored state matches
    continuing in-memory bit for bit."""
    run_devices(COMMON + """
import tempfile, os
from repro.checkpoint import save_checkpoint, load_checkpoint

tcfg = TrainConfig(policy="norm|bias=fp,default=orq-9", mode="fsdp",
                   fused_exchange=True, error_feedback=True)
state = init_state(model, mesh, tcfg, jax.random.key(0))
# group-aligned: the quantized group gets a buffer, the fp group None
# (an exact exchange has no quantization error to feed back)
assert state.ef is not None and len(state.ef) == 2
assert sum(e is None for e in state.ef) == 1, state.ef
step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
for i in range(2):
    state, m = step_fn(state, data.batch(i), jax.random.key(42))
maxes = [float(np.abs(np.asarray(e)).max())
         for e in state.ef if e is not None]
assert all(m > 0.0 for m in maxes), maxes    # residuals accumulate

path = os.path.join(tempfile.mkdtemp(), "ck")
save_checkpoint(path, state, step=int(state.step))
restored, _ = load_checkpoint(path, state)
for a, b in zip(jax.tree_util.tree_leaves(state.ef),
                jax.tree_util.tree_leaves(restored.ef)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

s_mem, _ = step_fn(state, data.batch(2), jax.random.key(42))
s_ck, _ = step_fn(restored, data.batch(2), jax.random.key(42))
for a, b in zip(jax.tree_util.tree_leaves(s_mem),
                jax.tree_util.tree_leaves(s_ck)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("FSDP-EF-CHECKPOINT OK")
""")


@pytest.mark.slow
def test_fsdp_train_step_collectives_o_groups():
    """Acceptance: the fused fsdp train-step jaxpr on an 8-device mesh
    issues O(#policy groups) quantized collectives (2 all_to_all per
    quantized group, phase-1 reduce-scatter only) and one parameter
    all-gather per sharded group — never O(#leaves). The per-leaf
    fallback scales with the leaf count."""
    run_devices(COMMON + """
from repro.train.step import plan_sharding

policy = "norm|bias=fp,embed=bingrad-b,default=orq-9"
aparams = jax.eval_shape(model.init, jax.random.key(0))
plan = plan_sharding(model, aparams, mesh)
tcfg = TrainConfig(policy=policy, mode="fsdp", fused_exchange=True)
fex = comm.FsdpExchange.build(
    tcfg.resolved_policy(), aparams, plan.dp_axes, paths=plan.paths,
    shard_dims=plan.full_shard_dims(), n_shards=plan.n_dp)
n_groups = len(fex.layout.groups)
n_q = fex.quantized_group_count()
n_sharded = sum(1 for g in fex.layout.groups if g.sharded)
n_leaves = len(jax.tree_util.tree_leaves(aparams))
assert n_leaves >= 10 and n_groups < n_leaves

def counts(fused):
    tcfg = TrainConfig(policy=policy, mode="fsdp", fused_exchange=fused)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    jx = str(jax.make_jaxpr(step_fn)(state, data.batch(0),
                                     jax.random.key(1)))
    return jx.count("all_to_all["), jx.count("all_gather[")

a2a_f, ag_f = counts(True)
a2a_l, ag_l = counts(False)
# fused: phase-1 RS = 2 all_to_all per quantized group, no phase-2
# broadcast; forward = one bf16 all_gather per SHARDED group
assert a2a_f == 2 * n_q, (a2a_f, n_q)
assert ag_f == n_sharded, (ag_f, n_sharded)
# per-leaf: one exchange per gathered leaf (scan bodies trace once, so
# the jaxpr count is a lower bound on runtime launches) — strictly more
assert a2a_l > a2a_f and ag_l > ag_f, ((a2a_l, ag_l), (a2a_f, ag_f))
print("FSDP-JAXPR OK", (a2a_f, ag_f), "vs per-leaf", (a2a_l, ag_l))
""")
