"""QuantPolicy API: parsing/resolution, the pluggable scheme registry, the
partitioned per-group layout, and the engine-level guarantees —

  * a UNIFORM policy through the partitioned engine must be bit-identical
    to the single-engine fused exchange (same buffers, same keys, same
    wire layout), including the error-feedback residuals, on an 8-device
    mesh (subprocess, forced host devices — same pattern as
    test_fused_exchange.py);
  * a mixed ``norm=fp,default=orq-9`` policy costs fewer wire bytes than
    uniform fp and issues O(#groups) collective launches in the train
    step's jaxpr, never O(#leaves).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantConfig, QuantPolicy, all_methods, comm,
                        make_quantizer, register_scheme, unregister_scheme)
from repro.core.quantizers import Quantizer

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# resolution / parsing
# ---------------------------------------------------------------------------

class TestResolution:
    def test_rule_order_first_match_wins(self):
        p = QuantPolicy.parse("norm=terngrad, norm|bias=fp, default=orq-9")
        assert p.resolve("final_norm").name == "terngrad"   # rule 1 first
        assert p.resolve("g0/pos0['bias']").name == "fp"    # rule 2
        assert p.resolve("g0/pos0['attn']['wk']").name == "orq-9"

    def test_default_fallback(self):
        p = QuantPolicy.parse("embed=bingrad-b, default=orq-9")
        assert p.resolve("embed").name == "bingrad-b"
        assert p.resolve("lm_head").name == "orq-9"
        # no explicit default -> fp
        p2 = QuantPolicy.parse("embed=bingrad-b")
        assert p2.resolve("lm_head").name == "fp"

    def test_regex_patterns(self):
        p = QuantPolicy.parse(r"norm\d+=fp, default=orq-9")
        assert p.resolve("g0/pos0['norm1']['scale']").name == "fp"
        assert p.resolve("final_norm").name == "orq-9"

    def test_regex_pattern_with_comma(self):
        # commas inside a regex (quantifiers, char classes) must survive
        # the entry split
        p = QuantPolicy.parse(r"wo{1,2}=terngrad, g[0,2]/=fp, default=orq-9")
        assert p.rules[0].pattern == r"wo{1,2}"
        assert p.resolve("g1/pos0['ffn']['woo']").name == "terngrad"
        assert p.resolve("g2/pos0['attn']['wq']").name == "fp"
        assert p.resolve("g1/pos0['attn']['wq']").name == "orq-9"
        with pytest.raises(ValueError, match="missing"):
            QuantPolicy.parse("norm=fp, danglingtail")

    def test_regex_pattern_with_lookahead_equals(self):
        # '=' inside a lookaround survives: entries split on the LAST '='
        p = QuantPolicy.parse(r"norm(?=\d)=fp, default=orq-9")
        assert p.rules[0].pattern == r"norm(?=\d)"
        assert p.resolve("g0/pos0['norm1']['scale']").name == "fp"
        assert p.resolve("final_norm").name == "orq-9"
        # ... and combined with an in-pattern comma: the entry only closes
        # once the text after the last '=' is a bare scheme token
        q = QuantPolicy.parse(r"w(?=o){1,2}=terngrad, default=orq-9")
        assert q.rules[0].pattern == r"w(?=o){1,2}"
        assert q.resolve("g0['ffn']['woo']").name == "terngrad"

    def test_dict_unknown_field_clean_error(self):
        with pytest.raises(ValueError, match="unknown QuantConfig field"):
            QuantPolicy.parse('{"embed": {"nam": "orq-9"}}')

    def test_dict_bad_value_type_clean_error(self):
        # launchers catch ValueError, so bad JSON values must not escape
        # as TypeError tracebacks
        with pytest.raises(ValueError, match="bad policy value"):
            QuantPolicy.parse('{"norm": 3}')

    def test_unmatched_rules_reported(self):
        p = QuantPolicy.parse("nrom=fp, bias=fp, default=orq-9")  # typo
        paths = ["final_norm", "g0['attn']['wq']", "g0['bias']"]
        assert p.unmatched_rules(paths) == ("nrom",)
        assert QuantPolicy.parse("norm=fp").unmatched_rules(paths) == ()

    def test_uniform_shorthand_and_backcompat(self):
        for spec in ("orq-9", "  ORQ_9 "):
            p = QuantPolicy.parse(spec)
            assert p.is_uniform and p.resolve("anything").name == "orq-9"
        cfg = QuantConfig(name="terngrad", bucket_size=128)
        u = QuantPolicy.uniform(cfg)
        assert u.is_uniform and u.resolve("x") is cfg
        assert QuantPolicy.uniform("fp").default.name == "fp"

    def test_defaults_thread_into_rules(self):
        p = QuantPolicy.parse("norm=fp, default=orq-9", bucket_size=512,
                              clip_c=2.5)
        assert p.default.bucket_size == 512 and p.default.clip_c == 2.5
        assert p.rules[0].cfg.bucket_size == 512

    def test_dict_and_json_forms(self):
        d = QuantPolicy.from_dict({"norm|bias": "fp", "default": "orq-9"})
        j = QuantPolicy.parse('{"norm|bias": "fp", "default": "orq-9"}')
        s = QuantPolicy.parse("norm|bias=fp, default=orq-9")
        assert d == j == s
        # dict values may be QuantConfig field dicts
        f = QuantPolicy.from_dict(
            {"embed": {"name": "qsgd-5", "bucket_size": 64}})
        assert f.rules[0].cfg == QuantConfig(name="qsgd-5", bucket_size=64)

    def test_trainconfig_quant_alias_removed(self):
        from repro.train import TrainConfig

        # the historical uniform alias fails loudly with a pointer at
        # policy= (QuantConfig rides policy= directly for uniform cases)
        with pytest.raises(ValueError, match="policy="):
            TrainConfig(quant=QuantConfig(name="orq-9"))
        p = TrainConfig(
            policy=QuantConfig(name="orq-9")).resolved_policy()
        assert p.is_uniform and p.default.name == "orq-9"
        # unset policy resolves to uniform fp
        assert TrainConfig().resolved_policy().default.name == "fp"

    def test_coerce(self):
        p = QuantPolicy.parse("norm=fp, default=orq-9")
        assert QuantPolicy.coerce(p) is p
        assert QuantPolicy.coerce("orq-9").is_uniform
        assert QuantPolicy.coerce(QuantConfig(name="fp")).is_uniform
        assert not QuantPolicy.coerce({"norm": "fp"}).is_uniform
        with pytest.raises(TypeError):
            QuantPolicy.coerce(42)

    def test_bad_pattern_errors(self):
        with pytest.raises(ValueError, match="bad policy pattern"):
            QuantPolicy.parse("no[rm=fp, default=orq-9")
        with pytest.raises(ValueError, match="grammar"):
            QuantPolicy.parse("no[rm=fp")

    def test_bad_scheme_names_valid_schemes(self):
        with pytest.raises(ValueError) as e:
            QuantPolicy.parse("norm=fp, default=bogus-3")
        msg = str(e.value)
        assert "bogus-3" in msg and "orq-9" in msg and "grammar" in msg

    def test_empty_pattern_rejected(self):
        # re.search("") matches everything — a stray '=' must not
        # silently capture the whole model
        for spec in ("=fp,default=orq-9", " =fp"):
            with pytest.raises(ValueError, match="empty policy pattern"):
                QuantPolicy.parse(spec)

    def test_bad_json(self):
        with pytest.raises(ValueError, match="bad policy JSON"):
            QuantPolicy.parse('{"norm": ')

    def test_duplicate_default_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            QuantPolicy.parse("default=fp, default=orq-9")

    def test_describe_round_trips(self):
        p = QuantPolicy.parse("norm|bias=fp,embed=bingrad-b,default=orq-9")
        assert QuantPolicy.parse(p.describe()) == p


# ---------------------------------------------------------------------------
# scheme registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_all_methods_derived_not_hand_listed(self):
        try:
            register_scheme(
                "myscheme",
                lambda suffix, **kw: Quantizer(
                    method="qsgd", num_levels=int(suffix or 5), **kw),
                variants=("myscheme-5",))
            assert "myscheme-5" in all_methods()
            qz = make_quantizer("myscheme-7", bucket_size=64)
            assert qz.num_levels == 7 and qz.bucket_size == 64
            # policies resolve registered schemes too
            p = QuantPolicy.parse("norm=myscheme-5, default=fp")
            assert p.resolve("final_norm").name == "myscheme-5"
        finally:
            unregister_scheme("myscheme")
        assert "myscheme-5" not in all_methods()
        with pytest.raises(ValueError, match="valid schemes"):
            make_quantizer("myscheme-5")

    def test_live_ALL_METHODS_attribute(self):
        import repro.core as core
        assert core.ALL_METHODS == all_methods()

    def test_bad_suffix_errors(self):
        with pytest.raises(ValueError):
            make_quantizer("bingrad-7")
        with pytest.raises(ValueError):
            make_quantizer("fp-3")

    def test_unparseable_variant_rejected_at_register_time(self):
        # an advertised variant that make_quantizer could never parse back
        # must be rejected up front, not surface in help/error text
        build = lambda suffix, **kw: Quantizer(method="qsgd", **kw)
        for bad in ("myscheme-fast", "otherscheme-5"):
            with pytest.raises(ValueError, match="parsed back"):
                register_scheme("myscheme", build, variants=(bad,))
        assert "myscheme" not in all_methods()


# ---------------------------------------------------------------------------
# partitioned layout
# ---------------------------------------------------------------------------

def _tree():
    k = jax.random.key(0)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    return {
        "embed": jax.random.normal(k1, (16, 8)),
        "norm": jax.random.normal(k2, (40,)).astype(jnp.bfloat16),
        "w": jax.random.normal(k3, (33, 7)),
        "bias": jax.random.normal(k4, ()),
    }


MIXED = "norm|bias=fp, embed=bingrad-b, default=orq-9"


class TestPolicyLayout:
    def test_grouping_and_offsets(self):
        tree = _tree()
        pl = comm.PolicyLayout.from_tree(tree, QuantPolicy.parse(MIXED))
        # canonical leaf order: bias, embed, norm, w
        names = [g.cfg.name for g in pl.groups]
        assert sorted(names) == ["bingrad-b", "fp", "orq-9"]
        by_name = {g.cfg.name: g for g in pl.groups}
        assert by_name["fp"].size == 1 + 40          # bias + norm
        assert by_name["bingrad-b"].size == 16 * 8
        assert by_name["orq-9"].size == 33 * 7
        # within-group offsets are contiguous
        fp_slots = [pl.slots[i] for i in by_name["fp"].leaf_ids]
        assert [s.offset for s in fp_slots] == [0, 1]

    def test_roundtrip_mixed_dtypes(self):
        tree = _tree()
        pl = comm.PolicyLayout.from_tree(tree, QuantPolicy.parse(MIXED))
        back = pl.unflatten_groups(pl.flatten_groups(tree))
        for want, got in zip(jax.tree_util.tree_leaves(tree),
                             jax.tree_util.tree_leaves(back)):
            assert got.dtype == want.dtype and got.shape == want.shape
            np.testing.assert_array_equal(np.asarray(got, np.float32),
                                          np.asarray(want, np.float32))
        res = pl.unflatten_groups(pl.flatten_groups(tree),
                                  restore_dtype=False)
        assert all(x.dtype == jnp.float32
                   for x in jax.tree_util.tree_leaves(res))

    def test_uniform_layout_equals_gradlayout(self):
        tree = _tree()
        pl = comm.PolicyLayout.from_tree(tree, QuantPolicy.uniform("orq-9"))
        gl = comm.GradLayout.from_tree(tree)
        assert len(pl.groups) == 1 and pl.groups[0].size == gl.size
        assert [(s.path, s.offset, s.size) for s in pl.slots] == \
               [(s.path, s.offset, s.size) for s in gl.slots]
        (buf,) = pl.flatten_groups(tree)
        np.testing.assert_array_equal(np.asarray(buf),
                                      np.asarray(gl.flatten(tree)))

    def test_dead_rule_warns_at_layout_build(self):
        tree = {"a": jnp.zeros(3), "b": jnp.zeros(4)}
        with pytest.warns(UserWarning, match="matched no parameter leaf"):
            comm.PolicyLayout.from_tree(
                tree, QuantPolicy.parse("nosuchleaf=fp, default=orq-9"))

    def test_paths_override(self):
        tree = {"a": jnp.zeros(3), "b": jnp.zeros(4)}
        paths = {"a": "final_norm", "b": "g0/attn/wq"}
        pl = comm.PolicyLayout.from_tree(
            tree, QuantPolicy.parse("norm=fp, default=orq-9"), paths=paths)
        assert [g.cfg.name for g in pl.groups] == ["fp", "orq-9"]
        assert pl.slots[0].path == "final_norm"

    def test_policy_stats_mixed_beats_fp(self):
        # acceptance: mixed policy costs fewer wire bytes than uniform fp
        path_sizes = [("final_norm", 512), ("embed", 2 ** 16),
                      ("g0/attn/wq", 2 ** 18), ("g0/norm1", 512)]
        n = sum(s for _, s in path_sizes)
        mixed = QuantPolicy.parse("norm=fp, default=orq-9", bucket_size=512)
        launches, bytes_, labels = comm.policy_stats(mixed, path_sizes, 8)
        _, fp_bytes = comm.fused_stats(make_quantizer("fp"),
                                       [s for _, s in path_sizes], 8)
        assert len(labels) == 2
        assert launches == 1 + 4       # fp psum + quantized 2×a2a + 2×ag
        assert bytes_ < fp_bytes
        assert fp_bytes == 4.0 * n


# ---------------------------------------------------------------------------
# engine equivalence on an 8-device mesh
# ---------------------------------------------------------------------------

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import QuantConfig, QuantPolicy, comm, make_quantizer
from repro.utils.compat import shard_map

mesh = jax.make_mesh((8,), ("data",))
DP = ("data",)
L = 8

def shmap(f, in_specs, out_specs):
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, axis_names={"data"}, check_vma=False))

def ragged_tree(key, scale=0.1):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w": jax.random.laplace(k1, (L, 33, 7)) * scale,
        "norm": jax.random.laplace(k2, (L, 40)) * scale,
        "m": {"embed": jax.random.laplace(k3, (L, 3, 5, 2)) * scale,
              "bias": jax.random.laplace(k4, (L, 1)) * scale},
    }

def worker_slice(tree):
    return jax.tree_util.tree_map(lambda x: x[0], tree)

IN = jax.tree_util.tree_map(lambda x: P("data", *([None] * (x.ndim - 1))),
                            {"w": jnp.zeros((L, 1, 1)),
                             "norm": jnp.zeros((L, 1)),
                             "m": {"embed": jnp.zeros((L, 1, 1, 1)),
                                   "bias": jnp.zeros((L, 1))}})
"""


def test_uniform_partitioned_bitidentical_to_fused_8dev():
    """Uniform policy through PartitionedExchange == the PR-1 single-engine
    fused exchange, bit for bit: exchanged buffers AND error-feedback
    residuals (same keys, same wire layout), on an 8-device mesh."""
    run_devices(COMMON + """
tree = ragged_tree(jax.random.key(0))
for name in ("orq-9", "terngrad", "fp"):
    qz = make_quantizer(name, bucket_size=64)
    cfg = QuantConfig(name=name, bucket_size=64)
    eng = comm.GradientExchange(qz, DP)
    pex = comm.PartitionedExchange.build(QuantPolicy.uniform(cfg),
                                         worker_slice(tree), DP)
    assert len(pex.engines) == 1

    def f(t):
        t = worker_slice(t)
        layout = comm.GradLayout.from_tree(t)
        flat = layout.flatten(t)
        key = jax.random.key(1)
        ref = eng.exchange_flat(flat, key)
        (buf,) = pex.layout.flatten_groups(t)
        (got,) = pex.exchange_parts((buf,), key)
        outs = [flat[None], buf[None], ref[None], got[None]]
        if name != "fp":
            ref_local = eng.local_qdq_flat(flat, key)
            (got_local,) = pex.local_qdq_parts((buf,), key)
            outs += [ref_local[None], got_local[None]]
        return tuple(outs)

    n_out = 4 if name == "fp" else 6
    spec = tuple([P("data", None)] * n_out)
    outs = shmap(f, (IN,), spec)(tree)
    outs = [np.asarray(o) for o in outs]
    np.testing.assert_array_equal(outs[0], outs[1])   # identical buffers
    np.testing.assert_array_equal(outs[2], outs[3])   # identical exchange
    if name != "fp":
        # identical EF residual stream: flat - local must match bit for bit
        np.testing.assert_array_equal(outs[4], outs[5])
        np.testing.assert_array_equal(outs[0] - outs[4],
                                      outs[1] - outs[5])
    print(name, "UNIFORM-BITIDENTICAL OK")
""")


def test_mixed_policy_partitioned_8dev():
    """Mixed norm|bias=fp policy: fp group is the exact across-worker mean,
    quantized group is within quantization variance, every worker
    reconstructs identical gradients, and EF residuals are zero exactly on
    the fp leaves."""
    run_devices(COMMON + """
tree = ragged_tree(jax.random.key(2))
policy = QuantPolicy.parse("norm|bias=fp, default=orq-9", bucket_size=64)
pex = comm.PartitionedExchange.build(policy, worker_slice(tree), DP)
assert len(pex.engines) == 2
true_mean = jax.tree_util.tree_map(lambda x: np.asarray(x.mean(0)), tree)

def f(t):
    t = worker_slice(t)
    key = jax.random.key(3)
    bufs = pex.layout.flatten_groups(t)
    mean = pex.layout.unflatten_groups(pex.exchange_parts(bufs, key))
    local = pex.local_qdq_parts(bufs, key)
    resid = pex.layout.unflatten_groups(
        [f_ - l_ for f_, l_ in zip(bufs, local)], restore_dtype=False)
    add = jax.tree_util.tree_map(lambda a: a[None], mean)
    addr = jax.tree_util.tree_map(lambda a: a[None], resid)
    return add, addr

mean, resid = shmap(f, (IN,), (IN, IN))(tree)
flat_mean = {k: np.asarray(v) for k, v in [
    ("w", mean["w"]), ("norm", mean["norm"]),
    ("embed", mean["m"]["embed"]), ("bias", mean["m"]["bias"])]}
# fp leaves: exact mean; quantized leaves: within variance
np.testing.assert_allclose(flat_mean["norm"][0], true_mean["norm"],
                           rtol=1e-6, atol=1e-7)
np.testing.assert_allclose(flat_mean["bias"][0], true_mean["m"]["bias"],
                           rtol=1e-6, atol=1e-7)
assert np.abs(flat_mean["w"][0] - true_mean["w"]).mean() < 0.05
# EF residuals: identically zero on fp leaves, nonzero on quantized ones
assert np.abs(np.asarray(resid["norm"])).max() == 0.0
assert np.abs(np.asarray(resid["m"]["bias"])).max() == 0.0
assert np.abs(np.asarray(resid["w"])).max() > 0.0
print("MIXED OK")
""")


# ---------------------------------------------------------------------------
# train step: O(#groups) collectives, never O(#leaves)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_step_collectives_o_groups():
    """Acceptance: a mixed norm=fp,default=orq-9 policy on lm-100m keeps
    the jaxpr at O(#groups) collective launches (2 all_to_all + 2
    all_gather from the single quantized group), never O(#leaves), and
    uniform-policy TrainConfig.policy matches the deprecated quant alias
    count for count."""
    from repro.configs.base import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models import LM
    from repro.optim.schedule import constant_lr
    from repro.train import TrainConfig, make_train_step
    from repro.train.step import init_state

    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2,
                       seed=0)
    n_leaves = len(jax.tree_util.tree_leaves(
        jax.eval_shape(model.init, jax.random.key(0))))
    assert n_leaves >= 10

    def counts(tcfg):
        state = init_state(model, mesh, tcfg, jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        jx = str(jax.make_jaxpr(step_fn)(state, data.batch(0),
                                         jax.random.key(1)))
        return jx.count("all_to_all["), jx.count("all_gather[")

    mixed = counts(TrainConfig(policy="norm=fp,default=orq-9",
                               mode="replicated"))
    assert mixed == (2, 2), mixed       # one quantized group, fp is a psum

    uniform_policy = counts(TrainConfig(policy="orq-9", mode="replicated"))
    uniform_cfg = counts(TrainConfig(
        policy=QuantConfig(name="orq-9", bucket_size=2048),
        mode="replicated"))
    assert uniform_policy == uniform_cfg == (2, 2)
