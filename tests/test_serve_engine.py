"""Serving engine: chunked prefill parity, continuous batching,
mixed-vs-alone determinism (the PR-7 acceptance criterion).

Workloads are deliberately tiny (smoke arch, prompts of a few tokens):
every Engine instance re-traces its forward, so the cost here is
compilation, not tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import LM
from repro.serve import Engine, ServeConfig

jax.config.update("jax_platform_name", "cpu")


def _model_params(arch="lm-100m", seed=0):
    model = LM(get_smoke_config(arch))
    params = jax.jit(model.init)(jax.random.key(seed))
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    return model, params


def _prompt(n, seed, vocab):
    return np.asarray(jax.random.randint(jax.random.key(seed), (n,), 0,
                                         vocab), np.int32)


class TestChunkedPrefill:
    """model.prefill_chunk fills the SAME cache bytes as the sequential
    decode loop and produces the same logits."""

    @pytest.mark.parametrize("arch", ["lm-100m", "gemma2-9b"])
    def test_matches_sequential_decode(self, arch):
        model, params = _model_params(arch)
        assert model.supports_chunked_prefill()
        B, S, C, chunk = 2, 8, 16, 4
        toks = jnp.asarray(np.stack([_prompt(S, 3 + b,
                                             model.cfg.vocab_size)
                                     for b in range(B)]))
        seq_cache = model.init_cache(B, C)
        for i in range(S):
            lg_seq, seq_cache = model.decode_step(
                params, seq_cache, toks[:, i][:, None], jnp.int32(i))
        chk_cache = model.init_cache(B, C)
        for off in range(0, S, chunk):
            lg_chk, chk_cache = model.prefill_chunk(
                params, chk_cache, toks[:, off:off + chunk],
                jnp.int32(off))
        for a, b in zip(jax.tree_util.tree_leaves(seq_cache),
                        jax.tree_util.tree_leaves(chk_cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(lg_seq[:, -1]),
                                   np.asarray(lg_chk[:, -1]),
                                   rtol=2e-5, atol=2e-5)
        assert np.array_equal(np.argmax(np.asarray(lg_seq[:, -1]), -1),
                              np.argmax(np.asarray(lg_chk[:, -1]), -1))

    def test_stateful_archs_unsupported(self):
        model, _ = _model_params("rwkv6-3b")
        assert not model.supports_chunked_prefill()


class TestEngineVsDense:
    def test_bf16_paged_matches_dense_decode_greedy(self):
        """The bf16 escape hatch is greedy-identical to the ring-buffer
        decode path at equal context."""
        model, params = _model_params()
        S, gen = 8, 4
        prompt = _prompt(S, 17, model.cfg.vocab_size)

        cfg = ServeConfig(kv_quant="bf16", page_size=4, max_batch=1,
                          max_pages_per_seq=4, prefill_chunk=4)
        eng = Engine(model, params, cfg)
        rid = eng.submit(prompt, max_new=gen)
        got = eng.run()[rid].generated

        cache = model.init_cache(1, cfg.max_context)
        toks = jnp.asarray(prompt[None])
        for i in range(S):
            lg, cache = model.decode_step(params, cache,
                                          toks[:, i][:, None], jnp.int32(i))
        want = [int(jnp.argmax(lg[0, -1]))]
        for i in range(gen - 1):
            lg, cache = model.decode_step(
                params, cache, jnp.asarray([[want[-1]]], jnp.int32),
                jnp.int32(S + i))
            want.append(int(jnp.argmax(lg[0, -1])))
        assert got == want


class TestEngineDeterminism:
    """PR-7 acceptance: a mixed prefill/decode workload with staggered
    arrivals produces per-request greedy outputs identical to running
    each request alone — including the random-round quantized schemes,
    whose rounding stream is keyed on content, not batch shape."""

    @pytest.mark.parametrize("scheme", ["orq-9", "bingrad-b"])
    def test_mixed_equals_alone(self, scheme):
        model, params = _model_params()
        lens = (8, 4, 12)       # multiples of the chunk: fewer retraces
        prompts = [_prompt(n, 23 + i, model.cfg.vocab_size)
                   for i, n in enumerate(lens)]
        cfg = ServeConfig(kv_quant=scheme, page_size=4, max_batch=3,
                          max_pages_per_seq=8, prefill_chunk=4)

        mixed = Engine(model, params, cfg)
        rids = [mixed.submit(p, max_new=5, arrival=2 * i)
                for i, p in enumerate(prompts)]
        mres = mixed.run()

        alone = Engine(model, params, cfg)   # reused across requests:
        for i, p in enumerate(prompts):      # also exercises page reuse
            rid = alone.submit(p, max_new=5)
            ares = alone.run()
            assert mres[rids[i]].generated == ares[rid].generated, scheme

    def test_quantized_differs_from_bf16(self):
        """Sanity that the quantized cache is actually in the loop: a
        1-bit KV cache must not reproduce the bf16 trajectory."""
        model, params = _model_params()
        prompt = _prompt(8, 31, model.cfg.vocab_size)
        outs = {}
        for scheme in ("bf16", "bingrad-b"):
            cfg = ServeConfig(kv_quant=scheme, page_size=4, max_batch=1,
                              max_pages_per_seq=4, prefill_chunk=4)
            eng = Engine(model, params, cfg)
            rid = eng.submit(prompt, max_new=6)
            outs[scheme] = eng.run()[rid].generated
        assert outs["bf16"] != outs["bingrad-b"]


class TestEngineLifecycle:
    def test_more_requests_than_slots_all_finish_and_pages_recycle(self):
        model, params = _model_params()
        cfg = ServeConfig(kv_quant="bf16", page_size=4, max_batch=2,
                          max_pages_per_seq=2, prefill_chunk=4)
        eng = Engine(model, params, cfg)
        rids = [eng.submit(_prompt(4, 40 + i, model.cfg.vocab_size),
                           max_new=3) for i in range(5)]
        res = eng.run()
        assert sorted(res) == sorted(rids)
        assert all(len(res[r].generated) == 3 for r in rids)
        # eviction returned every page and slot
        assert eng.sched.alloc.num_free == cfg.resolved_num_pages - 1
        assert all(st is None for st in eng.sched.slots)
        assert (eng.page_table == 0).all()
        # per-request lifecycle metrics populated
        for r in rids:
            st = res[r]
            assert st.first_token_time >= st.submit_time
            assert st.finish_time >= st.first_token_time
            assert len(st.token_times) == 3

    def test_request_exceeding_context_rejected(self):
        model, params = _model_params()
        cfg = ServeConfig(kv_quant="bf16", page_size=4, max_batch=1,
                          max_pages_per_seq=2, prefill_chunk=4)
        eng = Engine(model, params, cfg)
        with pytest.raises(ValueError, match="max_pages_per_seq"):
            eng.submit(_prompt(7, 1, model.cfg.vocab_size), max_new=3)

    def test_unsupported_archs_and_schemes_rejected(self):
        with pytest.raises(ValueError, match="GQA attention"):
            Engine(LM(get_smoke_config("rwkv6-3b")), None, ServeConfig())
        with pytest.raises(ValueError, match="MoE"):
            Engine(LM(get_smoke_config("mixtral-8x22b")), None,
                   ServeConfig())
        model = LM(get_smoke_config("lm-100m"))
        with pytest.raises(ValueError, match="fused one-pass encode"):
            Engine(model, None, ServeConfig(kv_quant="fp"))
